"""Cluster benchmark: multi-host routed serving vs the single-host
``PatternServer``, and the sharded-window streaming protocol vs the
single-host ``StreamingBank``, on the Table3 synthetic workload.

Emits ``BENCH_cluster.json``: routed queries/sec per (bank layout,
host count) with the single-host server as baseline, the per-drain
cross-host batching stats, sharded-window streamed updates/sec vs the
single-host streaming bank, and a ``metrics`` block (the summed
registry deltas of every timed pass) that ``scripts/check_bench.py``
gates on at counter level - in particular the L1/L2 cache hit rates.

The query mix is **Zipfian**: queries are drawn with repetition from a
fixed pool (rank-``r`` probability ∝ 1/r^s), and the drawn stream is
routed as several consecutive *drains*.  Production replay traffic is
exactly this shape, and it is what the two-level cache exists for: a
fingerprint resolved in an earlier drain is an L1 hit on its arrival
host and a single-hop L2 hit anywhere else - so the measured hit rates
are real nonzero numbers (a uniform one-shot mix pinned them at 0 and
left the cache path untested).

Exactness is asserted, not sampled - and this is the artifact's real
gate: every routed containment row and top-k must be *bit-equal* to the
single-host server on the same queries, and the sharded-window
post-refresh frequent map must be bit-equal to the single-host
``StreamingBank`` (itself property-tested == batch re-mine).  Any
divergence raises before the artifact is written; the committed
``divergences`` field is checked == 0 by scripts/check_bench.py.

The hosts are in-process simulations sharing one CPU device, so
multi-host qps measures *protocol overhead*, not parallel speedup -
the point of the scaling table is that per-shard work shrinks with
host count (each shard joins ~1/H of the bank) while the merged
answers stay identical; real scaling needs one device per host (the
subprocess test pins hosts to 8 virtual devices).

``--smoke`` is the CI tier-4 gate: a tiny config, both layouts, >= 2
hosts, hard-failing on any divergence, written atomically to
``BENCH_cluster_smoke.json``.  ``--trace PATH`` records the span
tracer (repro.obs.trace) across the run; render the phase-attribution
table with ``scripts/trace_report.py PATH``.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

try:
    from .bench_streaming import atomic_write_json, machine_id
except ImportError:  # pragma: no cover - run as a script
    from bench_streaming import atomic_write_json, machine_id

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.obs import trace
from repro.serving.bank import compile_bank
from repro.serving.cluster import ServingCluster, ShardedStreamingBank
from repro.serving.server import PatternServer
from repro.serving.streaming import StreamingBank

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "..", "BENCH_cluster.json")
OUT_SMOKE = os.path.join(HERE, "..", "BENCH_cluster_smoke.json")

ZIPF_S = 1.1  # rank exponent of the repeat mix


def zipf_mix(pool, n, seed=2, s=ZIPF_S):
    """Draw ``n`` queries from ``pool`` with rank-Zipfian repetition
    (deterministic under ``seed``)."""
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    p = ranks ** -s
    p /= p.sum()
    rng = np.random.default_rng(seed)
    return [pool[i] for i in rng.choice(len(pool), size=n, p=p)]


def _chunks(items, n_chunks):
    size = max(1, -(-len(items) // n_chunks))
    return [items[i: i + size] for i in range(0, len(items), size)]


def _merge_metrics(into, delta):
    for key, val in delta.items():
        into[key] = into.get(key, 0) + val


def _spread(queries, n_hosts):
    reqs = {h: [] for h in range(n_hosts)}
    for i, s in enumerate(queries):
        reqs[i % n_hosts].append(s)
    return reqs


def _routed_pass(cl, reqs):
    """Route one full drain; returns results flattened back to query
    order."""
    got = cl.query_multi(reqs)
    flat = {}
    for h, rs in got.items():
        for j, r in enumerate(rs):
            flat[j * len(reqs) + h] = r
    return [flat[i] for i in sorted(flat)]


def bench_serving_cluster(db, pool, sigma, max_len, host_counts,
                          layouts, n_queries, n_drains, metrics_sum):
    """Routed cluster vs single-host server on a Zipfian repeat mix;
    returns (payload section, divergence count - always 0 or the bench
    has already raised)."""
    bank = compile_bank(
        AcceleratedMiner(db).mine_rs(sigma, max_len=max_len))
    queries = zipf_mix(pool, n_queries)
    drains = _chunks(queries, n_drains)
    single_qps = {}
    cluster_qps = {}
    divergences = 0
    stats = {}
    for layout in layouts:
        srv = PatternServer(bank, bank_layout=layout)
        want = srv.query(queries)  # the bit-equality reference
        srv._cache.clear()  # else the warm drains all cache-hit...
        for dq in drains:   # ...and the per-drain jit buckets stay cold
            srv.query(dq)
        srv._cache.clear()
        t0 = time.perf_counter()
        for dq in drains:
            srv.query(dq)
        single_qps[layout] = len(queries) / (time.perf_counter() - t0)
        cluster_qps[layout] = {}
        for H in host_counts:
            cl = ServingCluster(bank, H, bank_layout=layout)
            for dq in drains:  # warm every shard's jit buckets
                _routed_pass(cl, _spread(dq, H))
            cl.router.clear_caches()
            before = cl.metrics.snapshot()
            t0 = time.perf_counter()
            got = []
            for dq in drains:
                got.extend(_routed_pass(cl, _spread(dq, H)))
            dt = time.perf_counter() - t0
            cluster_qps[layout][str(H)] = len(queries) / dt
            _merge_metrics(metrics_sum, cl.metrics.delta(before))
            for r, w in zip(got, want):
                if not (np.array_equal(r.contained, w.contained)
                        and r.topk == w.topk):
                    divergences += 1
            if divergences:
                raise AssertionError(
                    f"[{layout} H={H}] routed cluster diverged from the "
                    f"single-host server on {divergences} queries - "
                    "exactness contract broken"
                )
            stats[f"{layout}_H{H}"] = dict(cl.router.stats)
    return {
        "bank_patterns": bank.n_patterns,
        "pool_size": len(pool),
        "n_drains": n_drains,
        "zipf_s": ZIPF_S,
        "single_qps": single_qps,
        "cluster_qps": cluster_qps,
        "router_stats": stats,
    }, divergences


def bench_sharded_stream(db, stream, sigma, max_len, window, n_hosts,
                         batch_size, refresh_every, metrics_sum):
    """Sharded-window protocol vs the single-host StreamingBank on one
    arrival stream; hard-fails unless every post-refresh frequent map
    is bit-equal."""
    batches = [stream[i: i + batch_size]
               for i in range(0, len(stream), batch_size)]

    def run(make, observe, refresh):
        sb = make()
        before = sb.metrics.snapshot()
        t0 = time.perf_counter()
        maps = []
        for i, b in enumerate(batches):
            observe(sb, b)
            if (i + 1) % refresh_every == 0:
                maps.append(refresh(sb))
        maps.append(refresh(sb))
        return time.perf_counter() - t0, maps, sb, \
            sb.metrics.delta(before)

    def mk_single():
        return StreamingBank.from_db(
            db, minsup=sigma, window=window, max_len=max_len)

    def mk_sharded():
        return ShardedStreamingBank.from_db(
            db, minsup=sigma, n_hosts=n_hosts, window=window,
            max_len=max_len)

    run(mk_single, StreamingBank.observe, StreamingBank.refresh)  # warm
    t_single, maps_single, _, _ = run(
        mk_single, StreamingBank.observe, StreamingBank.refresh)
    run(mk_sharded, ShardedStreamingBank.observe,
        ShardedStreamingBank.refresh)  # warm
    t_sharded, maps_sharded, sh, delta = run(
        mk_sharded, ShardedStreamingBank.observe,
        ShardedStreamingBank.refresh)
    _merge_metrics(metrics_sum, delta)
    for i, (a, b) in enumerate(zip(maps_single, maps_sharded)):
        if a != b:
            raise AssertionError(
                f"sharded-window frequent map diverged from the "
                f"single-host streaming bank at refresh {i}: "
                f"{len(a)} vs {len(b)} patterns"
            )
    n = len(stream)
    return {
        "stream_window": window,
        "stream_hosts": n_hosts,
        "n_stream_updates": n,
        "single_stream_updates_per_sec": n / t_single,
        "sharded_stream_updates_per_sec": n / t_sharded,
        "stream_refresh_checks": len(maps_sharded),
        "allreduces": sh.stats["allreduces"],
        "dirty_subtrees": sh.stats["dirty_subtrees"],
    }


def main(csv=print, smoke: bool = False, trace_path=None):
    if smoke:
        db_size, n_queries, max_len = 40, 48, 3
        pool_size, n_drains = 16, 3
        host_counts, out_path = (1, 2, 3), OUT_SMOKE
        window, stream_n, batch_size, refresh_every = 24, 24, 8, 2
    else:
        db_size, n_queries, max_len = 120, 256, 4
        pool_size, n_drains = 64, 4
        host_counts, out_path = (1, 2, 4), OUT
        window, stream_n, batch_size, refresh_every = 60, 60, 10, 3
    if trace_path:
        trace.clear()
        trace.enable()
    params = Table3Params(db_size=db_size + window + stream_n, v_avg=5,
                          n_interstates=3)
    all_seqs = generate_table3_db(params, seed=0)
    db = all_seqs[:db_size]
    stream_db = all_seqs[db_size: db_size + window]
    stream = all_seqs[db_size + window:]
    sigma = max(2, db_size // 15)
    qparams = Table3Params(db_size=pool_size, v_avg=5, n_interstates=3)
    pool = generate_table3_db(qparams, seed=1)

    metrics_sum = {}
    serving, divergences = bench_serving_cluster(
        db, pool, sigma, max_len, host_counts, ("flat", "trie"),
        n_queries, n_drains, metrics_sum)
    streaming = bench_sharded_stream(
        stream_db, stream, max(2, window // 15), max_len, window,
        2, batch_size, refresh_every, metrics_sum)

    l1 = metrics_sum.get("cluster.router.l1_hits", 0)
    l2 = metrics_sum.get("cluster.router.l2_hits", 0)
    routed = metrics_sum.get("cluster.router.queries", 0)
    payload = {
        "machine": machine_id(),
        "n_queries": n_queries,
        "host_counts": list(host_counts),
        "divergences": divergences,
        "cache_hit_rate": (l1 + l2) / routed if routed else 0.0,
        **serving,
        **streaming,
        "metrics": metrics_sum,
    }
    if trace_path:
        trace.save(trace_path)
        trace.disable()
        csv(f"# trace saved to {trace_path} "
            f"({len(trace.tracer.events)} spans)")
    atomic_write_json(out_path, payload)
    for layout in ("flat", "trie"):
        base = serving["single_qps"][layout]
        for H in host_counts:
            qps = serving["cluster_qps"][layout][str(H)]
            csv(f"cluster/{layout}_H{H},{1e6 / qps:.0f},"
                f"qps={qps:.0f},x{qps / base:.2f}_vs_single")
    csv(f"cluster/stream_sharded,"
        f"{1e6 / streaming['sharded_stream_updates_per_sec']:.0f},"
        f"ups={streaming['sharded_stream_updates_per_sec']:.0f}")
    csv(f"cluster/stream_single,"
        f"{1e6 / streaming['single_stream_updates_per_sec']:.0f},"
        f"ups={streaming['single_stream_updates_per_sec']:.0f}")
    csv(f"cluster/cache,{payload['cache_hit_rate']:.3f},"
        f"l1={l1},l2={l2},routed={routed}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, >=2 hosts, hard-fail on any "
                         "divergence from single-host results (the CI "
                         "tier-4 gate)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span trace of the run (Chrome JSON "
                         "for .json paths, JSONL otherwise); inspect "
                         "with scripts/trace_report.py")
    args = ap.parse_args()
    out = main(smoke=args.smoke, trace_path=args.trace)
    print(f"# cluster routed serving bit-equal to single-host "
          f"({out['divergences']} divergences) across hosts "
          f"{out['host_counts']}; zipf cache hit rate "
          f"{out['cache_hit_rate']:.2f}; sharded window "
          f"{out['sharded_stream_updates_per_sec']:.0f} ups vs single "
          f"{out['single_stream_updates_per_sec']:.0f} ups over "
          f"{out['stream_hosts']} hosts")
