"""Cluster benchmark: multi-host routed serving vs the single-host
``PatternServer``, and the sharded-window streaming protocol vs the
single-host ``StreamingBank``, on the Table3 synthetic workload.

Emits ``BENCH_cluster.json``: routed queries/sec per (bank layout,
host count) with the single-host server as baseline, the per-drain
cross-host batching stats, sharded-window streamed updates/sec vs the
single-host streaming bank, and a ``metrics`` block (the summed
registry deltas of every timed pass) that ``scripts/check_bench.py``
gates on at counter level - in particular the L1/L2 cache hit rates.

The headline ``cluster_qps`` is the **async admission pipeline**
(``submit``/``collect`` continuous batching) under the production
offered-load model: **every host receives its own open-loop Zipfian
arrival stream** (per-host load held constant, so aggregate offered
load scales with H - the standard serving-bench convention), drains
are submitted without blocking - arrivals keep queueing while earlier
flushes compute on device - and collected at the end.  Aggregate
qps = (H * per-host queries) / wall.  This is where the cluster's
scaling story lives: the bank-sharded join work is *constant-sum*
across shards (every miss fans out once, each shard joins only its
~1/H slice from one shared query encoding), while each added host
brings its own L1 cache and admission capacity - so aggregate
throughput must not fall as hosts join.  ``scripts/check_bench.py``
gates ``cluster_qps`` monotonically non-decreasing in H for both
layouts.  The old bench split one fixed stream across hosts, which
divides the cacheable traffic H ways while keeping the join constant -
that measures per-shard protocol overhead (still reported, as
``cluster_route_qps`` on the same per-host streams via the synchronous
``route`` path), not cluster capacity, and is why the committed table
showed throughput "going backwards".

Every timed pass is **best-of-``N_ROUNDS``**: a single pass is ~tens
of milliseconds on this workload, small enough that one GC pause or
scheduler hiccup used to distort the committed scaling table (the seed
artifact's trie single-host number was a third of flat's from exactly
that).  Best-of over identical rounds measures the code, not the
noise.

The query mix is **Zipfian**: queries are drawn with repetition from a
fixed pool (rank-``r`` probability ∝ 1/r^s), and the drawn stream is
routed as several consecutive *drains*.  Production replay traffic is
exactly this shape, and it is what the two-level cache exists for: a
fingerprint resolved in an earlier drain is an L1 hit on its arrival
host and a single-hop L2 hit anywhere else - so the measured hit rates
are real nonzero numbers (a uniform one-shot mix pinned them at 0 and
left the cache path untested).

Exactness is asserted, not sampled - and this is the artifact's real
gate: every routed containment row and top-k must be *bit-equal* to the
single-host server on the same queries, and the sharded-window
post-refresh frequent map must be bit-equal to the single-host
``StreamingBank`` (itself property-tested == batch re-mine).  Any
divergence raises before the artifact is written; the committed
``divergences`` field is checked == 0 by scripts/check_bench.py.

The hosts are in-process simulations sharing one CPU device, so no
true parallelism is available here: the monotone aggregate comes from
the constant-sum join amortizing over the growing cacheable traffic
(every repeat past the first fan-out is an L1/L2 hit), not from
concurrent execution.  Real parallel speedup needs one device per
host (the subprocess test pins hosts to 8 virtual devices);
``cluster_route_qps`` exposes the residual per-shard protocol cost
that such a deployment would overlap away.

The **telemetry overhead** section is the always-on budget: the same
async pass timed with tracing disabled vs 10% sampled mode with the
full production wiring attached (flight recorder + SLO watchdog),
best-of each.  Results must stay bit-identical, the breach counter
must stay 0 on the healthy run, and ``check_bench.py`` gates
``telemetry_overhead <= 0.05``.  Sampling stays enabled across the
sampled rounds so the deterministic systematic sampler keeps >= 1
trace (``obs.sampled_spans`` > 0 is also gated).  The artifact's
``metrics`` block sums *additive* registry deltas across passes
(counters, histogram ``.count``/``.sum``); the absolute latency
percentiles (``cluster.router.e2e_seconds.p99`` etc.) are overlaid
from the telemetry pass, where they are meaningful - that is what
``scripts/trace_report.py --metrics BENCH_cluster.json --slo
scripts/slo_rules.json`` evaluates.

``--smoke`` is the CI tier-4 gate: a tiny config, both layouts, >= 2
hosts, hard-failing on any divergence, written atomically to
``BENCH_cluster_smoke.json``.  ``--trace PATH`` records the span
tracer (repro.obs.trace) across the run; render the phase-attribution
table with ``scripts/trace_report.py PATH``.  ``--trace-sampled PATH``
saves only the spans the sampled-mode rounds kept; ``--prom PATH``
writes the final registry as Prometheus text exposition (validated
strictly before writing); ``--sample-rate`` overrides the 10% default.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

try:
    from .bench_streaming import atomic_write_json, machine_id
except ImportError:  # pragma: no cover - run as a script
    from bench_streaming import atomic_write_json, machine_id

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.obs import FlightRecorder, load_rules, trace
from repro.obs.export import prometheus_text, validate_exposition
from repro.obs.slo import SloWatchdog
from repro.serving.bank import compile_bank
from repro.serving.cluster import ServingCluster, ShardedStreamingBank
from repro.serving.server import PatternServer
from repro.serving.streaming import StreamingBank

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "..", "BENCH_cluster.json")
OUT_SMOKE = os.path.join(HERE, "..", "BENCH_cluster_smoke.json")
RULES = os.path.join(HERE, "..", "scripts", "slo_rules.json")

ZIPF_S = 1.1  # rank exponent of the repeat mix
N_ROUNDS = 3  # best-of rounds per timed pass (see module docstring)

# histogram keys that are NOT additive across passes: summing medians
# is meaningless, so _merge_metrics drops them and bench_telemetry
# overlays the absolute values from its own instance instead
_NONADDITIVE = ("min", "max", "mean", "p50", "p95", "p99")


def zipf_mix(pool, n, seed=2, s=ZIPF_S):
    """Draw ``n`` queries from ``pool`` with rank-Zipfian repetition
    (deterministic under ``seed``)."""
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    p = ranks ** -s
    p /= p.sum()
    rng = np.random.default_rng(seed)
    return [pool[i] for i in rng.choice(len(pool), size=n, p=p)]


def _chunks(items, n_chunks):
    size = max(1, -(-len(items) // n_chunks))
    return [items[i: i + size] for i in range(0, len(items), size)]


def _merge_metrics(into, delta):
    for key, val in delta.items():
        if key.rsplit(".", 1)[-1] in _NONADDITIVE:
            continue
        into[key] = into.get(key, 0) + val


def _spread(queries, n_hosts):
    reqs = {h: [] for h in range(n_hosts)}
    for i, s in enumerate(queries):
        reqs[i % n_hosts].append(s)
    return reqs


def _flatten_drain(results, reqs):
    """Per-host drain results flattened back to query order (the
    inverse of ``_spread``)."""
    flat = {}
    for h, rs in results.items():
        for j, r in enumerate(rs):
            flat[j * len(reqs) + h] = r
    return [flat[i] for i in sorted(flat)]


def _best_of(run, rounds=N_ROUNDS):
    """Best (minimum) wall time over identical rounds of ``run``."""
    return min(run() for _ in range(rounds))


def _check_exact(results, want_by_fp, where):
    """Bit-equality of routed results vs the single-host reference
    (fingerprint-keyed); returns the divergence count after raising on
    the first nonzero."""
    divergences = 0
    n = 0
    for per_host in results:
        for rs in per_host.values():
            for r in rs:
                n += 1
                w = want_by_fp[r.fingerprint]
                if not (np.array_equal(r.contained, w.contained)
                        and r.topk == w.topk and r.exact):
                    divergences += 1
    assert n > 0
    if divergences:
        raise AssertionError(
            f"[{where}] routed cluster diverged from the single-host "
            f"server on {divergences} queries - exactness contract "
            "broken"
        )
    return divergences


def bench_serving_cluster(bank, pool, host_counts,
                          layouts, n_queries, n_drains, flush_batch,
                          metrics_sum):
    """Routed cluster vs single-host server under per-host Zipfian
    arrival streams (offered load scales with H - see the module
    docstring); returns (payload section, divergence count - always 0
    or the bench has already raised).  Each host count is timed twice:
    the async submit-all/collect pipeline (headline aggregate
    ``cluster_qps``) and the synchronous per-drain ``route``
    (``cluster_route_qps``)."""
    single_qps = {}
    cluster_qps = {}
    route_qps = {}
    divergences = 0
    stats = {}
    exact_ref = None  # flat-layout pool rows, reused by the shed demo
    for layout in layouts:
        srv = PatternServer(bank, bank_layout=layout)
        # the bit-equality reference: one result per distinct pool
        # sequence, looked up by canonical fingerprint
        pool_want = srv.query(pool)
        want_by_fp = {w.fingerprint: w for w in pool_want}
        if exact_ref is None:
            exact_ref = np.stack([w.contained for w in pool_want])
        stream0 = zipf_mix(pool, n_queries, seed=2)
        drains0 = _chunks(stream0, n_drains)

        def run_single():
            srv._cache.clear()  # else the drains all cache-hit
            t0 = time.perf_counter()
            for dq in drains0:
                srv.query(dq)
            return time.perf_counter() - t0

        run_single()  # warm the per-drain jit buckets
        single_qps[layout] = len(stream0) / _best_of(run_single)
        cluster_qps[layout] = {}
        route_qps[layout] = {}
        for H in host_counts:
            cl = ServingCluster(bank, H, bank_layout=layout,
                                flush_batch=flush_batch)
            # one independent arrival stream per host, same pool:
            # aggregate offered load is H * n_queries
            streams = [zipf_mix(pool, n_queries, seed=2 + 17 * h)
                       for h in range(H)]
            chunked = [_chunks(s, n_drains) for s in streams]
            reqs = [
                {h: chunked[h][d] for h in range(H)}
                for d in range(n_drains)
            ]
            total = sum(len(s) for s in streams)

            def run_route():
                cl.router.clear_caches()
                t0 = time.perf_counter()
                got = [cl.query_multi(r) for r in reqs]
                dt = time.perf_counter() - t0
                run_route.got = got
                return dt

            def run_async():
                # open-loop arrivals: every drain is admitted before
                # any result is fenced; flushes overlap with later
                # submits (JAX dispatch is async) and repeats
                # piggyback on queued/in-flight joins
                cl.router.clear_caches()
                t0 = time.perf_counter()
                tickets = [cl.submit(r) for r in reqs]
                got = [cl.collect(t) for t in tickets]
                dt = time.perf_counter() - t0
                run_async.got = got
                return dt

            run_route()  # warm every shard's jit buckets
            run_async()
            before = cl.metrics.snapshot()
            route_qps[layout][str(H)] = total / _best_of(run_route)
            cluster_qps[layout][str(H)] = total / _best_of(run_async)
            _merge_metrics(metrics_sum, cl.metrics.delta(before))
            divergences += _check_exact(
                run_route.got, want_by_fp, f"{layout} H={H} route")
            divergences += _check_exact(
                run_async.got, want_by_fp, f"{layout} H={H} async")
            for h in cl.hosts:  # per-host query accounting (was 0)
                if len(h.rows):
                    assert h.server.stats["queries"] > 0, \
                        f"h{h.hid} served joins but counted 0 queries"
            stats[f"{layout}_H{H}"] = dict(cl.router.stats)
    return {
        "bank_patterns": bank.n_patterns,
        "pool_size": len(pool),
        "n_drains": n_drains,
        "n_rounds": N_ROUNDS,
        "flush_batch": flush_batch,
        "zipf_s": ZIPF_S,
        "single_qps": single_qps,
        "cluster_qps": cluster_qps,
        "cluster_route_qps": route_qps,
        "router_stats": stats,
        "shed_stats": bench_shed_tier(
            bank, pool, exact_ref, max(host_counts)),
    }, divergences


def bench_shed_tier(bank, pool, exact_ref, n_hosts):
    """Exercise the overload tier on its own cluster instance (own
    registry: the headline metrics stay a pure exactness run).  With
    ``shed_depth=0`` every miss is answered from the host-side
    prescreen - sound superset bits, flagged inexact, never cached."""
    cl = ServingCluster(bank, n_hosts, shed_depth=0)
    sample = pool[:32]
    got = _flatten_drain(
        cl.collect(cl.submit(_spread(sample, n_hosts))),
        _spread(sample, n_hosts))
    for i, r in enumerate(got):
        assert not r.exact, "shed answers must be flagged inexact"
        assert not (exact_ref[i] & ~r.contained).any(), \
            "prescreen dropped a true containment - shed tier unsound"
    assert all(not h.l1 and not h.l2 for h in cl.hosts), \
        "approximate rows leaked into the caches"
    st = dict(cl.router.stats)
    assert st["shed_prescreen"] > 0
    return {k: st[k] for k in
            ("queries", "misses", "shed_prescreen", "shard_batches")}


def bench_telemetry(bank, pool, n_queries, n_drains, flush_batch,
                    n_hosts, metrics_sum, sample_rate, smoke,
                    prom_path=None, trace_sampled=None):
    """The always-on telemetry budget: the same async submit/collect
    pass timed with tracing disabled vs sampled mode with the full
    production wiring attached (flight recorder + SLO watchdog),
    best-of each.  Routed results must stay bit-identical, the breach
    counter must stay 0 on this healthy run, and check_bench.py gates
    the overhead ratio <= 5%.

    Sampling is enabled ONCE across the sampled rounds: the systematic
    sampler is a deterministic accumulator, so at 2 * n_drains roots
    per pass it is guaranteed to keep >= 1 trace over the section
    (check_bench also gates ``obs.sampled_spans`` > 0 in the metrics
    block)."""
    cl = ServingCluster(bank, n_hosts, bank_layout="flat",
                        flush_batch=flush_batch)
    streams = [zipf_mix(pool, n_queries, seed=5 + 13 * h)
               for h in range(n_hosts)]
    chunked = [_chunks(s, n_drains) for s in streams]
    reqs = [{h: chunked[h][d] for h in range(n_hosts)}
            for d in range(n_drains)]

    def run_pass():
        cl.router.clear_caches()
        t0 = time.perf_counter()
        tickets = [cl.submit(r) for r in reqs]
        got = [cl.collect(t) for t in tickets]
        run_pass.got = got
        return time.perf_counter() - t0

    def rows(got):
        return [(r.contained.tobytes(), tuple(r.topk), r.exact)
                for per_host in got
                for rs in per_host.values() for r in rs]

    rounds = 2 if smoke else N_ROUNDS
    was_full = trace.enabled()
    trace.disable()
    run_pass()  # warm every shard's jit buckets
    t_off = min(run_pass() for _ in range(rounds))
    ref = rows(run_pass.got)

    # the production wiring goes up only for the sampled rounds, so
    # t_off is a true telemetry-disabled baseline.  The warm pass's
    # jit compiles sit in the latency histograms as multi-second
    # outliers; the watchdog's quantile rules read histograms
    # absolutely, so reset first - scoping the histograms (and the
    # percentiles overlaid into the artifact) to steady state.
    cl.metrics.reset()
    flight = FlightRecorder(capacity=32, metrics=cl.metrics,
                            metrics_prefix="cluster.router")
    wd = SloWatchdog(cl.metrics, load_rules(RULES), flight=flight)
    cl.attach_watchdog(wd)
    before = cl.metrics.snapshot()
    saved_events = trace.tracer.events
    trace.tracer.events = []
    trace.enable_sampling(sample_rate, metrics=cl.metrics,
                          flight=flight)
    t_on = min(run_pass() for _ in range(rounds))
    got_on = rows(run_pass.got)
    trace.disable()
    sampled_events = trace.tracer.events
    trace.tracer.events = saved_events
    if was_full:
        trace.enable()  # restore the --trace run's full tracing

    if got_on != ref:
        raise AssertionError(
            "sampled telemetry changed routed results - the observe "
            "path leaked into the answers")
    delta = cl.metrics.delta(before)
    if delta.get("obs.sampled_spans", 0) <= 0:
        raise AssertionError(
            "sampled mode kept zero traces over "
            f"{rounds * 2 * n_drains} roots at rate {sample_rate} - "
            "the systematic sampler regressed")
    if cl.metrics.counter("cluster.router.slo_breaches").value:
        raise AssertionError(
            "SLO watchdog fired on the healthy telemetry pass: "
            f"{wd.last_breaches}")
    _merge_metrics(metrics_sum, delta)
    # absolute latency percentiles from this instance's histograms -
    # the one place they are meaningful in the summed metrics block
    # (feeds scripts/trace_report.py --metrics / --slo)
    metrics_sum.update(
        {k: v for k, v in cl.metrics.snapshot().items()
         if k.rsplit(".", 1)[-1] in _NONADDITIVE})
    if trace_sampled:
        trace.tracer.events = sampled_events
        trace.save(trace_sampled)
        trace.tracer.events = saved_events
    if prom_path:
        text = prometheus_text(cl.metrics)
        problems = validate_exposition(text)
        if problems:
            raise AssertionError(
                f"invalid Prometheus exposition: {problems[:3]}")
        with open(prom_path, "w") as f:
            f.write(text)
    return {
        "telemetry_overhead": max(0.0, t_on / t_off - 1.0),
        "telemetry_sample_rate": sample_rate,
        "telemetry_sampled_traces":
            delta.get("obs.sampled_traces", 0),
        "telemetry_watchdog_checks": wd.checks,
    }


def bench_sharded_stream(db, stream, sigma, max_len, window, n_hosts,
                         batch_size, refresh_every, metrics_sum):
    """Sharded-window protocol vs the single-host StreamingBank on one
    arrival stream; hard-fails unless every post-refresh frequent map
    is bit-equal."""
    batches = [stream[i: i + batch_size]
               for i in range(0, len(stream), batch_size)]

    def run(make, observe, refresh):
        sb = make()
        before = sb.metrics.snapshot()
        t0 = time.perf_counter()
        maps = []
        for i, b in enumerate(batches):
            observe(sb, b)
            if (i + 1) % refresh_every == 0:
                maps.append(refresh(sb))
        maps.append(refresh(sb))
        return time.perf_counter() - t0, maps, sb, \
            sb.metrics.delta(before)

    def mk_single():
        return StreamingBank.from_db(
            db, minsup=sigma, window=window, max_len=max_len)

    def mk_sharded():
        return ShardedStreamingBank.from_db(
            db, minsup=sigma, n_hosts=n_hosts, window=window,
            max_len=max_len)

    def best_of(make, observe, refresh):
        run(make, observe, refresh)  # warm the jit buckets
        best = None
        for _ in range(N_ROUNDS):
            r = run(make, observe, refresh)
            if best is None or r[0] < best[0]:
                best = r
        return best

    t_single, maps_single, _, _ = best_of(
        mk_single, StreamingBank.observe, StreamingBank.refresh)
    t_sharded, maps_sharded, sh, delta = best_of(
        mk_sharded, ShardedStreamingBank.observe,
        ShardedStreamingBank.refresh)
    _merge_metrics(metrics_sum, delta)
    for i, (a, b) in enumerate(zip(maps_single, maps_sharded)):
        if a != b:
            raise AssertionError(
                f"sharded-window frequent map diverged from the "
                f"single-host streaming bank at refresh {i}: "
                f"{len(a)} vs {len(b)} patterns"
            )
    n = len(stream)
    return {
        "stream_window": window,
        "stream_hosts": n_hosts,
        "n_stream_updates": n,
        "single_stream_updates_per_sec": n / t_single,
        "sharded_stream_updates_per_sec": n / t_sharded,
        "stream_refresh_checks": len(maps_sharded),
        "allreduces": sh.stats["allreduces"],
        "dirty_subtrees": sh.stats["dirty_subtrees"],
    }


def main(csv=print, smoke: bool = False, trace_path=None,
         sample_rate: float = 0.1, prom_path=None, trace_sampled=None):
    if smoke:
        db_size, n_queries, max_len = 40, 48, 3
        pool_size, n_drains, flush_batch = 16, 3, 8
        host_counts, out_path = (1, 2, 3), OUT_SMOKE
        window, stream_n, batch_size, refresh_every = 24, 24, 8, 2
    else:
        db_size, n_queries, max_len = 120, 256, 4
        pool_size, n_drains, flush_batch = 64, 4, 16
        host_counts, out_path = (1, 2, 4), OUT
        window, stream_n, batch_size, refresh_every = 60, 60, 10, 3
    if trace_path:
        trace.clear()
        trace.enable()
    params = Table3Params(db_size=db_size + window + stream_n, v_avg=5,
                          n_interstates=3)
    all_seqs = generate_table3_db(params, seed=0)
    db = all_seqs[:db_size]
    stream_db = all_seqs[db_size: db_size + window]
    stream = all_seqs[db_size + window:]
    sigma = max(2, db_size // 15)
    qparams = Table3Params(db_size=pool_size, v_avg=5, n_interstates=3)
    pool = generate_table3_db(qparams, seed=1)

    bank = compile_bank(
        AcceleratedMiner(db).mine_rs(sigma, max_len=max_len))
    metrics_sum = {}
    serving, divergences = bench_serving_cluster(
        bank, pool, host_counts, ("flat", "trie"),
        n_queries, n_drains, flush_batch, metrics_sum)
    streaming = bench_sharded_stream(
        stream_db, stream, max(2, window // 15), max_len, window,
        2, batch_size, refresh_every, metrics_sum)
    telemetry = bench_telemetry(
        bank, pool, n_queries, n_drains, flush_batch,
        max(host_counts), metrics_sum, sample_rate, smoke,
        prom_path=prom_path, trace_sampled=trace_sampled)

    host_q = sum(v for k, v in metrics_sum.items()
                 if k.startswith("serving.server.")
                 and k.endswith(".queries"))
    assert host_q > 0, \
        "per-host query accounting regressed to zero (satellite bug)"
    l1 = metrics_sum.get("cluster.router.l1_hits", 0)
    l2 = metrics_sum.get("cluster.router.l2_hits", 0)
    routed = metrics_sum.get("cluster.router.queries", 0)
    payload = {
        "machine": machine_id(),
        "n_queries": n_queries,
        "host_counts": list(host_counts),
        "divergences": divergences,
        "cache_hit_rate": (l1 + l2) / routed if routed else 0.0,
        **serving,
        **streaming,
        **telemetry,
        "metrics": metrics_sum,
    }
    if trace_path:
        trace.save(trace_path)
        trace.disable()
        csv(f"# trace saved to {trace_path} "
            f"({len(trace.tracer.events)} spans)")
    atomic_write_json(out_path, payload)
    for layout in ("flat", "trie"):
        base = serving["single_qps"][layout]
        for H in host_counts:
            qps = serving["cluster_qps"][layout][str(H)]
            rqps = serving["cluster_route_qps"][layout][str(H)]
            csv(f"cluster/{layout}_H{H},{1e6 / qps:.0f},"
                f"qps={qps:.0f},x{qps / base:.2f}_vs_single,"
                f"route_qps={rqps:.0f}")
    csv(f"cluster/stream_sharded,"
        f"{1e6 / streaming['sharded_stream_updates_per_sec']:.0f},"
        f"ups={streaming['sharded_stream_updates_per_sec']:.0f}")
    csv(f"cluster/stream_single,"
        f"{1e6 / streaming['single_stream_updates_per_sec']:.0f},"
        f"ups={streaming['single_stream_updates_per_sec']:.0f}")
    csv(f"cluster/cache,{payload['cache_hit_rate']:.3f},"
        f"l1={l1},l2={l2},routed={routed}")
    csv(f"cluster/telemetry_overhead,{0:.0f},"
        f"{100.0 * telemetry['telemetry_overhead']:.2f}%"
        f"@{sample_rate:.0%},"
        f"sampled_traces={telemetry['telemetry_sampled_traces']}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, >=2 hosts, hard-fail on any "
                         "divergence from single-host results (the CI "
                         "tier-4 gate)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span trace of the run (Chrome JSON "
                         "for .json paths, JSONL otherwise); inspect "
                         "with scripts/trace_report.py")
    ap.add_argument("--sample-rate", type=float, default=0.1,
                    metavar="R",
                    help="trace sampling rate for the telemetry "
                         "overhead section (default 0.1)")
    ap.add_argument("--trace-sampled", default=None, metavar="PATH",
                    help="save only the spans kept by the sampled-mode "
                         "telemetry rounds (same formats as --trace)")
    ap.add_argument("--prom", default=None, metavar="PATH",
                    help="write the telemetry cluster's registry as "
                         "Prometheus text exposition (validated "
                         "strictly before writing)")
    args = ap.parse_args()
    out = main(smoke=args.smoke, trace_path=args.trace,
               sample_rate=args.sample_rate, prom_path=args.prom,
               trace_sampled=args.trace_sampled)
    print(f"# cluster routed serving bit-equal to single-host "
          f"({out['divergences']} divergences) across hosts "
          f"{out['host_counts']}; zipf cache hit rate "
          f"{out['cache_hit_rate']:.2f}; sharded window "
          f"{out['sharded_stream_updates_per_sec']:.0f} ups vs single "
          f"{out['single_stream_updates_per_sec']:.0f} ups over "
          f"{out['stream_hosts']} hosts; sampled telemetry overhead "
          f"{100 * out['telemetry_overhead']:.1f}% at "
          f"{out['telemetry_sample_rate']:.0%}")
