"""Fault-tolerance benchmark: availability and added tail latency
under a standard seeded fault schedule, differentially gated against
the single-host ``PatternServer``.

Emits ``BENCH_faults.json``.  Everything runs on a **fake clock** (the
injector's ``sleep`` advances it), so the whole artifact is
deterministic: the fault schedule is a pure function of the injector
seed and per-host call index (``serving.faults.FaultInjector`` - no
RNG at query time), the drain timeline is a fixed sequence of virtual
``ADVANCE`` steps, and the latency percentiles are *virtual seconds* -
the injected delays, retry backoff and degraded-drain cost the fault
ladder actually added, with no wall-clock noise in them.

Four phases, one H=4 flat cluster each (same bank, so the jit shapes
are shared):

1. **Fault-free identity** - the same open-loop submit/poll/collect
   drive on a pre-fault cluster vs one with the injector *installed
   but idle* and the retry policy armed.  Results must be bit-identical
   pairwise (``fault_free_divergences`` == 0): the fault ladder's fast
   path really is the pre-fault path.
2. **Standard fault schedule** - transient errors (5%), injected
   delays (10%), and one host blacked out for half the drain timeline.
   Every submitted query must get exactly one answer
   (``lost_tickets`` == 0, ``availability`` >= 0.99): bit-equal to the
   single-host server when flagged ``exact``, a sound superset when
   degraded.  ``unflagged_inexact`` counts silent wrongness (exact-
   flagged answers whose bits diverge) and ``divergences`` counts
   unsound degradation (flagged answers that dropped a true
   containment) - both hard-gated == 0 here AND by
   ``scripts/check_bench.py`` on the committed artifact.
3. **Replica failover** - the crashed host's shard has a registered
   ``BankReplica``: its column block promotes to the replica's exact
   rows, so every answer stays ``exact=True`` and bit-equal
   (``failover_divergences`` == 0) while the breaker is open.
4. **Host recovery** - past the blackout + breaker cooldown, the next
   drain's half-open probe succeeds: the host rejoins with wiped
   caches (``cluster.faults.recoveries`` > 0) and serving is exact
   bit-equal again (``recovery_divergences`` == 0).

The headline pair is ``p99_e2e_faulty`` vs ``p99_e2e_fault_free``
(the ``cluster.router.e2e_seconds`` histogram of phases 2 and 1 on the
identical drain timeline): ``added_p99`` is the virtual tail latency
the fault schedule cost after retries/failover absorbed it.  The
``metrics`` block sums the additive registry deltas of all four
phases; ``check_bench.py`` additionally requires
``cluster.faults.injected`` > 0 and ``cluster.faults.breaker_open``
> 0 there (a schedule that stopped injecting proves nothing) and the
``cluster.faults.retry_seconds`` histogram to have observed.

Every gate raises *before* the artifact is written - a committed
artifact with a nonzero divergence count means it was hand-edited.
``--smoke`` is the CI tier-7 gate: a tiny config, same H=4 schedule
shape, written to ``BENCH_faults_smoke.json``.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

try:
    from .bench_cluster import _chunks, _merge_metrics, _NONADDITIVE, \
        _spread, zipf_mix
    from .bench_streaming import atomic_write_json, machine_id
except ImportError:  # pragma: no cover - run as a script
    from bench_cluster import _chunks, _merge_metrics, _NONADDITIVE, \
        _spread, zipf_mix
    from bench_streaming import atomic_write_json, machine_id

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.serving.bank import compile_bank
from repro.serving.cluster import BankReplica, ServingCluster
from repro.serving.faults import FaultInjector, RetryPolicy
from repro.serving.server import PatternServer

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "..", "BENCH_faults.json")
OUT_SMOKE = os.path.join(HERE, "..", "BENCH_faults_smoke.json")

N_HOSTS = 4          # the acceptance gate's H (one host faulted)
CRASH_HOST = 1
ADVANCE = 0.5        # virtual seconds between drains
ERROR_RATE = 0.05    # transient-error rate of the standard schedule
DELAY_RATE = 0.10    # injected-delay rate
DELAY = 0.02         # virtual seconds per injected delay
COLLECT_TIMEOUT = 2.0
POLICY = RetryPolicy(call_timeout=5.0, retries=2, backoff_base=0.001,
                     backoff_cap=0.01, breaker_threshold=3,
                     breaker_cooldown=1.0)


class FaultGateError(AssertionError):
    """A fault-tolerance gate failed - raised before the artifact is
    written."""


def _mk_cluster(bank, clock, flush_batch, injector=None, policy=None):
    return ServingCluster(
        bank, N_HOSTS, bank_layout="flat", clock=clock,
        injector=injector, fault_policy=policy,
        max_wait=ADVANCE / 2, flush_batch=flush_batch)


def _mk_injector(now, **kw):
    """An injector whose delays advance the fake clock (so injected
    latency lands in the virtual e2e histograms)."""
    return FaultInjector(
        0, clock=lambda: now[0],
        sleep=lambda s: now.__setitem__(0, now[0] + s), **kw)


def _drains(pool, n_queries, n_drains, seed0):
    """One open-loop Zipfian arrival stream per host (the
    bench_cluster offered-load model), chunked into per-drain request
    maps."""
    streams = [zipf_mix(pool, n_queries, seed=seed0 + 17 * h)
               for h in range(N_HOSTS)]
    chunked = [_chunks(s, n_drains) for s in streams]
    return [{h: chunked[h][d] for h in range(N_HOSTS)}
            for d in range(n_drains)]


def _drive(cl, reqs_by_drain, now, timeout=None):
    """The open-loop drive: admit every drain on the virtual timeline
    (one ``ADVANCE`` step + deadline pump per drain), then collect
    each ticket - with ``timeout`` the stragglers degrade instead of
    blocking."""
    tickets = []
    for reqs in reqs_by_drain:
        tickets.append(cl.submit(reqs))
        now[0] += ADVANCE
        cl.poll()
    return [cl.collect(t, timeout=timeout) for t in tickets]


def _audit(got_by_drain, reqs_by_drain, want_by_fp):
    """The one-answer contract, counted: every answer is either exact
    and bit-equal to the single-host reference, or flagged inexact and
    a sound superset."""
    submitted = sum(len(s) for reqs in reqs_by_drain
                    for s in reqs.values())
    n = dict(submitted=submitted, answered=0, exact_answers=0,
             degraded_answers=0, unflagged_inexact=0, divergences=0)
    for res in got_by_drain:
        for rs in res.values():
            for r in rs:
                n["answered"] += 1
                w = want_by_fp[r.fingerprint]
                if r.exact:
                    n["exact_answers"] += 1
                    if not (np.array_equal(r.contained, w.contained)
                            and r.topk == w.topk):
                        n["unflagged_inexact"] += 1
                else:
                    n["degraded_answers"] += 1
                    if (w.contained & ~r.contained).any():
                        n["divergences"] += 1
    n["lost_tickets"] = submitted - n["answered"]
    return n


def _exact_mismatches(got_by_drain, want_by_fp):
    """Answers that are not (exact AND bit-equal) - the strict count
    for the phases where degradation itself is a failure."""
    bad = 0
    for res in got_by_drain:
        for rs in res.values():
            for r in rs:
                w = want_by_fp[r.fingerprint]
                if not (r.exact
                        and np.array_equal(r.contained, w.contained)
                        and r.topk == w.topk):
                    bad += 1
    return bad


def bench_fault_free(bank, reqs, metrics_sum, flush_batch):
    """Phase 1: idle injector + armed policy vs the pre-fault cluster,
    identical virtual timeline - must be bit-identical pairwise."""
    now_a, now_b = [0.0], [0.0]
    ref = _mk_cluster(bank, lambda: now_a[0], flush_batch)
    inj = _mk_injector(now_b)          # all rates zero, no blackouts
    cl = _mk_cluster(bank, lambda: now_b[0], flush_batch,
                     injector=inj, policy=POLICY)
    got_ref = _drive(ref, reqs, now_a)
    got = _drive(cl, reqs, now_b)
    div = 0
    for ra, rb in zip(got_ref, got):
        for h in ra:
            for x, y in zip(ra[h], rb[h]):
                if not (np.array_equal(x.contained, y.contained)
                        and x.topk == y.topk and x.exact and y.exact):
                    div += 1
    if not inj.calls:
        raise FaultGateError(
            "idle injector never reached the host call boundary - the "
            "fault seam is no longer on the fast path")
    snap = cl.metrics.snapshot()
    if snap.get("cluster.faults.injected", 0) \
            or snap.get("cluster.faults.retries", 0):
        raise FaultGateError(
            "the idle injector injected faults on the fault-free run")
    _merge_metrics(metrics_sum, snap)
    _merge_metrics(metrics_sum, ref.metrics.snapshot())
    return div, snap.get("cluster.router.e2e_seconds.p99", 0.0)


def bench_fault_schedule(bank, pool, want_by_fp, n_queries, n_drains,
                         flush_batch, metrics_sum):
    """Phases 2 + 4: the standard schedule (errors + delays + one host
    blacked out for half the timeline), then the post-blackout
    recovery drain on the same cluster."""
    now = [0.0]
    horizon = n_drains * ADVANCE
    blackout = (CRASH_HOST, 0.3 * horizon, 0.8 * horizon)
    inj = _mk_injector(now, error_rate=ERROR_RATE,
                       delay_rate=DELAY_RATE, delay=DELAY,
                       blackouts=[blackout])
    cl = _mk_cluster(bank, lambda: now[0], flush_batch,
                     injector=inj, policy=POLICY)
    reqs = _drains(pool, n_queries, n_drains, seed0=2)
    got = _drive(cl, reqs, now, timeout=COLLECT_TIMEOUT)
    counts = _audit(got, reqs, want_by_fp)

    # phase 4: past the blackout and the breaker cooldown, one more
    # drain - the half-open probe rejoins the host, exact serving
    now[0] = horizon + POLICY.breaker_cooldown + 1.0
    rec_reqs = _drains(pool, max(4, n_queries // 4), 2, seed0=31)
    rec_got = _drive(cl, rec_reqs, now)
    recovery_divergences = _exact_mismatches(rec_got, want_by_fp)

    snap = cl.metrics.snapshot()
    _merge_metrics(metrics_sum, snap)
    counts.update(
        recovery_divergences=recovery_divergences,
        availability=(counts["answered"] / counts["submitted"]
                      if counts["submitted"] else 0.0),
        p99_e2e_faulty=snap.get("cluster.router.e2e_seconds.p99", 0.0),
    )
    for key, why in (
        ("cluster.faults.injected",
         "the schedule injected zero faults"),
        ("cluster.faults.retries",
         "no transient error was ever retried"),
        ("cluster.faults.breaker_open",
         "the blackout never opened the circuit breaker"),
        ("cluster.faults.recoveries",
         "the crashed host never rejoined"),
        ("cluster.faults.retry_seconds.count",
         "the retry-latency histogram stopped observing"),
    ):
        if snap.get(key, 0) <= 0:
            raise FaultGateError(f"{key} = {snap.get(key, 0)}: {why} "
                                 "- the standard schedule is vacuous")
    if counts["degraded_answers"] <= 0:
        raise FaultGateError(
            "the blackout produced zero degraded answers - the "
            "soundness gates never ran")
    return counts, snap


def bench_failover(bank, pool, want_by_fp, n_queries, flush_batch,
                   metrics_sum):
    """Phase 3: the crashed shard has a registered full-bank replica -
    every answer must stay exact and bit-equal while its breaker is
    open."""
    now = [0.0]
    inj = _mk_injector(now, blackouts=[(CRASH_HOST, 0.0, 10 ** 9)])
    cl = _mk_cluster(bank, lambda: now[0], flush_batch,
                     injector=inj, policy=POLICY)
    cl.attach_failover_replica(
        CRASH_HOST, BankReplica(bank, bank_layout="flat"))
    sample = zipf_mix(pool, n_queries, seed=7)
    got = [cl.query_multi(_spread(sample, N_HOSTS))]
    div = _exact_mismatches(got, want_by_fp)
    snap = cl.metrics.snapshot()
    if snap.get("cluster.faults.failovers", 0) <= 0:
        raise FaultGateError(
            "zero failovers with a permanently crashed host and a "
            "registered replica - the promotion ladder never ran")
    if snap.get("cluster.faults.degraded_answers", 0):
        raise FaultGateError(
            "replica failover still produced degraded answers - the "
            "ladder fell through to the prescreen")
    _merge_metrics(metrics_sum, snap)
    return div


def main(csv=print, smoke: bool = False):
    if smoke:
        db_size, pool_size, max_len = 40, 16, 3
        n_queries, n_drains, flush_batch = 24, 4, 4
        out_path = OUT_SMOKE
    else:
        db_size, pool_size, max_len = 120, 48, 4
        n_queries, n_drains, flush_batch = 96, 8, 8
        out_path = OUT
    params = Table3Params(db_size=db_size, v_avg=5, n_interstates=3)
    db = generate_table3_db(params, seed=0)
    sigma = max(2, db_size // 15)
    qparams = Table3Params(db_size=pool_size, v_avg=5, n_interstates=3)
    pool = generate_table3_db(qparams, seed=1)
    bank = compile_bank(
        AcceleratedMiner(db).mine_rs(sigma, max_len=max_len))

    # the single-host truth, fingerprint-keyed (one result per
    # distinct pool sequence)
    srv = PatternServer(bank, bank_layout="flat")
    want_by_fp = {w.fingerprint: w for w in srv.query(pool)}

    metrics_sum = {}
    ff_reqs = _drains(pool, n_queries, n_drains, seed0=2)
    fault_free_divergences, p99_clean = bench_fault_free(
        bank, ff_reqs, metrics_sum, flush_batch)
    counts, snap = bench_fault_schedule(
        bank, pool, want_by_fp, n_queries, n_drains, flush_batch,
        metrics_sum)
    failover_divergences = bench_failover(
        bank, pool, want_by_fp, n_queries, flush_batch, metrics_sum)

    # absolute virtual-latency percentiles from the faulty cluster
    # (the one place they are meaningful in the summed metrics block)
    metrics_sum.update(
        {k: v for k, v in snap.items()
         if k.rsplit(".", 1)[-1] in _NONADDITIVE})

    payload = {
        "machine": machine_id(),
        "bank_patterns": bank.n_patterns,
        "n_hosts": N_HOSTS,
        "n_drains": n_drains,
        "flush_batch": flush_batch,
        "error_rate": ERROR_RATE,
        "delay_rate": DELAY_RATE,
        **counts,
        "fault_free_divergences": fault_free_divergences,
        "failover_divergences": failover_divergences,
        "p99_e2e_fault_free": p99_clean,
        "added_p99": max(0.0, counts["p99_e2e_faulty"] - p99_clean),
        "metrics": metrics_sum,
    }
    # every contract gate raises BEFORE the artifact is written
    for key in ("lost_tickets", "unflagged_inexact", "divergences",
                "fault_free_divergences", "failover_divergences",
                "recovery_divergences"):
        if payload[key] != 0:
            raise FaultGateError(
                f"{key} = {payload[key]} - the fault-tolerance "
                "contract is broken (see module docstring)")
    if payload["availability"] < 0.99:
        raise FaultGateError(
            f"availability {payload['availability']:.4f} < 0.99 with "
            f"one of {N_HOSTS} hosts faulted")
    atomic_write_json(out_path, payload)
    csv(f"faults/availability,{payload['availability']:.4f},"
        f"answered={payload['answered']}/{payload['submitted']},"
        f"degraded={payload['degraded_answers']}")
    csv(f"faults/ladder,{snap.get('cluster.faults.injected', 0):.0f},"
        f"retries={snap.get('cluster.faults.retries', 0):.0f},"
        f"breaker_open={snap.get('cluster.faults.breaker_open', 0):.0f},"
        f"failovers_phase3=1,"
        f"recoveries={snap.get('cluster.faults.recoveries', 0):.0f}")
    csv(f"faults/added_p99,{payload['added_p99']:.3f},"
        f"virtual_s,faulty={payload['p99_e2e_faulty']:.3f},"
        f"clean={payload['p99_e2e_fault_free']:.3f}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, same H=4 fault schedule shape, "
                         "hard-fail on any contract violation (the CI "
                         "tier-7 gate)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(f"# fault schedule: availability "
          f"{out['availability']:.4f} with 1/{out['n_hosts']} hosts "
          f"blacked out, {out['degraded_answers']} flagged degraded "
          f"answers, 0 unflagged-inexact / lost / divergent; replica "
          f"failover and post-blackout recovery bit-equal; added "
          f"virtual p99 {out['added_p99']:.3f}s")
