"""Microbenchmark: the match/count hot loop - jnp reference vs the Pallas
kernel (interpret mode; on CPU the *jnp* timing is the meaningful one,
the kernel timing just proves the path runs)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.match_count.ops import match_signatures_kernel
from repro.mining.engine import match_signatures


def _inputs(E, G, T, NI=16, NV=12, P=64, seed=0):
    rng = np.random.default_rng(seed)
    tokens = np.zeros((G, T, 6), np.int32)
    tokens[..., 0] = rng.integers(0, 6, (G, T))
    tokens[..., 1] = rng.integers(0, 16, (G, T))
    tokens[..., 2] = rng.integers(0, 16, (G, T))
    tokens[..., 3] = rng.integers(0, 5, (G, T))
    tokens[..., 4] = np.sort(rng.integers(0, 8, (G, T)), 1)
    tokens[..., 5] = 1
    gid = rng.integers(0, G, E).astype(np.int32)
    phi = np.full((E, NI), 0x3FFFFFF, np.int32)
    phi[:, 0] = rng.integers(0, 4, E)
    psi = np.full((E, NV), -2, np.int32)
    psi[:, 0] = rng.integers(0, 16, E)
    valid = np.ones(E, np.int32)
    existing = np.full((P, 5), -9, np.int32)
    return [jnp.asarray(x) for x in
            (tokens, gid, phi, psi, valid, existing)]


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def main(csv=print):
    scal = [jnp.int32(1), jnp.int32(1), jnp.int32(2)]
    for E, G, T in [(1024, 256, 128), (4096, 1024, 128), (8192, 1024, 256)]:
        args = _inputs(E, G, T)
        t_ref = _time(lambda *a: match_signatures(*a, *scal), *args)
        pairs = E * T
        csv(
            f"kernel/match_jnp_E{E}_T{T},{t_ref*1e6:.0f},"
            f"gpairs_per_s={pairs/t_ref/1e9:.3f}"
        )
        if E <= 4096:
            t_k = _time(
                lambda *a: match_signatures_kernel(*a, *scal,
                                                   interpret=True),
                *args,
            )
            csv(
                f"kernel/match_pallas_interp_E{E}_T{T},{t_k*1e6:.0f},"
                f"gpairs_per_s={pairs/t_k/1e9:.3f}"
            )


if __name__ == "__main__":
    main()
