"""Kernel microbenchmarks + the fused trie-walk artifact.

Two parts:

1. the match/count hot loop - jnp reference vs the Pallas kernel
   (interpret mode; on CPU the *jnp* timing is the meaningful one, the
   kernel timing just proves the path runs), CSV rows only;
2. the fused trie-walk megakernel (``kernels.trie_walk`` behind
   ``bank_layout="trie_fused"``) vs the unrolled per-level walk, on a
   mined bank: interleaved cold rounds of the *walk itself*
   (launch + scatter, no cache/score), a device-dispatch count per
   query batch (the fused path's contract is ONE, independent of trie
   depth; the per-level path pays one per level), a full three-layout
   row-divergence count, and a measured-vs-roofline table for the fused
   dispatch from ``roofline/hlo_cost.py``'s trip-count-aware HLO walk.

   The timed regime is the *router flush*: small query chunks
   (``FLUSH_CHUNK``) with a precomputed ``SharedEncoding`` per chunk -
   exactly what ``ClusterRouter`` hands ``launch_rows`` on every async
   flush.  That is the dispatch-bound regime the fusion targets (one
   launch per flush instead of one per trie level); huge offline
   batches amortize the per-level launches and are served fine by the
   per-level layout, which stays the default.  Sharing the encoding
   keeps the common encode term out of both sides of the ratio.
   Emits ``BENCH_kernel.json`` (``--smoke``:
   ``BENCH_kernel_smoke.json``), gated by ``scripts/check_bench.py``
   (fused median >= 1.5x per-level, dispatches_per_query == 1,
   divergences == 0).  Writes go through tempfile + rename so a failed
   run never truncates the committed artifact.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.match_count.ops import match_signatures_kernel
from repro.mining.engine import match_signatures

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernel.json")
OUT_SMOKE = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_kernel_smoke.json"
)


def _inputs(E, G, T, NI=16, NV=12, P=64, seed=0):
    rng = np.random.default_rng(seed)
    tokens = np.zeros((G, T, 6), np.int32)
    tokens[..., 0] = rng.integers(0, 6, (G, T))
    tokens[..., 1] = rng.integers(0, 16, (G, T))
    tokens[..., 2] = rng.integers(0, 16, (G, T))
    tokens[..., 3] = rng.integers(0, 5, (G, T))
    tokens[..., 4] = np.sort(rng.integers(0, 8, (G, T)), 1)
    tokens[..., 5] = 1
    gid = rng.integers(0, G, E).astype(np.int32)
    phi = np.full((E, NI), 0x3FFFFFF, np.int32)
    phi[:, 0] = rng.integers(0, 4, E)
    psi = np.full((E, NV), -2, np.int32)
    psi[:, 0] = rng.integers(0, 16, E)
    valid = np.ones(E, np.int32)
    existing = np.full((P, 5), -9, np.int32)
    return [jnp.asarray(x) for x in
            (tokens, gid, phi, psi, valid, existing)]


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def main(csv=print):
    scal = [jnp.int32(1), jnp.int32(1), jnp.int32(2)]
    for E, G, T in [(1024, 256, 128), (4096, 1024, 128), (8192, 1024, 256)]:
        args = _inputs(E, G, T)
        t_ref = _time(lambda *a: match_signatures(*a, *scal), *args)
        pairs = E * T
        csv(
            f"kernel/match_jnp_E{E}_T{T},{t_ref*1e6:.0f},"
            f"gpairs_per_s={pairs/t_ref/1e9:.3f}"
        )
        if E <= 4096:
            t_k = _time(
                lambda *a: match_signatures_kernel(*a, *scal,
                                                   interpret=True),
                *args,
            )
            csv(
                f"kernel/match_pallas_interp_E{E}_T{T},{t_k*1e6:.0f},"
                f"gpairs_per_s={pairs/t_k/1e9:.3f}"
            )


def _count_dispatches(server_mod, names):
    """Wrap server-module device entry points with call counters;
    returns (counts, restore)."""
    counts = {n: 0 for n in names}
    saved = {n: getattr(server_mod, n) for n in names}

    def _wrap(n, real):
        def wrapper(*a, **kw):
            counts[n] += 1
            return real(*a, **kw)
        return wrapper

    for n in names:
        setattr(server_mod, n, _wrap(n, saved[n]))

    def restore():
        for n in names:
            setattr(server_mod, n, saved[n])

    return counts, restore


FLUSH_CHUNK = 4  # queries per timed flush - the router's latency regime


def _timed_walk(srv, chunks, encs, layouts_mod):
    """One cold pass of the walk alone over pre-encoded flush chunks -
    launch (fenced) + first-pass scatter, no cache, no scoring, no
    escalation resolve - the part the fused kernel replaces.  The
    per-chunk SharedEncoding mirrors ClusterRouter's flush path and
    keeps the common encode cost out of the measurement."""
    t0 = time.perf_counter()
    for seqs, enc in zip(chunks, encs):
        flight = srv.launch_rows(seqs, enc)
        layouts_mod.get_layout(flight.layout).finalize(srv, flight)
    return time.perf_counter() - t0


def _fused_roofline(fused_srv, queries):
    """Lower + compile the one fused dispatch this bank/batch shape
    issues and extract the trip-count-aware HLO cost terms
    (roofline/hlo_cost.py); pair them with the measured per-dispatch
    time.  t_compute/t_memory are the TPU-v5e roofline bounds the
    analysis module models - on a CPU run they bound what the same
    dispatch costs on the accelerator, while achieved_* report this
    host."""
    import repro.serving.server as server_mod
    from repro.roofline import analysis
    from repro.serving.batch import fused_trie_walk

    captured = {}
    real = fused_trie_walk

    def capture(*a, **kw):
        captured["args"], captured["kw"] = a, kw
        return real(*a, **kw)

    server_mod.fused_trie_walk = capture
    try:
        fused_srv._cache.clear()
        fused_srv.query(queries)
    finally:
        server_mod.fused_trie_walk = real
    if "args" not in captured:
        return None
    a, kw = captured["args"], captured["kw"]
    lowered = real.lower(*a, **kw)
    compiled = lowered.compile()
    # measure the dispatch alone (args already on device, fenced)
    real(*a, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    iters = 5
    for _ in range(iters):
        acc, _ = real(*a, **kw)
    acc.block_until_ready()
    t_meas = (time.perf_counter() - t0) / iters
    roof = analysis.from_compiled(compiled, n_chips=1, model_flops=0.0)
    n_cells = int(a[4].shape[0])
    return {
        "n_cells": n_cells,
        "n_slots": int(a[5].shape[1]),
        "hlo_flops": roof.flops_per_chip,
        "hlo_bytes": roof.hbm_bytes_per_chip,
        "t_measured_s": t_meas,
        "t_compute_bound_s": roof.t_compute,
        "t_memory_bound_s": roof.t_memory,
        "bound": roof.bottleneck,
        "achieved_gbytes_per_s": roof.hbm_bytes_per_chip / t_meas / 1e9
        if t_meas > 0 else 0.0,
        "achieved_gflops_per_s": roof.flops_per_chip / t_meas / 1e9
        if t_meas > 0 else 0.0,
        "cells_per_s": n_cells / t_meas if t_meas > 0 else 0.0,
    }


def fused_main(csv=print, smoke: bool = False):
    import repro.serving.layouts as layouts_mod
    import repro.serving.server as server_mod
    from repro.data.synthetic import Table3Params, generate_table3_db
    from repro.mining.driver import AcceleratedMiner
    from repro.serving.bank import compile_bank
    from repro.serving.server import PatternServer, encode_queries
    from repro.serving.trie import build_trie, pack_subtrees

    try:
        from .bench_streaming import atomic_write_json, machine_id
    except ImportError:
        from bench_streaming import atomic_write_json, machine_id

    if smoke:
        db_size, n_queries, n_rounds = 60, 128, 2
        sigma_div, out_path = 10, OUT_SMOKE
    else:
        db_size, n_queries, n_rounds = 150, 1000, 6
        sigma_div, out_path = 15, OUT
    params = Table3Params(db_size=db_size, v_avg=5, n_interstates=3)
    db = generate_table3_db(params, seed=0)
    sigma = max(2, len(db) // sigma_div)
    bank = compile_bank(AcceleratedMiner(db).mine_rs(sigma, max_len=4))
    trie = build_trie(bank)
    pack = pack_subtrees(trie)
    queries = generate_table3_db(
        Table3Params(db_size=n_queries, v_avg=5, n_interstates=3),
        seed=1,
    )
    mb = max(16, 1 << (n_queries - 1).bit_length())
    perlevel = PatternServer(bank, max_batch=mb, bank_layout="trie",
                             trie=trie, metrics_ns="serving.trie")
    fused = PatternServer(bank, max_batch=mb, bank_layout="trie_fused",
                          trie=trie, metrics_ns="serving.fused")
    flat = PatternServer(bank, max_batch=mb, metrics_ns="serving.flat")

    # --- exactness gate + dispatch counts (one query batch each) ---
    counts, restore = _count_dispatches(server_mod, [
        "fused_trie_walk", "trie_root_advance",
        "trie_level_advance_gather",
    ])
    try:
        rows = {}
        for name, srv in (("flat", flat), ("trie", perlevel),
                          ("fused", fused)):
            rows[name] = np.stack(
                [r.contained for r in srv.query(queries)])
    finally:
        restore()
    divergences = int((rows["fused"] != rows["trie"]).sum()
                      + (rows["fused"] != rows["flat"]).sum())
    if divergences:
        raise AssertionError(
            f"fused layout diverged on {divergences} cells - the "
            "megakernel's bit-identity contract is broken"
        )
    n_batches = -(-len(queries) // mb)
    dispatches_per_query = counts["fused_trie_walk"] / n_batches
    perlevel_dispatches = (
        counts["trie_root_advance"]
        + counts["trie_level_advance_gather"]
    ) / n_batches

    # --- timed regime: router-flush chunks with a shared encoding
    # per chunk (see module docstring) ---
    chunks = [queries[i:i + FLUSH_CHUNK]
              for i in range(0, len(queries), FLUSH_CHUNK)]
    encs = [encode_queries(c, n_label_keys=bank.n_label_keys)
            for c in chunks]
    perlevel_c = PatternServer(bank, max_batch=FLUSH_CHUNK,
                               bank_layout="trie", trie=trie)
    fused_c = PatternServer(bank, max_batch=FLUSH_CHUNK,
                            bank_layout="trie_fused", trie=trie)
    # warm both jit caches so the rounds time steady-state dispatches
    _timed_walk(perlevel_c, chunks, encs, layouts_mod)
    _timed_walk(fused_c, chunks, encs, layouts_mod)

    # --- interleaved cold walk rounds (min of two per side per round,
    # adjacent in time: this box swings 2x between windows) ---
    rounds = []
    for _ in range(n_rounds):
        t_pl = min(_timed_walk(perlevel_c, chunks, encs, layouts_mod),
                   _timed_walk(perlevel_c, chunks, encs, layouts_mod))
        t_f = min(_timed_walk(fused_c, chunks, encs, layouts_mod),
                  _timed_walk(fused_c, chunks, encs, layouts_mod))
        rounds.append({
            "perlevel_walk_s": t_pl,
            "fused_walk_s": t_f,
            "speedup_fused_vs_perlevel": t_pl / t_f,
        })
    sp = sorted(r["speedup_fused_vs_perlevel"] for r in rounds)
    roof = _fused_roofline(fused, queries)
    payload = {
        "machine": machine_id(),
        "bank_patterns": bank.n_patterns,
        "trie_nodes": trie.n_nodes,
        "trie_depth": trie.depth,
        "n_subtrees": pack.n_subtrees,
        "n_slots": pack.n_slots,
        "n_queries": len(queries),
        "n_batches": n_batches,
        "flush_chunk": FLUSH_CHUNK,
        "n_flushes": len(chunks),
        "divergences": divergences,
        "dispatches_per_query": dispatches_per_query,
        "perlevel_dispatches_per_query": perlevel_dispatches,
        "speedup_fused_vs_perlevel": sp[-1],
        "speedup_fused_vs_perlevel_median": sp[len(sp) // 2],
        "rounds": rounds,
        "roofline": roof or {},
        "metrics": {**fused.metrics.snapshot(),
                    **perlevel.metrics.snapshot()},
    }
    atomic_write_json(out_path, payload)
    csv(f"kernel/fused_walk,{rounds[-1]['fused_walk_s']*1e6:.0f},"
        f"x{sp[len(sp) // 2]:.2f}_vs_perlevel")
    csv(f"kernel/fused_dispatches,{dispatches_per_query:.0f},"
        f"perlevel={perlevel_dispatches:.0f}")
    if roof:
        csv(f"kernel/fused_roofline,{roof['t_measured_s']*1e6:.0f},"
            f"bound={roof['bound']}_"
            f"tmem={roof['t_memory_bound_s']*1e6:.1f}us")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fused-walk config writing "
                         "BENCH_kernel_smoke.json (the CI tier-2 "
                         "dispatch/divergence gate)")
    ap.add_argument("--micro", action="store_true",
                    help="also run the match/count micro rows")
    args = ap.parse_args()
    if args.micro:
        main()
    out = fused_main(smoke=args.smoke)
    print(f"# fused trie walk: x"
          f"{out['speedup_fused_vs_perlevel_median']:.2f} median vs "
          f"per-level ({out['perlevel_dispatches_per_query']:.0f} -> "
          f"{out['dispatches_per_query']:.0f} dispatches/query batch, "
          f"depth {out['trie_depth']})")
