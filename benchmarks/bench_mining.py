"""Mining benchmark: the wavefront scheduler vs per-pattern device
dispatch vs the pure-host reference miner.

The paper's headline claim is mining speed, and reverse search's
independent subtrees are exactly what makes cross-pattern batching
sound - so this bench measures what the wavefront actually buys: for a
grid of DB sizes x minsup, each miner's wall time, device dispatch
count, device seconds (split into async-launch vs blocked-execution
time - jax dispatch is async, so timing the call alone measures launch,
not work), and patterns/sec.

Emits ``BENCH_mining.json``: per-config rows plus the summary gates
``check_bench.py`` enforces - median wavefront-over-per-pattern speedup
(>= 3x) and median device-call reduction (>= 5x), with divergences
(any frequent-map mismatch between the three miners) required to be 0;
the bench raises before writing on any divergence.  ``--smoke`` is the
CI tier-5 gate: one tiny config, every miner cross-checked, written to
``BENCH_mining_smoke.json`` (atomically - a failing run never clobbers
the last good artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import statistics
import tempfile
import time

from repro.core.reverse_search import mine_gtrace_rs
from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.obs import trace

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "..", "BENCH_mining.json")
OUT_SMOKE = os.path.join(HERE, "..", "BENCH_mining_smoke.json")


def machine_id() -> str:
    return f"{platform.node()}/{os.cpu_count()}cpu/{platform.machine()}"


def atomic_write_json(path: str, payload: dict) -> None:
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def _run_device(db, sigma, max_len, dispatch, rounds=2):
    """Best-of-N timed runs (the box swings between measurement
    windows); a cold warmup pass outside the clock absorbs jit
    compiles.  Returns (result, wall, miner-of-best-run)."""
    AcceleratedMiner(db, dispatch=dispatch).mine_rs(sigma, max_len=max_len)
    best = None
    for _ in range(rounds):
        # per-dispatch registry namespace: the artifact's metrics block
        # keeps the two miners' counters apart
        m = AcceleratedMiner(db, dispatch=dispatch,
                             metrics_ns=f"mining.{dispatch}")
        t0 = time.perf_counter()
        res = m.mine_rs(sigma, max_len=max_len)
        wall = time.perf_counter() - t0
        if best is None or wall < best[1]:
            best = (res, wall, m)
    return best


def _merge_metrics(into, delta):
    for key, val in delta.items():
        into[key] = into.get(key, 0) + val


def main(csv=print, smoke: bool = False, trace_path=None):
    if trace_path:
        trace.clear()
        trace.enable()
    if smoke:
        grid = [(30, 4)]
        max_len, host_cap, rounds = 3, 10_000, 1
    else:
        # db_size x minsup: minsup scales with the DB so the pattern
        # population (and therefore the frontier width the wavefront
        # packs) stays in the regime the paper mines
        grid = [(60, 4), (120, 6), (240, 10)]
        max_len, host_cap, rounds = 4, 130, 2
    rows = []
    divergences = 0
    metrics_sum = {}
    for db_size, sigma in grid:
        params = Table3Params(db_size=db_size, v_avg=5, n_interstates=3)
        db = generate_table3_db(params, seed=0)

        wf_res, wf_wall, wf = _run_device(db, sigma, max_len, "wavefront",
                                          rounds=rounds)
        pp_res, pp_wall, pp = _run_device(db, sigma, max_len, "pattern",
                                          rounds=rounds)
        if wf_res.patterns != pp_res.patterns:
            divergences += 1
        host_wall = None
        if db_size <= host_cap:
            t0 = time.perf_counter()
            host = mine_gtrace_rs(db, sigma, max_len=max_len)
            host_wall = time.perf_counter() - t0
            if host.patterns != wf_res.patterns:
                divergences += 1
        if divergences:
            raise AssertionError(
                f"frequent-map divergence at db_size={db_size} "
                f"sigma={sigma} - wavefront/per-pattern/host miners "
                "must be bit-equal"
            )
        n_pat = len(wf_res.patterns)
        row = {
            "db_size": db_size,
            "minsup": sigma,
            "max_len": max_len,
            "patterns": n_pat,
            "wavefront_seconds": wf_wall,
            "pattern_seconds": pp_wall,
            "host_seconds": host_wall,
            "speedup_wavefront": pp_wall / wf_wall,
            "patterns_per_sec_wavefront": n_pat / wf_wall,
            "patterns_per_sec_pattern": n_pat / pp_wall,
            "n_device_calls_wavefront": wf.n_device_calls,
            "n_device_calls_pattern": pp.n_device_calls,
            "device_call_reduction":
                pp.n_device_calls / max(wf.n_device_calls, 1),
            "device_seconds_wavefront": wf.device_seconds,
            "device_seconds_pattern": pp.device_seconds,
            "dispatch_seconds_wavefront": wf.dispatch_seconds,
            "dispatch_seconds_pattern": pp.dispatch_seconds,
        }
        rows.append(row)
        _merge_metrics(metrics_sum, wf.metrics.snapshot())
        _merge_metrics(metrics_sum, pp.metrics.snapshot())
        csv(f"mining/db{db_size}_s{sigma},{wf_wall * 1e6:.0f},"
            f"x{row['speedup_wavefront']:.1f};"
            f"calls={wf.n_device_calls}vs{pp.n_device_calls};"
            f"rfts={n_pat}")

    payload = {
        "machine": machine_id(),
        "configs": rows,
        "divergences": divergences,
        "speedup_wavefront_median":
            statistics.median(r["speedup_wavefront"] for r in rows),
        "device_call_reduction_median":
            statistics.median(r["device_call_reduction"] for r in rows),
        "patterns_per_sec_best":
            max(r["patterns_per_sec_wavefront"] for r in rows),
        # summed best-run registry snapshots across the grid; keys are
        # namespaced mining.{wavefront,pattern}.* (check_bench gates on
        # the wavefront/per-pattern device-call ordering here)
        "metrics": metrics_sum,
    }
    if trace_path:
        trace.save(trace_path)
        trace.disable()
        csv(f"# trace saved to {trace_path} "
            f"({len(trace.tracer.events)} spans)")
    atomic_write_json(OUT_SMOKE if smoke else OUT, payload)
    csv(f"mining/speedup_median,0,"
        f"x{payload['speedup_wavefront_median']:.2f}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config; hard-fail on any frequent-map "
                         "divergence between the wavefront, per-pattern "
                         "and host miners (the CI tier-5 gate)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span trace of the run (Chrome JSON "
                         "for .json paths, JSONL otherwise); inspect "
                         "with scripts/trace_report.py")
    args = ap.parse_args()
    out = main(smoke=args.smoke, trace_path=args.trace)
    med = out["speedup_wavefront_median"]
    calls = out["device_call_reduction_median"]
    print(f"# wavefront x{med:.2f} median over per-pattern dispatch "
          f"(device calls cut x{calls:.1f} median), divergences="
          f"{out['divergences']}")
