"""Render the roofline table from the dry-run artifacts (results/dryrun).

One CSV row per (arch x shape x mesh) cell: the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        d = json.load(open(path))
        if not d.get("ok"):
            out.append({"name": f"roofline/{d['arch']}/{d['shape']}/"
                                f"{d.get('mesh','?')}",
                        "error": d.get("error", "?")[:60]})
            continue
        r = d["roofline"]
        out.append({
            "name": f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}",
            "t_compute": r["t_compute"],
            "t_memory": r["t_memory"],
            "t_collective": r["t_collective"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_flops_ratio"],
            "fraction": r["roofline_fraction"],
        })
    return out


def main(csv=print):
    n = 0
    for r in rows():
        if "error" in r:
            csv(f"{r['name']},nan,ERROR:{r['error']}")
            continue
        csv(
            f"{r['name']},{r['t_compute']*1e6:.1f},"
            f"t_mem_us={r['t_memory']*1e6:.1f};"
            f"t_coll_us={r['t_collective']*1e6:.1f};"
            f"bottleneck={r['bottleneck']};"
            f"useful={r['useful_ratio']:.4f};"
            f"frac={r['fraction']:.5f}"
        )
        n += 1
    if n == 0:
        csv("roofline/none,0,run `python -m repro.launch.dryrun` first")


if __name__ == "__main__":
    main()
