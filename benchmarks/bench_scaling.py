"""Mining-engine scaling microbench: device-scan throughput vs DB size
(the |DB|-proportional scaling of Table 4's first block) and embedding
batch size, measured on the real device path."""
from __future__ import annotations

import time

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner


def main(csv=print):
    for n in (100, 200, 400):
        db = generate_table3_db(
            Table3Params(db_size=n, v_avg=5, n_interstates=4), seed=3
        )
        miner = AcceleratedMiner(db)
        sigma = max(2, n // 10)
        t0 = time.perf_counter()
        res = miner.mine_rs(sigma, max_len=4)
        dt = time.perf_counter() - t0
        csv(
            f"scaling/db_{n},{dt*1e6:.0f},"
            f"rfts={len(res.patterns)};scans={res.n_extension_scans};"
            f"device_s={miner.device_seconds:.3f}"
        )


if __name__ == "__main__":
    main()
