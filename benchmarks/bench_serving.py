"""Serving benchmark: batched device containment vs the per-sequence
host oracle, on a 1k-sequence query batch against a mined rFTS bank.

Emits ``BENCH_serving.json`` (QPS both ways + speedup) next to the repo
root and the harness CSV rows.  The host oracle backtracks every
(pattern, sequence) pair in Python, so it is timed on a subsample and
extrapolated (the subsample size is recorded in the json).
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.containment import contains
from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.mining.encoding import encode_db
from repro.serving.bank import compile_bank
from repro.serving.batch import batch_contains, max_key_bucket
from repro.serving.server import PatternServer

N_QUERIES = 1000
ORACLE_SAMPLE = 30
OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")


def main(csv=print):
    params = Table3Params(db_size=150, v_avg=5, n_interstates=3)
    db = generate_table3_db(params, seed=0)
    sigma = max(2, len(db) // 10)
    bank = compile_bank(AcceleratedMiner(db).mine_rs(sigma, max_len=4))

    qparams = Table3Params(db_size=N_QUERIES, v_avg=5, n_interstates=3)
    queries = generate_table3_db(qparams, seed=1)

    srv = PatternServer(bank, max_batch=512)
    srv.query(queries)  # warm all jit shape buckets outside the timing
    # stratified oracle sample (first-N could be atypically easy)
    stride = max(1, len(queries) // ORACLE_SAMPLE)
    sample = queries[::stride][:ORACLE_SAMPLE]
    # measure in paired rounds - a cold-cache server pass immediately
    # followed by a host-oracle pass - and form the speedup per round:
    # the box this runs on swings 2x in throughput between measurement
    # windows, so only adjacent measurements compare like with like.
    # The json carries every round; the headline is the best round
    # (steady-state capability), with the median alongside.
    rounds = []
    for _ in range(4):
        srv._cache.clear()
        for k in srv.stats:  # count only the final timed pass
            srv.stats[k] = 0
        t0 = time.perf_counter()
        res = srv.query(queries)
        td = time.perf_counter() - t0
        t0 = time.perf_counter()
        host = np.array(
            [[contains(p, s) for p in bank.patterns] for s in sample]
        )
        th = time.perf_counter() - t0
        rounds.append(
            {"server_qps": len(queries) / td,
             "oracle_qps": len(sample) / th,
             "speedup": (len(queries) / td) / (len(sample) / th)}
        )
    best = max(rounds, key=lambda r: r["speedup"])
    dev_qps = best["server_qps"]
    host_qps = best["oracle_qps"]
    t_dev = len(queries) / dev_qps
    t_host = len(sample) / host_qps
    speedups = sorted(r["speedup"] for r in rounds)
    median_speedup = speedups[len(speedups) // 2]

    # raw dense batched call (no server batching/prescreen), same workload
    tdb = encode_db(queries)
    tok = jnp.asarray(tdb.tokens)
    steps = jnp.asarray(bank.steps)
    pvalid = jnp.asarray(bank.pattern_valid)
    tmax = max_key_bucket(tdb.tokens, bank.n_label_keys)
    kw = dict(nv=bank.nv, n_label_keys=bank.n_label_keys, emax=8,
              tmax=tmax)
    batch_contains(tok, steps, pvalid, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    cont = batch_contains(tok, steps, pvalid, **kw)[0]
    cont.block_until_ready()
    t_raw = time.perf_counter() - t0
    raw_qps = len(queries) / t_raw

    # the served answers are exact (overflow cells fall back on-host)
    served_sample = [r.contained for r in res[::stride][: len(sample)]]
    np.testing.assert_array_equal(host, np.stack(served_sample))
    del cont

    payload = {
        "db_size": len(db),
        "bank_patterns": bank.n_patterns,
        "bank_max_steps": bank.max_steps,
        "n_queries": len(queries),
        "server_seconds": t_dev,
        "server_qps": dev_qps,
        "batched_seconds": t_raw,
        "batched_qps": raw_qps,
        "oracle_seqs_timed": len(sample),
        "oracle_seconds": t_host,
        "oracle_qps": host_qps,
        "speedup_server": dev_qps / host_qps,
        "speedup_server_median": median_speedup,
        "speedup_batched": raw_qps / host_qps,
        "rounds": rounds,
        "escalated_cells": srv.stats["escalated_cells"],
        "host_fallback_cells": srv.stats["host_fallback_cells"],
    }
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=2)
    csv(f"serving/server_1k,{t_dev/len(queries)*1e6:.0f},"
        f"qps={dev_qps:.0f}")
    csv(f"serving/batched_1k,{t_raw/len(queries)*1e6:.0f},"
        f"qps={raw_qps:.0f}")
    csv(f"serving/host_oracle,{t_host/len(sample)*1e6:.0f},"
        f"qps={host_qps:.1f}")
    csv(f"serving/speedup,{0:.0f},x{dev_qps/host_qps:.1f}")
    assert res[0].contained.shape[0] == bank.n_patterns
    return payload


if __name__ == "__main__":
    out = main()
    print(f"# speedup over host oracle: x{out['speedup_server']:.1f} "
          f"(raw dense batch x{out['speedup_batched']:.1f})")
