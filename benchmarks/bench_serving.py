"""Serving benchmark: batched device containment vs the per-sequence
host oracle, across all three bank layouts (flat / per-level trie /
fused trie megakernel), on a query batch against a mined rFTS bank.

Emits ``BENCH_serving.json`` (QPS for the flat, trie and fused servers
and the host oracle; joined-steps counts and layout speedups) next
to the repo root plus the harness CSV rows.  The host oracle backtracks
every (pattern, sequence) pair in Python, so it is timed on a subsample
and extrapolated (the subsample size is recorded in the json).

``--smoke`` is the CI tier-2 gate: a tiny config, ALL THREE layouts
over the same queries, and a hard failure on any pairwise containment
row mismatch (results are written to ``BENCH_serving_smoke.json`` so
the full-run json is never clobbered by a smoke pass).  All json writes
go through a tempfile + rename, so a failing or interrupted run never
truncates the last good artifact (scripts/check_bench.py compares
against it).  The fused kernel's dispatch-count and walk-level speedup
gates live in ``bench_kernel.py`` / ``BENCH_kernel.json``.
"""
from __future__ import annotations

import argparse
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.containment import contains
from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.mining.encoding import encode_db
from repro.obs import FlightRecorder, trace
from repro.serving.bank import compile_bank, sequence_fingerprint
from repro.serving.batch import batch_contains, max_key_bucket
from repro.serving.server import PatternServer
from repro.serving.trie import build_trie, parent_prefix_hits

try:
    from .bench_streaming import atomic_write_json, machine_id
except ImportError:  # standalone `python benchmarks/bench_serving.py`
    from bench_streaming import atomic_write_json, machine_id

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")
OUT_SMOKE = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serving_smoke.json"
)


def _timed_pass(srv, queries):
    srv._cache.clear()
    sequence_fingerprint.cache_clear()  # truly cold: re-canonicalize
    # count only the final timed pass - through the registry's one
    # sanctioned reset (each layout server owns a private registry, so
    # a full reset scopes to exactly this server's namespace; the old
    # stats[k] = 0 assignment idiom broke Counter monotonicity and
    # missed the latency histograms)
    srv.metrics.reset()
    t0 = time.perf_counter()
    res = srv.query(queries)
    return res, time.perf_counter() - t0


def main(csv=print, smoke: bool = False, trace_path=None):
    if trace_path:
        trace.clear()
        trace.enable()
    if smoke:
        db_size, n_queries, oracle_sample, n_rounds = 60, 128, 8, 2
        sigma_div, out_path = 10, OUT_SMOKE
    else:
        # sigma = |DB|/15 mines a ~150-pattern bank: comfortably past
        # the regime where prefix sharing pays (the trie's win grows
        # with bank size; tiny banks are flat's territory, see trie.py)
        db_size, n_queries, oracle_sample, n_rounds = 150, 1000, 30, 6
        sigma_div, out_path = 15, OUT
    params = Table3Params(db_size=db_size, v_avg=5, n_interstates=3)
    db = generate_table3_db(params, seed=0)
    sigma = max(2, len(db) // sigma_div)
    result = AcceleratedMiner(db).mine_rs(sigma, max_len=4)
    bank = compile_bank(result)
    trie = build_trie(bank)

    qparams = Table3Params(db_size=n_queries, v_avg=5, n_interstates=3)
    queries = generate_table3_db(qparams, seed=1)

    # per-layout registry namespaces keep the artifact's metrics block
    # counters apart (each server owns a private registry)
    flat_srv = PatternServer(bank, max_batch=1024,
                             metrics_ns="serving.flat")
    trie_srv = PatternServer(bank, max_batch=1024, bank_layout="trie",
                             trie=trie, metrics_ns="serving.trie")
    fused_srv = PatternServer(bank, max_batch=1024,
                              bank_layout="trie_fused", trie=trie,
                              metrics_ns="serving.fused")
    # warm all jit shape buckets outside the timing, and gate on the
    # layouts agreeing on every (query, pattern) cell - all three are
    # exact, so any mismatch is a bug (this is the CI tier-2 smoke
    # check)
    flat_rows = np.stack([r.contained for r in flat_srv.query(queries)])
    trie_rows = np.stack([r.contained for r in trie_srv.query(queries)])
    fused_rows = np.stack(
        [r.contained for r in fused_srv.query(queries)])
    for name, rows in (("trie", trie_rows), ("trie_fused", fused_rows)):
        if not np.array_equal(flat_rows, rows):
            bad = int((flat_rows != rows).sum())
            raise AssertionError(
                f"flat/{name} mismatch on {bad} cells of "
                f"{flat_rows.size} - exactness contract broken"
            )

    # stratified oracle sample (first-N could be atypically easy)
    stride = max(1, len(queries) // oracle_sample)
    sample = queries[::stride][:oracle_sample]
    # measure in paired rounds - interleaved cold-cache flat/trie/flat/
    # trie passes (per-layout minimum, so a transient slowdown landing
    # mid-round cannot bias one side), then a host-oracle pass - and
    # form speedups per round: the box this runs on swings 2x in
    # throughput between measurement windows, so only adjacent
    # measurements compare like with like.  The json carries every
    # round; headlines are the best round (steady-state capability),
    # with the median alongside.
    rounds = []
    for _ in range(n_rounds):
        res, td_flat = _timed_pass(flat_srv, queries)
        _, td_trie = _timed_pass(trie_srv, queries)
        _, td_fused = _timed_pass(fused_srv, queries)
        _, td_flat2 = _timed_pass(flat_srv, queries)
        _, td_trie2 = _timed_pass(trie_srv, queries)
        _, td_fused2 = _timed_pass(fused_srv, queries)
        td_flat = min(td_flat, td_flat2)
        td_trie = min(td_trie, td_trie2)
        td_fused = min(td_fused, td_fused2)
        t0 = time.perf_counter()
        host = np.array(
            [[contains(p, s) for p in bank.patterns] for s in sample]
        )
        th = time.perf_counter() - t0
        rounds.append({
            "server_qps": len(queries) / td_flat,
            "trie_qps": len(queries) / td_trie,
            "fused_qps": len(queries) / td_fused,
            "oracle_qps": len(sample) / th,
            "speedup": (len(queries) / td_flat) / (len(sample) / th),
            "speedup_trie_vs_flat": td_flat / td_trie,
            "speedup_fused_vs_trie": td_trie / td_fused,
        })
    best = max(rounds, key=lambda r: r["speedup"])
    dev_qps = best["server_qps"]
    host_qps = best["oracle_qps"]
    t_dev = len(queries) / dev_qps
    t_host = len(sample) / host_qps
    best_trie = max(rounds, key=lambda r: r["speedup_trie_vs_flat"])
    best_fused = max(rounds, key=lambda r: r["speedup_fused_vs_trie"])
    tvf = sorted(r["speedup_trie_vs_flat"] for r in rounds)
    fvt = sorted(r["speedup_fused_vs_trie"] for r in rounds)
    speedups = sorted(r["speedup"] for r in rounds)
    median_speedup = speedups[len(speedups) // 2]

    # raw dense batched call (no server batching/prescreen), same workload
    tdb = encode_db(queries)
    tok = jnp.asarray(tdb.tokens)
    steps = jnp.asarray(bank.steps)
    pvalid = jnp.asarray(bank.pattern_valid)
    tmax = max_key_bucket(tdb.tokens, bank.n_label_keys)
    kw = dict(nv=bank.nv, n_label_keys=bank.n_label_keys, emax=8,
              tmax=tmax)
    batch_contains(tok, steps, pvalid, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    cont = batch_contains(tok, steps, pvalid, **kw)[0]
    cont.block_until_ready()
    t_raw = time.perf_counter() - t0
    raw_qps = len(queries) / t_raw

    # the served answers are exact (overflow cells fall back on-host)
    served_sample = [r.contained for r in res[::stride][: len(sample)]]
    np.testing.assert_array_equal(host, np.stack(served_sample))
    del cont

    # telemetry overhead: the always-on budget.  Interleaved cold
    # passes on the flat server, tracing disabled vs 10% sampled mode
    # (with a flight recorder attached, the full production wiring),
    # best-of each; results must stay bit-identical and check_bench
    # gates the sampled-mode overhead <= 5%.
    sample_rate = 0.1
    was_full = trace.enabled()
    t_off = t_on = float("inf")
    for _ in range(2 if smoke else 3):
        trace.disable()
        r_off, td = _timed_pass(flat_srv, queries)
        t_off = min(t_off, td)
        flight = FlightRecorder(capacity=32, metrics=flat_srv.metrics,
                                metrics_prefix="serving.flat")
        trace.enable_sampling(sample_rate, metrics=flat_srv.metrics,
                              flight=flight)
        r_on, td = _timed_pass(flat_srv, queries)
        t_on = min(t_on, td)
        trace.disable()
        off_rows = np.stack([r.contained for r in r_off])
        on_rows = np.stack([r.contained for r in r_on])
        if not np.array_equal(off_rows, on_rows):
            raise AssertionError(
                "sampled telemetry changed containment results")
    if was_full:
        trace.enable()  # restore the --trace run's full tracing
    telemetry_overhead = max(0.0, t_on / t_off - 1.0)

    payload = {
        "machine": machine_id(),
        "db_size": len(db),
        "bank_patterns": bank.n_patterns,
        "bank_max_steps": bank.max_steps,
        "bank_total_steps": int(bank.n_steps[: bank.n_patterns].sum()),
        "trie_nodes": trie.n_nodes,
        "trie_depth": trie.depth,
        "trie_sharing_ratio": trie.sharing_ratio,
        "parent_prefix_hits": parent_prefix_hits(bank),
        "n_queries": len(queries),
        "server_seconds": t_dev,
        "server_qps": dev_qps,
        "trie_qps": best_trie["trie_qps"],
        "fused_qps": best_fused["fused_qps"],
        "batched_seconds": t_raw,
        "batched_qps": raw_qps,
        "oracle_seqs_timed": len(sample),
        "oracle_seconds": t_host,
        "oracle_qps": host_qps,
        "speedup_server": dev_qps / host_qps,
        "speedup_server_median": median_speedup,
        "speedup_trie_vs_flat": best_trie["speedup_trie_vs_flat"],
        "speedup_trie_vs_flat_median": tvf[len(tvf) // 2],
        "speedup_fused_vs_trie": best_fused["speedup_fused_vs_trie"],
        "speedup_fused_vs_trie_median": fvt[len(fvt) // 2],
        "speedup_batched": raw_qps / host_qps,
        # per-cold-pass join work: the trie advances one frontier per
        # surviving (sequence, trie node), the flat layout one per
        # surviving (sequence, pattern) program step, the fused layout
        # one per padded subtree slot of each surviving root cell
        "joined_steps_flat": flat_srv.stats["joined_steps"],
        "joined_steps_trie": trie_srv.stats["joined_steps"],
        "joined_steps_fused": fused_srv.stats["joined_steps"],
        "rounds": rounds,
        "escalated_cells": trie_srv.stats["escalated_cells"],
        "host_fallback_cells": trie_srv.stats["host_fallback_cells"],
        # always-on budget: sampled-mode wall overhead vs telemetry
        # off, best-of passes (clamped at 0 - noise can make the
        # sampled pass the faster one); check_bench gates <= 0.05
        "telemetry_overhead": telemetry_overhead,
        "telemetry_sample_rate": sample_rate,
        # final-timed-pass registry snapshots of the layout servers
        # (disjoint serving.{flat,trie,fused}.* namespaces)
        "metrics": {**flat_srv.metrics.snapshot(),
                    **trie_srv.metrics.snapshot(),
                    **fused_srv.metrics.snapshot()},
    }
    if trace_path:
        trace.save(trace_path)
        trace.disable()
        csv(f"# trace saved to {trace_path} "
            f"({len(trace.tracer.events)} spans)")
    # tempfile + rename: a mismatch-failure above or a crash mid-run
    # must never clobber the last good artifact CI baselines against
    atomic_write_json(out_path, payload)
    csv(f"serving/server_1k,{t_dev/len(queries)*1e6:.0f},"
        f"qps={dev_qps:.0f}")
    csv(f"serving/trie_1k,"
        f"{1e6/max(best_trie['trie_qps'], 1e-9):.0f},"
        f"qps={best_trie['trie_qps']:.0f}")
    csv(f"serving/batched_1k,{t_raw/len(queries)*1e6:.0f},"
        f"qps={raw_qps:.0f}")
    csv(f"serving/host_oracle,{t_host/len(sample)*1e6:.0f},"
        f"qps={host_qps:.1f}")
    csv(f"serving/speedup,{0:.0f},x{dev_qps/host_qps:.1f}")
    csv(f"serving/trie_vs_flat,{0:.0f},"
        f"x{best_trie['speedup_trie_vs_flat']:.2f}")
    csv(f"serving/fused_vs_trie,{0:.0f},"
        f"x{best_fused['speedup_fused_vs_trie']:.2f}")
    csv(f"serving/joined_steps,"
        f"{payload['joined_steps_trie']},"
        f"flat={payload['joined_steps_flat']}")
    csv(f"serving/telemetry_overhead,{0:.0f},"
        f"{100.0 * telemetry_overhead:.2f}%@{sample_rate:.0%}")
    assert res[0].contained.shape[0] == bank.n_patterns
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config; hard-fails on flat/trie mismatch"
                         " (the CI tier-2 gate)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span trace of the run (Chrome JSON "
                         "for .json paths, JSONL otherwise); inspect "
                         "with scripts/trace_report.py")
    args = ap.parse_args()
    out = main(smoke=args.smoke, trace_path=args.trace)
    print(f"# speedup over host oracle: x{out['speedup_server']:.1f} "
          f"(raw dense batch x{out['speedup_batched']:.1f}); "
          f"trie vs flat x{out['speedup_trie_vs_flat']:.2f} "
          f"(joined steps {out['joined_steps_flat']} -> "
          f"{out['joined_steps_trie']}, "
          f"sharing x{out['trie_sharing_ratio']:.2f})")
