"""Streaming benchmark: incremental sliding-window support maintenance
(StreamingBank.observe + periodic refresh) vs the re-mine-per-window
baseline, on a synthetic arrival stream against a mined rFTS bank.

Emits ``BENCH_streaming.json``: streamed updates/sec for both bank
layouts (observe cost + amortized incremental refreshes + the final
reconciling refresh), the extrapolated re-mine-per-window updates/sec
(one full ``mine_rs`` of the window per arrival batch - what keeping
supports fresh costs without the incremental path), and the frontier
work accounting (scans run vs clean subtrees pruned).

Exactness is asserted, not sampled: after the final refresh the
streamed frequent map must be *bit-equal* to a batch re-mine of the
final window, for both layouts.  ``--smoke`` is the CI tier-3 gate: a
tiny config that additionally re-mines at every refresh point and
hard-fails on any divergence, writing ``BENCH_streaming_smoke.json``
(atomically - a failing run never clobbers the last good artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import tempfile
import time

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner
from repro.obs import trace
from repro.serving.streaming import StreamingBank

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "..", "BENCH_streaming.json")
OUT_SMOKE = os.path.join(HERE, "..", "BENCH_streaming_smoke.json")


def machine_id() -> str:
    """Coarse identity of the box a benchmark ran on.  check_bench.py
    only *gates* on throughput regressions between runs of the same
    machine (absolute qps is meaningless across hardware); cross-machine
    comparisons are advisory."""
    return f"{platform.node()}/{os.cpu_count()}cpu/{platform.machine()}"


def atomic_write_json(path: str, payload: dict) -> None:
    """Write via tempfile + rename so a crashed / failed run never
    truncates or clobbers the previously committed artifact."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def _remine(seqs, sigma, max_len):
    return AcceleratedMiner(seqs).mine_rs(sigma, max_len=max_len).patterns


def _stream_once(db, batches, *, layout, window, sigma, max_len,
                 refresh_every, check_every_refresh):
    """Run the full streamed phase; returns timings + the bank.

    The exactness checks (streamed frequent map vs batch re-mine of the
    same window) collect their window snapshots inside the loop but
    re-mine *after* the clock stops, so verification never inflates the
    streamed timings that CI regressions are judged on."""
    t0 = time.perf_counter()
    sb = StreamingBank.from_db(
        db, minsup=sigma, window=window, max_len=max_len,
        bank_layout=layout, refresh_every=0,
    )
    t_seed = time.perf_counter() - t0
    checks = []
    t_observe = 0.0
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        t1 = time.perf_counter()
        sb.observe(batch)
        t_observe += time.perf_counter() - t1
        if (i + 1) % refresh_every == 0:
            got = sb.refresh()
            if check_every_refresh:
                checks.append((i, got, list(sb.window_seqs)))
    got = sb.refresh()
    t_stream = time.perf_counter() - t0
    checks.append(("final", got, list(sb.window_seqs)))
    for tag, got, win in checks:  # the hard exactness gate
        want = dict(_remine(win, sigma, max_len))
        if got != want:
            raise AssertionError(
                f"[{layout}] streamed supports != batch re-mine at "
                f"{tag}: {len(got)} vs {len(want)} patterns - "
                "exactness contract broken"
            )
    return t_seed, t_stream, t_observe, sb


def main(csv=print, smoke: bool = False, trace_path=None):
    if trace_path:
        trace.clear()
        trace.enable()
    if smoke:
        window, n_batches, batch_size, max_len = 40, 4, 8, 3
        refresh_every, n_base, out_path = 2, 2, OUT_SMOKE
    else:
        # refresh cadence is the freshness knob for *discovery* only:
        # maintained supports of active patterns are exact after every
        # observe, so the stream refreshes roughly once per window
        # turnover while the baseline must re-mine every batch to get
        # any fresh support at all.  (At this arrival rate nearly every
        # pattern is touched between refreshes, so each refresh costs
        # about one full re-mine - the clean-subtree pruning regime of
        # low-churn streams is exercised by the tests instead.)
        window, n_batches, batch_size, max_len = 100, 24, 10, 4
        refresh_every, n_base, out_path = 12, 3, OUT
    # one population for window + stream: the seed window and the
    # arrivals share the planted interstate patterns, so churn comes
    # from sampling noise at the minsup boundary (the realistic
    # streaming regime), not from two unrelated patterns sets
    params = Table3Params(
        db_size=window + n_batches * batch_size, v_avg=5,
        n_interstates=3,
    )
    all_seqs = generate_table3_db(params, seed=0)
    db, stream = all_seqs[:window], all_seqs[window:]
    sigma = max(2, window // 15)
    batches = [stream[i * batch_size: (i + 1) * batch_size]
               for i in range(n_batches)]
    n_updates = len(stream)

    results = {}
    metrics_sum = {}
    for layout in ("flat", "trie"):
        # cold pass warms every jit shape bucket; the second pass is
        # the timed, steady-state one (same stream, fresh state)
        _stream_once(db, batches, layout=layout, window=window,
                     sigma=sigma, max_len=max_len,
                     refresh_every=refresh_every,
                     check_every_refresh=smoke)
        t_seed, t_stream, t_observe, sb = _stream_once(
            db, batches, layout=layout, window=window, sigma=sigma,
            max_len=max_len, refresh_every=refresh_every,
            check_every_refresh=smoke,
        )
        results[layout] = {
            "seed_seconds": t_seed,
            "stream_seconds": t_stream,
            "observe_seconds": t_observe,
            "updates_per_sec": n_updates / t_stream,
            "observe_updates_per_sec": n_updates / t_observe,
            "stats": dict(sb.stats),
            "bank_patterns": sb.bank.n_patterns,
        }
        # summed timed-run registry snapshots across the layouts
        for key, val in sb.metrics.snapshot().items():
            metrics_sum[key] = metrics_sum.get(key, 0) + val

    # baseline: a full re-mine of the window after every batch (what
    # exact supports cost without incremental maintenance); timed on
    # the first n_base batches and extrapolated.  Two rounds - the box
    # swings ~2x between measurement windows - and the *faster* round
    # is used, which can only understate the streaming speedup.
    round_times = []
    for _ in range(2):
        win = list(db)
        t_remine = 0.0
        for batch in batches[:n_base]:
            win = (win + list(batch))[-window:]
            t0 = time.perf_counter()
            _remine(win, sigma, max_len)
            t_remine += time.perf_counter() - t0
        round_times.append(t_remine / n_base)
    remine_per_batch = min(round_times)
    remine_updates_per_sec = batch_size / remine_per_batch

    flat = results["flat"]
    trie = results["trie"]
    speedup = flat["updates_per_sec"] / remine_updates_per_sec
    st = flat["stats"]
    payload = {
        "machine": machine_id(),
        "window": window,
        "minsup": sigma,
        "max_len": max_len,
        "n_batches": n_batches,
        "batch_size": batch_size,
        "n_updates": n_updates,
        "refresh_every": refresh_every,
        "bank_patterns": flat["bank_patterns"],
        "streamed_updates_per_sec": flat["updates_per_sec"],
        "streamed_updates_per_sec_trie": trie["updates_per_sec"],
        "observe_updates_per_sec": flat["observe_updates_per_sec"],
        "observe_updates_per_sec_trie":
            trie["observe_updates_per_sec"],
        "remine_batches_timed": n_base,
        "remine_seconds_per_window": remine_per_batch,
        "remine_updates_per_sec": remine_updates_per_sec,
        "speedup_streaming": speedup,
        "speedup_streaming_trie":
            trie["updates_per_sec"] / remine_updates_per_sec,
        "refreshes": st["refreshes"],
        "frontier_scans": st["frontier_scans"],
        "frontier_scans_skipped": st["frontier_scans_skipped"],
        "frontier_retained": st["frontier_retained"],
        "tombstoned": st["tombstoned"],
        "recovered": st["recovered"],
        "added": st["added"],
        "layouts": results,
        "metrics": metrics_sum,
    }
    if trace_path:
        trace.save(trace_path)
        trace.disable()
        csv(f"# trace saved to {trace_path} "
            f"({len(trace.tracer.events)} spans)")
    atomic_write_json(out_path, payload)
    csv(f"streaming/observe_flat,{1e6 / flat['updates_per_sec']:.0f},"
        f"ups={flat['updates_per_sec']:.0f}")
    csv(f"streaming/observe_trie,{1e6 / trie['updates_per_sec']:.0f},"
        f"ups={trie['updates_per_sec']:.0f}")
    csv(f"streaming/remine_window,{remine_per_batch * 1e6:.0f},"
        f"ups={remine_updates_per_sec:.2f}")
    csv(f"streaming/speedup,0,x{speedup:.1f}")
    csv(f"streaming/frontier,{st['frontier_scans']},"
        f"skipped={st['frontier_scans_skipped']}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config; re-mine at every refresh point "
                         "and hard-fail on any support divergence (the "
                         "CI tier-3 gate)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span trace of the run (Chrome JSON "
                         "for .json paths, JSONL otherwise); inspect "
                         "with scripts/trace_report.py")
    args = ap.parse_args()
    out = main(smoke=args.smoke, trace_path=args.trace)
    print(f"# streamed maintenance x{out['speedup_streaming']:.1f} over "
          f"re-mine-per-window (flat "
          f"{out['streamed_updates_per_sec']:.0f} ups, trie "
          f"{out['streamed_updates_per_sec_trie']:.0f} ups, re-mine "
          f"{out['remine_updates_per_sec']:.2f} ups); frontier scans "
          f"{out['frontier_scans']} (+{out['frontier_scans_skipped']} "
          f"subtrees pruned clean)")
