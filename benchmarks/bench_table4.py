"""Table 4 analog: artificial datasets, GTRACE-RS vs original GTRACE.

Scaled to CPU single-core budgets (|DB| in the hundreds, not thousands);
the sweep structure mirrors the paper exactly: |DB|, |V_avg|, p_i, |L_e|,
sigma'.  Reported: computation time and #rFTSs for the proposed method
(PM), time and #FTSs for GTRACE (GT), plus the enumeration ratio - the
paper's core claim is PM enumerates only the relevant patterns.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner

MAX_LEN = 4


def _run(db, sigma) -> Dict[str, float]:
    miner = AcceleratedMiner(db)
    t0 = time.perf_counter()
    rs = miner.mine_rs(sigma, max_len=MAX_LEN)
    t_rs = time.perf_counter() - t0
    t0 = time.perf_counter()
    gt = miner.mine_gtrace(sigma, max_len=MAX_LEN)
    t_gt = time.perf_counter() - t0
    rel = gt.relevant()
    assert rel == rs.patterns, "correctness check failed"
    return {
        "pm_time_s": t_rs,
        "gt_time_s": t_gt,
        "n_rfts": len(rs.patterns),
        "n_fts": len(gt.patterns),
        "speedup": t_gt / max(t_rs, 1e-9),
        "fts_per_rfts": len(gt.patterns) / max(len(rs.patterns), 1),
    }


def rows() -> List[dict]:
    out = []
    base = dict(db_size=120, v_avg=5, n_interstates=4)

    def cell(tag, sigma_frac=0.1, **kw):
        p = Table3Params(**{**base, **kw})
        db = generate_table3_db(p, seed=0)
        sigma = max(2, int(sigma_frac * len(db)))
        r = _run(db, sigma)
        r["name"] = f"table4/{tag}"
        out.append(r)

    for n in (60, 120, 240):
        cell(f"db_{n}", db_size=n)
    for v in (4, 5, 6):
        cell(f"vavg_{v}", v_avg=v)
    for pi in (0.6, 0.8, 1.0):
        cell(f"pi_{int(pi*100)}", p_i=pi, p_d=min(0.1, 1 - pi))
    for le in (1, 3, 5):
        cell(f"le_{le}", n_elabels=le)
    for sf in (0.08, 0.1, 0.15):
        cell(f"sigma_{sf}", sigma_frac=sf)
    return out


def main(csv=print):
    for r in rows():
        csv(
            f"{r['name']},{r['pm_time_s']*1e6:.0f},"
            f"gt_us={r['gt_time_s']*1e6:.0f};rfts={r['n_rfts']};"
            f"fts={r['n_fts']};speedup={r['speedup']:.2f};"
            f"fts_per_rfts={r['fts_per_rfts']:.2f}"
        )


if __name__ == "__main__":
    main()
