"""Table 5 analog: Enron-like weekly communication graph sequences.

Sweeps the paper's three axes - #persons |V|, minimum support sigma', and
#interstates n - on the synthetic Enron-style generator.
"""
from __future__ import annotations

import time
from typing import List

from repro.data.synthetic import generate_enron_like_db
from repro.mining.driver import AcceleratedMiner

MAX_LEN = 4


def _run(db, sigma):
    miner = AcceleratedMiner(db)
    t0 = time.perf_counter()
    rs = miner.mine_rs(sigma, max_len=MAX_LEN)
    t_rs = time.perf_counter() - t0
    t0 = time.perf_counter()
    gt = miner.mine_gtrace(sigma, max_len=MAX_LEN)
    t_gt = time.perf_counter() - t0
    assert gt.relevant() == rs.patterns
    return t_rs, t_gt, len(rs.patterns), len(gt.patterns)


def rows() -> List[dict]:
    out = []

    def cell(tag, n_weeks=30, n_persons=12, n_interstates=4,
             sigma_frac=0.35):
        db = generate_enron_like_db(
            n_weeks=n_weeks, n_persons=n_persons,
            n_interstates=n_interstates, seed=1,
        )
        sigma = max(2, int(sigma_frac * len(db)))
        t_rs, t_gt, n_rfts, n_fts = _run(db, sigma)
        out.append({
            "name": f"table5/{tag}", "pm_time_s": t_rs, "gt_time_s": t_gt,
            "n_rfts": n_rfts, "n_fts": n_fts,
        })

    for v in (8, 12, 16):
        cell(f"persons_{v}", n_persons=v)
    for sf in (0.3, 0.35, 0.45):
        cell(f"sigma_{sf}", sigma_frac=sf)
    for n in (3, 4, 5):
        cell(f"interstates_{n}", n_interstates=n)
    return out


def main(csv=print):
    for r in rows():
        csv(
            f"{r['name']},{r['pm_time_s']*1e6:.0f},"
            f"gt_us={r['gt_time_s']*1e6:.0f};rfts={r['n_rfts']};"
            f"fts={r['n_fts']}"
        )


if __name__ == "__main__":
    main()
