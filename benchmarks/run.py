"""Benchmark harness: one module per paper table + system microbenches.

Prints ``name,us_per_call,derived`` CSV rows (one per cell).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import bench_kernel, bench_roofline, bench_scaling
    from . import bench_serving, bench_table4, bench_table5

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod, tag in (
        (bench_table4, "table4 (PM vs GT, artificial data)"),
        (bench_table5, "table5 (PM vs GT, Enron-like data)"),
        (bench_scaling, "mining scaling"),
        (bench_kernel, "match kernel micro"),
        (bench_serving, "pattern serving vs host oracle"),
        (bench_roofline, "roofline table from dry-run"),
    ):
        print(f"# --- {tag} ---", file=sys.stderr)
        try:
            mod.main()
        except Exception as e:  # keep the harness robust
            print(f"{mod.__name__},nan,ERROR:{e}")
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
