"""Distributed mining on 8 virtual devices: DB sharded over a (4 data x 2
model) mesh, one extension scan via the shard_map step, verified against
the exact host path; then a checkpoint/kill/resume cycle of the full
miner (the fault-tolerance drill a real cluster job runs).

    PYTHONPATH=src python examples/distributed_mining.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import random  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.compile import compile_sequence  # noqa: E402
from repro.data.synthetic import random_graph_sequence  # noqa: E402
from repro.mining.distributed import make_mining_step  # noqa: E402
from repro.mining.driver import AcceleratedMiner  # noqa: E402
from repro.mining.encoding import (  # noqa: E402
    encode_db,
    encode_embeddings,
    encode_pattern_trs,
)
from repro.mining.engine import (  # noqa: E402
    MODE_ROOT,
    aggregate_host,
    match_signatures,
)


def main():
    rng = random.Random(0)
    db = [compile_sequence(random_graph_sequence(rng, n_steps=5, n_v=5))
          for _ in range(16)]

    # ---- one sharded extension scan vs the exact single-device path
    tdb = encode_db(db, pad_to=64)
    embs = [(g, (), ()) for g in range(len(db))]
    gid, phi, psi = encode_embeddings(embs, 8, 8)
    valid = np.ones((len(embs),), np.int32)
    existing = encode_pattern_trs((), 16)
    sigs = match_signatures(
        jnp.asarray(tdb.tokens), jnp.asarray(gid), jnp.asarray(phi),
        jnp.asarray(psi), jnp.asarray(valid), jnp.asarray(existing),
        jnp.int32(0), jnp.int32(0), jnp.int32(MODE_ROOT))
    host = {s: len(g_) for s, (g_, _) in
            aggregate_host(np.asarray(sigs), gid).items()}

    from repro.compat import set_mesh_compat

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    step = make_mining_step(mesh, k=1024, db_axes=("data",),
                            tok_axis="model")
    gid_local = (gid % (len(db) // 4)).astype(np.int32)
    with set_mesh_compat(mesh):
        uniq, counts, _ = step(
            jnp.asarray(tdb.tokens), jnp.asarray(gid_local),
            jnp.asarray(phi), jnp.asarray(psi), jnp.asarray(valid),
            jnp.asarray(existing),
            jnp.int32(0), jnp.int32(0), jnp.int32(MODE_ROOT))
    dev = {int(s): int(c)
           for s, c in zip(np.asarray(uniq), np.asarray(counts)) if s >= 0}
    assert dev == host
    print(f"sharded scan over {len(jax.devices())} devices == exact host "
          f"counts ({len(dev)} candidate extensions)  OK")

    # ---- fault tolerance: checkpoint, simulated crash, resume
    ck = "/tmp/repro_mine.ckpt"
    if os.path.exists(ck):
        os.unlink(ck)
    full = AcceleratedMiner(db).mine_rs(2, max_len=5)

    from repro.mining import checkpoint as ckpt
    calls = {"n": 0}
    orig = ckpt.save_state

    class Crash(Exception):
        pass

    def crashing(path, patterns, stack, meta=None):
        orig(path, patterns, stack, meta)
        calls["n"] += 1
        if calls["n"] == 2 and stack:
            raise Crash("simulated worker loss")

    ckpt.save_state = crashing
    try:
        AcceleratedMiner(db).mine_rs(2, max_len=5, checkpoint_path=ck,
                                     checkpoint_every=2)
        crashed = False
    except Crash:
        crashed = True
    finally:
        ckpt.save_state = orig
    resumed = AcceleratedMiner(db).mine_rs(2, max_len=5,
                                           checkpoint_path=ck, resume=True)
    assert resumed.patterns == full.patterns
    print(f"crash-after-checkpoint {'simulated' if crashed else '(ran out)'}"
          f", resume produced identical {len(resumed.patterns)} rFTSs  OK")


if __name__ == "__main__":
    main()
