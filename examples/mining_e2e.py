"""End-to-end driver (the paper's kind of workload): generate a Table-3
style artificial graph-sequence DB, mine it with GTRACE-RS and the
original GTRACE, verify equality, and report the speed/enumeration gap.

    PYTHONPATH=src python examples/mining_e2e.py [--db 300]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.data.synthetic import Table3Params, generate_table3_db
from repro.mining.driver import AcceleratedMiner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db", type=int, default=300)
    ap.add_argument("--max-len", type=int, default=5)
    ap.add_argument("--sigma-frac", type=float, default=0.1)
    args = ap.parse_args()

    params = Table3Params(db_size=args.db, v_avg=5, n_interstates=4)
    db = generate_table3_db(params, seed=0)
    sigma = max(2, int(args.sigma_frac * len(db)))
    avg_len = sum(sum(len(i) for i in s) for s in db) / len(db)
    print(f"|DB|={len(db)}  sigma'={sigma}  avg seq len={avg_len:.1f}")

    miner = AcceleratedMiner(db)
    t0 = time.perf_counter()
    rs = miner.mine_rs(sigma, max_len=args.max_len)
    t_rs = time.perf_counter() - t0
    print(f"GTRACE-RS : {len(rs.patterns):6d} rFTSs   "
          f"{rs.n_enumerated:6d} nodes   {t_rs:7.2f}s "
          f"(device {miner.device_seconds:.2f}s)")

    t0 = time.perf_counter()
    gt = miner.mine_gtrace(sigma, max_len=args.max_len)
    t_gt = time.perf_counter() - t0
    rel = gt.relevant()
    print(f"GTRACE    : {len(gt.patterns):6d} FTSs -> {len(rel):6d} rFTSs"
          f"   {t_gt:7.2f}s")
    assert rel == rs.patterns
    print(f"\nspeedup {t_gt/t_rs:0.2f}x;  GTRACE enumerates "
          f"{len(gt.patterns)/max(1,len(rs.patterns)):0.1f}x more patterns "
          f"({100*(1-len(rel)/max(1,len(gt.patterns))):.0f}% irrelevant)")


if __name__ == "__main__":
    main()
