"""Quickstart: compile a graph sequence into transformation rules and mine
rFTSs with GTRACE-RS (the paper's Fig. 8 evolution).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.compile import compile_sequence
from repro.core.graphseq import LabeledGraph, pattern_str
from repro.core.gtrace import mine_gtrace
from repro.core.reverse_search import mine_gtrace_rs

A, B, C, dash = 10, 11, 12, 0


def fig8_sequence(extra_noise: bool):
    g = LabeledGraph()
    seq = []
    g.add_vertex(1, A); seq.append(g.copy())
    g.add_vertex(2, B); seq.append(g.copy())
    g.add_vertex(3, C)
    if extra_noise:
        g.add_vertex(9, A)
    seq.append(g.copy())
    g.add_edge(1, 2, dash); g.add_edge(2, 3, dash); seq.append(g.copy())
    g.remove_edge(2, 3); seq.append(g.copy())
    return seq


def main():
    db = [compile_sequence(fig8_sequence(False)),
          compile_sequence(fig8_sequence(True))]
    print("compiled transformation sequences:")
    for i, s in enumerate(db):
        for j, itemset in enumerate(s):
            for tr in itemset:
                print(f"  d{i} interstate {j}: {tr.short()}")

    rs = mine_gtrace_rs(db, min_support=2, max_len=6)
    gt = mine_gtrace(db, min_support=2, max_len=6)
    print(f"\nGTRACE-RS enumerated {rs.n_enumerated} nodes -> "
          f"{len(rs.patterns)} rFTSs")
    print(f"GTRACE    enumerated {gt.n_enumerated} FTSs -> "
          f"{len(gt.relevant())} rFTSs after postfilter")
    print("\nmined rFTSs (support >= 2):")
    for p, sup in sorted(rs.patterns.items(),
                         key=lambda kv: (-kv[1], pattern_str(kv[0]))):
        print(f"  [{sup}] {pattern_str(p)}")
    assert gt.relevant() == rs.patterns
    print("\nreverse search == filtered baseline  (verified)")


if __name__ == "__main__":
    main()
