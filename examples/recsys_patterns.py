"""GTRACE x BERT4Rec integration: user sessions as graph sequences
(items = vertices, co-interaction = edges, sessions evolve over time),
mined for frequent interaction patterns; the mined pattern ids become
extra context features scored alongside the BERT4Rec session encoder.

Mine-then-serve end-to-end: the mined bank is compiled into a
PatternServer and the per-session pattern features come from batched
device containment queries (repro.serving) instead of the per-sequence
host backtracker.

    PYTHONPATH=src python examples/recsys_patterns.py
"""
import random
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile import compile_sequence
from repro.core.graphseq import LabeledGraph, pattern_str
from repro.mining.driver import AcceleratedMiner
from repro.models import bert4rec as b4r
from repro.models.embedding import embedding_bag
from repro.serving import PatternServer, compile_bank


def session_to_graphseq(items, rng, n_cats=5):
    """A session becomes a graph sequence: each step adds the interacted
    item (vertex labeled by category) linked to the previous item."""
    g = LabeledGraph()
    seq = []
    prev = None
    for it in items:
        if it not in g.vlabels:
            g.add_vertex(it, it % n_cats)
        if prev is not None and prev != it:
            e = (min(prev, it), max(prev, it))
            if e not in g.elabels:
                g.add_edge(prev, it, 0)
        prev = it
        seq.append(g.copy())
    return seq


def main():
    rng = random.Random(0)
    # sessions with shared structure (clustered item co-occurrence)
    sessions = []
    for _ in range(60):
        base = rng.randrange(4) * 10
        items = [base + rng.randrange(4) for _ in range(5)]
        sessions.append(items)
    db = [compile_sequence(session_to_graphseq(s, rng)) for s in sessions]

    miner = AcceleratedMiner(db)
    res = miner.mine_rs(min_support=12, max_len=4)

    # mine-then-serve: compile the strongest rFTSs into a pattern bank
    # and answer "which patterns does each session contain?" as one
    # batched device query (repro.serving)
    bank = compile_bank(res, top=8)
    srv = PatternServer(bank, topk=8)
    print(f"mined {len(res.patterns)} session patterns; serving top "
          f"{bank.n_patterns}:")
    for pid in range(bank.n_patterns):
        print(f"  [{bank.support[pid]:3d}] "
              f"{pattern_str(bank.patterns[pid])}")

    results = srv.query(db)
    feats = np.stack([r.contained for r in results]).astype(np.float32)
    print(f"\npattern-feature matrix: {feats.shape}, "
          f"density {feats.mean():.2f}, server stats {srv.stats}")

    # embed the pattern-id bags alongside the BERT4Rec session encoding
    cfg = b4r.Bert4RecConfig(name="demo", n_items=64, seq_len=8,
                             v_chunk=32, topk=5)
    params = b4r.init_params(jax.random.PRNGKey(0), cfg)
    seqs = jnp.asarray(
        [[min(i + 1, 64) for i in s[: cfg.seq_len]]
         + [0] * (cfg.seq_len - len(s[: cfg.seq_len])) for s in sessions]
    )
    hidden = b4r.encode(params, seqs, cfg)  # [B,S,D]

    # EmbeddingBag over each session's pattern ids (the recsys substrate)
    pat_table = jax.random.normal(jax.random.PRNGKey(1),
                                  (bank.n_patterns, cfg.d_model)) * 0.1
    nz = np.nonzero(feats)
    pat_emb = embedding_bag(
        pat_table, jnp.asarray(nz[1], jnp.int32),
        jnp.asarray(nz[0], jnp.int32), len(db), mode="mean",
    )
    query = hidden[:, -1] + pat_emb
    scores, ids = b4r.chunked_topk_scores(params, query, cfg)
    print(f"scored {len(db)} sessions with pattern-augmented queries; "
          f"top-{cfg.topk} ids shape {ids.shape}  OK")


if __name__ == "__main__":
    main()
