"""Train a reduced SmolLM-style decoder on synthetic markov tokens with
the full training substrate (AdamW + cosine schedule, grad accumulation,
async checkpointing, resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.lm import token_batches
from repro.models import transformer as tf
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default="/tmp/repro_lm.ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch("smollm-135m").smoke_cfg
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.2f}M  "
          f"vocab={cfg.vocab}")

    batches = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in token_batches(0, cfg.vocab, args.batch, args.seq)
    )
    opt = AdamW(lr=cosine_schedule(1e-3, 30, args.steps),
                weight_decay=0.01)
    _, _, losses = train(
        lambda p, b: tf.lm_loss(p, b, cfg), params, batches,
        args.steps, opt=opt, checkpoint_path=args.checkpoint,
        resume=args.resume, checkpoint_every=50,
    )
    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARNING: flat'})")


if __name__ == "__main__":
    main()
