#!/usr/bin/env python
"""Validate committed BENCH_*.json artifacts and gate CI on them.

Three kinds of checks, all stdlib-only (runs before deps install if
needed):

1. **Schema**: every known artifact present in the repo parses and
   carries its required keys with sane types/signs.  A benchmark that
   silently stopped writing a field fails here, not three PRs later.
2. **Invariant gates** (committed full-run artifacts):
   - ``BENCH_serving.json``: the trie layout must not have regressed
     below parity - ``speedup_trie_vs_flat_median >= 1.0`` (the trie is
     pointless the moment the flat join beats it on the bank it was
     built for), and the serving speedup over the host oracle must stay
     > 1.
   - ``BENCH_kernel.json``: the fused trie-walk megakernel must issue
     exactly ONE device dispatch per query batch (depth-independent,
     vs the per-level baseline's one-per-level, which must stay > 1 or
     the comparison is vacuous), diverge on zero cells from the
     per-level and flat layouts, and keep a median walk speedup >= 1.5x
     over the per-level scan.
   - ``BENCH_streaming.json``: streamed maintenance must beat the
     re-mine-per-window baseline by >= 5x (``speedup_streaming``), and
     the final frequent-map equality is asserted inside the bench
     itself (it raises before writing on any divergence).
   - ``BENCH_faults.json``: the fault-tolerance contract under the
     standard seeded schedule - availability >= 0.99 at H=4 with one
     host faulted, zero unflagged-inexact / lost / divergent answers,
     bit-equal replica failover and post-blackout recovery, and the
     schedule must actually inject (nonzero injected faults, breaker
     opens, recoveries in the metrics block).
   - ``BENCH_cluster.json``: zero divergences, >= 2 hosts, nonzero
     L1+L2 cache hits, the shed tier actually exercised, async
     ``cluster_qps`` monotone non-decreasing in host count for both
     layouts (per-host offered load - see bench_cluster.py), and
     sharded-window streaming >= 0.8x the single-host bank.
3. **Smoke throughput regression** (fresh tier-2 runs): the smoke
   artifact just (re)written by ``bench_serving.py --smoke`` is
   compared against the committed baseline (``git show HEAD:...``);
   a >3x drop in ``server_qps`` fails.  The wide factor absorbs the
   ~2x box-to-box throughput swings the full benches document; an
   actual serving-path pessimization lands well past it.

Every artifact also carries a ``metrics`` block - a flat registry
snapshot (``repro.obs.metrics``) of the counters the timed code paths
actually incremented.  The schema check requires every entry to be
numeric plus, per artifact, the presence of the always-on latency
histogram keys (``METRICS_REQUIRED``: the ``.count`` of each bucket
histogram the instrumented seam must have fed - a count pinned at 0
means the telemetry stopped observing).  Counter-level gates read
specific entries: the cluster artifacts must show nonzero L1+L2 cache
hits (the Zipfian repeat mix exists to exercise the two-level cache),
``obs.sampled_spans`` > 0 with ``cluster.router.slo_breaches`` == 0
(sampled tracing kept traces AND the watchdog stayed quiet on the
healthy run), and the mining artifacts must show the wavefront
issuing fewer device calls than per-pattern dispatch.

The serving and cluster artifacts additionally carry the **always-on
telemetry budget**: ``telemetry_overhead`` (sampled-mode wall time
over the telemetry-disabled baseline, best-of passes) is gated
<= ``TELEMETRY_OVERHEAD_MAX`` (5%) - the number that justifies
leaving sampling on in production.

Exit code 0 = all gates green.  Used by scripts/ci.sh tier-2.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# artifact -> {key: type or (type, predicate)}
_NUM = (int, float)
SCHEMAS = {
    "BENCH_serving.json": {
        "bank_patterns": int,
        "n_queries": int,
        "server_qps": _NUM,
        "trie_qps": _NUM,
        "fused_qps": _NUM,
        "oracle_qps": _NUM,
        "speedup_server": _NUM,
        "speedup_trie_vs_flat": _NUM,
        "speedup_trie_vs_flat_median": _NUM,
        "speedup_fused_vs_trie": _NUM,
        "speedup_fused_vs_trie_median": _NUM,
        "joined_steps_flat": int,
        "joined_steps_trie": int,
        "joined_steps_fused": int,
        "telemetry_overhead": _NUM,
        "telemetry_sample_rate": _NUM,
        "rounds": list,
        "metrics": dict,
    },
    "BENCH_kernel.json": {
        "bank_patterns": int,
        "trie_depth": int,
        "n_subtrees": int,
        "n_queries": int,
        "divergences": int,
        "dispatches_per_query": _NUM,
        "perlevel_dispatches_per_query": _NUM,
        "speedup_fused_vs_perlevel": _NUM,
        "speedup_fused_vs_perlevel_median": _NUM,
        "rounds": list,
        "roofline": dict,
        "metrics": dict,
    },
    "BENCH_kernel_smoke.json": {
        "bank_patterns": int,
        "divergences": int,
        "dispatches_per_query": _NUM,
        "perlevel_dispatches_per_query": _NUM,
        "metrics": dict,
    },
    "BENCH_serving_smoke.json": {
        "bank_patterns": int,
        "server_qps": _NUM,
        "speedup_server": _NUM,
        "telemetry_overhead": _NUM,
        "telemetry_sample_rate": _NUM,
        "metrics": dict,
    },
    "BENCH_streaming.json": {
        "window": int,
        "minsup": int,
        "n_updates": int,
        "streamed_updates_per_sec": _NUM,
        "streamed_updates_per_sec_trie": _NUM,
        "remine_updates_per_sec": _NUM,
        "speedup_streaming": _NUM,
        "refreshes": int,
        "frontier_scans": int,
        "frontier_scans_skipped": int,
        "metrics": dict,
    },
    "BENCH_streaming_smoke.json": {
        "window": int,
        "streamed_updates_per_sec": _NUM,
        "remine_updates_per_sec": _NUM,
        "speedup_streaming": _NUM,
        "metrics": dict,
    },
    "BENCH_cluster.json": {
        "bank_patterns": int,
        "n_queries": int,
        "n_rounds": int,
        "flush_batch": int,
        "host_counts": list,
        "divergences": int,
        "single_qps": dict,
        "cluster_qps": dict,
        "cluster_route_qps": dict,
        "shed_stats": dict,
        "stream_window": int,
        "stream_hosts": int,
        "single_stream_updates_per_sec": _NUM,
        "sharded_stream_updates_per_sec": _NUM,
        "cache_hit_rate": _NUM,
        "telemetry_overhead": _NUM,
        "telemetry_sample_rate": _NUM,
        "metrics": dict,
    },
    "BENCH_cluster_smoke.json": {
        "bank_patterns": int,
        "host_counts": list,
        "divergences": int,
        "cluster_qps": dict,
        "cluster_route_qps": dict,
        "shed_stats": dict,
        "sharded_stream_updates_per_sec": _NUM,
        "cache_hit_rate": _NUM,
        "telemetry_overhead": _NUM,
        "telemetry_sample_rate": _NUM,
        "metrics": dict,
    },
    "BENCH_faults.json": {
        "bank_patterns": int,
        "n_hosts": int,
        "n_drains": int,
        "flush_batch": int,
        "error_rate": _NUM,
        "delay_rate": _NUM,
        "submitted": int,
        "answered": int,
        "availability": _NUM,
        "exact_answers": int,
        "degraded_answers": int,
        "unflagged_inexact": int,
        "divergences": int,
        "lost_tickets": int,
        "fault_free_divergences": int,
        "failover_divergences": int,
        "recovery_divergences": int,
        "p99_e2e_faulty": _NUM,
        "p99_e2e_fault_free": _NUM,
        "added_p99": _NUM,
        "metrics": dict,
    },
    "BENCH_faults_smoke.json": {
        "bank_patterns": int,
        "n_hosts": int,
        "submitted": int,
        "answered": int,
        "availability": _NUM,
        "degraded_answers": int,
        "unflagged_inexact": int,
        "divergences": int,
        "lost_tickets": int,
        "fault_free_divergences": int,
        "failover_divergences": int,
        "recovery_divergences": int,
        "metrics": dict,
    },
    "BENCH_mining.json": {
        "configs": list,
        "divergences": int,
        "speedup_wavefront_median": _NUM,
        "device_call_reduction_median": _NUM,
        "patterns_per_sec_best": _NUM,
        "metrics": dict,
    },
    "BENCH_mining_smoke.json": {
        "configs": list,
        "divergences": int,
        "speedup_wavefront_median": _NUM,
        "device_call_reduction_median": _NUM,
        "metrics": dict,
    },
}

SMOKE_REGRESSION_FACTOR = 3.0

# the always-on budget: sampled-mode wall overhead over the
# telemetry-disabled baseline, gated on every artifact that measures it
TELEMETRY_OVERHEAD_MAX = 0.05

# metric keys that must be present AND nonzero in each artifact's
# metrics block: the .count of every always-on latency bucket
# histogram the instrumented seam feeds (0 or absent = the telemetry
# layer silently stopped observing that seam)
_SERVING_HISTS = [
    "serving.flat.query_seconds.count",
    "serving.trie.query_seconds.count",
    "serving.fused.query_seconds.count",
]
_KERNEL_HISTS = [
    "serving.trie.query_seconds.count",
    "serving.fused.query_seconds.count",
]
_STREAMING_HISTS = [
    "streaming.bank.observe_seconds.count",
    "streaming.bank.refresh_seconds.count",
]
_CLUSTER_HISTS = [
    "cluster.router.e2e_seconds.count",
    "cluster.router.queue_wait_seconds.count",
    "cluster.router.flush_seconds.count",
    "cluster.router.route_seconds.count",
    "streaming.sharded.observe_seconds.count",
    "streaming.sharded.refresh_seconds.count",
    "obs.sampled_spans",
]
_MINING_HISTS = [
    "mining.wavefront.wave_seconds.count",
    "mining.pattern.wave_seconds.count",
]
_FAULTS_HISTS = [
    "cluster.faults.retry_seconds.count",
    "cluster.router.e2e_seconds.count",
]
METRICS_REQUIRED = {
    "BENCH_serving.json": _SERVING_HISTS,
    "BENCH_serving_smoke.json": _SERVING_HISTS,
    "BENCH_kernel.json": _KERNEL_HISTS,
    "BENCH_kernel_smoke.json": _KERNEL_HISTS,
    "BENCH_streaming.json": _STREAMING_HISTS,
    "BENCH_streaming_smoke.json": _STREAMING_HISTS,
    "BENCH_cluster.json": _CLUSTER_HISTS,
    "BENCH_cluster_smoke.json": _CLUSTER_HISTS,
    "BENCH_mining.json": _MINING_HISTS,
    "BENCH_mining_smoke.json": _MINING_HISTS,
    "BENCH_faults.json": _FAULTS_HISTS,
    "BENCH_faults_smoke.json": _FAULTS_HISTS,
}


class GateError(Exception):
    pass


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_schema(name: str, payload: dict) -> None:
    schema = SCHEMAS[name]
    for key, ty in schema.items():
        if key not in payload:
            raise GateError(f"{name}: missing key {key!r}")
        val = payload[key]
        if not isinstance(val, ty) or isinstance(val, bool):
            raise GateError(
                f"{name}: {key} has type {type(val).__name__}, "
                f"expected {ty}"
            )
        if isinstance(val, _NUM) and not isinstance(val, bool) \
                and val < 0:
            raise GateError(f"{name}: {key} = {val} is negative")
    metrics = payload.get("metrics")
    if metrics is not None:
        # registry snapshots are flat {dotted.name: number}; a nested
        # or non-numeric entry means the bench stopped writing real
        # counter deltas
        for key, val in metrics.items():
            if not isinstance(val, _NUM) or isinstance(val, bool):
                raise GateError(
                    f"{name}: metrics[{key!r}] has type "
                    f"{type(val).__name__}, expected a number"
                )
        for key in METRICS_REQUIRED.get(name, ()):
            if metrics.get(key, 0) <= 0:
                raise GateError(
                    f"{name}: metrics[{key!r}] = "
                    f"{metrics.get(key, 'absent')} - the always-on "
                    "latency histogram on that seam stopped observing"
                )


def check_invariants(name: str, payload: dict) -> None:
    if name == "BENCH_serving.json":
        med = payload["speedup_trie_vs_flat_median"]
        if med < 1.0:
            raise GateError(
                f"{name}: trie/flat median speedup {med:.3f} < 1.0 - "
                "the trie layout regressed below parity"
            )
        if payload["speedup_server"] <= 1.0:
            raise GateError(
                f"{name}: serving speedup over the host oracle "
                f"{payload['speedup_server']:.2f} <= 1"
            )
    if name in ("BENCH_kernel.json", "BENCH_kernel_smoke.json"):
        # the megakernel's contract is bit-identity: the bench raises
        # before writing on any fused/trie/flat row mismatch, so a
        # nonzero committed count means the artifact was hand-edited
        if payload["divergences"] != 0:
            raise GateError(
                f"{name}: {payload['divergences']} cells diverged "
                "between the fused, per-level and flat layouts"
            )
        # THE fused-walk guarantee: one device dispatch per query
        # batch, independent of trie depth (the per-level count stays
        # recorded alongside as the depth-dependent baseline)
        if payload["dispatches_per_query"] != 1:
            raise GateError(
                f"{name}: fused layout issued "
                f"{payload['dispatches_per_query']} device dispatches "
                "per query batch - the megakernel stopped fusing"
            )
        if payload["perlevel_dispatches_per_query"] <= \
                payload["dispatches_per_query"]:
            raise GateError(
                f"{name}: per-level walk issued "
                f"{payload['perlevel_dispatches_per_query']} dispatches "
                "per batch - the baseline stopped paying per level, "
                "the comparison is vacuous"
            )
        if name == "BENCH_kernel.json":
            med = payload["speedup_fused_vs_perlevel_median"]
            if med < 1.5:
                raise GateError(
                    f"{name}: median fused-vs-per-level walk speedup "
                    f"{med:.2f} < 1.5 - the fused kernel regressed "
                    "below its landing bar"
                )
    if name == "BENCH_streaming.json":
        sp = payload["speedup_streaming"]
        if sp < 5.0:
            raise GateError(
                f"{name}: streamed maintenance speedup {sp:.2f} < 5.0 "
                "over re-mine-per-window"
            )
    if name in ("BENCH_mining.json", "BENCH_mining_smoke.json"):
        # mining is exactness-gated like the cluster: the bench raises
        # before writing on any frequent-map mismatch between the
        # wavefront, per-pattern and host miners
        if payload["divergences"] != 0:
            raise GateError(
                f"{name}: {payload['divergences']} mining configs "
                "diverged between the wavefront/per-pattern/host miners"
            )
        if name == "BENCH_mining.json":
            med = payload["speedup_wavefront_median"]
            if med < 3.0:
                raise GateError(
                    f"{name}: median wavefront speedup {med:.2f} < 3.0 "
                    "over per-pattern dispatch - the frontier batching "
                    "regressed"
                )
            calls = payload["device_call_reduction_median"]
            if calls < 5.0:
                raise GateError(
                    f"{name}: median device-call reduction {calls:.1f} "
                    "< 5.0 - the wavefront stopped packing patterns"
                )
        # counter-level gate (the metrics block): total wavefront
        # device calls across the grid must stay below per-pattern's -
        # the aggregate restatement of the per-config reduction gate,
        # read from the registry counters the miners actually increment
        m = payload["metrics"]
        wf = m.get("mining.wavefront.n_device_calls", 0)
        pp = m.get("mining.pattern.n_device_calls", 0)
        if not (0 < wf < pp):
            raise GateError(
                f"{name}: metrics device-call counters out of order - "
                f"wavefront {wf} must be nonzero and below "
                f"per-pattern {pp}"
            )
    if name in ("BENCH_faults.json", "BENCH_faults_smoke.json"):
        # the fault-tolerance contract (bench_faults.py raises before
        # writing on any violation, so a nonzero committed count means
        # the artifact was hand-edited): every submitted query answered
        # exactly once, bit-equal when flagged exact, sound superset
        # when degraded - and the schedule itself must not be vacuous
        for key in ("unflagged_inexact", "divergences", "lost_tickets",
                    "fault_free_divergences", "failover_divergences",
                    "recovery_divergences"):
            if payload[key] != 0:
                raise GateError(
                    f"{name}: {key} = {payload[key]} - the "
                    "fault-tolerance contract is broken"
                )
        if payload["n_hosts"] < 4:
            raise GateError(
                f"{name}: n_hosts = {payload['n_hosts']} < 4 - the "
                "availability gate is specified at H=4 with one host "
                "faulted"
            )
        if payload["availability"] < 0.99:
            raise GateError(
                f"{name}: availability {payload['availability']:.4f} "
                "< 0.99 with one host faulted"
            )
        if payload["degraded_answers"] <= 0:
            raise GateError(
                f"{name}: zero degraded answers - the blackout never "
                "exercised the degradation ladder"
            )
        m = payload["metrics"]
        for key in ("cluster.faults.injected",
                    "cluster.faults.breaker_open",
                    "cluster.faults.recoveries"):
            if m.get(key, 0) <= 0:
                raise GateError(
                    f"{name}: metrics[{key!r}] = "
                    f"{m.get(key, 'absent')} - the standard fault "
                    "schedule stopped exercising the fault ladder"
                )
    if name in ("BENCH_cluster.json", "BENCH_cluster_smoke.json"):
        # the cluster's contract is exactness, not in-process speed:
        # the bench raises before writing on any divergence, so a
        # nonzero committed count means the artifact was hand-edited
        # or the bench was bypassed
        if payload["divergences"] != 0:
            raise GateError(
                f"{name}: {payload['divergences']} routed queries "
                "diverged from the single-host server"
            )
        if max(payload["host_counts"], default=0) < 2:
            raise GateError(
                f"{name}: host_counts {payload['host_counts']} never "
                "exercises a real multi-host split"
            )
        # counter-level gate (the metrics block): the Zipfian repeat
        # mix must actually exercise the two-level cache - a hit rate
        # pinned at 0 means the bench regressed to a one-shot mix or
        # the L1/L2 path stopped being consulted
        m = payload["metrics"]
        hits = (m.get("cluster.router.l1_hits", 0)
                + m.get("cluster.router.l2_hits", 0))
        if hits <= 0:
            raise GateError(
                f"{name}: zero L1+L2 cache hits in the metrics block - "
                "the Zipfian repeat mix no longer exercises the "
                "two-level cache"
            )
        # the shed-tier demo must actually shed: a zero counter means
        # the overload path silently degraded to exact serving and its
        # soundness assertions (superset bits, inexact flag, no cache
        # pollution) no longer ran
        if payload["shed_stats"].get("shed_prescreen", 0) <= 0:
            raise GateError(
                f"{name}: shed_stats shows zero shed_prescreen answers "
                "- the load-shedding tier was never exercised"
            )
        # the watchdog must have stayed quiet on the healthy telemetry
        # pass (the bench raises before writing when it fires, so a
        # nonzero committed counter means the artifact was hand-edited)
        if m.get("cluster.router.slo_breaches", 0) != 0:
            raise GateError(
                f"{name}: cluster.router.slo_breaches = "
                f"{m.get('cluster.router.slo_breaches')} on the "
                "healthy telemetry run"
            )
    # the always-on budget: serving + cluster artifacts measure the
    # sampled-mode overhead vs a telemetry-disabled baseline; a ratio
    # past 5% means the observe path grew a real per-query cost and
    # can no longer claim to be production-safe default-on
    if "telemetry_overhead" in SCHEMAS[name]:
        ov = payload["telemetry_overhead"]
        if ov > TELEMETRY_OVERHEAD_MAX:
            raise GateError(
                f"{name}: telemetry_overhead {ov:.3f} > "
                f"{TELEMETRY_OVERHEAD_MAX} at sample rate "
                f"{payload.get('telemetry_sample_rate')} - sampled "
                "tracing is no longer cheap enough to leave on"
            )
    if name == "BENCH_cluster.json":
        # the PR-7 scaling gate, full artifact only (the smoke config
        # is small enough for timing noise to invert adjacent points):
        # under per-host offered load (every host drives its own
        # arrival stream), aggregate async qps must be monotone
        # non-decreasing in host count for BOTH layouts - the
        # bank-sharded join is constant-sum across shards, so each
        # added host's cache + admission capacity must not make the
        # cluster slower.  The 3% tolerance absorbs best-of-N residual
        # jitter, nothing more; the old split-one-stream bench decayed
        # ~25% per host step and fails this by an order of magnitude.
        noise = 0.97
        for layout, by_h in payload["cluster_qps"].items():
            hs = sorted(int(h) for h in by_h)
            for lo, hi in zip(hs, hs[1:]):
                if by_h[str(hi)] < by_h[str(lo)] * noise:
                    raise GateError(
                        f"{name}: {layout} cluster_qps fell from "
                        f"{by_h[str(lo)]:.0f} (H={lo}) to "
                        f"{by_h[str(hi)]:.0f} (H={hi}) - scaling went "
                        "negative again"
                    )
        # the sharded-window protocol must stay within 0.8x of the
        # single-host streaming bank (it was at 0.46x before the
        # shared-encoding + launch/fence split): one all-reduce per
        # refresh is the only protocol cost that may remain
        sh = payload["sharded_stream_updates_per_sec"]
        sg = payload["single_stream_updates_per_sec"]
        if sh < 0.8 * sg:
            raise GateError(
                f"{name}: sharded streaming {sh:.0f} ups < 0.8x the "
                f"single-host bank {sg:.0f} ups - the sharded-window "
                "protocol overhead regressed"
            )


def committed_baseline(name: str) -> dict | None:
    """The artifact as committed at HEAD (None when unavailable - fresh
    repo without the artifact, or no git)."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{name}"], cwd=ROOT,
            capture_output=True, text=True, timeout=30,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def check_smoke_regression(payload: dict) -> str:
    base = committed_baseline("BENCH_serving_smoke.json")
    if base is None or "server_qps" not in base:
        return "smoke regression: no committed baseline, skipped"
    cur, ref = payload["server_qps"], base["server_qps"]
    if base.get("machine") != payload.get("machine"):
        # absolute qps is meaningless across hardware (a CI runner is
        # legitimately >3x slower than a dev box): advisory only
        return (f"smoke regression: baseline from a different machine "
                f"({base.get('machine')!r} vs "
                f"{payload.get('machine')!r}), advisory: server_qps "
                f"{cur:.0f} vs committed {ref:.0f}")
    if ref > 0 and cur < ref / SMOKE_REGRESSION_FACTOR:
        raise GateError(
            f"BENCH_serving_smoke.json: server_qps {cur:.0f} dropped "
            f">{SMOKE_REGRESSION_FACTOR:.0f}x below the committed "
            f"same-machine baseline {ref:.0f}"
        )
    return (f"smoke regression: server_qps {cur:.0f} vs committed "
            f"{ref:.0f} (>{ref / SMOKE_REGRESSION_FACTOR:.0f} required)")


def main() -> int:
    failures = []
    for name in SCHEMAS:
        path = os.path.join(ROOT, name)
        if not os.path.exists(path):
            # smoke artifacts only exist after a tier-2/3 run; full
            # artifacts are committed - warn loudly if those vanish
            level = ("missing (committed artifact!)"
                     if "smoke" not in name else "absent, skipped")
            print(f"[check_bench] {name}: {level}")
            if "smoke" not in name:
                failures.append(f"{name} missing from the repo")
            continue
        try:
            payload = _load(path)
            check_schema(name, payload)
            check_invariants(name, payload)
            print(f"[check_bench] {name}: schema + invariants OK")
            if name == "BENCH_serving_smoke.json":
                print(f"[check_bench] {check_smoke_regression(payload)}")
        except (GateError, json.JSONDecodeError, OSError) as e:
            failures.append(str(e))
            print(f"[check_bench] FAIL {name}: {e}")
    if failures:
        print(f"[check_bench] {len(failures)} gate(s) failed")
        return 1
    print("[check_bench] all gates green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
