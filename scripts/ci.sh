#!/usr/bin/env bash
# Tier-1 verification, reproducible from a clean checkout:
#   pip install -r requirements-dev.txt   (optional deps stay optional)
#   scripts/ci.sh [extra pytest args]
#
# Tier-2 (CI_TIER2=0 to skip): a tiny-config serving benchmark smoke
# that runs BOTH bank layouts over the same queries and hard-fails on
# any flat/trie containment mismatch (the layouts are required to be
# exact, so any disagreement is a correctness bug).  No timing
# assertions - perf numbers come from the full benchmark run.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

if [[ "${CI_TIER2:-1}" != "0" ]]; then
    echo "[ci] tier-2: serving smoke (flat vs trie layout agreement)"
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python benchmarks/bench_serving.py --smoke
fi
