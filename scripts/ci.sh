#!/usr/bin/env bash
# Tier-1 verification, reproducible from a clean checkout:
#   pip install -r requirements-dev.txt   (optional deps stay optional)
#   scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
