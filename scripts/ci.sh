#!/usr/bin/env bash
# Tiered CI, reproducible from a clean checkout:
#   pip install -r requirements-dev.txt   (optional deps stay optional)
#   scripts/ci.sh [extra pytest args]
#
# Tier matrix (each tier gated by its env toggle, default = run):
#
#   tier-1  CI_TIER1=0 skips   pytest suite.  CI_FAST=1 runs the fast
#           lane (-m "not slow": skips the multi-device subprocess
#           tests and the heavy hypothesis differentials); the default
#           full lane runs everything.  Extra args pass through.
#   tier-2  CI_TIER2=0 skips   serving smoke: bench_serving.py --smoke
#           runs BOTH bank layouts over the same queries and hard-fails
#           on any flat/trie containment mismatch (the layouts are
#           required to be exact, so any disagreement is a correctness
#           bug).
#   tier-3  CI_TIER3=0 skips   streaming smoke: bench_streaming.py
#           --smoke drives an arrival stream through StreamingBank
#           (both layouts) and hard-fails if the streamed supports
#           differ from a batch re-mine of the same window at ANY
#           refresh point - the incremental-maintenance exactness gate.
#   tier-4  CI_TIER4=0 skips   cluster smoke: bench_cluster.py --smoke
#           routes queries through the multi-host cluster (simulated
#           hosts, both layouts, >= 2 hosts) and streams through the
#           sharded-window protocol, hard-failing on ANY divergence
#           from the single-host server / streaming bank - the
#           multi-host exactness gate.
#   tier-5  CI_TIER5=0 skips   mining smoke: bench_mining.py --smoke
#           runs the wavefront, per-pattern-dispatch and pure-host
#           miners over the same DB and hard-fails on ANY frequent-map
#           divergence - the wavefront exactness gate.  Off in the
#           fast lane.
#   gates   run with tier-2, but AFTER tiers 3-5 so the freshly
#           written smoke artifacts are the ones validated:
#           scripts/check_bench.py checks every BENCH_*.json schema,
#           gates on the committed trie/flat median speedup (>= 1.0),
#           streaming speedup (>= 5x), cluster divergences == 0, and
#           mining wavefront speedup (median >= 3x, device calls cut
#           >= 5x, divergences == 0), and fails if smoke throughput
#           dropped >3x below the committed same-machine baseline.
#
# No timing assertions inside the smokes - perf numbers come from the
# full benchmark runs; regressions are caught by check_bench.py against
# the committed artifacts.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${CI_TIER1:-1}" != "0" ]]; then
    if [[ "${CI_FAST:-0}" == "1" ]]; then
        echo "[ci] tier-1: pytest (fast lane, -m 'not slow')"
        python -m pytest -x -q -m "not slow" "$@"
    else
        echo "[ci] tier-1: pytest (full lane)"
        python -m pytest -x -q "$@"
    fi
fi

if [[ "${CI_TIER2:-1}" != "0" ]]; then
    echo "[ci] tier-2: serving smoke (flat vs trie layout agreement)"
    python benchmarks/bench_serving.py --smoke
fi

if [[ "${CI_TIER3:-1}" != "0" ]]; then
    echo "[ci] tier-3: streaming smoke (streamed == batch re-mine)"
    python benchmarks/bench_streaming.py --smoke
fi

if [[ "${CI_TIER4:-1}" != "0" ]]; then
    echo "[ci] tier-4: cluster smoke (routed == single-host, sharded window == streaming bank)"
    python benchmarks/bench_cluster.py --smoke
fi

if [[ "${CI_TIER5:-1}" != "0" ]]; then
    echo "[ci] tier-5: mining smoke (wavefront == per-pattern == host)"
    python benchmarks/bench_mining.py --smoke
fi

if [[ "${CI_TIER2:-1}" != "0" ]]; then
    echo "[ci] bench artifact gates (schemas + committed baselines)"
    python scripts/check_bench.py
fi
