#!/usr/bin/env bash
# Tiered CI, reproducible from a clean checkout:
#   pip install -r requirements-dev.txt   (optional deps stay optional)
#   scripts/ci.sh [extra pytest args]
#
# Tier matrix (each tier gated by its env toggle, default = run):
#
#   tier-1  CI_TIER1=0 skips   pytest suite.  CI_FAST=1 runs the fast
#           lane (-m "not slow": skips the multi-device subprocess
#           tests and the heavy hypothesis differentials); the default
#           full lane runs everything.  Extra args pass through.
#   tier-2  CI_TIER2=0 skips   serving smoke: bench_serving.py --smoke
#           runs ALL THREE bank layouts (flat, per-level trie, fused
#           trie megakernel) over the same queries and hard-fails on
#           any pairwise containment mismatch (the layouts are required
#           to be exact, so any disagreement is a correctness bug);
#           then bench_kernel.py --smoke re-checks the three-layout
#           agreement at walk level and writes the dispatch counts
#           check_bench.py gates on (fused == 1 per query batch,
#           per-level > 1).
#   tier-3  CI_TIER3=0 skips   streaming smoke: bench_streaming.py
#           --smoke drives an arrival stream through StreamingBank
#           (both layouts) and hard-fails if the streamed supports
#           differ from a batch re-mine of the same window at ANY
#           refresh point - the incremental-maintenance exactness gate.
#   tier-4  CI_TIER4=0 skips   cluster smoke: bench_cluster.py --smoke
#           routes queries through the multi-host cluster (simulated
#           hosts, both layouts, >= 2 hosts) twice - the synchronous
#           route path AND the async continuous-batching pipeline
#           (submit/flush/collect, open-loop arrivals) - plus the
#           shed-tier soundness check (approximate answers must be
#           flagged supersets) and the sharded-window streaming
#           protocol, hard-failing on ANY divergence from the
#           single-host server / streaming bank - the multi-host
#           exactness gate.
#   tier-5  CI_TIER5=0 skips   mining smoke: bench_mining.py --smoke
#           runs the wavefront, per-pattern-dispatch and pure-host
#           miners over the same DB and hard-fails on ANY frequent-map
#           divergence - the wavefront exactness gate.  Off in the
#           fast lane.
#   tier-7  CI_TIER7=0 skips   fault-tolerance smoke (off in the fast
#           lane): bench_faults.py --smoke drives the H=4 cluster
#           through the standard seeded fault schedule (transient
#           errors, injected delays, one host blacked out) on a fake
#           clock and hard-fails unless every submitted query gets
#           exactly one answer - bit-equal to the single-host server
#           or a flagged sound superset - with availability >= 0.99,
#           zero unflagged-inexact answers, bit-equal replica failover
#           and bit-equal post-blackout recovery.  Writes
#           BENCH_faults_smoke.json for the check_bench.py gates.
#   tier-6  CI_TIER6=0 skips   observability smoke (also off in the
#           fast lane, CI_FAST=1): re-runs the cluster and mining
#           smokes with --trace, then validates the recorded spans
#           with scripts/trace_report.py --check (schema: every span
#           needs a known category, non-negative ts/dur, >= 1 wall
#           root; coverage: the phase-attribution table must account
#           for >= 90% of traced wall time), and fails if any BENCH
#           smoke artifact written this run is missing its metrics
#           block.  Tracing is off by default everywhere else - the
#           no-op path is property-tested to change nothing.
#
#           The cluster smoke additionally exercises the ALWAYS-ON
#           telemetry path: --trace-sampled saves the spans the 10%
#           sampled rounds kept (schema-checked with
#           --min-coverage 0.0: sampled/tail roots legitimately have
#           sparse children), --prom writes the registry as Prometheus
#           text exposition (validated strictly via
#           repro.obs.export.validate_exposition), the SLO rules in
#           scripts/slo_rules.json are evaluated against the fresh
#           BENCH_cluster_smoke.json metrics block (trace_report
#           --slo exits nonzero on any breach), and
#           scripts/watchdog_smoke.py proves the alarm path end to
#           end - the watchdog must demonstrably fire (breach counter
#           + flight-recorder dump) on an injected stall while results
#           stay exact.
#
#           Reading a trace by hand:
#             scripts/trace_report.py /tmp/trace.json          # tables
#             scripts/trace_report.py t.jsonl --top 20         # more rows
#             scripts/trace_report.py t.json --json            # machine-readable
#             scripts/trace_report.py t.json --check \
#                 --min-coverage 0.9                           # CI gate mode
#           (.json traces are Chrome-trace format - load them in
#           chrome://tracing / Perfetto for a timeline view.)
#   gates   run with tier-2, but AFTER tiers 3-5 so the freshly
#           written smoke artifacts are the ones validated:
#           scripts/check_bench.py checks every BENCH_*.json schema,
#           gates on the committed trie/flat median speedup (>= 1.0),
#           streaming speedup (>= 5x), cluster divergences == 0 with
#           qps monotone non-decreasing in host count (both layouts)
#           and sharded streaming >= 0.8x single-host, and mining
#           wavefront speedup (median >= 3x, device calls cut >= 5x,
#           divergences == 0), and fails if smoke throughput dropped
#           >3x below the committed same-machine baseline.
#
# No timing assertions inside the smokes - perf numbers come from the
# full benchmark runs; regressions are caught by check_bench.py against
# the committed artifacts.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${CI_TIER1:-1}" != "0" ]]; then
    if [[ "${CI_FAST:-0}" == "1" ]]; then
        echo "[ci] tier-1: pytest (fast lane, -m 'not slow')"
        python -m pytest -x -q -m "not slow" "$@"
    else
        echo "[ci] tier-1: pytest (full lane)"
        python -m pytest -x -q "$@"
    fi
fi

if [[ "${CI_TIER2:-1}" != "0" ]]; then
    echo "[ci] tier-2: serving smoke (flat vs trie vs fused layout agreement)"
    python benchmarks/bench_serving.py --smoke
    echo "[ci] tier-2: fused-kernel smoke (dispatch counts + walk-level agreement)"
    python benchmarks/bench_kernel.py --smoke
fi

if [[ "${CI_TIER3:-1}" != "0" ]]; then
    echo "[ci] tier-3: streaming smoke (streamed == batch re-mine)"
    python benchmarks/bench_streaming.py --smoke
fi

if [[ "${CI_TIER4:-1}" != "0" ]]; then
    echo "[ci] tier-4: cluster smoke (route + async pipeline == single-host, sharded window == streaming bank)"
    python benchmarks/bench_cluster.py --smoke
fi

if [[ "${CI_TIER5:-1}" != "0" ]]; then
    echo "[ci] tier-5: mining smoke (wavefront == per-pattern == host)"
    python benchmarks/bench_mining.py --smoke
fi

if [[ "${CI_TIER7:-1}" != "0" && "${CI_FAST:-0}" != "1" ]]; then
    echo "[ci] tier-7: fault-tolerance smoke (availability + soundness under the standard fault schedule)"
    python benchmarks/bench_faults.py --smoke
fi

if [[ "${CI_TIER6:-1}" != "0" && "${CI_FAST:-0}" != "1" ]]; then
    echo "[ci] tier-6: observability smoke (traced runs + span schema + metrics blocks)"
    TRACE_DIR="$(mktemp -d)"
    python benchmarks/bench_cluster.py --smoke --trace "$TRACE_DIR/cluster.json" \
        --trace-sampled "$TRACE_DIR/cluster_sampled.jsonl" --prom "$TRACE_DIR/cluster.prom"
    python benchmarks/bench_mining.py --smoke --trace "$TRACE_DIR/mining.jsonl"
    python scripts/trace_report.py "$TRACE_DIR/cluster.json" --check --min-coverage 0.9
    python scripts/trace_report.py "$TRACE_DIR/mining.jsonl" --check --min-coverage 0.9
    echo "[ci] tier-6: sampled-trace schema + Prometheus exposition + SLO rules"
    python scripts/trace_report.py "$TRACE_DIR/cluster_sampled.jsonl" --check --min-coverage 0.0 \
        --metrics BENCH_cluster_smoke.json --slo scripts/slo_rules.json
    python - "$TRACE_DIR/cluster.prom" <<'PY'
import sys
from repro.obs.export import validate_exposition
text = open(sys.argv[1]).read()
problems = validate_exposition(text)
for p in problems:
    print(f"[ci] tier-6: prom exposition problem: {p}")
n = sum(1 for ln in text.splitlines()
        if ln and not ln.startswith("#"))
print(f"[ci] tier-6: Prometheus exposition "
      + (f"INVALID ({len(problems)} problem(s))" if problems
         else f"OK ({n} samples)"))
sys.exit(1 if problems or n == 0 else 0)
PY
    echo "[ci] tier-6: watchdog fires on an injected stall"
    python scripts/watchdog_smoke.py
    python - <<'PY'
import json, os, sys
# every smoke artifact present after this run must carry the metrics
# block check_bench gates on (flat numeric registry snapshot)
bad = []
for name in sorted(os.listdir(".")):
    if not (name.startswith("BENCH_") and name.endswith("_smoke.json")):
        continue
    m = json.load(open(name)).get("metrics")
    if not isinstance(m, dict) or not m or not all(
            isinstance(v, (int, float)) and not isinstance(v, bool)
            for v in m.values()):
        bad.append(name)
print("[ci] tier-6: metrics blocks " +
      ("MISSING/MALFORMED in " + ", ".join(bad) if bad else "OK"))
sys.exit(1 if bad else 0)
PY
    rm -rf "$TRACE_DIR"
fi

if [[ "${CI_TIER2:-1}" != "0" ]]; then
    echo "[ci] bench artifact gates (schemas + committed baselines)"
    python scripts/check_bench.py
fi
