"""Render EXPERIMENTS.md from results/dryrun* artifacts + the recorded
hillclimb log.  Re-run after refreshing the dry-run grid."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(dirname):
    out = {}
    for f in sorted(glob.glob(os.path.join(ROOT, dirname, "*.json"))):
        d = json.load(open(f))
        out[(d["arch"], d["shape"], d.get("mesh", "?"))] = d
    return out


def fmt_table(cells, mesh):
    lines = [
        "| arch | shape | GiB/dev | t_compute s | t_memory s | "
        "t_collective s | bottleneck | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), d in sorted(cells.items()):
        if m != mesh or not d.get("ok"):
            continue
        r = d["roofline"]
        mem = d["memory"].get("per_device_total_bytes", 0) / 2**30
        lines.append(
            f"| {a} | {s} | {mem:.2f} | {r['t_compute']:.4g} | "
            f"{r['t_memory']:.4g} | {r['t_collective']:.4g} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.5f} |"
        )
    return "\n".join(lines)


def dryrun_summary(cells):
    n_ok = sum(1 for d in cells.values() if d.get("ok"))
    rows = [
        "| arch | shape | mesh | compile s | bytes/dev | status |",
        "|---|---|---|---|---|---|",
    ]
    for (a, s, m), d in sorted(cells.items()):
        mem = d.get("memory", {}).get("per_device_total_bytes", 0)
        rows.append(
            f"| {a} | {s} | {m} | {d.get('t_compile_s','-')} | "
            f"{mem/2**30:.2f} GiB | {'OK' if d.get('ok') else 'FAIL'} |"
        )
    return n_ok, len(cells), "\n".join(rows)


def cmp_rows(base, new, keys):
    lines = [
        "| cell | metric | baseline | optimized | change |",
        "|---|---|---|---|---|",
    ]
    for key, metrics in keys:
        b, n = base.get(key), new.get(key)
        if not (b and n and b.get("ok") and n.get("ok")):
            continue
        for mt, label, scale in metrics:
            bv = b["roofline"][mt] * scale
            nv = n["roofline"][mt] * scale
            chg = (f"{bv/nv:.1f}x lower" if nv < bv and nv > 0 else
                   (f"{nv/bv:.1f}x higher" if bv > 0 else "-"))
            lines.append(
                f"| {key[0]} {key[1]} ({key[2]}) | {label} | "
                f"{bv:.4g} | {nv:.4g} | {chg} |"
            )
    return "\n".join(lines)


def main():
    base = load("results/dryrun_baseline")
    new = load("results/dryrun")
    n_ok, n_all, table = dryrun_summary(new)

    hill = cmp_rows(base, new, [
        (("glm4-9b", "train_4k", "16x16"),
         [("t_compute", "t_compute [s]", 1),
          ("t_memory", "t_memory [s]", 1),
          ("t_collective", "t_collective [s]", 1),
          ("roofline_fraction", "roofline fraction", 1),
          ("useful_flops_ratio", "useful-FLOPs ratio", 1)]),
        (("bert4rec", "serve_bulk", "16x16"),
         [("t_compute", "t_compute [s]", 1),
          ("t_memory", "t_memory [s]", 1),
          ("t_collective", "t_collective [s]", 1),
          ("useful_flops_ratio", "useful-FLOPs ratio", 1)]),
        (("gtrace-mining", "scan_xl", "16x16"),
         [("t_memory", "t_memory [ms]", 1e3),
          ("t_collective", "t_collective [ms]", 1e3),
          ("useful_flops_ratio", "useful-FLOPs ratio", 1)]),
    ])

    gen_rows = ["| cell | frac before | frac after | gain | t_memory "
                "before -> after [s] |", "|---|---|---|---|---|"]
    for a in ("glm4-9b", "gemma-7b", "smollm-135m",
              "llama4-maverick-400b-a17b", "olmoe-1b-7b"):
        for s in ("train_4k", "prefill_32k"):
            key = (a, s, "16x16")
            b, n = base.get(key), new.get(key)
            if not (b and n and b.get("ok") and n.get("ok")):
                continue
            rb, rn = b["roofline"], n["roofline"]
            gain = (rn["roofline_fraction"]
                    / max(rb["roofline_fraction"], 1e-12))
            gen_rows.append(
                f"| {a} {s} | {rb['roofline_fraction']:.5f} | "
                f"{rn['roofline_fraction']:.5f} | {gain:.1f}x | "
                f"{rb['t_memory']:.1f} -> {rn['t_memory']:.1f} |"
            )

    tmpl = open(os.path.join(ROOT, "scripts", "experiments_body.md")).read()
    out = tmpl.format(
        n_ok=n_ok, n_all=n_all,
        dryrun_table=table,
        roofline_single=fmt_table(new, "16x16"),
        roofline_single_baseline=fmt_table(base, "16x16"),
        hillclimb_table=hill,
        generalization_table="\n".join(gen_rows),
    )
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(out)
    print(f"EXPERIMENTS.md written ({n_ok}/{n_all} cells ok)")


if __name__ == "__main__":
    main()
