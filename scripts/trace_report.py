#!/usr/bin/env python
"""Render a phase-attribution table from a saved trace (and gate CI on
trace-file health).

Reads the span traces written by ``repro.obs.trace.save`` - Chrome
``traceEvents`` JSON (``*.json``) or JSONL (one span per line) - and
attributes wall time to buckets by **self time** (a span's duration
minus its nested children), so nesting never double-counts:

* ``host``     - python/numpy bookkeeping (encode, finalize, ring
                 upkeep, routing/merge logic)
* ``dispatch`` - jax launch cost (the async call returning)
* ``device``   - blocked device execution (``block_until_ready`` /
                 host transfers)
* ``cache``    - fingerprint + L1/L2 cache resolution
* root spans (``cat="wall"``) define the denominator; their own self
  time is reported as *(uninstrumented)* - the honesty line: gaps the
  instrumentation does not explain.

This is the tool that answers "where did the H4 qps go": run
``benchmarks/bench_cluster.py --smoke --trace /tmp/t.json`` and the
table splits a routed drain into e.g. per-shard dispatch overhead vs
device time vs cache hits vs merge cost.

Examples::

    python benchmarks/bench_cluster.py --smoke --trace /tmp/t.json
    python scripts/trace_report.py /tmp/t.json
    python scripts/trace_report.py /tmp/t.json --top 15 --json
    python scripts/trace_report.py /tmp/t.json --check --min-coverage 0.9
    python scripts/trace_report.py /tmp/t.json \
        --metrics BENCH_cluster_smoke.json --slo scripts/slo_rules.json

``--check`` is the CI tier-6 gate: it validates the trace schema
(every span well-formed, categories known, at least one root span) and
fails when attribution coverage - the non-uninstrumented share of wall
time - drops below ``--min-coverage`` (default 0.9).  Exit code 0 =
healthy trace.

``--metrics PATH`` reads a metrics snapshot (a BENCH artifact with a
``metrics`` block, or a flat ``{name: value}`` JSON) and renders the
latency percentile block from the bucket-histogram keys
(``*_seconds.p50/.p95/.p99``).  ``--slo RULES.json`` additionally
evaluates the declarative SLO rules (``repro.obs.slo``) against that
snapshot and exits nonzero on any breach - the tier-6 gate reads SLOs,
not just coverage.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

BUCKETS = ("device", "dispatch", "cache", "host")
CATEGORIES = BUCKETS + ("wall",)


class TraceError(Exception):
    pass


def load_events(path: str) -> List[Dict[str, Any]]:
    """Load spans from Chrome-trace JSON or JSONL into the internal
    {name, cat, ts, dur, trace, args} form (times in microseconds)."""
    with open(path) as f:
        text = f.read()
    text = text.strip()
    if not text:
        raise TraceError(f"{path}: empty trace file")
    # format sniff: a JSONL line is itself a JSON object, so "starts
    # with {" cannot distinguish the formats - parse the first line
    # and look for the Chrome traceEvents envelope
    first_line = text.splitlines()[0]
    try:
        head = json.loads(first_line)
        is_chrome = isinstance(head, dict) and "traceEvents" in head
    except json.JSONDecodeError:
        is_chrome = True  # pretty-printed (multi-line) Chrome JSON
    if is_chrome:
        doc = json.loads(text)
        if "traceEvents" not in doc:
            raise TraceError(f"{path}: no traceEvents key")
        events = []
        for ev in doc["traceEvents"]:
            if ev.get("ph") != "X":
                continue
            args = dict(ev.get("args", {}))
            trace = args.pop("trace", None)
            events.append({
                "name": ev.get("name"), "cat": ev.get("cat"),
                "ts": ev.get("ts"), "dur": ev.get("dur"),
                "trace": trace, "args": args,
            })
        return events
    events = []
    for i, line in enumerate(text.splitlines()):
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError as e:
            raise TraceError(f"{path}:{i + 1}: bad JSONL span: {e}")
    return events


def validate(events: List[Dict[str, Any]]) -> List[str]:
    """Schema check: every span well-formed, categories known, at
    least one root.  Returns a list of problems (empty = healthy)."""
    problems = []
    n_wall = 0
    for i, ev in enumerate(events):
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"span {i}: missing/empty name")
            continue
        cat = ev.get("cat")
        if cat not in CATEGORIES:
            problems.append(
                f"span {i} ({ev['name']}): unknown cat {cat!r}")
        for key in ("ts", "dur"):
            v = ev.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v < 0:
                problems.append(
                    f"span {i} ({ev['name']}): bad {key}={v!r}")
        tr = ev.get("trace")
        if tr is not None and not isinstance(tr, int):
            problems.append(
                f"span {i} ({ev['name']}): bad trace id {tr!r}")
        if cat == "wall":
            n_wall += 1
    if not events:
        problems.append("trace contains no spans")
    elif n_wall == 0:
        problems.append(
            "no root (cat='wall') span - nothing defines wall time")
    return problems


def attribute(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Self-time attribution: one interval-nesting sweep.  Spans from
    one process/timeline are properly nested, so a (ts-sorted) stack
    walk assigns each span's duration minus its children to its own
    category."""
    order = sorted(range(len(events)),
                   key=lambda i: (events[i]["ts"], -events[i]["dur"]))
    child_dur = [0.0] * len(events)
    depth = [0] * len(events)
    stack: List[int] = []  # indices of open spans
    eps = 1e-6  # µs; absorbs float noise at shared boundaries
    for i in order:
        ev = events[i]
        while stack and (events[stack[-1]]["ts"]
                         + events[stack[-1]]["dur"]) <= ev["ts"] + eps:
            stack.pop()
        if stack:
            child_dur[stack[-1]] += ev["dur"]
            depth[i] = len(stack)
        stack.append(i)

    wall = sum(ev["dur"] for i, ev in enumerate(events)
               if depth[i] == 0)
    buckets = {b: 0.0 for b in BUCKETS}
    uninstrumented = 0.0
    by_name: Dict[str, Dict[str, float]] = {}
    for i, ev in enumerate(events):
        self_t = max(0.0, ev["dur"] - child_dur[i])
        if ev["cat"] == "wall":
            uninstrumented += self_t
        else:
            buckets[ev["cat"]] = buckets.get(ev["cat"], 0.0) + self_t
        agg = by_name.setdefault(
            ev["name"], {"self": 0.0, "dur": 0.0, "count": 0,
                         "cat": ev["cat"]})
        agg["self"] += self_t
        agg["dur"] += ev["dur"]
        agg["count"] += 1

    subsystems: Dict[str, Dict[str, float]] = {}
    for i, ev in enumerate(events):
        if ev["cat"] == "wall":
            continue
        sub = ev["name"].split(".", 1)[0]
        row = subsystems.setdefault(sub, {b: 0.0 for b in BUCKETS})
        row[ev["cat"]] += max(0.0, ev["dur"] - child_dur[i])

    n_traces = len({ev["trace"] for ev in events
                    if ev.get("trace") is not None})
    coverage = 1.0 - (uninstrumented / wall) if wall > 0 else 0.0
    return {
        "wall_us": wall,
        "buckets_us": buckets,
        "uninstrumented_us": uninstrumented,
        "coverage": coverage,
        "by_name": by_name,
        "subsystems": subsystems,
        "n_spans": len(events),
        "n_traces": n_traces,
    }


def _pct(x: float, wall: float) -> str:
    return f"{100.0 * x / wall:5.1f}%" if wall > 0 else "    -"


def render(report: Dict[str, Any], top: int = 12) -> str:
    wall = report["wall_us"]
    lines = []
    lines.append(f"trace: {report['n_spans']} spans, "
                 f"{report['n_traces']} traces, "
                 f"wall {wall / 1e6:.4f}s")
    lines.append("")
    lines.append("phase attribution (self time per bucket)")
    lines.append(f"  {'bucket':<16} {'seconds':>10}  share")
    for b in BUCKETS:
        v = report["buckets_us"][b]
        lines.append(f"  {b:<16} {v / 1e6:>10.4f}  {_pct(v, wall)}")
    u = report["uninstrumented_us"]
    lines.append(f"  {'(uninstrumented)':<16} {u / 1e6:>10.4f}  "
                 f"{_pct(u, wall)}")
    lines.append(f"  {'wall':<16} {wall / 1e6:>10.4f}  100.0%")
    lines.append(f"  coverage: {100.0 * report['coverage']:.1f}% of "
                 f"wall time attributed")
    if report["subsystems"]:
        lines.append("")
        lines.append("per subsystem (self-time share of wall)")
        lines.append("  " + f"{'subsystem':<12}" + "".join(
            f"{b:>10}" for b in BUCKETS))
        for sub in sorted(report["subsystems"],
                          key=lambda s: -sum(
                              report["subsystems"][s].values())):
            row = report["subsystems"][sub]
            lines.append("  " + f"{sub:<12}" + "".join(
                _pct(row[b], wall).rjust(10) for b in BUCKETS))
    lines.append("")
    lines.append(f"top spans by self time")
    lines.append(f"  {'span':<34} {'cat':<9} {'count':>7} "
                 f"{'self_s':>9}  share")
    ranked = sorted(report["by_name"].items(),
                    key=lambda kv: -kv[1]["self"])[:top]
    for name, agg in ranked:
        lines.append(
            f"  {name:<34} {agg['cat']:<9} {agg['count']:>7} "
            f"{agg['self'] / 1e6:>9.4f}  {_pct(agg['self'], wall)}"
        )
    return "\n".join(lines)


def load_metrics(path: str) -> Dict[str, float]:
    """Flat metrics snapshot from a BENCH artifact (its ``metrics``
    block) or a flat ``{name: value}`` JSON dict."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        raise TraceError(f"{path}: metrics file is not a JSON object")
    if "metrics" in doc and isinstance(doc["metrics"], dict):
        doc = doc["metrics"]
    return {k: v for k, v in doc.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}


def render_percentiles(snap: Dict[str, float]) -> str:
    """The latency percentile block: one row per bucket histogram
    that exported quantiles (``<base>.p50/.p95/.p99`` snapshot keys)."""
    bases = sorted({k[: -len(".p50")] for k in snap if k.endswith(".p50")})
    if not bases:
        return "latency percentiles: (no bucket histograms in snapshot)"
    lines = ["latency percentiles (bucket-histogram upper bounds)"]
    lines.append(f"  {'histogram':<40} {'count':>8} {'p50':>10} "
                 f"{'p95':>10} {'p99':>10} {'max':>10}")
    for base in bases:
        def col(suffix):
            v = snap.get(f"{base}.{suffix}")
            if v is None:
                return "-".rjust(10)
            return f"{v * 1e3:>9.3f}m" if suffix != "count" \
                else f"{int(v):>8}"
        lines.append(f"  {base:<40} {col('count')} {col('p50')} "
                     f"{col('p95')} {col('p99')} {col('max')}")
    return "\n".join(lines)


def check_slo(rules_path: str, snap: Dict[str, float]) -> int:
    """Evaluate declarative SLO rules against the snapshot; prints a
    verdict per rule set and returns the breach count."""
    from repro.obs.slo import evaluate, load_rules
    rules = load_rules(rules_path)
    breaches = evaluate(rules, snap)
    for b in breaches:
        print(f"[trace_report] {b}")
    if breaches:
        print(f"[trace_report] SLO FAIL: {len(breaches)} of "
              f"{len(rules)} rule(s) breached")
    else:
        print(f"[trace_report] SLO OK: {len(rules)} rule(s) within "
              "bounds")
    return len(breaches)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="trace file (.json Chrome trace or "
                                  "JSONL from repro.obs.trace.save)")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: validate the span schema and fail "
                         "below --min-coverage attribution")
    ap.add_argument("--min-coverage", type=float, default=0.9,
                    help="minimum attributed share of wall time for "
                         "--check (default 0.9)")
    ap.add_argument("--top", type=int, default=12,
                    help="rows in the top-spans table")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable summary instead "
                         "of the table")
    ap.add_argument("--metrics", metavar="PATH",
                    help="metrics snapshot (BENCH artifact or flat "
                         "JSON): renders the latency percentile block")
    ap.add_argument("--slo", metavar="RULES.json",
                    help="evaluate SLO rules against --metrics; exit "
                         "nonzero on any breach")
    args = ap.parse_args(argv)
    if args.slo and not args.metrics:
        ap.error("--slo requires --metrics")

    try:
        events = load_events(args.trace)
    except (OSError, json.JSONDecodeError, TraceError) as e:
        print(f"[trace_report] FAIL: {e}")
        return 1
    problems = validate(events)
    if problems:
        for p in problems:
            print(f"[trace_report] malformed span: {p}")
        if args.check:
            print(f"[trace_report] FAIL: {len(problems)} schema "
                  "problem(s)")
            return 1
    report = attribute(events)
    if args.json:
        out = dict(report)
        out["by_name"] = {k: v for k, v in sorted(
            report["by_name"].items())}
        print(json.dumps(out, indent=2))
    else:
        print(render(report, top=args.top))
    if args.check:
        if report["coverage"] < args.min_coverage:
            print(f"[trace_report] FAIL: coverage "
                  f"{report['coverage']:.3f} < "
                  f"{args.min_coverage:.3f} - the instrumentation "
                  "does not explain enough of the wall time")
            return 1
        print(f"[trace_report] check OK: {report['n_spans']} spans, "
              f"coverage {report['coverage']:.3f} >= "
              f"{args.min_coverage:.3f}")
    if args.metrics:
        try:
            snap = load_metrics(args.metrics)
        except (OSError, json.JSONDecodeError, TraceError) as e:
            print(f"[trace_report] FAIL: {e}")
            return 1
        if not args.json:
            print()
            print(render_percentiles(snap))
        if args.slo and check_slo(args.slo, snap):
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
