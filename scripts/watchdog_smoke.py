#!/usr/bin/env python
"""CI tier-6 smoke: the SLO watchdog fires on an injected stall.

Builds a toy serving cluster on an injectable fake clock, runs a
healthy pass (no breach expected), then injects a stall - queries
admitted but never flushed while the fake clock jumps past the
queue-aging bound - and asserts the watchdog demonstrably fires:

* ``cluster.router.slo_breaches`` > 0
* the flight recorder dump lands on disk (with the breach reason)
* after collecting the stalled tickets, results are still exact

Exit 0 = the always-on alarm path works end to end.
"""
from __future__ import annotations

import json
import os
import random
import sys
import tempfile

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.compile import compile_sequence  # noqa: E402
from repro.data.synthetic import random_graph_sequence  # noqa: E402
from repro.mining.driver import AcceleratedMiner  # noqa: E402
from repro.obs import FlightRecorder, load_rules, trace  # noqa: E402
from repro.obs.slo import SloWatchdog  # noqa: E402
from repro.serving.bank import compile_bank  # noqa: E402
from repro.serving.cluster import ServingCluster  # noqa: E402

RULES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "slo_rules.json")


def _db(seed, n_seq):
    rng = random.Random(seed)
    return [compile_sequence(random_graph_sequence(rng, n_steps=4,
                                                   n_v=4, n_vl=2,
                                                   n_el=2))
            for _ in range(n_seq)]


def main() -> int:
    bank = compile_bank(AcceleratedMiner(_db(3, 12)).mine_rs(2,
                                                             max_len=3))
    assert bank.n_patterns, "toy mine produced an empty bank"
    queries = _db(7, 8)

    now = [0.0]
    clock = lambda: now[0]  # noqa: E731
    cl = ServingCluster(bank, 2, bank_layout="flat",
                        max_wait=10.0, clock=clock)
    dump_path = os.path.join(tempfile.mkdtemp(prefix="wd_smoke_"),
                             "flight.jsonl")
    flight = FlightRecorder(capacity=16, metrics=cl.metrics,
                            metrics_prefix="cluster.router",
                            clock=clock)
    trace.enable_sampling(0.5, metrics=cl.metrics, flight=flight)
    wd = SloWatchdog(cl.metrics, load_rules(RULES), clock=clock,
                     min_interval=0.5, flight=flight,
                     dump_path=dump_path)
    cl.attach_watchdog(wd)
    breaches = cl.metrics.counter("cluster.router.slo_breaches")

    # healthy pass: submit + collect promptly, no rule should fire
    t = cl.submit({0: queries[:4]})
    now[0] += 0.01
    res_healthy = cl.collect(t)
    now[0] += 1.0
    cl.poll()
    if breaches.value:
        print(f"[watchdog_smoke] FAIL: {breaches.value} breach(es) on "
              "the healthy pass")
        return 1
    print("[watchdog_smoke] healthy pass: 0 breaches "
          f"({wd.checks} checks)")

    # injected stall: admit fresh misses, then let the fake clock run
    # past the queue-aging bound with no flush (max_wait=10 keeps the
    # deadline trigger out of the way; poll still drives the watchdog)
    stalled = cl.submit({1: queries[4:]})
    for _ in range(8):
        now[0] += 1.5
        cl.poll()
    if not breaches.value:
        print("[watchdog_smoke] FAIL: watchdog never fired under an "
              f"8x1.5s stall (checks={wd.checks})")
        return 1
    if not os.path.exists(dump_path):
        print("[watchdog_smoke] FAIL: breach fired but no flight dump "
              f"at {dump_path}")
        return 1
    with open(dump_path) as f:
        header = json.loads(f.readline())
    if not header.get("flight_recorder") or \
            not str(header.get("reason", "")).startswith("slo:"):
        print(f"[watchdog_smoke] FAIL: bad dump header {header}")
        return 1
    print(f"[watchdog_smoke] stall detected: breaches="
          f"{breaches.value}, dump reason={header['reason']!r}")

    # the stalled ticket still collects exactly - alarms observe, they
    # never change answers
    res = cl.collect(stalled)
    exact = all(r.exact for rs in res.values() for r in rs)
    n_res = sum(len(rs) for rs in res.values()) + \
        sum(len(rs) for rs in res_healthy.values())
    if not exact or n_res != len(queries):
        print("[watchdog_smoke] FAIL: stalled collect returned "
              f"exact={exact}, n={n_res}")
        return 1
    trace.disable()
    trace.clear()
    print(f"[watchdog_smoke] OK: {n_res} exact results, watchdog + "
          "flight-recorder alarm path verified")
    return 0


if __name__ == "__main__":
    sys.exit(main())
