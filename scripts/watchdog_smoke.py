#!/usr/bin/env python
"""CI tier-6 smoke: the SLO watchdog fires on an injected stall.

Builds a toy serving cluster on an injectable fake clock, runs a
healthy pass (no breach expected), then injects a stall - queries
admitted but never flushed while the fake clock jumps past the
queue-aging bound - and asserts the watchdog demonstrably fires:

* ``cluster.router.slo_breaches`` > 0
* the flight recorder dump lands on disk (with the breach reason)
* after collecting the stalled tickets, results are still exact

Then the host-blackout scenario: a fresh cluster with a
``FaultInjector`` blacking out one host and the retry/breaker policy
armed.  The breaker opens, the ``breaker-open`` SLO rule breaches on
the counter's movement, the flight dump carries the ``host_fault``
trace marks the retry ladder emitted - and the service keeps
answering, degraded (flagged ``exact=False``) but complete.

Exit 0 = the always-on alarm path works end to end.
"""
from __future__ import annotations

import json
import os
import random
import sys
import tempfile

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.compile import compile_sequence  # noqa: E402
from repro.data.synthetic import random_graph_sequence  # noqa: E402
from repro.mining.driver import AcceleratedMiner  # noqa: E402
from repro.obs import FlightRecorder, load_rules, trace  # noqa: E402
from repro.obs.slo import SloWatchdog  # noqa: E402
from repro.serving.bank import compile_bank  # noqa: E402
from repro.serving.cluster import ServingCluster  # noqa: E402
from repro.serving.faults import FaultInjector, RetryPolicy  # noqa: E402

RULES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "slo_rules.json")


def _db(seed, n_seq):
    rng = random.Random(seed)
    return [compile_sequence(random_graph_sequence(rng, n_steps=4,
                                                   n_v=4, n_vl=2,
                                                   n_el=2))
            for _ in range(n_seq)]


def main() -> int:
    bank = compile_bank(AcceleratedMiner(_db(3, 12)).mine_rs(2,
                                                             max_len=3))
    assert bank.n_patterns, "toy mine produced an empty bank"
    queries = _db(7, 8)

    now = [0.0]
    clock = lambda: now[0]  # noqa: E731
    cl = ServingCluster(bank, 2, bank_layout="flat",
                        max_wait=10.0, clock=clock)
    dump_path = os.path.join(tempfile.mkdtemp(prefix="wd_smoke_"),
                             "flight.jsonl")
    flight = FlightRecorder(capacity=16, metrics=cl.metrics,
                            metrics_prefix="cluster.router",
                            clock=clock)
    trace.enable_sampling(0.5, metrics=cl.metrics, flight=flight)
    wd = SloWatchdog(cl.metrics, load_rules(RULES), clock=clock,
                     min_interval=0.5, flight=flight,
                     dump_path=dump_path)
    cl.attach_watchdog(wd)
    breaches = cl.metrics.counter("cluster.router.slo_breaches")

    # healthy pass: submit + collect promptly, no rule should fire
    t = cl.submit({0: queries[:4]})
    now[0] += 0.01
    res_healthy = cl.collect(t)
    now[0] += 1.0
    cl.poll()
    if breaches.value:
        print(f"[watchdog_smoke] FAIL: {breaches.value} breach(es) on "
              "the healthy pass")
        return 1
    print("[watchdog_smoke] healthy pass: 0 breaches "
          f"({wd.checks} checks)")

    # injected stall: admit fresh misses, then let the fake clock run
    # past the queue-aging bound with no flush (max_wait=10 keeps the
    # deadline trigger out of the way; poll still drives the watchdog)
    stalled = cl.submit({1: queries[4:]})
    for _ in range(8):
        now[0] += 1.5
        cl.poll()
    if not breaches.value:
        print("[watchdog_smoke] FAIL: watchdog never fired under an "
              f"8x1.5s stall (checks={wd.checks})")
        return 1
    if not os.path.exists(dump_path):
        print("[watchdog_smoke] FAIL: breach fired but no flight dump "
              f"at {dump_path}")
        return 1
    with open(dump_path) as f:
        header = json.loads(f.readline())
    if not header.get("flight_recorder") or \
            not str(header.get("reason", "")).startswith("slo:"):
        print(f"[watchdog_smoke] FAIL: bad dump header {header}")
        return 1
    print(f"[watchdog_smoke] stall detected: breaches="
          f"{breaches.value}, dump reason={header['reason']!r}")

    # the stalled ticket still collects exactly - alarms observe, they
    # never change answers
    res = cl.collect(stalled)
    exact = all(r.exact for rs in res.values() for r in rs)
    n_res = sum(len(rs) for rs in res.values()) + \
        sum(len(rs) for rs in res_healthy.values())
    if not exact or n_res != len(queries):
        print("[watchdog_smoke] FAIL: stalled collect returned "
              f"exact={exact}, n={n_res}")
        return 1
    trace.disable()
    trace.clear()
    print(f"[watchdog_smoke] OK: {n_res} exact results, watchdog + "
          "flight-recorder alarm path verified")

    # host-blackout scenario: one host dark behind the injector, the
    # breaker opens, the breaker-open rule breaches, the flight dump
    # carries the host_fault marks - and every query still answers
    now3 = [0.0]
    clock3 = lambda: now3[0]  # noqa: E731
    inj = FaultInjector(0, blackouts=[(1, 0.0, 10 ** 9)], clock=clock3)
    cl3 = ServingCluster(
        bank, 2, bank_layout="flat", clock=clock3, injector=inj,
        fault_policy=RetryPolicy(retries=1, breaker_threshold=2,
                                 breaker_cooldown=100.0),
        max_wait=10.0)
    dump3 = os.path.join(os.path.dirname(dump_path), "flight_fault.jsonl")
    flight3 = FlightRecorder(capacity=32, metrics=cl3.metrics,
                             metrics_prefix="cluster.router",
                             clock=clock3)
    trace.enable_sampling(1.0, metrics=cl3.metrics, flight=flight3)
    wd3 = SloWatchdog(cl3.metrics, load_rules(RULES), clock=clock3,
                      min_interval=0.5, flight=flight3,
                      dump_path=dump3)
    cl3.attach_watchdog(wd3)
    breaches3 = cl3.metrics.counter("cluster.router.slo_breaches")
    res3 = cl3.query_multi({0: queries[:4], 1: queries[4:]})
    for _ in range(3):
        now3[0] += 1.0
        cl3.poll()
    trace.disable()
    trace.clear()
    got3 = [r for rs in res3.values() for r in rs]
    if len(got3) != len(queries) or any(r.exact for r in got3):
        print("[watchdog_smoke] FAIL: blackout drain answered "
              f"{len(got3)}/{len(queries)} with exact flags "
              f"{[r.exact for r in got3]} - expected all degraded")
        return 1
    if not breaches3.value:
        print("[watchdog_smoke] FAIL: breaker opened but the "
              f"breaker-open rule never breached (checks={wd3.checks})")
        return 1
    if not os.path.exists(dump3):
        print(f"[watchdog_smoke] FAIL: no flight dump at {dump3}")
        return 1
    with open(dump3) as f:
        dump_text = f.read()
    header3 = json.loads(dump_text.splitlines()[0])
    if "breaker-open" not in str(header3.get("reason", "")):
        print(f"[watchdog_smoke] FAIL: dump reason "
              f"{header3.get('reason')!r} missing breaker-open")
        return 1
    if "host_fault" not in dump_text:
        print("[watchdog_smoke] FAIL: flight dump carries no "
              "host_fault trace marks")
        return 1
    print(f"[watchdog_smoke] OK: host blackout -> breaker open, "
          f"breaches={breaches3.value}, dump "
          f"reason={header3['reason']!r} with host_fault marks, "
          f"{len(got3)} degraded answers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
