"""jax version-compatibility shims (leaf module: importable from any
layer without cycles).

Covers the 0.4 -> 0.5+ API moves used in this repo: ``shard_map``'s
promotion out of jax.experimental (with the ``check_rep`` ->
``check_vma`` kwarg rename happening separately) and the ``set_mesh``
context manager.
"""
from __future__ import annotations

import contextlib
import inspect

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions."""
    if hasattr(jax, "shard_map"):
        params = inspect.signature(jax.shard_map).parameters
        kw = "check_vma" if "check_vma" in params else "check_rep"
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **{kw: False})
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def set_mesh_compat(mesh):
    """jax.set_mesh context across versions (pre-0.5 shard_map takes the
    mesh explicitly, so the context is a no-op)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext()
