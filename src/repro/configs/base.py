"""Arch x shape grid: every assigned architecture is an ``Arch`` exposing
a uniform surface to the launcher/dry-run:

* ``abstract_params(shape)`` / ``init_params(rng, shape)``
* ``make_step(shape)``   -> (step_fn, abstract example args)
* ``arg_specs(shape, mesh)`` -> PartitionSpec pytree matching the args
* ``model_flops(shape)`` -> useful-work FLOPs for the roofline ratio
* ``smoke_bundle(rng)``  -> reduced-config one-step closure for CPU tests

Step kinds: "train" lowers loss+grad+optimizer; "prefill"/"serve"/"score"
lower the inference path the shape dictates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..models import common
from ..training.optimizer import AdamW, clip_by_global_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str  # train | prefill | serve | score
    meta: Dict[str, Any]


LM_SHAPES = {
    "train_4k": ShapeDef("train_4k", "train",
                         {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeDef("prefill_32k", "prefill",
                            {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeDef("decode_32k", "serve",
                           {"seq": 32768, "batch": 128}),
    "long_500k": ShapeDef("long_500k", "serve",
                          {"seq": 524288, "batch": 1}),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeDef(
        "full_graph_sm", "train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
         "n_classes": 7, "task": "node"},
    ),
    "minibatch_lg": ShapeDef(
        "minibatch_lg", "train",
        {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
         "fanout": (15, 10), "d_feat": 602, "n_classes": 41,
         "task": "node_sampled",
         # padded static sizes for one sampled block
         "pad_nodes": 180224, "pad_edges": 179200},
    ),
    "ogb_products": ShapeDef(
        "ogb_products", "train",
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
         "n_classes": 47, "task": "node"},
    ),
    "molecule": ShapeDef(
        "molecule", "train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "task": "graph",
         "n_classes": 2, "d_feat": 10},
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeDef("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeDef("serve_p99", "score", {"batch": 512}),
    "serve_bulk": ShapeDef("serve_bulk", "score", {"batch": 262144}),
    "retrieval_cand": ShapeDef(
        "retrieval_cand", "score", {"batch": 1, "n_candidates": 1_000_000}
    ),
}

MINING_SHAPES = {
    "scan_1m": ShapeDef(
        "scan_1m", "mine",
        {"n_seq": 1_048_576, "tokens": 128, "emb_batch": 4096, "ni": 16,
         "nv": 12, "k": 8192},
    ),
    "scan_xl": ShapeDef(
        "scan_xl", "mine",
        {"n_seq": 262144, "tokens": 512, "emb_batch": 16384, "ni": 16,
         "nv": 12, "k": 8192},
    ),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


class Arch:
    name: str
    family: str
    shapes: Dict[str, ShapeDef]

    # ---- to implement per family ----
    def abstract_params(self, shape: str) -> PyTree:
        raise NotImplementedError

    def init_params(self, rng, shape: str) -> PyTree:
        raise NotImplementedError

    def param_rules(self) -> common.Rules:
        raise NotImplementedError

    def batch_abstract(self, shape: str) -> PyTree:
        raise NotImplementedError

    def batch_spec_templates(self, shape: str) -> PyTree:
        raise NotImplementedError

    def loss_fn(self, shape: str) -> Callable:
        raise NotImplementedError

    def model_flops(self, shape: str) -> float:
        raise NotImplementedError

    def smoke_bundle(self) -> Tuple[Callable, PyTree]:
        """(one-step closure, inputs) on a reduced config; returns loss."""
        raise NotImplementedError

    # ---- shared machinery ----
    def optimizer(self) -> AdamW:
        return AdamW(lr=1e-3, weight_decay=0.01)

    def make_train_step(self, shape: str, mesh=None):
        loss_fn = self.loss_fn(shape)
        opt = self.optimizer()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.update(grads, opt_state, params)
            return loss, params, opt_state

        params = self.abstract_params(shape)
        opt_state = jax.eval_shape(opt.init, params)
        batch = self.batch_abstract(shape)
        return train_step, (params, opt_state, batch)

    def make_step(self, shape: str, mesh=None):
        kind = self.shapes[shape].kind
        if kind == "train":
            return self.make_train_step(shape, mesh)
        return self.make_serve_step(shape, mesh)

    def make_serve_step(self, shape: str, mesh=None):
        raise NotImplementedError

    def arg_specs(self, shape: str, mesh: Mesh, args: PyTree) -> PyTree:
        """PartitionSpec pytree matching make_step's abstract args."""
        kind = self.shapes[shape].kind
        rules = self.param_rules()

        if kind == "train":
            params, opt_state, batch = args
            pspec = common.tree_param_specs(params, rules, mesh)
            ospec = opt_state_specs(opt_state, rules, mesh)
            bspec = resolve_batch(self.batch_spec_templates(shape), mesh)
            bspec = common.guard_tree_specs(batch, bspec, mesh)
            return (pspec, ospec, bspec)
        params = args[0]
        pspec = common.tree_param_specs(params, rules, mesh)
        rest = [
            common.guard_tree_specs(a, resolve_batch(t, mesh), mesh)
            for a, t in zip(args[1:], self.serve_spec_templates(shape))
        ]
        return (pspec, *rest)


def resolve_batch(tpl_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda t: common.resolve_template(t, mesh),
        tpl_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None), tuple)) for e in x
        ),
    )


def opt_state_specs(opt_state, rules, mesh) -> PyTree:
    """Optimizer state mirrors param sharding; quantized scales drop the
    spec entry on their size-1 trailing axis (handled by the dim-1 guard
    in tree_param_specs)."""
    return common.tree_param_specs(opt_state, rules, mesh)
