"""Family adapters: LM / GNN / RecSys / Mining archs with the uniform
Arch surface (see base.py)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import bert4rec as b4r
from ..models import gnn as gnn_mod
from ..models import mace as mace_mod
from ..models import transformer as tf
from ..models.moe import MoEConfig
from ..training.optimizer import AdamW
from .base import (
    Arch,
    GNN_SHAPES,
    LM_SHAPES,
    MINING_SHAPES,
    RECSYS_SHAPES,
    ShapeDef,
    _sds,
)

PyTree = Any
DATA = "DATA"
MODEL = "MODEL"


def _pad_mult(n: int, mult: int = 1024) -> int:
    """Round edge counts up so every mesh factorization divides them
    (the data pipeline pads edge lists with masked / (0,0)-self-loop
    entries; see DESIGN.md)."""
    return -(-n // mult) * mult


# =================================================================== LM
class LMArch(Arch):
    family = "lm"
    shapes = LM_SHAPES

    def __init__(self, cfg: tf.TransformerConfig,
                 smoke_cfg: tf.TransformerConfig,
                 opt_state_dtype: str = "float32",
                 active_params_ratio: float = 1.0):
        self.name = cfg.name
        self.cfg = cfg
        self.smoke_cfg = smoke_cfg
        self.opt_state_dtype = opt_state_dtype
        self._active_ratio = active_params_ratio

    # ---- params
    def abstract_params(self, shape: str) -> PyTree:
        return tf.abstract_params(self.cfg)

    def init_params(self, rng, shape: str) -> PyTree:
        return tf.init_params(rng, self.cfg)

    def param_rules(self):
        # TP over "model", FSDP/ZeRO over the pure-DP axes ("DATA")
        return [
            (r"embed", (MODEL, DATA)),                   # [V, D]
            (r"head", (DATA, MODEL)),                    # [D, V]
            (r"moe/wr", (None, DATA, None)),             # router [n,D,E]
            (r"moe/shared_wi|moe/shared_wg", (None, DATA, MODEL)),
            (r"moe/shared_wo", (None, MODEL, DATA)),
            (r"moe/wi|moe/wg", (None, MODEL, DATA, None)),  # [n,E,D,F]
            (r"moe/wo", (None, MODEL, None, DATA)),      # [n,E,F,D]
            (r"wq$|wk$|wv$", (None, DATA, MODEL)),       # [n,D,H*hd]
            (r"wo$", (None, MODEL, DATA)),               # [n,H*hd,D]
            (r"mlp/wi|mlp/wg", (None, DATA, MODEL)),     # [n,D,F]
            (r"mlp/wo", (None, MODEL, DATA)),            # [n,F,D]
            (r"ln", ()),
        ]

    def optimizer(self) -> AdamW:
        return AdamW(lr=3e-4, weight_decay=0.01,
                     state_dtype=self.opt_state_dtype)

    # ---- batches
    def batch_abstract(self, shape: str) -> PyTree:
        m = self.shapes[shape].meta
        return {
            "tokens": _sds((m["batch"], m["seq"]), jnp.int32),
            "targets": _sds((m["batch"], m["seq"]), jnp.int32),
        }

    def batch_spec_templates(self, shape: str) -> PyTree:
        return {"tokens": (DATA, None), "targets": (DATA, None)}

    def loss_fn(self, shape: str) -> Callable:
        cfg = self.cfg
        return lambda params, batch: tf.lm_loss(params, batch, cfg)

    def _mesh_cfg(self, mesh):
        import dataclasses as _dc
        from ..models.common import dp_axes
        if mesh is None:
            return self.cfg
        return _dc.replace(self.cfg, batch_axes=dp_axes(mesh))

    def make_train_step(self, shape: str, mesh=None):
        if mesh is not None:
            cfg = self._mesh_cfg(mesh)
            import dataclasses as _dc
            arch = LMArch(cfg, self.smoke_cfg, self.opt_state_dtype)
            return super(LMArch, arch).make_train_step(shape)
        return super().make_train_step(shape)

    # ---- serve / prefill
    def make_serve_step(self, shape: str, mesh=None):
        sd = self.shapes[shape]
        m = sd.meta
        cfg = self._mesh_cfg(mesh)
        params = self.abstract_params(shape)
        if sd.kind == "prefill":
            def prefill(params, tokens):
                hidden, _ = tf.forward(params, tokens, cfg)
                # return only the last-position logits (next-token)
                return tf.logits_fn(params, hidden[:, -1:, :], cfg)

            tokens = _sds((m["batch"], m["seq"]), jnp.int32)
            return prefill, (params, tokens)
        # decode: one token against a full cache
        cache = tf.abstract_cache(cfg, m["batch"], m["seq"])
        tokens = _sds((m["batch"], 1), jnp.int32)

        def decode(params, cache, tokens):
            return tf.decode_step(params, cache, tokens, cfg)

        return decode, (params, cache, tokens)

    def serve_spec_templates(self, shape: str):
        sd = self.shapes[shape]
        m = sd.meta
        if sd.kind == "prefill":
            return [(DATA, None)]  # tokens
        batch_axes = DATA if m["batch"] > 1 else None
        # cache [n_super, B, S, KV, hd]: batch over DATA when possible,
        # sequence over MODEL (split-KV decode); B=1 long-context shards
        # the sequence over every axis.
        seq_axes = MODEL if m["batch"] > 1 else (DATA, MODEL)
        kv_spec = (None, batch_axes, seq_axes, None, None)
        cache_spec = {
            "kv": {
                f"sub{i}": {"k": kv_spec, "v": kv_spec}
                for i in range(self.cfg.moe_period)
            },
            "len": (batch_axes,),
        }
        return [cache_spec, (batch_axes, None)]

    # ---- metrics
    def n_params(self, active_only=False) -> float:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.head_dim
        attn = cfg.n_layers * (
            d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        )
        n_moe_layers = (cfg.n_layers // cfg.moe_period
                        if cfg.moe else 0)
        n_dense_layers = cfg.n_layers - n_moe_layers
        nmat = 3 if cfg.gated_mlp else 2
        mlp = n_dense_layers * nmat * d * cfg.d_ff
        moe = 0.0
        if cfg.moe:
            e = cfg.moe.top_k if active_only else cfg.moe.n_experts
            moe = n_moe_layers * (
                nmat * (e + cfg.moe.n_shared) * d * cfg.moe.d_ff
                + d * cfg.moe.n_experts
            )
        embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
        return float(attn + mlp + moe + embed)

    def model_flops(self, shape: str) -> float:
        m = self.shapes[shape].meta
        n_act = self.n_params(active_only=True)
        if self.shapes[shape].kind == "train":
            return 6.0 * n_act * m["batch"] * m["seq"]
        if self.shapes[shape].kind == "prefill":
            return 2.0 * n_act * m["batch"] * m["seq"]
        # decode: one token per row + attention over the cache
        cfg = self.cfg
        attn = (4.0 * m["batch"] * m["seq"] * cfg.n_layers
                * cfg.n_kv_heads * cfg.head_dim)
        return 2.0 * n_act * m["batch"] + attn

    # ---- smoke
    def smoke_bundle(self):
        cfg = self.smoke_cfg
        rng = jax.random.PRNGKey(0)
        params = tf.init_params(rng, cfg)
        toks = jax.random.randint(rng, (2, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: tf.lm_loss(p, batch, cfg)
            )(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return loss, params, opt_state

        return step, (params, opt_state, batch)


# ================================================================== GNN
class GNNArch(Arch):
    family = "gnn"
    shapes = GNN_SHAPES

    def __init__(self, name: str, kind: str, n_layers: int, d_hidden: int,
                 n_heads: int = 1):
        self.name = name
        self.kind = kind
        self.n_layers = n_layers
        self.d_hidden = d_hidden
        self.n_heads = n_heads

    def _cfg(self, shape: str) -> gnn_mod.GNNConfig:
        m = self.shapes[shape].meta
        return gnn_mod.GNNConfig(
            name=self.name, kind=self.kind, n_layers=self.n_layers,
            d_in=m.get("d_feat", 16), d_hidden=self.d_hidden,
            n_classes=m.get("n_classes", 2), n_heads=self.n_heads,
        )

    def abstract_params(self, shape: str) -> PyTree:
        cfg = self._cfg(shape)
        return jax.eval_shape(
            lambda: gnn_mod.init_params(jax.random.PRNGKey(0), cfg)
        )

    def init_params(self, rng, shape: str) -> PyTree:
        return gnn_mod.init_params(rng, self._cfg(shape))

    def param_rules(self):
        return [(r".*", ())]  # GNN params are tiny: replicate

    def optimizer(self) -> AdamW:
        return AdamW(lr=1e-2, weight_decay=5e-4)

    def batch_abstract(self, shape: str) -> PyTree:
        m = self.shapes[shape].meta
        task = m["task"]
        if task == "node":
            n, e = m["n_nodes"], m["n_edges"]
            e_tot = _pad_mult(2 * e + n)  # both dirs + self loops, padded
            return {
                "x": _sds((n, m["d_feat"]), jnp.float32),
                "edges": _sds((2, e_tot), jnp.int32),
                "labels": _sds((n,), jnp.int32),
                "mask": _sds((n,), jnp.float32),
            }
        if task == "node_sampled":
            n, e = m["pad_nodes"], m["pad_edges"]
            e_tot = _pad_mult(2 * e + n)
            return {
                "x": _sds((n, m["d_feat"]), jnp.float32),
                "edges": _sds((2, e_tot), jnp.int32),
                "labels": _sds((n,), jnp.int32),
                "mask": _sds((n,), jnp.float32),
                "edge_mask": _sds((e_tot,), jnp.int32),
            }
        # molecule: batched small graphs
        b, npg, epg = m["batch"], m["n_nodes"], m["n_edges"]
        n = b * npg
        e_tot = _pad_mult(2 * b * epg)
        return {
            "edges": _sds((2, e_tot), jnp.int32),
            "graph_id": _sds((n,), jnp.int32),
            "graph_labels": _sds((b,), jnp.int32),
            "x": _sds((n, m["d_feat"]), jnp.float32),
        }

    def batch_spec_templates(self, shape: str) -> PyTree:
        m = self.shapes[shape].meta
        big = m["task"] in ("node", "node_sampled") and m["n_nodes"] > 10000
        espec = (None, DATA) if big else (None, None)
        out = {
            "x": (None, None),  # d_feat of the assigned shapes is not
            # divisible by the model axis; features replicate (see the
            # padded-feature hillclimb in EXPERIMENTS.md SPerf)
            "edges": espec,
            "labels": (None,),
            "mask": (None,),
        }
        if m["task"] == "node_sampled":
            out["edge_mask"] = (DATA,) if big else (None,)
            out["edge_mask"] = (None,)  # mask aligned with edges: replicate
        if m["task"] == "graph":
            out = {
                "edges": (None, DATA),
                "graph_id": (None,),
                "graph_labels": (None,),
                "x": (None, None),
            }
        return out

    def loss_fn(self, shape: str) -> Callable:
        cfg = self._cfg(shape)
        m = self.shapes[shape].meta
        task = m["task"]
        if task == "graph":
            return lambda p, b: gnn_mod.graph_classification_loss(
                p, {**b, "n_graphs": m["batch"]}, cfg
            )
        return lambda p, b: gnn_mod.node_classification_loss(p, b, cfg)

    def model_flops(self, shape: str) -> float:
        m = self.shapes[shape].meta
        cfg = self._cfg(shape)
        if m["task"] == "graph":
            n = m["batch"] * m["n_nodes"]
            e = 2 * m["batch"] * m["n_edges"]
            d_in = 10
        elif m["task"] == "node_sampled":
            n, e = m["pad_nodes"], 2 * m["pad_edges"] + m["pad_nodes"]
            d_in = m["d_feat"]
        else:
            n, e = m["n_nodes"], 2 * m["n_edges"] + m["n_nodes"]
            d_in = m["d_feat"]
        fl = 0.0
        d_prev = d_in
        for li in range(cfg.n_layers):
            d_out = (cfg.n_classes if li == cfg.n_layers - 1
                     else cfg.d_hidden)
            heads = cfg.n_heads if cfg.kind == "gat" else 1
            fl += 2.0 * n * d_prev * d_out * heads   # transform
            fl += 2.0 * e * d_out * heads            # message agg
            d_prev = d_out * (heads if cfg.kind == "gat"
                              and li < cfg.n_layers - 1 else 1)
        return 3.0 * fl  # fwd + bwd ~ 3x fwd for message passing

    def smoke_bundle(self):
        from ..data.graphs import random_molecule_batch, random_node_graph

        rng_np = np.random.default_rng(0)
        shape = "full_graph_sm"
        cfg = dataclasses.replace(
            self._cfg(shape), d_in=16, n_classes=4, d_hidden=8
        )
        g = random_node_graph(rng_np, 64, 128, 16, 4)
        batch = {k: jnp.asarray(v) for k, v in g.items()}
        params = gnn_mod.init_params(jax.random.PRNGKey(0), cfg)
        opt = self.optimizer()
        opt_state = opt.init(params)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: gnn_mod.node_classification_loss(p, batch, cfg)
            )(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return loss, params, opt_state

        return step, (params, opt_state, batch)


# ================================================================= MACE
class MACEArch(Arch):
    family = "gnn"
    shapes = GNN_SHAPES

    def __init__(self, cfg: mace_mod.MACEConfig):
        self.name = cfg.name
        self.cfg = cfg

    def abstract_params(self, shape: str) -> PyTree:
        return jax.eval_shape(
            lambda: mace_mod.init_params(jax.random.PRNGKey(0), self.cfg)
        )

    def init_params(self, rng, shape: str) -> PyTree:
        return mace_mod.init_params(rng, self.cfg)

    def param_rules(self):
        return [(r".*", ())]

    def optimizer(self) -> AdamW:
        return AdamW(lr=1e-2)

    def _sizes(self, shape: str):
        m = self.shapes[shape].meta
        if m["task"] == "graph":
            return (m["batch"] * m["n_nodes"],
                    _pad_mult(2 * m["batch"] * m["n_edges"]), m["batch"])
        if m["task"] == "node_sampled":
            return (m["pad_nodes"],
                    _pad_mult(2 * m["pad_edges"] + m["pad_nodes"]), 1)
        return (m["n_nodes"], _pad_mult(2 * m["n_edges"] + m["n_nodes"]), 1)

    def batch_abstract(self, shape: str) -> PyTree:
        n, e, g = self._sizes(shape)
        return {
            "species": _sds((n,), jnp.int32),
            "pos": _sds((n, 3), jnp.float32),
            "edges": _sds((2, e), jnp.int32),
            "graph_id": _sds((n,), jnp.int32),
            "targets": _sds((g,), jnp.float32),
        }

    def batch_spec_templates(self, shape: str) -> PyTree:
        n, e, _ = self._sizes(shape)
        big = e > 1_000_000
        return {
            "species": (None,),
            "pos": (None, None),
            "edges": (None, DATA) if big else (None, None),
            "graph_id": (None,),
            "targets": (None,),
        }

    def loss_fn(self, shape: str) -> Callable:
        cfg = self.cfg
        g = self._sizes(shape)[2]
        return lambda p, b: mace_mod.energy_loss(
            p, {**b, "n_graphs": g}, cfg
        )

    def model_flops(self, shape: str) -> float:
        n, e, _ = self._sizes(shape)
        C = self.cfg.d_hidden
        per_layer = (
            2.0 * e * self.cfg.n_rbf * C + 2.0 * e * C * C  # radial MLP
            + 2.0 * e * 9 * C                               # messages
            + 2.0 * n * 9 * 3 * C * C                       # mix
            + 2.0 * n * 9 * C * C                           # self
        )
        return 3.0 * self.cfg.n_layers * per_layer

    def smoke_bundle(self):
        from ..data.graphs import random_molecule_batch

        cfg = dataclasses.replace(self.cfg, d_hidden=16, n_layers=2)
        g = random_molecule_batch(np.random.default_rng(0), 4, 8, 16)
        batch = {
            k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
            for k, v in g.items()
            if k in ("species", "pos", "edges", "graph_id", "targets")
        }
        params = mace_mod.init_params(jax.random.PRNGKey(0), cfg)
        opt = self.optimizer()
        opt_state = opt.init(params)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: mace_mod.energy_loss(
                    p, {**batch, "n_graphs": 4}, cfg)
            )(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return loss, params, opt_state

        return step, (params, opt_state, batch)


# =============================================================== recsys
class RecsysArch(Arch):
    family = "recsys"
    shapes = RECSYS_SHAPES

    def __init__(self, cfg: b4r.Bert4RecConfig,
                 smoke_cfg: b4r.Bert4RecConfig):
        self.name = cfg.name
        self.cfg = cfg
        self.smoke_cfg = smoke_cfg

    def abstract_params(self, shape: str) -> PyTree:
        return jax.eval_shape(
            lambda: b4r.init_params(jax.random.PRNGKey(0), self.cfg)
        )

    def init_params(self, rng, shape: str) -> PyTree:
        return b4r.init_params(rng, self.cfg)

    def param_rules(self):
        return [
            (r"item_emb", (MODEL, None)),  # the big table: vocab-sharded
            (r".*", ()),
        ]

    def batch_abstract(self, shape: str) -> PyTree:
        m = self.shapes[shape].meta
        cfg = self.cfg
        if self.shapes[shape].kind == "train":
            return {
                "seq": _sds((m["batch"], cfg.seq_len), jnp.int32),
                "masked_pos": _sds((m["batch"], cfg.n_masked), jnp.int32),
                "masked_ids": _sds((m["batch"], cfg.n_masked), jnp.int32),
                "negatives": _sds((cfg.n_negatives,), jnp.int32),
            }
        return {"seq": _sds((m["batch"], cfg.seq_len), jnp.int32)}

    def batch_spec_templates(self, shape: str) -> PyTree:
        if self.shapes[shape].kind == "train":
            return {
                "seq": (DATA, None),
                "masked_pos": (DATA, None),
                "masked_ids": (DATA, None),
                "negatives": (None,),
            }
        m = self.shapes[shape].meta
        return {"seq": ((DATA, None) if m["batch"] > 1 else (None, None))}

    def loss_fn(self, shape: str) -> Callable:
        cfg = self.cfg
        return lambda p, b: b4r.masked_item_loss(p, b, cfg)

    def make_serve_step(self, shape: str, mesh=None):
        cfg = self.cfg
        params = self.abstract_params(shape)
        batch = self.batch_abstract(shape)
        m = self.shapes[shape].meta
        if mesh is not None and m["batch"] > 1:
            from ..models.common import dp_axes
            import numpy as _np

            dp = dp_axes(mesh)
            dp_size = int(_np.prod([mesh.shape[a] for a in dp]))
            if m["batch"] % dp_size == 0:
                serve = b4r.make_sharded_serve(cfg, mesh, dp)
                return serve, (params, batch)

        def serve(params, batch):
            return b4r.serve_scores(params, batch, cfg)

        return serve, (params, batch)

    def serve_spec_templates(self, shape: str):
        return [self.batch_spec_templates(shape)]

    def model_flops(self, shape: str) -> float:
        m = self.shapes[shape].meta
        cfg = self.cfg
        d, s = cfg.d_model, cfg.seq_len
        per_tok = cfg.n_blocks * (4 * d * d + 2 * d * cfg.d_ff) * 2
        attn = cfg.n_blocks * 4 * s * d * 2
        enc = m["batch"] * (s * per_tok + attn)
        if self.shapes[shape].kind == "train":
            neg = (m["batch"] * cfg.n_masked
                   * (cfg.n_negatives + 1) * d * 2)
            return 3.0 * (enc + neg)
        score = 2.0 * m["batch"] * cfg.n_items * d
        return enc + score

    def smoke_bundle(self):
        from ..data.recsys import session_batches

        cfg = self.smoke_cfg
        it = session_batches(0, cfg.n_items, 4, cfg.seq_len,
                             cfg.n_masked, cfg.mask_id, cfg.n_negatives)
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params = b4r.init_params(jax.random.PRNGKey(0), cfg)
        opt = self.optimizer()
        opt_state = opt.init(params)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: b4r.masked_item_loss(p, batch, cfg)
            )(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return loss, params, opt_state

        return step, (params, opt_state, batch)


# =============================================================== mining
class MiningArch(Arch):
    """The paper's own workload as a dry-runnable 'architecture': one
    distributed extension-scan step over a sharded DB."""

    family = "mining"
    shapes = MINING_SHAPES

    def __init__(self, name: str = "gtrace-mining"):
        self.name = name

    def abstract_params(self, shape: str) -> PyTree:
        return {}

    def param_rules(self):
        return [(r".*", ())]

    def batch_abstract(self, shape: str) -> PyTree:
        m = self.shapes[shape].meta
        return {
            "tokens": _sds((m["n_seq"], m["tokens"], 6), jnp.int32),
            "gid": _sds((m["emb_batch"],), jnp.int32),
            "phi": _sds((m["emb_batch"], m["ni"]), jnp.int32),
            "psi": _sds((m["emb_batch"], m["nv"]), jnp.int32),
            "valid": _sds((m["emb_batch"],), jnp.int32),
            "existing": _sds((64, 5), jnp.int32),
        }

    def make_step(self, shape: str, mesh=None):
        raise RuntimeError(
            "mining arch lowers via make_mining_step (needs the mesh); "
            "handled specially by launch.dryrun"
        )

    def model_flops(self, shape: str) -> float:
        m = self.shapes[shape].meta
        # useful int-ops per (embedding, token) pair: psi/phi lookups,
        # predicate evaluation, packing  (~ 2*(NV+NI) + 40)
        per_pair = 2.0 * (m["nv"] + m["ni"]) + 40.0
        return m["emb_batch"] * m["tokens"] * per_pair

    def smoke_bundle(self):
        from ..core.compile import compile_sequence
        from ..data.synthetic import random_graph_sequence
        from ..mining.driver import AcceleratedMiner
        import random as _random

        rng = _random.Random(0)
        db = [
            compile_sequence(random_graph_sequence(rng))
            for _ in range(6)
        ]

        def step():
            res = AcceleratedMiner(db).mine_rs(2, max_len=3)
            return jnp.asarray(float(len(res.patterns)))

        return (lambda: step()), ()
