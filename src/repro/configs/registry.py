"""The assigned architecture pool: ``get_arch(id)`` / ``list_archs()``.

Exact configs from the assignment table (sources noted inline); every
arch also carries a reduced smoke config exercised by tests/test_archs.py.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from ..models.bert4rec import Bert4RecConfig
from ..models.mace import MACEConfig
from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .families import GNNArch, LMArch, MACEArch, MiningArch, RecsysArch


def _smoke_lm(name, **kw):
    base = dict(
        name=name + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, block_q=16,
        block_kv=16, loss_chunk=16,
    )
    base.update(kw)
    return TransformerConfig(**base)


@functools.cache
def get_arch(arch_id: str):
    if arch_id == "glm4-9b":
        # [hf:THUDM/glm-4-9b] 40L d4096 32H GQA(kv=2) dff 13696 v151552
        cfg = TransformerConfig(
            name="glm4-9b", n_layers=40, d_model=4096, n_heads=32,
            n_kv_heads=2, head_dim=128, d_ff=13696, vocab=151552,
            act="silu", gated_mlp=True, rope_theta=10000.0,
            param_dtype=jnp.float32, compute_dtype=jnp.bfloat16,
        )
        return LMArch(cfg, _smoke_lm("glm4"))
    if arch_id == "gemma-7b":
        # [arXiv:2403.08295] 28L d3072 16H MHA(kv=16) dff 24576 GeGLU
        # head_dim=256, vocab 256000, tied embeddings
        cfg = TransformerConfig(
            name="gemma-7b", n_layers=28, d_model=3072, n_heads=16,
            n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
            act="gelu", gated_mlp=True, tie_embeddings=True,
            param_dtype=jnp.float32, compute_dtype=jnp.bfloat16,
        )
        return LMArch(cfg, _smoke_lm("gemma", act="gelu",
                                     tie_embeddings=True))
    if arch_id == "smollm-135m":
        # [hf:HuggingFaceTB/SmolLM-135M] 30L d576 9H GQA(kv=3) dff 1536
        cfg = TransformerConfig(
            name="smollm-135m", n_layers=30, d_model=576, n_heads=9,
            n_kv_heads=3, head_dim=64, d_ff=1536, vocab=49152,
            act="silu", gated_mlp=True, tie_embeddings=True,
            param_dtype=jnp.float32, compute_dtype=jnp.bfloat16,
        )
        return LMArch(cfg, _smoke_lm("smollm", tie_embeddings=True))
    if arch_id == "llama4-maverick-400b-a17b":
        # [hf:meta-llama (unverified)] 48L d5120 40H GQA(kv=8) vocab
        # 202048; MoE 128 experts top-1 (+1 shared), dff_expert 8192,
        # dense/MoE interleaved (moe_period=2) -> ~400B total / 17B active
        cfg = TransformerConfig(
            name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120,
            n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
            vocab=202048, act="silu", gated_mlp=True,
            moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, n_shared=1),
            moe_period=2,
            param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        )
        return LMArch(
            cfg,
            _smoke_lm("llama4", moe=MoEConfig(4, 1, 64, n_shared=1),
                      moe_period=2, n_kv_heads=4),
            opt_state_dtype="int8",
        )
    if arch_id == "olmoe-1b-7b":
        # [arXiv:2409.02060] 16L d2048 16H MHA dff 1024/expert,
        # 64 experts top-8, vocab 50304
        cfg = TransformerConfig(
            name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
            n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50304,
            act="silu", gated_mlp=True,
            moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024),
            moe_period=1,
            param_dtype=jnp.float32, compute_dtype=jnp.bfloat16,
        )
        return LMArch(
            cfg,
            _smoke_lm("olmoe", moe=MoEConfig(8, 2, 32), moe_period=1,
                      n_kv_heads=4),
        )
    if arch_id == "gcn-cora":
        # [arXiv:1609.02907] 2L hidden 16, sym-norm mean aggregation
        return GNNArch("gcn-cora", "gcn", n_layers=2, d_hidden=16)
    if arch_id == "gat-cora":
        # [arXiv:1710.10903] 2L hidden 8, 8 heads, attn aggregation
        return GNNArch("gat-cora", "gat", n_layers=2, d_hidden=8,
                       n_heads=8)
    if arch_id == "gin-tu":
        # [arXiv:1810.00826] 5L hidden 64, sum agg, learnable eps
        return GNNArch("gin-tu", "gin", n_layers=5, d_hidden=64)
    if arch_id == "mace":
        # [arXiv:2206.07697] 2L hidden 128 l_max=2 corr=3 n_rbf=8
        return MACEArch(MACEConfig(name="mace", n_layers=2, d_hidden=128,
                                   l_max=2, correlation=3, n_rbf=8))
    if arch_id == "bert4rec":
        # [arXiv:1904.06690] embed 64, 2 blocks, 2 heads, seq 200.
        # Catalog 2^20-2 items so the table shards 16-way evenly
        # (assignment says 1e6 candidates; see DESIGN.md).
        cfg = Bert4RecConfig(name="bert4rec", n_items=1_048_574)
        smoke = Bert4RecConfig(name="bert4rec-smoke", n_items=1000,
                               seq_len=32, n_masked=4, n_negatives=32,
                               v_chunk=256)
        return RecsysArch(cfg, smoke)
    if arch_id == "gtrace-mining":
        return MiningArch()
    raise KeyError(arch_id)


ARCH_IDS = [
    "glm4-9b",
    "gemma-7b",
    "smollm-135m",
    "llama4-maverick-400b-a17b",
    "olmoe-1b-7b",
    "mace",
    "gcn-cora",
    "gat-cora",
    "gin-tu",
    "bert4rec",
]

EXTRA_IDS = ["gtrace-mining"]


def list_archs(include_extra: bool = False):
    return ARCH_IDS + (EXTRA_IDS if include_extra else [])
