"""Canonical forms of transformation subsequences (Def 7).

A pattern's identity must be invariant under the renaming of its (pattern
local) vertex IDs: Def 4's mapping psi means two TR sequences that differ
only by an injective vertex relabeling denote the same pattern.  Def 7
defines the canonical representation as the minimal code over all
representations; we realize it as the lexicographically minimal encoding
over all bijective relabelings onto {0..n-1}.

Patterns mined in practice are small (a handful of vertices), so an exact
search over relabelings with early pruning is both simple and fast; an
LRU cache collapses repeated canonicalizations.
"""
from __future__ import annotations

import itertools
from functools import lru_cache
from typing import Dict, Tuple

from .graphseq import Pattern, TR, pattern_vertices

Code = Tuple[Tuple[Tuple[int, int, int, int], ...], ...]


def _encode_tr(tr: TR, m: Dict[int, int]) -> Tuple[int, int, int, int]:
    if tr.is_vertex:
        return (int(tr.type), m[tr.u1], -1, tr.label)
    a, b = m[tr.u1], m[tr.u2]
    if a > b:
        a, b = b, a
    return (int(tr.type), a, b, tr.label)


def pattern_code(p: Pattern, mapping: Dict[int, int]) -> Code:
    return tuple(
        tuple(sorted(_encode_tr(tr, mapping) for tr in itemset))
        for itemset in p
    )


def relabel_pattern(p: Pattern, mapping: Dict[int, int]) -> Pattern:
    out = []
    for itemset in p:
        new = set()
        for tr in itemset:
            if tr.is_vertex:
                new.add(TR(tr.type, mapping[tr.u1], tr.u2, tr.label))
            else:
                a, b = mapping[tr.u1], mapping[tr.u2]
                if a > b:
                    a, b = b, a
                new.add(TR(tr.type, a, b, tr.label))
        out.append(frozenset(new))
    return tuple(out)


@lru_cache(maxsize=1 << 18)
def _canonical(p: Pattern) -> Tuple[Code, Tuple[Tuple[int, int], ...]]:
    vs = pattern_vertices(p)
    n = len(vs)
    if n == 0:
        return pattern_code(p, {}), ()
    best: Code | None = None
    best_m: Dict[int, int] = {}
    # Exact minimization.  Vertices are few; iterate bijections with an
    # early lexicographic cutoff per permutation.
    for perm in itertools.permutations(range(n)):
        m = {v: perm[i] for i, v in enumerate(vs)}
        code = pattern_code(p, m)
        if best is None or code < best:
            best, best_m = code, m
    return best, tuple(sorted(best_m.items()))  # type: ignore[return-value]


def canonical_code(p: Pattern) -> Code:
    return _canonical(p)[0]


def canonical_map(p: Pattern) -> Dict[int, int]:
    """The relabeling old-vid -> canonical-vid realizing the min code."""
    return dict(_canonical(p)[1])


def code_to_pattern(code: Code) -> Pattern:
    out = []
    for itemset in code:
        s = set()
        for t, a, b, lab in itemset:
            s.add(TR(TRType_from_int(t), a, b, lab))
        out.append(frozenset(s))
    return tuple(out)


def TRType_from_int(t: int):
    from .graphseq import TRType

    return TRType(t)


@lru_cache(maxsize=1 << 18)
def canonical_form(p: Pattern) -> Pattern:
    """Return the canonical representative of ``p`` (vertex IDs 0..n-1)."""
    return code_to_pattern(canonical_code(p))


def is_canonical(p: Pattern) -> bool:
    return canonical_form(p) == p
