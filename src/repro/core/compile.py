"""Compile graph sequences into transformation sequences (Defs 1-3).

The diff between two successive interstates is a minimal edit script;
because all vertices carry persistent IDs it is computable in linear time
(Sec. 2.1).  Within one intrastate sequence we order rules so that the
script is *applicable*: relabels first, then edge deletions, vertex
deletions, vertex insertions, edge insertions (an edge can only be deleted
before its endpoint disappears and inserted after both endpoints exist).

``encode_initial=True`` (default) prepends an empty interstate so the
construction of g(1) itself is part of the sequence; this matches the
worked examples in the paper (Figs. 7-8) where ``vi`` rules for the first
graph appear in the compiled data.
"""
from __future__ import annotations

from typing import List

from .graphseq import (
    LabeledGraph,
    GraphSequence,
    TR,
    TRSeq,
    TRType,
    edge_tr,
    vertex_tr,
)


def diff_graphs(g0: LabeledGraph, g1: LabeledGraph) -> List[TR]:
    """Minimal applicable edit script transforming ``g0`` into ``g1``."""
    trs: List[TR] = []
    # relabels
    for u in sorted(g0.vlabels.keys() & g1.vlabels.keys()):
        if g0.vlabels[u] != g1.vlabels[u]:
            trs.append(vertex_tr(TRType.VR, u, g1.vlabels[u]))
    for e in sorted(g0.elabels.keys() & g1.elabels.keys()):
        if g0.elabels[e] != g1.elabels[e]:
            trs.append(edge_tr(TRType.ER, e[0], e[1], g1.elabels[e]))
    # deletions (edges before vertices)
    for e in sorted(g0.elabels.keys() - g1.elabels.keys()):
        trs.append(edge_tr(TRType.ED, e[0], e[1]))
    for u in sorted(g0.vlabels.keys() - g1.vlabels.keys()):
        trs.append(vertex_tr(TRType.VD, u))
    # insertions (vertices before edges)
    for u in sorted(g1.vlabels.keys() - g0.vlabels.keys()):
        trs.append(vertex_tr(TRType.VI, u, g1.vlabels[u]))
    for e in sorted(g1.elabels.keys() - g0.elabels.keys()):
        trs.append(edge_tr(TRType.EI, e[0], e[1], g1.elabels[e]))
    return trs


def compile_sequence(d: GraphSequence, encode_initial: bool = True) -> TRSeq:
    """Graph sequence -> interstate transformation sequence (Def 3)."""
    graphs = list(d)
    if encode_initial:
        graphs = [LabeledGraph()] + graphs
    out = []
    for g0, g1 in zip(graphs, graphs[1:]):
        out.append(tuple(diff_graphs(g0, g1)))
    return tuple(out)


def apply_tr(g: LabeledGraph, tr: TR) -> None:
    """Apply one TR in place (validity-checked)."""
    if tr.type == TRType.VI:
        assert tr.u1 not in g.vlabels, f"vi on existing vertex {tr.u1}"
        g.add_vertex(tr.u1, tr.label)
    elif tr.type == TRType.VD:
        g.remove_vertex(tr.u1)
    elif tr.type == TRType.VR:
        assert tr.u1 in g.vlabels
        g.vlabels[tr.u1] = tr.label
    elif tr.type == TRType.EI:
        assert (tr.u1, tr.u2) not in g.elabels
        g.add_edge(tr.u1, tr.u2, tr.label)
    elif tr.type == TRType.ED:
        g.remove_edge(tr.u1, tr.u2)
    elif tr.type == TRType.ER:
        assert (tr.u1, tr.u2) in g.elabels
        g.elabels[(tr.u1, tr.u2)] = tr.label
    else:  # pragma: no cover
        raise ValueError(tr)


def reconstruct(s: TRSeq, initial: LabeledGraph | None = None) -> GraphSequence:
    """Replay a transformation sequence into the graph sequence it encodes."""
    g = (initial or LabeledGraph()).copy()
    out: GraphSequence = []
    for itemset in s:
        for tr in itemset:
            apply_tr(g, tr)
        out.append(g.copy())
    return out
