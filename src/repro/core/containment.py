"""Containment oracle (Def 4) by explicit backtracking.

``s_p [= s_d`` iff there are injective maps phi (strictly increasing over
intrastate indices) and psi (over vertex IDs) such that every pattern TR
has a matching data TR of the same type and label in the mapped intrastate
with psi-mapped operands.

This is the reference implementation used by tests and by the host-side
fallback engine; the scalable path lives in ``repro.mining`` and must agree
with this oracle exactly (property-tested).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

from .graphseq import Pattern, TR, TRSeq

Embedding = Tuple[Tuple[int, ...], Tuple[Tuple[int, int], ...]]
# (phi: data itemset index per pattern itemset, psi: sorted (pat_v, dat_v))


def _match_itemset(
    pat_trs: List[TR],
    data_trs: Tuple[TR, ...],
    psi: Dict[int, int],
    used: set,
) -> Iterator[Dict[int, int]]:
    """Yield extensions of psi matching all ``pat_trs`` into ``data_trs``."""
    if not pat_trs:
        yield dict(psi)
        return
    # most-constrained-first: prefer TRs whose vertices are already mapped
    pat_trs = sorted(
        pat_trs, key=lambda t: sum(v not in psi for v in t.vertices())
    )
    tr = pat_trs[0]
    rest = pat_trs[1:]
    for dtr in data_trs:
        if dtr.type != tr.type or dtr.label != tr.label:
            continue
        if tr.is_vertex:
            cands = [((tr.u1, dtr.u1),)]
        else:
            cands = [
                ((tr.u1, dtr.u1), (tr.u2, dtr.u2)),
                ((tr.u1, dtr.u2), (tr.u2, dtr.u1)),
            ]
        for pairs in cands:
            add: Dict[int, int] = {}
            ok = True
            for pv, dv in pairs:
                cur = psi.get(pv, add.get(pv))
                if cur is not None:
                    if cur != dv:
                        ok = False
                        break
                elif dv in used or dv in add.values():
                    ok = False
                    break
                else:
                    add[pv] = dv
            if not ok:
                continue
            psi.update(add)
            used.update(add.values())
            yield from _match_itemset(rest, data_trs, psi, used)
            for k in add:
                del psi[k]
                used.discard(add[k])


def iter_embeddings(p: Pattern, s: TRSeq) -> Iterator[Embedding]:
    """All embeddings of pattern ``p`` in data sequence ``s``."""
    n = len(p)

    def rec(pi: int, start: int, psi: Dict[int, int], used: set,
            phi: List[int]) -> Iterator[Embedding]:
        if pi == n:
            yield (tuple(phi), tuple(sorted(psi.items())))
            return
        for di in range(start, len(s)):
            for new_psi in _match_itemset(list(p[pi]), s[di], psi, used):
                phi.append(di)
                yield from rec(
                    pi + 1, di + 1, new_psi,
                    set(new_psi.values()), phi,
                )
                phi.pop()

    yield from rec(0, 0, {}, set(), [])


def contains(p: Pattern, s: TRSeq) -> bool:
    for _ in iter_embeddings(p, s):
        return True
    return False


def support(p: Pattern, db: List[TRSeq]) -> int:
    return sum(1 for s in db if contains(p, s))
