"""Shared pattern-growth machinery (host reference implementation).

Both the GTRACE baseline and GTRACE-RS grow a pattern by one TR per step
and need, for the current pattern, the set of *extensions* observed in the
database together with their supports and occurrence lists.  This module
implements that discovery from explicit embedding (occurrence) lists --
the pattern-growth analogue of gSpan's rightmost-extension scan and of the
paper's ``Subprocedure`` DB scan (Fig. 11, lines 2-4).

An embedding of pattern ``p`` in data sequence ``gid`` is
``(gid, phi, psi)`` where ``phi`` maps pattern itemset index -> data
itemset index (strictly increasing) and ``psi`` maps pattern vertex ->
data vertex (injective).  Extending ``p`` by inserting a TR at a *slot*
(either joining existing itemset ``i`` or forming a new itemset at gap
``g``) corresponds 1:1 to extending an embedding by one matching data TR,
which makes the enumeration complete (any embedding of the child restricts
to an embedding of the parent).

The device engine in ``repro.mining`` vectorizes exactly this computation;
tests assert bit-identical supports against this module.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, List, Sequence, Tuple

from .graphseq import (
    NO_VERTEX,
    Pattern,
    TR,
    TRSeq,
    pattern_vertices,
)

# (gid, phi, psi) with psi as a sorted tuple of (pat_v, dat_v) pairs
Emb = Tuple[int, Tuple[int, ...], Tuple[Tuple[int, int], ...]]
# slot: ("in", itemset_index) or ("gap", gap_index in 0..n)
Slot = Tuple[str, int]
ExtKey = Tuple[Slot, TR]


def root_embeddings(db: Sequence[TRSeq]) -> List[Emb]:
    return [(gid, (), ()) for gid in range(len(db))]


@dataclass
class Extension:
    key: ExtKey
    gids: set = field(default_factory=set)
    embeddings: List[Emb] = field(default_factory=list)

    @property
    def support(self) -> int:
        return len(self.gids)


def _insert_slot(phi: Tuple[int, ...], slot: Slot, di: int) -> Tuple[int, ...]:
    kind, idx = slot
    if kind == "in":
        return phi
    return phi[:idx] + (di,) + phi[idx:]


def find_extensions(
    pattern: Pattern,
    embeddings: Sequence[Emb],
    db: Sequence[TRSeq],
    allow: Callable[[Slot, TR], bool],
    tail_only: bool = False,
) -> Dict[ExtKey, Extension]:
    """Scan the DB (via occurrence lists) for one-TR extensions.

    ``allow(slot, tr_in_pattern_coords)`` filters candidate classes (the
    reverse-search phases or the baseline's unrestricted growth).
    ``tail_only`` restricts slots to PrefixSpan-style tail growth: join the
    last itemset or append a new last itemset.
    """
    n = len(pattern)
    nv = len(pattern_vertices(pattern))
    out: Dict[ExtKey, Extension] = {}

    for gid, phi, psi_t in embeddings:
        seq = db[gid]
        psi = dict(psi_t)
        inv = {dv: pv for pv, dv in psi.items()}
        used_data_v = set(inv.keys())
        pos_of_di = {di: i for i, di in enumerate(phi)}
        last_di = phi[-1] if phi else -1

        for di, data_itemset in enumerate(seq):
            # which slot does this data itemset correspond to?
            if di in pos_of_di:
                slot: Slot = ("in", pos_of_di[di])
            else:
                # find gap index: number of phi entries < di
                g = 0
                while g < n and phi[g] < di:
                    g += 1
                slot = ("gap", g)
            if tail_only:
                if slot[0] == "in" and slot[1] != n - 1:
                    continue
                if slot[0] == "gap" and slot[1] != n:
                    continue
                if slot[0] == "gap" and di <= last_di:
                    continue

            for dtr in data_itemset:
                # map the data TR into pattern coordinates
                if dtr.is_vertex:
                    if dtr.u1 in inv:
                        ptr = TR(dtr.type, inv[dtr.u1], NO_VERTEX, dtr.label)
                        fresh: Tuple[Tuple[int, int], ...] = ()
                    else:
                        ptr = TR(dtr.type, nv, NO_VERTEX, dtr.label)
                        fresh = ((nv, dtr.u1),)
                else:
                    a_in, b_in = dtr.u1 in inv, dtr.u2 in inv
                    if a_in and b_in:
                        pa, pb = inv[dtr.u1], inv[dtr.u2]
                        if pa > pb:
                            pa, pb = pb, pa
                        ptr = TR(dtr.type, pa, pb, dtr.label)
                        fresh = ()
                    elif a_in:
                        ptr = TR(dtr.type, min(inv[dtr.u1], nv),
                                 max(inv[dtr.u1], nv), dtr.label)
                        fresh = ((nv, dtr.u2),)
                    elif b_in:
                        ptr = TR(dtr.type, min(inv[dtr.u2], nv),
                                 max(inv[dtr.u2], nv), dtr.label)
                        fresh = ((nv, dtr.u1),)
                    else:
                        # both endpoints fresh (disconnected edge)
                        ptr = TR(dtr.type, nv, nv + 1, dtr.label)
                        fresh = ((nv, dtr.u1), (nv + 1, dtr.u2))
                # injectivity: fresh data vertices must be unused
                if any(dv in used_data_v for _, dv in fresh):
                    continue
                if len(fresh) == 2 and fresh[0][1] == fresh[1][1]:
                    continue
                # no duplicate TR within an itemset (sets collapse)
                if slot[0] == "in" and ptr in pattern[slot[1]]:
                    continue
                if not allow(slot, ptr):
                    continue
                key = (slot, ptr)
                ext = out.get(key)
                if ext is None:
                    ext = out[key] = Extension(key)
                ext.gids.add(gid)
                new_phi = _insert_slot(phi, slot, di)
                new_psi = tuple(sorted(psi_t + fresh))
                ext.embeddings.append((gid, new_phi, new_psi))
    return out


def merge_extensions_by_canonical(
    pattern: Pattern,
    exts: Dict[ExtKey, Extension],
) -> Dict[Pattern, Tuple[set, List[Emb]]]:
    """Group raw extension keys by the canonical class of their child.

    When the parent has automorphisms, isomorphic raw children (e.g. a
    vertex TR attached to either endpoint of a symmetric edge) are
    distinct keys each carrying only part of the occurrence list; supports
    and embeddings must be merged *before* thresholding or patterns at the
    support boundary are lost.
    """
    from .canonical import canonical_form, canonical_map

    out: Dict[Pattern, Tuple[set, List[Emb]]] = {}
    embsets: Dict[Pattern, set] = {}
    for key, ext in exts.items():
        child_raw = apply_extension(pattern, key)
        child = canonical_form(child_raw)
        vmap = canonical_map(child_raw)
        if child not in out:
            out[child] = (set(), [])
            embsets[child] = set()
        gids, embs = out[child]
        gids |= ext.gids
        es = embsets[child]
        for e in ext.embeddings:
            r = remap_embedding(e, vmap)
            if r not in es:
                es.add(r)
                embs.append(r)
    return out


def apply_extension(pattern: Pattern, key: ExtKey) -> Pattern:
    """Insert the extension's TR into the pattern at its slot."""
    (kind, idx), tr = key
    if kind == "in":
        return tuple(
            (s | {tr}) if i == idx else s for i, s in enumerate(pattern)
        )
    return pattern[:idx] + (frozenset({tr}),) + pattern[idx:]


def remap_embedding(emb: Emb, vmap: Dict[int, int]) -> Emb:
    gid, phi, psi = emb
    return (gid, phi, tuple(sorted((vmap[pv], dv) for pv, dv in psi)))
