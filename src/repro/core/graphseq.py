"""Graph-sequence and transformation-rule (TR) data model.

Implements the representation layer of GTRACE / GTRACE-RS (Inokuchi,
Ikuta & Washio 2011), Defs 1-3 and Table 2:

* a labeled graph ``g = (V, E, L, f)`` with globally persistent vertex IDs,
* a graph sequence ``d = <g(1) ... g(n)>``,
* six transformation rules (vi, vd, vr, ei, ed, er) describing the minimal
  edit script between successive interstates,
* transformation sequences as *sequences of itemsets* of TRs.  Within an
  intrastate the order of TRs is irrelevant for containment (Def 4 only
  requires existence of a matching TR in the mapped intrastate), which is
  exactly why the paper converts intrastates to itemsets in Sec. 4.3.  We
  therefore treat the intrastate index ``j`` as the itemset index and drop
  ``k`` from pattern identity.

Labels are small non-negative ints.  ``NO_LABEL`` (the paper's bullet) is
used by deletions.
"""
from __future__ import annotations

import enum
from typing import Dict, FrozenSet, Iterable, List, NamedTuple, Tuple

NO_LABEL = -1
NO_VERTEX = -1


class TRType(enum.IntEnum):
    """The six transformation-rule types of Table 2."""

    VI = 0  # vertex insertion
    VD = 1  # vertex deletion
    VR = 2  # vertex relabeling
    EI = 3  # edge insertion
    ED = 4  # edge deletion
    ER = 5  # edge relabeling


VERTEX_TR_TYPES = frozenset({TRType.VI, TRType.VD, TRType.VR})
EDGE_TR_TYPES = frozenset({TRType.EI, TRType.ED, TRType.ER})


class TR(NamedTuple):
    """One transformation rule.

    ``u2 == NO_VERTEX`` for vertex rules; ``label == NO_LABEL`` for
    deletions.  Edge endpoints are stored with ``u1 < u2`` (undirected).
    """

    type: TRType
    u1: int
    u2: int
    label: int

    @property
    def is_vertex(self) -> bool:
        return self.type in VERTEX_TR_TYPES

    @property
    def is_edge(self) -> bool:
        return self.type in EDGE_TR_TYPES

    @property
    def edge(self) -> Tuple[int, int]:
        return (self.u1, self.u2)

    def vertices(self) -> Tuple[int, ...]:
        if self.is_vertex:
            return (self.u1,)
        return (self.u1, self.u2)

    def short(self) -> str:
        names = ["vi", "vd", "vr", "ei", "ed", "er"]
        lab = "." if self.label == NO_LABEL else str(self.label)
        if self.is_vertex:
            return f"{names[self.type]}[{self.u1},{lab}]"
        return f"{names[self.type]}[({self.u1},{self.u2}),{lab}]"


def vertex_tr(type_: TRType, u: int, label: int = NO_LABEL) -> TR:
    assert type_ in VERTEX_TR_TYPES
    if type_ == TRType.VD:
        label = NO_LABEL
    return TR(type_, u, NO_VERTEX, label)


def edge_tr(type_: TRType, u1: int, u2: int, label: int = NO_LABEL) -> TR:
    assert type_ in EDGE_TR_TYPES and u1 != u2
    if type_ == TRType.ED:
        label = NO_LABEL
    if u1 > u2:
        u1, u2 = u2, u1
    return TR(type_, u1, u2, label)


# An itemset of TRs (one intrastate transformation sequence, order dropped).
Itemset = FrozenSet[TR]
# A pattern: sequence of non-empty itemsets, vertex IDs pattern-local.
Pattern = Tuple[Itemset, ...]
# A data transformation sequence: itemsets may be empty (unchanged steps).
TRSeq = Tuple[Tuple[TR, ...], ...]

EMPTY_PATTERN: Pattern = ()


def pattern_from_lists(itemsets: Iterable[Iterable[TR]]) -> Pattern:
    return tuple(frozenset(s) for s in itemsets)


def pattern_length(p: Pattern) -> int:
    """Number of TRs (the paper's sequence length)."""
    return sum(len(s) for s in p)


def pattern_vertices(p: Pattern) -> Tuple[int, ...]:
    vs = set()
    for itemset in p:
        for tr in itemset:
            vs.update(tr.vertices())
    return tuple(sorted(vs))


def pattern_str(p: Pattern) -> str:
    return " | ".join(
        " ".join(tr.short() for tr in sorted(s)) for s in p
    ) or "<empty>"


class LabeledGraph:
    """Labeled undirected graph with persistent vertex IDs."""

    __slots__ = ("vlabels", "elabels")

    def __init__(
        self,
        vlabels: Dict[int, int] | None = None,
        elabels: Dict[Tuple[int, int], int] | None = None,
    ):
        self.vlabels: Dict[int, int] = dict(vlabels or {})
        self.elabels: Dict[Tuple[int, int], int] = {}
        for (u, v), l in (elabels or {}).items():
            self.add_edge(u, v, l)

    def add_vertex(self, u: int, label: int) -> None:
        self.vlabels[u] = label

    def add_edge(self, u: int, v: int, label: int) -> None:
        assert u != v
        if u > v:
            u, v = v, u
        assert u in self.vlabels and v in self.vlabels, (u, v, self.vlabels)
        self.elabels[(u, v)] = label

    def remove_edge(self, u: int, v: int) -> None:
        if u > v:
            u, v = v, u
        del self.elabels[(u, v)]

    def remove_vertex(self, u: int) -> None:
        assert not self.incident(u), f"vertex {u} is not isolated"
        del self.vlabels[u]

    def incident(self, u: int) -> List[Tuple[int, int]]:
        return [e for e in self.elabels if u in e]

    def copy(self) -> "LabeledGraph":
        g = LabeledGraph()
        g.vlabels = dict(self.vlabels)
        g.elabels = dict(self.elabels)
        return g

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, LabeledGraph)
            and self.vlabels == other.vlabels
            and self.elabels == other.elabels
        )

    def __repr__(self) -> str:
        return f"LabeledGraph(V={self.vlabels}, E={self.elabels})"


GraphSequence = List[LabeledGraph]
