"""The original GTRACE (baseline, Sec. 2.2-2.3).

PrefixSpan-style tail growth over *all* frequent transformation
subsequences (FTSs), followed by the relevance postfilter.  This is the
method the paper is orders of magnitude faster than; we need it both as
the correctness oracle (its postfiltered output must equal GTRACE-RS's
output) and as the comparison baseline for the Table-4/5 benchmarks.

Duplicate patterns (same canonical class reached through different raw
vertex labelings) are pruned with a canonical seen-set; supports are exact
because every raw key's occurrence list is complete for the child pattern.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from .canonical import canonical_code, canonical_form, canonical_map
from .enumerate_host import (
    apply_extension,
    find_extensions,
    merge_extensions_by_canonical,
    root_embeddings,
)
from .graphseq import Pattern, TRSeq, pattern_length
from .union_graph import is_relevant


@dataclass
class MiningResult:
    patterns: Dict[Pattern, int] = field(default_factory=dict)  # canonical -> support
    n_enumerated: int = 0  # nodes expanded (FTSs for GT, rFTSs for RS)
    n_extension_scans: int = 0

    def relevant(self) -> Dict[Pattern, int]:
        return {p: s for p, s in self.patterns.items() if is_relevant(p)}


def mine_gtrace(
    db: Sequence[TRSeq],
    min_support: int,
    max_len: int | None = None,
) -> MiningResult:
    """Mine all FTSs (result.patterns), callers filter via .relevant()."""
    res = MiningResult()
    seen = set()

    def allow_all(slot, tr) -> bool:
        return True

    stack = [((), root_embeddings(db))]
    while stack:
        pattern, embs = stack.pop()
        if max_len is not None and pattern_length(pattern) >= max_len:
            continue
        res.n_extension_scans += 1
        exts = find_extensions(pattern, embs, db, allow_all, tail_only=True)
        for child, (gids, child_embs) in merge_extensions_by_canonical(
            pattern, exts
        ).items():
            if len(gids) < min_support:
                continue
            if child in seen:
                continue
            seen.add(child)
            res.patterns[child] = len(gids)
            res.n_enumerated += 1
            stack.append((child, child_embs))
    return res
