"""GTRACE-RS: reverse-search enumeration of rFTSs (Sec. 3-4).

The parent functions P1/P2/P3 (Defs 8-10) define a spanning tree over the
set of canonical relevant FTSs; traversing it from the root enumerates
*only* relevant patterns, which is the paper's source of speedup.

``parent`` implements the P1 > P2 > P3 priority exactly:

* P1 - the pattern contains vertex TRs: remove the temporally last vertex
  TR (ties inside an itemset broken by the encoded-tuple order on the
  canonical representation; any fixed rule yields a valid spanning tree).
* P2 - only edge TRs and more TRs than union-graph edges: among the TRs
  that have an earlier (strictly smaller itemset index) TR on the same
  union-graph edge, remove the temporally last.  (See DESIGN.md for why
  Def 9 is read "among"-style; the literal reading leaves some rFTSs
  parentless.)
* P3 - every TR on a distinct union-graph edge: remove the temporally
  last TR whose removal keeps the union graph connected.

Children are produced generate-and-verify: the DB scan proposes every
relevance-preserving one-TR insertion observed in the data (complete by
the occurrence-list argument in ``enumerate_host``), and a candidate is
kept iff ``parent(child) == node`` - exactly the reverse-search membership
test ``s_p diamond r in P_i^{-1}(s_p)`` of Fig. 11.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .canonical import canonical_code, canonical_form, canonical_map
from .enumerate_host import (
    Emb,
    apply_extension,
    find_extensions,
    merge_extensions_by_canonical,
    remap_embedding,
    root_embeddings,
)
from .gtrace import MiningResult
from .graphseq import (
    Pattern,
    TR,
    TRSeq,
    pattern_length,
    pattern_vertices,
)
from .union_graph import is_relevant, pattern_union_graph


def _tr_key(tr: TR) -> Tuple[int, int, int, int]:
    return (int(tr.type), tr.u1, tr.u2, tr.label)


def _remove(pattern: Pattern, idx: int, tr: TR) -> Pattern:
    out = []
    for i, itemset in enumerate(pattern):
        if i == idx:
            rest = itemset - {tr}
            if rest:
                out.append(rest)
        else:
            out.append(itemset)
    return tuple(out)


def parent(p: Pattern) -> Optional[Pattern]:
    """The unique reverse-search parent (canonical form), None for the root
    or for pathological patterns outside S (never generated from compiled
    data)."""
    if not p:
        return None
    has_vertex = any(tr.is_vertex for s in p for tr in s)
    if has_vertex:
        # P1: last itemset containing a vertex TR, max-tuple tie-break
        for i in range(len(p) - 1, -1, -1):
            vtrs = [tr for tr in p[i] if tr.is_vertex]
            if vtrs:
                tr = max(vtrs, key=_tr_key)
                return canonical_form(_remove(p, i, tr))
        raise AssertionError("unreachable")
    ug = pattern_union_graph(p)
    if pattern_length(p) > len(ug.edges):
        # P2: among TRs with an earlier same-edge TR, remove the last
        seen_edges = set()
        candidates: List[Tuple[int, TR]] = []
        for i, itemset in enumerate(p):
            here = sorted(itemset, key=_tr_key)
            for tr in here:
                if tr.edge in seen_edges:
                    candidates.append((i, tr))
            seen_edges.update(tr.edge for tr in here)
        if not candidates:
            return None  # duplicates only inside one itemset: outside S
        i, tr = max(candidates, key=lambda it: (it[0], _tr_key(it[1])))
        return canonical_form(_remove(p, i, tr))
    # P3: last TR whose removal keeps the union graph connected
    for i in range(len(p) - 1, -1, -1):
        for tr in sorted(p[i], key=_tr_key, reverse=True):
            cand = _remove(p, i, tr)
            if is_relevant(cand):
                return canonical_form(cand)
    return None  # disconnected input: outside S


def mine_gtrace_rs(
    db: Sequence[TRSeq],
    min_support: int,
    max_len: int | None = None,
) -> MiningResult:
    """Enumerate all rFTSs by reverse search (Fig. 11)."""
    res = MiningResult()

    def expand(node: Pattern, embs: List[Emb]) -> None:
        if max_len is not None and pattern_length(node) >= max_len:
            return
        nv = len(pattern_vertices(node))
        has_vertex = any(tr.is_vertex for s in node for tr in s)
        empty = not node

        def allow(slot, tr: TR) -> bool:
            if tr.is_vertex:
                # P1-class child: vertex TR on an existing union-graph
                # vertex (fresh only from the root -> single-vertex chains)
                return empty or tr.u1 < nv
            # edge TR children only exist below edge-only nodes
            if has_vertex:
                return False
            # P2-class (duplicate TR on existing edge) or P3-class (new
            # union-graph edge attached to the existing component)
            if tr.u1 >= nv and tr.u2 >= nv:
                return empty  # both endpoints fresh: single-edge patterns
            return True

        res.n_extension_scans += 1
        exts = find_extensions(node, embs, db, allow)
        merged = merge_extensions_by_canonical(node, exts)
        for child, (gids, child_embs) in merged.items():
            if len(gids) < min_support:
                continue
            if parent(child) != node:
                continue  # reverse-search membership test
            res.patterns[child] = len(gids)
            res.n_enumerated += 1
            expand(child, child_embs)

    root: Pattern = ()
    expand(root, root_embeddings(db))
    return res
