"""Union graphs and relevance (Defs 5-6).

The union graph of a transformation (sub)sequence collects every vertex ID
touched by any TR and every vertex-ID pair touched by any edge TR.  A
pattern is *relevant* iff its union graph is connected.
"""
from __future__ import annotations

from typing import Dict, Iterable, Set, Tuple

from .graphseq import Pattern, TR


class UnionGraph:
    __slots__ = ("vertices", "edges")

    def __init__(self) -> None:
        self.vertices: Set[int] = set()
        self.edges: Set[Tuple[int, int]] = set()

    def add_tr(self, tr: TR) -> None:
        if tr.is_vertex:
            self.vertices.add(tr.u1)
        else:
            self.vertices.add(tr.u1)
            self.vertices.add(tr.u2)
            self.edges.add((tr.u1, tr.u2))

    def connected(self) -> bool:
        if not self.vertices:
            return True  # the empty pattern (root) is trivially relevant
        parent: Dict[int, int] = {v: v for v in self.vertices}

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, v in self.edges:
            ru, rv = find(u), find(v)
            if ru != rv:
                parent[ru] = rv
        roots = {find(v) for v in self.vertices}
        return len(roots) <= 1


def union_graph(trs: Iterable[TR]) -> UnionGraph:
    g = UnionGraph()
    for tr in trs:
        g.add_tr(tr)
    return g


def pattern_union_graph(p: Pattern) -> UnionGraph:
    g = UnionGraph()
    for itemset in p:
        for tr in itemset:
            g.add_tr(tr)
    return g


def is_relevant(p: Pattern) -> bool:
    """Def 5/6: union graph connected (single vertex counts)."""
    return pattern_union_graph(p).connected()
