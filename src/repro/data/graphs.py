"""Graph data: synthetic node-classification graphs, batched molecules,
and a real layer-wise neighbor sampler (GraphSAGE-style) for the
minibatch_lg shape."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def _with_self_loops_bidir(src, dst, n):
    s = np.concatenate([src, dst, np.arange(n)])
    d = np.concatenate([dst, src, np.arange(n)])
    return np.stack([s, d]).astype(np.int32)


def random_node_graph(
    rng: np.random.Generator, n_nodes: int, n_edges: int, d_feat: int,
    n_classes: int, label_frac: float = 0.5,
) -> Dict[str, np.ndarray]:
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    edges = _with_self_loops_bidir(src, dst, n_nodes)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    # features correlated with the label so training can learn
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    x = centers[labels] + 0.5 * rng.normal(size=(n_nodes, d_feat)).astype(
        np.float32
    )
    mask = (rng.random(n_nodes) < label_frac).astype(np.float32)
    return {"x": x, "edges": edges, "labels": labels, "mask": mask}


def random_molecule_batch(
    rng: np.random.Generator, n_graphs: int, nodes_per: int, edges_per: int,
    n_species: int = 10, n_classes: int = 2,
) -> Dict[str, np.ndarray]:
    N = n_graphs * nodes_per
    species = rng.integers(0, n_species, N).astype(np.int32)
    pos = rng.normal(size=(N, 3)).astype(np.float32) * 2.0
    srcs, dsts = [], []
    for g in range(n_graphs):
        off = g * nodes_per
        s = rng.integers(0, nodes_per, edges_per) + off
        d = rng.integers(0, nodes_per, edges_per) + off
        srcs.append(s)
        dsts.append(d)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    edges = np.stack(
        [np.concatenate([src, dst]), np.concatenate([dst, src])]
    ).astype(np.int32)
    graph_id = np.repeat(np.arange(n_graphs), nodes_per).astype(np.int32)
    return {
        "species": species,
        "pos": pos,
        "edges": edges,
        "graph_id": graph_id,
        "n_graphs": n_graphs,
        "targets": rng.normal(size=(n_graphs,)).astype(np.float32),
        "graph_labels": rng.integers(0, n_classes, n_graphs).astype(np.int32),
        # node features for non-geometric GNNs on the molecule shape
        "x": np.eye(n_species, dtype=np.float32)[species],
        "labels": np.zeros((N,), np.int32),
        "mask": np.zeros((N,), np.float32),
    }


class CSRGraph:
    """Compressed neighbor lists for host-side sampling."""

    def __init__(self, n_nodes: int, src: np.ndarray, dst: np.ndarray):
        order = np.argsort(src, kind="stable")
        self.nbr = dst[order].astype(np.int32)
        counts = np.bincount(src, minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)]).astype(
            np.int64
        )
        self.n_nodes = n_nodes

    def sample_neighbors(self, rng, nodes: np.ndarray, fanout: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """Uniform with-replacement fanout sample; returns (src=neighbor,
        dst=node) edge arrays (padded with self loops for deg-0 nodes)."""
        starts = self.offsets[nodes]
        degs = self.offsets[nodes + 1] - starts
        r = rng.integers(0, np.maximum(degs, 1)[:, None],
                         (len(nodes), fanout))
        nbrs = self.nbr[
            (starts[:, None] + r).clip(0, len(self.nbr) - 1)
        ]
        nbrs = np.where(degs[:, None] > 0, nbrs, nodes[:, None])
        dst = np.repeat(nodes, fanout)
        return nbrs.reshape(-1).astype(np.int32), dst.astype(np.int32)


def sample_blocks(
    csr: CSRGraph, rng: np.random.Generator, seeds: np.ndarray,
    fanouts: Sequence[int], x: np.ndarray, labels: np.ndarray,
) -> Dict[str, np.ndarray]:
    """Layer-wise sampling -> one merged subgraph batch with relabeled
    node ids (seeds first), padded to a static size by the caller."""
    frontier = seeds.astype(np.int32)
    all_src: List[np.ndarray] = []
    all_dst: List[np.ndarray] = []
    nodes = [seeds.astype(np.int32)]
    for f in fanouts:
        s, d = csr.sample_neighbors(rng, frontier, f)
        all_src.append(s)
        all_dst.append(d)
        frontier = np.unique(s)
        nodes.append(frontier)
    uniq = np.unique(np.concatenate(nodes))
    # relabel with seeds occupying the first len(seeds) slots
    seed_set = np.zeros(csr.n_nodes + 1, bool)
    seed_set[seeds] = True
    rest = uniq[~seed_set[uniq]]
    order = np.concatenate([seeds, rest])
    remap = np.full(csr.n_nodes, -1, np.int32)
    remap[order] = np.arange(len(order), dtype=np.int32)
    src = remap[np.concatenate(all_src)]
    dst = remap[np.concatenate(all_dst)]
    n_sub = len(order)
    edges = _with_self_loops_bidir(src, dst, n_sub)
    mask = np.zeros(n_sub, np.float32)
    mask[: len(seeds)] = 1.0
    return {
        "x": x[order],
        "edges": edges,
        "labels": labels[order].astype(np.int32),
        "mask": mask,
    }


def pad_block(batch: Dict[str, np.ndarray], n_nodes: int, n_edges: int
              ) -> Dict[str, np.ndarray]:
    """Pad a sampled block to static shapes (adds edge_mask)."""
    nn = batch["x"].shape[0]
    ne = batch["edges"].shape[1]
    assert nn <= n_nodes and ne <= n_edges, (nn, n_nodes, ne, n_edges)
    out = {
        "x": np.pad(batch["x"], ((0, n_nodes - nn), (0, 0))),
        "edges": np.pad(batch["edges"], ((0, 0), (0, n_edges - ne))),
        "labels": np.pad(batch["labels"], (0, n_nodes - nn)),
        "mask": np.pad(batch["mask"], (0, n_nodes - nn)),
        "edge_mask": np.concatenate(
            [np.ones(ne, np.int32), np.zeros(n_edges - ne, np.int32)]
        ),
    }
    return out
