"""Synthetic LM token streams (zipf-distributed with local structure so a
small model's loss visibly decreases)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def token_batches(
    seed: int, vocab: int, batch: int, seq: int
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    # a random order-1 markov chain gives learnable structure
    k = min(vocab, 512)
    trans = rng.dirichlet(np.ones(k) * 0.05, size=k).astype(np.float32)
    cum = np.cumsum(trans, axis=1)
    while True:
        state = rng.integers(0, k, batch)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = state
        u = rng.random((batch, seq)).astype(np.float32)
        for t in range(seq):
            state = (cum[state] < u[:, t : t + 1]).sum(1).clip(0, k - 1)
            toks[:, t + 1] = state
        yield {"tokens": toks[:, :-1] % vocab, "targets": toks[:, 1:] % vocab}
