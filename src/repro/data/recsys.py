"""Synthetic user-session data for BERT4Rec: cluster-structured item
sequences + Cloze masking, and session graph-sequences feeding the GTRACE
mining integration example."""
from __future__ import annotations

from typing import Dict, Iterator, List

import numpy as np


def session_batches(
    seed: int, n_items: int, batch: int, seq: int, n_masked: int,
    mask_id: int, n_negatives: int = 1024, n_clusters: int = 64,
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    cluster_size = max(2, n_items // n_clusters)
    while True:
        cl = rng.integers(0, n_clusters, batch)
        base = 1 + cl * cluster_size
        seqs = (
            base[:, None]
            + rng.integers(0, cluster_size, (batch, seq))
        ).astype(np.int32)
        seqs = np.clip(seqs, 1, n_items)
        lengths = rng.integers(seq // 2, seq + 1, batch)
        pad = np.arange(seq)[None] >= lengths[:, None]
        seqs[pad] = 0
        masked_pos = np.stack(
            [rng.choice(max(l, n_masked), n_masked, replace=False)
             .clip(0, l - 1) if l > 0 else np.zeros(n_masked, np.int64)
             for l in lengths]
        ).astype(np.int32)
        masked_ids = np.take_along_axis(seqs, masked_pos, 1)
        inp = seqs.copy()
        np.put_along_axis(inp, masked_pos, mask_id, 1)
        negatives = rng.integers(1, n_items + 1, n_negatives).astype(
            np.int32
        )
        yield {
            "seq": inp,
            "masked_pos": masked_pos,
            "masked_ids": masked_ids,
            "negatives": negatives,
        }
