"""Synthetic graph-sequence generators.

* ``generate_table3_db`` reproduces the artificial-dataset generator of
  the paper's Sec. 5.1 / Table 3: graph sequences grown by per-interstate
  insert/delete/relabel operations (probabilities p_i / p_d / 1-p_i-p_d),
  grown until relevant, then overlaid with N embedded rFTS patterns with
  probability 1/N each.
* ``generate_enron_like_db`` mimics the Enron weekly-communication data of
  Sec. 5.2: |V| persons with role labels, n daily interstates per week,
  gradually-changing communication edges labeled by mail volume.
* ``random_graph_sequence`` is the small fuzzer used by property tests.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Tuple

from ..core.compile import compile_sequence
from ..core.graphseq import (
    LabeledGraph,
    Pattern,
    TR,
    TRSeq,
    TRType,
    edge_tr,
    pattern_from_lists,
    vertex_tr,
)
from ..core.union_graph import is_relevant


def _mutate(g: LabeledGraph, rng: random.Random, p_i: float, p_d: float,
            n_v: int, n_vl: int, n_el: int, p_e: float) -> None:
    """One Table-3 style mutation: insert / delete / relabel."""
    r = rng.random()
    vs = sorted(g.vlabels)
    if r < p_i or not vs:
        # insertion: a vertex (with edges to existing per p_e) or an edge
        if rng.random() < 0.5 or len(vs) < 2:
            u = 0
            while u in g.vlabels:
                u += 1
            if u >= n_v:
                return
            g.add_vertex(u, rng.randrange(n_vl))
            for v in vs:
                if rng.random() < p_e:
                    g.add_edge(u, v, rng.randrange(n_el))
        else:
            u, v = rng.sample(vs, 2)
            e = (min(u, v), max(u, v))
            if e not in g.elabels:
                g.add_edge(u, v, rng.randrange(n_el))
    elif r < p_i + p_d:
        # deletion: an edge, or an isolated vertex
        if g.elabels and rng.random() < 0.7:
            e = rng.choice(sorted(g.elabels))
            g.remove_edge(*e)
        else:
            iso = [u for u in g.vlabels if not g.incident(u)]
            if iso:
                g.remove_vertex(rng.choice(iso))
    else:
        # relabeling
        if g.elabels and rng.random() < 0.5:
            e = rng.choice(sorted(g.elabels))
            g.elabels[e] = rng.randrange(n_el)
        elif vs:
            u = rng.choice(vs)
            g.vlabels[u] = rng.randrange(n_vl)


def random_graph_sequence(
    rng: random.Random,
    n_steps: int = 4,
    n_v: int = 4,
    n_vl: int = 2,
    n_el: int = 2,
    p_i: float = 0.6,
    p_d: float = 0.2,
    p_e: float = 0.3,
    muts_per_step: Tuple[int, int] = (1, 2),
) -> List[LabeledGraph]:
    g = LabeledGraph()
    seq = []
    for _ in range(n_steps):
        for _ in range(rng.randint(*muts_per_step)):
            _mutate(g, rng, p_i, p_d, n_v, n_vl, n_el, p_e)
        seq.append(g.copy())
    return seq


@dataclasses.dataclass
class Table3Params:
    """Default values of Table 3 (scaled down by callers as needed)."""

    p_i: float = 0.80
    p_d: float = 0.10
    v_avg: int = 6
    v_avg_pattern: int = 3
    n_vlabels: int = 5
    n_elabels: int = 5
    n_patterns: int = 10
    db_size: int = 1000
    p_e: float = 0.15
    d_ist: int = 2
    n_interstates: int = 5


def _grow_sequence(rng: random.Random, p: Table3Params,
                   n_v: int) -> List[LabeledGraph]:
    """Start from |V|/2 vertices w/ edge prob p_e, mutate d_ist times per
    interstate, continue until the compiled sequence is relevant."""
    g = LabeledGraph()
    for u in range(max(1, n_v // 2)):
        g.add_vertex(u, rng.randrange(p.n_vlabels))
    vs = sorted(g.vlabels)
    for i in range(len(vs)):
        for j in range(i + 1, len(vs)):
            if rng.random() < p.p_e:
                g.add_edge(vs[i], vs[j], rng.randrange(p.n_elabels))
    seq = [g.copy()]
    for _ in range(p.n_interstates - 1):
        for _ in range(p.d_ist):
            _mutate(g, rng, p.p_i, p.p_d, n_v, p.n_vlabels, p.n_elabels,
                    p.p_e)
        seq.append(g.copy())
    return seq


def _overlay(s: TRSeq, pattern: Pattern, rng: random.Random,
             vertex_base: int) -> TRSeq:
    """Inject a pattern's TRs into a compiled sequence (fresh vertex IDs,
    random strictly-increasing itemset positions)."""
    n = len(s)
    if n < len(pattern):
        return s
    positions = sorted(rng.sample(range(n), len(pattern)))
    vmap = {}
    out = [list(itemset) for itemset in s]
    for pos, itemset in zip(positions, pattern):
        for tr in sorted(itemset):
            for v in tr.vertices():
                if v not in vmap:
                    vmap[v] = vertex_base + len(vmap)
            if tr.is_vertex:
                ntr = TR(tr.type, vmap[tr.u1], tr.u2, tr.label)
            else:
                a, b = vmap[tr.u1], vmap[tr.u2]
                ntr = TR(tr.type, min(a, b), max(a, b), tr.label)
            if ntr not in out[pos]:
                out[pos].append(ntr)
    return tuple(tuple(x) for x in out)


def generate_pattern(rng: random.Random, p: Table3Params) -> Pattern:
    """A small relevant pattern (the paper's embedded rFTS)."""
    while True:
        seq = random_graph_sequence(
            rng, n_steps=rng.randint(2, 3), n_v=p.v_avg_pattern,
            n_vl=p.n_vlabels, n_el=p.n_elabels, p_i=0.85, p_d=0.05,
            p_e=0.5,
        )
        s = compile_sequence(seq)
        pat = pattern_from_lists([it for it in s if it])
        if pat and is_relevant(pat) and sum(len(i) for i in pat) >= 2:
            return pat


def generate_table3_db(
    params: Table3Params | None = None, seed: int = 0
) -> List[TRSeq]:
    p = params or Table3Params()
    rng = random.Random(seed)
    patterns = [generate_pattern(rng, p) for _ in range(p.n_patterns)]
    db: List[TRSeq] = []
    for _ in range(p.db_size):
        seq = _grow_sequence(rng, p, p.v_avg)
        s = compile_sequence(seq)
        for pat in patterns:
            if rng.random() < 1.0 / p.n_patterns:
                s = _overlay(s, pat, rng, vertex_base=1000)
        db.append(s)
    return db


def generate_enron_like_db(
    n_weeks: int = 123,
    n_persons: int = 30,
    n_interstates: int = 7,
    n_roles: int = 8,
    n_volumes: int = 5,
    p_edge_on: float = 0.05,
    p_edge_off: float = 0.5,
    seed: int = 0,
) -> List[TRSeq]:
    """Weekly graph sequences of daily communication graphs (Sec. 5.2)."""
    rng = random.Random(seed)
    roles = {u: rng.randrange(n_roles) for u in range(n_persons)}
    db: List[TRSeq] = []
    for _ in range(n_weeks):
        g = LabeledGraph()
        seq = []
        for _day in range(n_interstates):
            # edges toggle gradually day to day
            for e in sorted(g.elabels):
                if rng.random() < p_edge_off:
                    g.remove_edge(*e)
            n_new = rng.randint(1, max(2, int(n_persons * p_edge_on)))
            for _ in range(n_new):
                u, v = rng.sample(range(n_persons), 2)
                for w in (u, v):
                    if w not in g.vlabels:
                        g.add_vertex(w, roles[w])
                e = (min(u, v), max(u, v))
                if e not in g.elabels:
                    g.add_edge(u, v, rng.randrange(n_volumes))
            # drop now-isolated persons
            for u in sorted(g.vlabels):
                if not g.incident(u):
                    g.remove_vertex(u)
            seq.append(g.copy())
        db.append(compile_sequence(seq))
    return db
