# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas interpret-mode default: compile for real only on TPU.

    Off-TPU backends (cpu, gpu) execute the kernel body through the
    interpreter so the same call sites validate everywhere; on TPU the
    kernel is compiled (interpret would silently serialize the hot loop).
    """
    return jax.default_backend() != "tpu"
