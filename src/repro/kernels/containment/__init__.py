"""Containment-step kernel: the per-step embedding-join predicate of the
serving path (repro.serving.batch).  Same layout as match_count: ref.py is
the pure-jnp oracle, containment.py the Pallas TPU kernel, ops.py the
jitted public wrapper."""
from .ops import contain_step_kernel  # noqa: F401
from .ref import contain_step_core  # noqa: F401
