"""Pallas TPU kernel for the batched containment step (the serving hot
loop).

One query step evaluates the embedding-join predicate for every
(cell g, frontier row e, window token t) triple, where a *cell* is one
(sequence, pattern) pair of the serving batch - the flattened
sequences x patterns grid (dense, or prescreen-compacted to the
surviving pairs, see repro.serving.batch).  Per cell the step touches
its [Tm, 6] token window, its [E, NV] psi frontier and its [E, 8] step
table; E (frontier capacity) and Tm (token-window width) are small
statics, so the kernel grids over cells only and keeps whole cells in
VMEM - the [bG, E, Tm, NV] injectivity broadcasts live in VMEM/VREGs
instead of HBM.

Tiling: grid (G/bG,); per grid step the kernel touches
  tok block   [bG, Tm, 6]  int32
  psi/srow    [bG, E, NV], [bG, E, 8]
  out         [bG, E, Tm]  int32
Default bG=64 with E,Tm <= 32 keeps the working set well under 1 MB of
VMEM.

E and Tm are small statics well below the TPU tile (8 sublanes x 128
lanes), so every block load/store would be relayout-padded by the
hardware anyway; ``lane_pad`` makes the padding explicit up front - Tm
(the lane dim of the output / token axis) to the 128-lane boundary, E
(its sublane dim) to a multiple of 8 - with all-zero rows/tokens, which
are inert by the same argument as the bG padding (token valid=0 /
row_valid=0 -> no match bits).  It follows the existing backend
auto-select: on exactly when the kernel compiles for real
(interpret=False, i.e. on TPU), off in interpret mode where it only
adds work - interpret-mode parity is tested by forcing it on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import default_interpret
from .ref import contain_step_core

LANE = 128
SUBLANE = 8


def _kernel(tok_ref, psi_ref, srow_ref, out_ref):
    out_ref[...] = contain_step_core(
        tok_ref[...], psi_ref[...], srow_ref[...]
    )


def contain_step_blocked(
    tok,        # [G, Tm, 6] int32 (per-cell token window)
    psi,        # [G, E, NV] int32
    srow,       # [G, E, 8] int32
    *,
    block_g: int = 64,
    interpret: bool | None = None,
    lane_pad: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    if lane_pad is None:
        lane_pad = not interpret  # pad only when compiling for real
    G, Tm, _ = tok.shape
    _, E, NV = psi.shape
    if lane_pad:
        Tp = -(-Tm // LANE) * LANE
        Ep = -(-E // SUBLANE) * SUBLANE
        if Tp != Tm:  # zero tokens: valid=0 -> no match bits
            tok = jnp.pad(tok, ((0, 0), (0, Tp - Tm), (0, 0)))
        if Ep != E:  # zero rows: row_valid=0 -> no match bits
            psi = jnp.pad(psi, ((0, 0), (0, Ep - E), (0, 0)))
            srow = jnp.pad(srow, ((0, 0), (0, Ep - E), (0, 0)))
        if Tp != Tm or Ep != E:
            out = contain_step_blocked(
                tok, psi, srow, block_g=block_g, interpret=interpret,
                lane_pad=False,
            )
            return out[:, :E, :Tm]
    Gp = -(-G // block_g) * block_g
    if Gp != G:
        # zero padding gives token valid=0 / row_valid=0 -> no match bits
        tok = jnp.pad(tok, ((0, Gp - G), (0, 0), (0, 0)))
        psi = jnp.pad(psi, ((0, Gp - G), (0, 0), (0, 0)))
        srow = jnp.pad(srow, ((0, Gp - G), (0, 0), (0, 0)))
    grid = (Gp // block_g,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_g, Tm, 6), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_g, E, NV), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_g, E, 8), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_g, E, Tm), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Gp, E, Tm), jnp.int32),
        interpret=interpret,
    )(
        tok.astype(jnp.int32),
        psi.astype(jnp.int32),
        srow.astype(jnp.int32),
    )
    return out[:G]
