"""Pallas TPU kernel for the batched containment step (the serving hot
loop).

One query step evaluates the embedding-join predicate for every
(cell g, frontier row e, window token t) triple, where a *cell* is one
(sequence, pattern) pair of the serving batch - the flattened
sequences x patterns grid (dense, or prescreen-compacted to the
surviving pairs, see repro.serving.batch).  Per cell the step touches
its [Tm, 6] token window, its [E, NV] psi frontier and its [E, 8] step
table; E (frontier capacity) and Tm (token-window width) are small
statics, so the kernel grids over cells only and keeps whole cells in
VMEM - the [bG, E, Tm, NV] injectivity broadcasts live in VMEM/VREGs
instead of HBM.

Tiling: grid (G/bG,); per grid step the kernel touches
  tok block   [bG, Tm, 6]  int32
  psi/srow    [bG, E, NV], [bG, E, 8]
  out         [bG, E, Tm]  int32
Default bG=64 with E,Tm <= 32 keeps the working set well under 1 MB of
VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import default_interpret
from .ref import contain_step_core


def _kernel(tok_ref, psi_ref, srow_ref, out_ref):
    out_ref[...] = contain_step_core(
        tok_ref[...], psi_ref[...], srow_ref[...]
    )


def contain_step_blocked(
    tok,        # [G, Tm, 6] int32 (per-cell token window)
    psi,        # [G, E, NV] int32
    srow,       # [G, E, 8] int32
    *,
    block_g: int = 64,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    G, Tm, _ = tok.shape
    _, E, NV = psi.shape
    Gp = -(-G // block_g) * block_g
    if Gp != G:
        # zero padding gives token valid=0 / row_valid=0 -> no match bits
        tok = jnp.pad(tok, ((0, Gp - G), (0, 0), (0, 0)))
        psi = jnp.pad(psi, ((0, Gp - G), (0, 0), (0, 0)))
        srow = jnp.pad(srow, ((0, Gp - G), (0, 0), (0, 0)))
    grid = (Gp // block_g,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_g, Tm, 6), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_g, E, NV), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_g, E, 8), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_g, E, Tm), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Gp, E, Tm), jnp.int32),
        interpret=interpret,
    )(
        tok.astype(jnp.int32),
        psi.astype(jnp.int32),
        srow.astype(jnp.int32),
    )
    return out[:G]
