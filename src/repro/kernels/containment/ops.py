"""Jitted public wrapper for the containment-step kernel."""
from __future__ import annotations

import functools

import jax

from .containment import contain_step_blocked


@functools.partial(
    jax.jit, static_argnames=("block_g", "interpret", "lane_pad")
)
def contain_step_kernel(
    tok,        # [G, Tm, 6] int32 (per-cell token window)
    psi,        # [G, E, NV] int32
    srow,       # [G, E, 8] int32
    *,
    block_g: int = 64,
    interpret: bool | None = None,
    lane_pad: bool | None = None,
):
    """Drop-in replacement for ``contain_step_core`` as used by
    repro.serving.batch (``interpret=None`` auto-selects: compiled on
    TPU, interpreter elsewhere; ``lane_pad=None`` follows the same
    auto-select, padding the small E/Tm dims to the hardware tile only
    when compiling)."""
    return contain_step_blocked(
        tok, psi, srow, block_g=block_g, interpret=interpret,
        lane_pad=lane_pad,
    )
