"""Pure-jnp oracle for the containment-step Pallas kernel.

``contain_step_core`` evaluates one step of the query-time embedding join
(repro.serving.batch): given the partial-embedding frontiers of a batch
of (sequence, pattern) *cells* and each cell's token window for the
step's (type, label) key, it decides for every
(cell, frontier row, token) triple whether the token realizes the
pattern's next TR under the Def-4 constraints the host oracle backtracks
over:

* itemset slot: the first TR of a pattern itemset may claim any data
  itemset strictly after the previous one (``j > prev_phi``); later TRs
  of the same itemset must land in the already-claimed one
  (``j == cur_phi``),
* type and label equal exactly,
* psi consistency: mapped pattern vertices must hit their psi image,
  fresh ones may only bind data vertices outside the (injective) image.

Edge TRs may match in two orientations; the result packs both decisions
into one int32 bitmask (bit0: ``pu1->u1, pu2->u2``; bit1: swapped), so
the state update downstream can reconstruct the binding without a second
pass.  Everything is elementwise int32 over masked-min/any lookups - the
same pure-VPU formulation as match_count's ``match_core``.
"""
from __future__ import annotations

import jax.numpy as jnp

# plain python int, NOT a jnp array (see match_count.ref for the rationale)
_BIG = 0x3FFFFFF

# srow column layout (per frontier row):
#   0 ty, 1 pu1, 2 pu2, 3 label, 4 new_itemset,
#   5 prev_phi, 6 cur_phi, 7 row_valid
SROW_FIELDS = 8


def contain_step_core(tok, psi, srow):
    """tok [G,T,6] int32 (per-cell token window: type,u1,u2,label,j,valid),
    psi [G,E,NV] int32 (PAD_PSI = unbound), srow [G,E,SROW_FIELDS] int32.
    Returns bits [G,E,T] int32: 0 = no match, bit0/bit1 = orientation."""
    t_ty = tok[:, None, :, 0]
    u1 = tok[:, None, :, 1]
    u2 = tok[:, None, :, 2]
    t_lab = tok[:, None, :, 3]
    j = tok[:, None, :, 4]
    t_val = tok[:, None, :, 5] > 0

    sty = srow[:, :, 0:1]
    spu1 = srow[:, :, 1:2]
    spu2 = srow[:, :, 2:3]
    slab = srow[:, :, 3:4]
    snew = srow[:, :, 4:5]
    sprev = srow[:, :, 5:6]
    scur = srow[:, :, 6:7]
    sval = srow[:, :, 7:8]

    base = t_val & (sval > 0) & (t_ty == sty) & (t_lab == slab)
    slot_ok = jnp.where(snew > 0, j > sprev, j == scur)

    # per-row psi gather at the step's pattern vertices (masked-min: the
    # matching column is unique, so the minimum is the looked-up value)
    nv_ids = jnp.arange(psi.shape[-1], dtype=jnp.int32)[None, None, :]
    pvv1 = jnp.min(jnp.where(nv_ids == spu1, psi, _BIG), -1, keepdims=True)
    pvv2 = jnp.min(jnp.where(nv_ids == spu2, psi, _BIG), -1, keepdims=True)
    bound1 = (pvv1 >= 0) & (pvv1 < _BIG)
    bound2 = (pvv2 >= 0) & (pvv2 < _BIG)

    # injectivity: is a data vertex already in the psi image?
    u1_mapped = (psi[:, :, None, :] == u1[..., None]).any(-1)  # [G,E,T]
    u2_mapped = (psi[:, :, None, :] == u2[..., None]).any(-1)

    is_v = sty <= 2
    ok_vert = jnp.where(bound1, u1 == pvv1, ~u1_mapped)

    # edge orientations: v0 assigns (pu1->u1, pu2->u2), v1 the swap
    e1_0 = jnp.where(bound1, u1 == pvv1, ~u1_mapped)
    e2_0 = jnp.where(bound2, u2 == pvv2, ~u2_mapped)
    e1_1 = jnp.where(bound1, u2 == pvv1, ~u2_mapped)
    e2_1 = jnp.where(bound2, u1 == pvv2, ~u1_mapped)
    distinct = bound1 | bound2 | (u1 != u2)
    ok_e0 = e1_0 & e2_0 & distinct
    ok_e1 = e1_1 & e2_1 & distinct

    keep = base & slot_ok
    bit0 = keep & jnp.where(is_v, ok_vert, ok_e0)
    bit1 = keep & ~is_v & ok_e1
    return bit0.astype(jnp.int32) | (bit1.astype(jnp.int32) << 1)
