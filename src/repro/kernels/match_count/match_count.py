"""Pallas TPU kernel for the embedding-join match (the mining hot loop).

The computation is elementwise int32 predicate work over an
(embeddings x tokens) grid with three small per-row tables (phi, psi) and
two tiny replicated tables (existing-TR list, scalars).  It is memory
bound: ~arithmetic-intensity (NV+NI+P) int ops per 4-byte signature
written, with the [bE,bT,NV] broadcast intermediates living entirely in
VMEM/VREGs instead of HBM (the jnp reference materializes them to HBM on
the XLA side unless fused).

Tiling: grid (E/bE, T/bT); per grid step the kernel touches
  tok block   [bE, bT, 6]  int32   (24*bE*bT bytes)
  phi/psi     [bE, NI], [bE, NV]
  out         [bE, bT]     int32
Defaults bE=64, bT=128 keep the working set < 1 MB of VMEM and the lane
dimension of the output a multiple of 128.

The phi/psi blocks' *lane* (last) dims are the small NI/NV statics
(typically 16/12), which a TPU would relayout to the 128-lane boundary
on every block load; ``lane_pad`` pads them up front with the inert
sentinels (PAD_PHI / PAD_PSI - both unmatched by construction, so the
signatures are unchanged).  It follows the existing backend
auto-select: on by default exactly when the kernel compiles for real
(interpret=False, i.e. on TPU), off in interpret mode where padding
only adds work - interpret-mode parity is tested by forcing it on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...mining.encoding import PAD_PHI, PAD_PSI
from .. import default_interpret
from .ref import match_core

LANE = 128


def _lane_pad_to(n: int) -> int:
    return -(-n // LANE) * LANE


def _kernel(scal_ref, tok_ref, phi_ref, psi_ref, valid_ref, ex_ref,
            out_ref):
    nv = scal_ref[0, 0]
    n_pat = scal_ref[0, 1]
    mode = scal_ref[0, 2]
    out_ref[...] = match_core(
        tok_ref[...],
        phi_ref[...],
        psi_ref[...],
        valid_ref[...][:, 0],
        ex_ref[...],
        nv,
        n_pat,
        mode,
    )


def match_signatures_blocked(
    tok_e,       # [E, T, 6] int32 (pre-gathered per embedding)
    phi,         # [E, NI] int32
    psi,         # [E, NV] int32
    emb_valid,   # [E] int32
    existing,    # [P, 5] int32
    nv,          # int32 scalar
    n_pat,       # int32 scalar
    mode,        # int32 scalar
    *,
    block_e: int = 64,
    block_t: int = 128,
    interpret: bool | None = None,
    lane_pad: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    if lane_pad is None:
        lane_pad = not interpret  # pad only when compiling for real
    E, T, _ = tok_e.shape
    NI, NV, P = phi.shape[1], psi.shape[1], existing.shape[0]
    if lane_pad:
        # PAD_PHI / PAD_PSI columns are inert: PAD_PHI is never equal to
        # or below a data itemset index, PAD_PSI never equals a data
        # vertex (>= NO_VERTEX = -1), so padded lookups cannot match
        NIp, NVp = _lane_pad_to(NI), _lane_pad_to(NV)
        if NIp != NI:
            phi = jnp.pad(phi, ((0, 0), (0, NIp - NI)),
                          constant_values=PAD_PHI)
            NI = NIp
        if NVp != NV:
            psi = jnp.pad(psi, ((0, 0), (0, NVp - NV)),
                          constant_values=PAD_PSI)
            NV = NVp
    Ep = -(-E // block_e) * block_e
    Tp = -(-T // block_t) * block_t
    if Ep != E or Tp != T:
        # zero padding gives tok valid=0 / emb_valid=0 -> INVALID_SIG
        tok_e = jnp.pad(tok_e, ((0, Ep - E), (0, Tp - T), (0, 0)))
        phi = jnp.pad(phi, ((0, Ep - E), (0, 0)))
        psi = jnp.pad(psi, ((0, Ep - E), (0, 0)))
        emb_valid = jnp.pad(emb_valid, (0, Ep - E))
    scal = jnp.stack([nv, n_pat, mode, jnp.int32(0)]).reshape(1, 4)
    grid = (Ep // block_e, Tp // block_t)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (0, 0)),
            pl.BlockSpec((block_e, block_t, 6), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_e, NI), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, NV), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((P, 5), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Ep, Tp), jnp.int32),
        interpret=interpret,
    )(
        scal.astype(jnp.int32),
        tok_e.astype(jnp.int32),
        phi.astype(jnp.int32),
        psi.astype(jnp.int32),
        emb_valid.astype(jnp.int32).reshape(-1, 1),
        existing.astype(jnp.int32),
    )
    return out[:E, :T]
