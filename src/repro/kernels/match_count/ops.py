"""Jitted public wrapper for the match_count kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .match_count import match_signatures_blocked


@functools.partial(
    jax.jit, static_argnames=("block_e", "block_t", "interpret",
                              "lane_pad")
)
def match_signatures_kernel(
    tokens,      # [G, T, 6] int32
    gid,         # [E] int32
    phi,         # [E, NI] int32
    psi,         # [E, NV] int32
    emb_valid,   # [E] int32
    existing,    # [P, 5] int32
    nv,          # int32 scalar
    n_pat,       # int32 scalar
    mode,        # int32 scalar
    *,
    block_e: int = 64,
    block_t: int = 128,
    interpret: bool | None = None,
    lane_pad: bool | None = None,
):
    """Drop-in replacement for repro.mining.engine.match_signatures that
    runs the match predicate as a Pallas kernel (``interpret=None``
    auto-selects from the backend: compiled on TPU, interpreter
    elsewhere - real TPU runs must not silently take the slow path;
    ``lane_pad=None`` follows the same auto-select, padding the small
    NI/NV lane dims to the 128-lane boundary only when compiling)."""
    tok_e = tokens[gid]
    return match_signatures_blocked(
        tok_e, phi, psi, emb_valid, existing,
        jnp.asarray(nv, jnp.int32), jnp.asarray(n_pat, jnp.int32),
        jnp.asarray(mode, jnp.int32),
        block_e=block_e, block_t=block_t, interpret=interpret,
        lane_pad=lane_pad,
    )
