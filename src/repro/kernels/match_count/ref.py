"""Pure-jnp oracle for the match_count Pallas kernel.

``match_core`` evaluates the embedding-join predicate for every
(embedding, token) pair over *pre-gathered* tokens and emits packed int32
extension signatures (see repro.mining.encoding for the bit layout and
repro.mining.engine for the search-phase semantics).

The formulation is deliberately TPU-friendly: vertex lookups are
min-over-masked-iota (psi rows are injective so the minimum is the unique
match) instead of argmax, and everything is elementwise/int32 - pure VPU
work.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...mining.encoding import (
    INVALID_SIG,
    SENT_V,
    _LAB_BITS,
    _PU_BITS,
    _SL_BITS,
    _TY_BITS,
)

MODE_ROOT = 0
MODE_VERTEX_PHASE = 1
MODE_EDGE_PHASE = 2
MODE_TAIL = 3

# NOTE: plain python int, NOT a jnp array: module-level device constants
# become hoisted jaxpr consts (extra executable buffers) and trip a
# dispatch/aliasing bug on the CPU backend in jax 0.8.
_BIG = 0x3FFFFFF


def _lookup(psi, u):
    """psi [E,NV], u [E,T] -> (mapped [E,T] bool, pid [E,T] int32: index of
    the unique matching psi column, BIG when unmapped)."""
    eq = psi[:, None, :] == u[:, :, None]  # [E,T,NV]
    nv_ids = jnp.arange(psi.shape[-1], dtype=jnp.int32)
    pid = jnp.min(jnp.where(eq, nv_ids[None, None, :], _BIG), axis=-1)
    return pid < _BIG, pid.astype(jnp.int32)


def match_core(tok, phi, psi, emb_valid, existing, nv, n_pat, mode):
    """tok [E,T,6] int32 (pre-gathered per embedding), phi [E,NI],
    psi [E,NV], emb_valid [E], existing [P,5], scalars nv/n_pat/mode.
    Returns sigs [E,T] int32 (-1 = no extension).

    The wavefront miner packs rows of *different* patterns into one
    scan, so ``nv``/``n_pat``/``mode`` may also be per-row ``[E]``
    vectors and ``existing`` a per-row ``[E,P,5]`` table (pre-gathered
    by pattern id); scalars and the shared ``[P,5]`` table remain the
    single-pattern fast path (and the Pallas kernel's calling
    convention)."""
    nv = jnp.asarray(nv)
    n_pat = jnp.asarray(n_pat)
    mode = jnp.asarray(mode)
    if nv.ndim == 1:
        nv = nv[:, None]          # [E,1] broadcasts against [E,T]
    if n_pat.ndim == 1:
        n_pat = n_pat[:, None]
    if mode.ndim == 1:
        mode = mode[:, None]
    ty = tok[..., 0]
    u1 = tok[..., 1]
    u2 = tok[..., 2]
    lab = tok[..., 3]
    j = tok[..., 4]
    valid = tok[..., 5] > 0
    is_v = ty <= 2

    m1, pid1 = _lookup(psi, u1)
    m2, pid2 = _lookup(psi, u2)
    pid1 = jnp.where(m1, pid1, nv)
    pid2 = jnp.where(m2, pid2, nv)

    # vertex-TR candidate
    ok_v = (mode == MODE_ROOT) | (mode == MODE_TAIL) | m1

    # edge-TR candidate
    both = m1 & m2
    one = m1 ^ m2
    mapped_pid = jnp.where(m1, pid1, pid2)
    a = jnp.where(both, jnp.minimum(pid1, pid2),
                  jnp.where(one, mapped_pid, nv))
    b = jnp.where(both, jnp.maximum(pid1, pid2),
                  jnp.where(one, nv, nv + 1))
    ok_e = jnp.where(
        mode == MODE_VERTEX_PHASE,
        False,
        jnp.where(mode == MODE_EDGE_PHASE, m1 | m2, True),
    )

    pu1 = jnp.where(is_v, pid1, a).astype(jnp.int32)
    pu2 = jnp.where(is_v, SENT_V, b).astype(jnp.int32)
    allowed = valid & jnp.where(is_v, ok_v, ok_e)

    # temporal slot
    in_eq = phi[:, None, :] == j[:, :, None]  # [E,T,NI]
    ni_ids = jnp.arange(phi.shape[-1], dtype=jnp.int32)
    in_pos = jnp.min(jnp.where(in_eq, ni_ids[None, None, :], _BIG), axis=-1)
    in_any = in_pos < _BIG
    in_idx = jnp.where(in_any, in_pos, 0).astype(jnp.int32)
    gap_idx = (phi[:, None, :] < j[:, :, None]).sum(-1).astype(jnp.int32)
    slot_kind = jnp.where(in_any, 0, 1).astype(jnp.int32)
    slot_idx = jnp.where(in_any, in_idx, gap_idx)

    tail_ok = jnp.where(
        mode == MODE_TAIL,
        (in_any & (in_idx == n_pat - 1)) | (~in_any & (gap_idx == n_pat)),
        True,
    )

    # duplicate-TR-in-itemset rejection
    ex = existing  # [P,5] shared, or [E,P,5] per-row
    if ex.ndim == 3:
        def _exc(c):
            return ex[:, None, :, c]      # [E,1,P]
    else:
        def _exc(c):
            return ex[None, None, :, c]   # [1,1,P]
    dup = (
        (_exc(0) == slot_idx[..., None])
        & (_exc(1) == ty[..., None])
        & (_exc(2) == pu1[..., None])
        & (_exc(3) == pu2[..., None])
        & (_exc(4) == lab[..., None])
    ).any(-1) & in_any

    v = slot_kind
    v = (v << _SL_BITS) | slot_idx
    v = (v << _TY_BITS) | ty
    v = (v << _PU_BITS) | pu1
    v = (v << _PU_BITS) | pu2
    v = (v << _LAB_BITS) | (lab + 1)
    keep = allowed & tail_ok & ~dup & (emb_valid[:, None] > 0)
    return jnp.where(keep, v, INVALID_SIG)
