"""Fused trie-walk megakernel: the whole subtree walk in one dispatch.

* ``ref.py``       - ``trie_walk_core``: the slot-topological walk over
                     in-kernel frontier buffers (jnp; also the kernel
                     body), bit-identical to the per-level scan in
                     repro.serving.batch.
* ``trie_walk.py`` - ``trie_walk_blocked``: the Pallas kernel gridded
                     over (sequence, depth-1 subtree) cells, behind the
                     same interpret/lane-pad backend auto-select as the
                     containment kernel.

Serving entry point: ``repro.serving.batch.fused_trie_walk`` (gathers
per-cell arrays inside one jitted program); layout registration:
``bank_layout="trie_fused"`` (repro.serving.layouts / server).
"""
from .ref import trie_walk_core  # noqa: F401
from .trie_walk import trie_walk_blocked  # noqa: F401
