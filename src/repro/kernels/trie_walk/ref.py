"""jnp reference for the fused trie walk (the megakernel's oracle).

The serving trie join (repro.serving.batch) advances one frontier per
(sequence, trie node) in a level-synchronous scan: one device dispatch
per trie *level*, frontiers gathered from the previous level's cell
array on every hop.  The fused walk collapses that ladder: one *cell*
is a (sequence, depth-1 subtree) pair, and the whole subtree - every
node, every level - is walked inside a single program over fixed
in-kernel frontier buffers:

* ``steps[:, n]`` / ``parent[:, n]`` lay the subtree out in topological
  slot order (parents before children - trie node ids are assigned in
  program order, see repro.serving.trie), so an unrolled pass over the
  slots visits each node exactly once with its parent's compacted
  frontier already written,
* slot ``n`` seeds from ``parent[:, n]``'s buffer row (or the root
  state when ``parent < 0``), applies the per-node residual-``req``
  prescreen *in kernel* (a failing node's seed frontier dies before the
  step - exactly the per-level path never seeding the cell), advances
  one ``_walk_step``, and writes its compacted frontier back,
* terminal accept/overflow bits for every slot come out together - one
  dispatch per (query batch, subtree shard) regardless of trie depth.

Bit-identity with the per-level path (and hence with the flat join and
``core.containment``) is the whole contract.  ``_walk_step`` is a
transliteration of ``serving.batch._step_once`` (``uniform=False``,
``compact=True``) onto per-cell token arrays - same candidate order,
same first-emax min-extraction compaction, same overflow flags - and
the root seed is the per-level 1-wide root frontier widened to ``emax``
rows with only column 0 valid: invalid rows flag no candidates and the
candidate order is row-major, so the compacted state (not just the
accept bit) agrees bitwise, which matters because children seed from
it.  The differential harness (tests/test_trie_fused.py) pins all of
this against the unrolled walk, the flat join, and the host oracle.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..containment.ref import contain_step_core

# local mirrors of the serving-layer constants (the kernels layer stays
# import-free of repro.serving; equality is asserted at the batch.py
# import site)
PAD_PHI = 0x3FFFFFF   # mining.encoding.PAD_PHI: +inf itemset sentinel
PAD_PSI = -2          # mining.encoding.PAD_PSI: unbound-vertex sentinel
REQ_MASKED = np.iinfo(np.int32).max  # serving.trie.REQ_MASKED


def _walk_step(tok_c, order_c, start_c, count_c, step_k, phi, psi,
               valid, *, emax, tmax):
    """One embedding-join step for N cells over *per-cell* token arrays
    (``tok_c[i]`` is cell i's own token table) - the in-kernel form of
    ``serving.batch._step_once`` (``uniform=False``), where the batch
    gather ``tokens[cell_b]`` has already happened outside.  Returns
    ``(phi_new, psi_new, new_valid, frontier_ovf, window_ovf)`` - both
    overflow legs separately, so the caller can assemble the per-level
    path's ``ovf_state`` (children inherit) vs ``ovf_term`` (terminal
    undecidedness drops this step's own frontier overflow) split."""
    T = tok_c.shape[1]
    N, Ein, NI = phi.shape
    NV = psi.shape[2]
    E, Tm = emax, tmax
    C = Ein * Tm * 2  # candidates: frontier rows x window x orient
    nv_ids = jnp.arange(NV, dtype=jnp.int32)
    ni_ids = jnp.arange(NI, dtype=jnp.int32)
    m_ids = jnp.arange(Tm, dtype=jnp.int32)
    cand_ids = jnp.arange(C, dtype=jnp.int32)
    ty_s, pu1_s, pu2_s, lab_s, new_s, idx_s, sval_s, key_s = (
        step_k[:, c] for c in range(8)
    )

    # ---- per-cell token window for this step's (type,label) bucket
    st_sel = jnp.take_along_axis(start_c, key_s[:, None], axis=1)[:, 0]
    ct_sel = jnp.take_along_axis(count_c, key_s[:, None], axis=1)[:, 0]
    wpos = jnp.minimum(st_sel[:, None] + m_ids[None, :], T - 1)
    wvalid = m_ids[None, :] < ct_sel[:, None]
    tpos = jnp.take_along_axis(order_c, wpos, axis=1)     # [N, Tm]
    tok_w = jnp.take_along_axis(tok_c, tpos[..., None], axis=1)
    tok_w = tok_w.at[..., 5].set(
        jnp.where(wvalid, tok_w[..., 5], 0)
    )

    # ---- per-row step table for the predicate
    idx_b = jnp.broadcast_to(idx_s[:, None, None], (N, Ein, 1))
    cur_phi = jnp.take_along_axis(phi, idx_b, axis=-1)[..., 0]
    prev_b = jnp.clip(idx_b - 1, 0, NI - 1)
    prev_phi = jnp.take_along_axis(phi, prev_b, axis=-1)[..., 0]
    prev_phi = jnp.where(idx_s[:, None] > 0, prev_phi, -1)
    row_valid = valid & (sval_s[:, None] > 0)

    def bro(x):  # [N] -> [N, Ein]
        return jnp.broadcast_to(x[:, None], (N, Ein))

    srow = jnp.stack(
        [bro(ty_s), bro(pu1_s), bro(pu2_s), bro(lab_s), bro(new_s),
         prev_phi, cur_phi, row_valid.astype(jnp.int32)],
        axis=-1,
    )

    bits = contain_step_core(tok_w, psi, srow)

    # ---- first-emax compaction by iterative min-extraction (the same
    # candidate order and extraction as _step_once, so the kept slots
    # and their order agree bitwise)
    flags = (
        jnp.stack([bits & 1, (bits >> 1) & 1], -1) > 0
    ).reshape(N, C)
    window_ovf = (ct_sel > Tm) & valid.any(-1)
    cand_row = cand_ids[None, :]
    sels = []
    last = jnp.full((N, 1), -1, jnp.int32)
    for _ in range(E):
        cur = jnp.min(
            jnp.where(flags & (cand_row > last), cand_row, C),
            -1, keepdims=True,
        )
        sels.append(cur)
        last = cur
    frontier_ovf = jnp.min(
        jnp.where(flags & (cand_row > last), cand_row, C), -1
    ) < C
    sel = jnp.concatenate(sels, -1)  # [N, E] ascending, C = empty
    new_valid = sel < C
    sel = jnp.minimum(sel, C - 1)
    e_old = sel // (Tm * 2)
    t_w = (sel // 2) % Tm
    var = sel % 2

    phi_src = jnp.take_along_axis(phi, e_old[..., None], axis=1)
    psi_src = jnp.take_along_axis(psi, e_old[..., None], axis=1)

    def wfield(f):  # [N, E] gather of tok_w[n, t_w, f]
        return jnp.take_along_axis(tok_w[..., f], t_w, axis=1)

    u1_g, u2_g, j_g = wfield(1), wfield(2), wfield(4)

    claim = (new_s[:, None] > 0) & new_valid
    onehot_ni = ni_ids[None, None, :] == idx_s[:, None, None]
    phi_new = jnp.where(
        onehot_ni & claim[..., None], j_g[..., None], phi_src
    )

    a_g = jnp.where(var == 0, u1_g, u2_g)
    b_g = jnp.where(var == 0, u2_g, u1_g)
    is_v = (ty_s <= 2)[:, None]
    pu1_b = jnp.broadcast_to(pu1_s[:, None, None], (N, E, 1))
    pu2_b = jnp.broadcast_to(pu2_s[:, None, None], (N, E, 1))
    fresh1 = jnp.take_along_axis(psi_src, pu1_b, axis=-1)[..., 0] < 0
    fresh2 = jnp.take_along_axis(psi_src, pu2_b, axis=-1)[..., 0] < 0
    onehot1 = nv_ids[None, None, :] == pu1_b
    onehot2 = nv_ids[None, None, :] == pu2_b
    assign1 = jnp.where(is_v, u1_g, a_g)
    psi_new = jnp.where(
        onehot1 & (fresh1 & new_valid)[..., None],
        assign1[..., None], psi_src,
    )
    psi_new = jnp.where(
        onehot2 & ((~is_v) & fresh2 & new_valid)[..., None],
        b_g[..., None], psi_new,
    )
    return phi_new, psi_new, new_valid, frontier_ovf, window_ovf


def trie_walk_core(tok_c, order_c, start_c, count_c, steps, parent,
                   req, *, emax, tmax, ni, nv):
    """Walk S subtree slots for N cells over in-kernel frontier buffers
    - the fused megakernel's body, shared verbatim by the Pallas kernel
    (trie_walk.py) and the jnp reference path.

    Per cell i: ``tok_c[i]``/``order_c[i]``/``start_c[i]``/``count_c[i]``
    are its sequence's token table + inverted index, ``steps[i]`` /
    ``parent[i]`` / ``req[i]`` its packed subtree (slot-topological:
    every real slot's parent slot index is smaller; ``parent = -1`` is
    the subtree root, which seeds from the shared root state).  Padding
    slots carry ``step_valid=0`` rows, ``parent=-1`` and
    ``req=REQ_MASKED`` - dead on arrival.

    Returns ``(acc [N,S] bool, ovf_term [N,S] bool)``: per slot the
    terminal accept bit and the terminal-undecidedness flag, matching
    the per-level path's ``(accepted, ovf_term)`` outputs bit for bit
    (internal slots like its ``compact=True`` cells, leaf slots like
    its ``compact=False, count_frontier_ovf=False`` cells - the accept
    bit of a full compaction equals the compaction-free any-candidate
    test, and ``ovf_term`` never includes the slot's own frontier
    overflow)."""
    N, S, _ = steps.shape
    E = emax
    steps = steps.astype(jnp.int32)
    parent = parent.astype(jnp.int32)
    # the per-level root seed (trie_root_state) widened to E rows with
    # only column 0 valid - bitwise the same compacted outputs (module
    # docstring)
    root_phi = jnp.full((N, E, ni), PAD_PHI, jnp.int32)
    root_psi = jnp.full((N, E, nv), PAD_PSI, jnp.int32)
    root_valid = jnp.zeros((N, E), jnp.bool_).at[:, 0].set(True)
    # in-kernel per-node residual prescreen, one compare for all slots
    poss_all = (count_c[:, None, :] >= req).all(-1)        # [N, S]
    phi_buf = jnp.zeros((N, S, E, ni), jnp.int32)
    psi_buf = jnp.zeros((N, S, E, nv), jnp.int32)
    valid_buf = jnp.zeros((N, S, E), jnp.bool_)
    ovf_buf = jnp.zeros((N, S), jnp.bool_)
    accs, ovfts = [], []
    for n in range(S):
        pidx = parent[:, n]
        isroot = pidx < 0
        pcl = jnp.clip(pidx, 0, max(S - 1, 0))
        ix4 = pcl[:, None, None, None]
        seed_phi = jnp.where(
            isroot[:, None, None], root_phi,
            jnp.take_along_axis(phi_buf, ix4, axis=1)[:, 0],
        )
        seed_psi = jnp.where(
            isroot[:, None, None], root_psi,
            jnp.take_along_axis(psi_buf, ix4, axis=1)[:, 0],
        )
        seed_valid = jnp.where(
            isroot[:, None], root_valid,
            jnp.take_along_axis(
                valid_buf, pcl[:, None, None], axis=1)[:, 0],
        )
        seed_ovf = jnp.where(
            isroot, False,
            jnp.take_along_axis(ovf_buf, pcl[:, None], axis=1)[:, 0],
        )
        poss = poss_all[:, n]
        # a prescreen-failed node's frontier dies before the step: no
        # candidates, no window overflow - exactly the per-level scan
        # never seeding the cell (req monotonicity makes the whole
        # subtree agree)
        seed_valid = seed_valid & poss[:, None]
        phi_n, psi_n, new_valid, frontier_ovf, window_ovf = _walk_step(
            tok_c, order_c, start_c, count_c, steps[:, n],
            seed_phi, seed_psi, seed_valid, emax=emax, tmax=tmax,
        )
        accs.append(new_valid.any(-1) & poss)
        ovfts.append((seed_ovf | window_ovf) & poss)
        phi_buf = phi_buf.at[:, n].set(phi_n)
        psi_buf = psi_buf.at[:, n].set(psi_n)
        valid_buf = valid_buf.at[:, n].set(new_valid)
        ovf_buf = ovf_buf.at[:, n].set(
            (seed_ovf | frontier_ovf | window_ovf) & poss)
    return jnp.stack(accs, -1), jnp.stack(ovfts, -1)
