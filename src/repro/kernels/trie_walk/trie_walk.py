"""Pallas megakernel for the fused trie walk.

One grid step walks ``block_n`` (sequence, depth-1 subtree) cells
through their *entire* subtree - level iteration, frontier buffers and
the per-node residual prescreen all live inside the kernel body
(trie_walk_core, shared verbatim with the jnp reference in ref.py), so
a query batch costs one dispatch per subtree shard regardless of trie
depth.  Per grid step the kernel touches

  tok block     [bN, T, 6]    int32 (the cell's own token table)
  order/start   [bN, T], [bN, K]
  steps/req     [bN, S, 8], [bN, S, K]
  out           2 x [bN, S]   int32 (accept / terminal-overflow bits)

with S = padded subtree slots and per-slot [bN, E, *] frontier state in
VMEM/VREGs; the default ``block_n=8`` keeps the working set small -
fused cells are ~S times heavier than a single containment step, so the
cell block is correspondingly narrower than containment's ``block_g``.

``lane_pad`` follows the backend auto-select of the containment kernel
(repro.kernels.containment): on when compiling for real
(interpret=False, i.e. on TPU), off in interpret mode.  It pads the
slot axis S - the lane dim of both outputs - to the 128-lane boundary
with inert slots (``step_valid=0`` rows, ``parent=-1``,
``req=REQ_MASKED``: dead on arrival by the same prescreen argument as
the cell padding), then slices back.  Interpret-mode parity with
forced ``lane_pad=True`` is covered by tests/test_trie_fused.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import default_interpret
from .ref import REQ_MASKED, trie_walk_core

LANE = 128


def _make_kernel(emax, tmax, ni, nv):
    def _kernel(tok_ref, order_ref, start_ref, count_ref, steps_ref,
                parent_ref, req_ref, acc_ref, ovft_ref):
        acc, ovft = trie_walk_core(
            tok_ref[...], order_ref[...], start_ref[...],
            count_ref[...], steps_ref[...], parent_ref[...],
            req_ref[...], emax=emax, tmax=tmax, ni=ni, nv=nv,
        )
        acc_ref[...] = acc.astype(jnp.int32)
        ovft_ref[...] = ovft.astype(jnp.int32)

    return _kernel


def trie_walk_blocked(
    tok_c,      # [N, T, 6] int32 (per-cell token tables)
    order_c,    # [N, T] int32 (per-cell inverted index)
    start_c,    # [N, K] int32
    count_c,    # [N, K] int32
    steps,      # [N, S, 8] int32 (packed subtree per cell)
    parent,     # [N, S] int32 (slot of parent; -1 = root seed / pad)
    req,        # [N, S, K] int32 (per-node residual prescreen rows)
    *,
    emax: int,
    tmax: int,
    ni: int,
    nv: int,
    block_n: int = 8,
    interpret: bool | None = None,
    lane_pad: bool | None = None,
):
    """Returns ``(acc [N,S] int32, ovf_term [N,S] int32)`` - the fused
    walk's terminal accept / undecidedness bits per subtree slot (see
    ref.trie_walk_core for the exact per-level bit-identity contract).
    """
    if interpret is None:
        interpret = default_interpret()
    if lane_pad is None:
        lane_pad = not interpret  # pad only when compiling for real
    N, T, _ = tok_c.shape
    K = start_c.shape[1]
    S = steps.shape[1]
    if lane_pad:
        Sp = -(-S // LANE) * LANE
        if Sp != S:
            # inert slots: step_valid=0, parent=-1, req=REQ_MASKED -
            # prescreen-dead, so acc/ovft come back 0 and slice away
            steps = jnp.pad(steps, ((0, 0), (0, Sp - S), (0, 0)))
            parent = jnp.pad(parent, ((0, 0), (0, Sp - S)),
                             constant_values=-1)
            req = jnp.pad(req, ((0, 0), (0, Sp - S), (0, 0)),
                          constant_values=REQ_MASKED)
            acc, ovft = trie_walk_blocked(
                tok_c, order_c, start_c, count_c, steps, parent, req,
                emax=emax, tmax=tmax, ni=ni, nv=nv, block_n=block_n,
                interpret=interpret, lane_pad=False,
            )
            return acc[:, :S], ovft[:, :S]
    Np = -(-N // block_n) * block_n
    if Np != N:
        # zero cells: empty token tables + REQ_MASKED prescreen rows
        # accept nothing; callers slice their real rows anyway
        tok_c = jnp.pad(tok_c, ((0, Np - N), (0, 0), (0, 0)))
        order_c = jnp.pad(order_c, ((0, Np - N), (0, 0)))
        start_c = jnp.pad(start_c, ((0, Np - N), (0, 0)))
        count_c = jnp.pad(count_c, ((0, Np - N), (0, 0)))
        steps = jnp.pad(steps, ((0, Np - N), (0, 0), (0, 0)))
        parent = jnp.pad(parent, ((0, Np - N), (0, 0)),
                         constant_values=-1)
        req = jnp.pad(req, ((0, Np - N), (0, 0), (0, 0)),
                      constant_values=REQ_MASKED)
    grid = (Np // block_n,)
    acc, ovft = pl.pallas_call(
        _make_kernel(emax, tmax, ni, nv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, T, 6), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_n, T), lambda g: (g, 0)),
            pl.BlockSpec((block_n, K), lambda g: (g, 0)),
            pl.BlockSpec((block_n, K), lambda g: (g, 0)),
            pl.BlockSpec((block_n, S, 8), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_n, S), lambda g: (g, 0)),
            pl.BlockSpec((block_n, S, K), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, S), lambda g: (g, 0)),
            pl.BlockSpec((block_n, S), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, S), jnp.int32),
            jax.ShapeDtypeStruct((Np, S), jnp.int32),
        ],
        interpret=interpret,
    )(
        tok_c.astype(jnp.int32),
        order_c.astype(jnp.int32),
        start_c.astype(jnp.int32),
        count_c.astype(jnp.int32),
        steps.astype(jnp.int32),
        parent.astype(jnp.int32),
        req.astype(jnp.int32),
    )
    return acc[:N], ovft[:N]
