import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  This module is the ONLY place the 512-device override is set.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs.registry import get_arch, list_archs  # noqa: E402
from ..models import common  # noqa: E402
from ..roofline import analysis  # noqa: E402
from .mesh import make_production_mesh, set_mesh_compat  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_stats(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        out["per_device_total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


def _sharding_tree(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch_id: str, shape: str, multi_pod: bool):
    """Lower + compile one (arch x shape x mesh) cell; return stats."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = get_arch(arch_id)
    t0 = time.time()

    if arch.family == "mining":
        from ..mining.distributed import make_mining_step

        m = arch.shapes[shape].meta
        db_axes = common.dp_axes(mesh)
        step = make_mining_step(mesh, k=m["k"], db_axes=db_axes,
                                tok_axis="model")
        b = arch.batch_abstract(shape)
        args = (b["tokens"], b["gid"], b["phi"], b["psi"], b["valid"],
                b["existing"], jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        with set_mesh_compat(mesh):
            lowered = step.lower(*args)
    else:
        step, args = arch.make_step(shape, mesh)
        specs = arch.arg_specs(shape, mesh, args)
        shardings = _sharding_tree(specs, mesh)
        with set_mesh_compat(mesh):
            lowered = jax.jit(step, in_shardings=shardings).lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    n_chips = int(np.prod(list(mesh.shape.values())))
    roof = analysis.from_compiled(
        compiled, n_chips, arch.model_flops(shape), hlo_text=hlo
    )
    coll = analysis.parse_collectives(hlo)
    return {
        "arch": arch_id,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "ok": True,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": _mem_stats(compiled),
        "collectives": coll,
        "roofline": roof.to_dict(),
    }


def run_cell_to_file(arch_id, shape, multi_pod, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch_id}__{shape}__{'multi' if multi_pod else 'single'}"
    path = os.path.join(out_dir, tag + ".json")
    try:
        res = lower_cell(arch_id, shape, multi_pod)
        print(f"[dryrun] OK   {tag}  compile={res['t_compile_s']}s "
              f"bottleneck={res['roofline']['bottleneck']}")
    except Exception as e:
        res = {
            "arch": arch_id, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "ok": False, "error": str(e),
            "traceback": traceback.format_exc(),
        }
        print(f"[dryrun] FAIL {tag}: {e}")
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    return res


def all_cells(include_mining=True):
    cells = []
    for arch_id in list_archs(include_extra=include_mining):
        arch = get_arch(arch_id)
        for shape in arch.shapes:
            cells.append((arch_id, shape))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.arch:
        cells = [(args.arch, s) for s in get_arch(args.arch).shapes]
    else:
        cells = all_cells()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch_id, shape in cells:
        for multi in meshes:
            tag = (f"{arch_id}__{shape}__"
                   f"{'multi' if multi else 'single'}")
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                try:
                    ok = json.load(open(path)).get("ok")
                except Exception:
                    ok = False
                if ok:
                    print(f"[dryrun] SKIP {tag}")
                    continue
            run_cell_to_file(arch_id, shape, multi, args.out)


if __name__ == "__main__":
    main()
