"""Production mesh construction.

A function, not a module constant: importing this module must never touch
jax device state (smoke tests run on 1 real CPU device; only dryrun.py
requests 512 virtual devices via XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from ..compat import set_mesh_compat, shard_map_compat  # noqa: F401


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist, data x model (for tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
