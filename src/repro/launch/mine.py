"""Mining launcher: run GTRACE-RS (or the GTRACE baseline) over a
generated or loaded graph-sequence DB with checkpoint/restart."""
from __future__ import annotations

import argparse
import time

from ..data.synthetic import Table3Params, generate_table3_db
from ..mining.driver import AcceleratedMiner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=200)
    ap.add_argument("--v-avg", type=int, default=5)
    ap.add_argument("--interstates", type=int, default=4)
    ap.add_argument("--min-support-frac", type=float, default=0.1)
    ap.add_argument("--max-len", type=int, default=6)
    ap.add_argument("--algo", choices=["rs", "gtrace", "both"],
                    default="both")
    ap.add_argument("--dispatch", choices=["wavefront", "pattern"],
                    default="wavefront",
                    help="wavefront = frontier-batched device scans "
                         "(default); pattern = seed one-dispatch-per-"
                         "pattern baseline")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    params = Table3Params(db_size=args.db_size, v_avg=args.v_avg,
                          n_interstates=args.interstates)
    db = generate_table3_db(params, seed=args.seed)
    sigma = max(2, int(args.min_support_frac * len(db)))
    print(f"[mine] |DB|={len(db)} sigma={sigma} max_len={args.max_len}")

    miner = AcceleratedMiner(db, dispatch=args.dispatch)
    if args.algo in ("rs", "both"):
        t0 = time.time()
        rs = miner.mine_rs(sigma, max_len=args.max_len,
                           checkpoint_path=args.checkpoint,
                           resume=args.resume)
        print(f"[mine] GTRACE-RS: {len(rs.patterns)} rFTSs "
              f"({rs.n_enumerated} nodes) in {time.time()-t0:.2f}s, "
              f"device {miner.device_seconds:.2f}s "
              f"(launch {miner.dispatch_seconds:.2f}s)/"
              f"{miner.n_device_calls} calls")
    if args.algo in ("gtrace", "both"):
        t0 = time.time()
        gt = miner.mine_gtrace(sigma, max_len=args.max_len)
        rel = gt.relevant()
        print(f"[mine] GTRACE:   {len(gt.patterns)} FTSs -> "
              f"{len(rel)} rFTSs in {time.time()-t0:.2f}s")
    if args.algo == "both":
        assert rel == rs.patterns, "baseline/RS mismatch!"
        print("[mine] GTRACE.relevant() == GTRACE-RS  (verified)")


if __name__ == "__main__":
    main()
