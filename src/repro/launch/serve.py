"""Serving launcher: mine (or resume) an rFTS bank, stand up a
PatternServer, and drive a synthetic query workload end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --db-size 150 --queries 500

With ``--window N`` the launcher instead stands up a ``StreamingBank``:
the mined DB seeds an N-sequence sliding window, the query stream is
observed batch by batch (supports maintained incrementally, tombstones
masked), and ``--refresh-every R`` reconciles the bank with the window
every R batches via the frontier re-mine.

    PYTHONPATH=src python -m repro.launch.serve --db-size 100 \
        --queries 200 --window 100 --refresh-every 4 --bank-layout trie

``--hosts N`` (N > 1) stands the bank up as a multi-host cluster
(serving.cluster): queries arrive round-robin across hosts and are
routed through per-shard device batches; with ``--window`` the cluster
runs the sharded-window streaming protocol instead (per-host ring
slices, supports all-reduced at refresh).  ``--replicas R`` (streaming
mode) adds R read replicas behind a single writer and serves the query
sample from a replica after shipping the writer's deltas.

    PYTHONPATH=src python -m repro.launch.serve --db-size 100 \
        --queries 200 --hosts 4 --bank-layout trie
"""
from __future__ import annotations

import argparse
import time

from ..core.graphseq import pattern_str
from ..data.synthetic import Table3Params, generate_table3_db
from ..mining.driver import AcceleratedMiner
from ..serving.bank import compile_bank
from ..serving.server import PatternServer
from ..serving.streaming import StreamingBank


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=150)
    ap.add_argument("--v-avg", type=int, default=5)
    ap.add_argument("--interstates", type=int, default=3)
    ap.add_argument("--min-support-frac", type=float, default=0.1)
    ap.add_argument("--max-len", type=int, default=4)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--emax", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=512)
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--top-patterns", type=int, default=None,
                    help="serve only the strongest N patterns")
    ap.add_argument("--bank-layout",
                    choices=("flat", "trie", "trie_fused"),
                    default="flat",
                    help="flat per-pattern joins, or the prefix-trie "
                         "layout that joins shared rFTS prefixes once")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run the match predicate as the Pallas kernel")
    ap.add_argument("--window", type=int, default=None,
                    help="streaming mode: maintain supports over a "
                         "sliding window of this many sequences")
    ap.add_argument("--refresh-every", type=int, default=4,
                    help="streaming mode: reconcile (frontier re-mine) "
                         "every N observed batches")
    ap.add_argument("--stream-batch", type=int, default=25,
                    help="streaming mode: arrivals per observed batch")
    ap.add_argument("--hosts", type=int, default=1,
                    help="multi-host cluster: shard the bank across "
                         "this many simulated hosts (with --window, "
                         "run the sharded-window streaming protocol)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="streaming mode: read replicas behind the "
                         "single writer (deltas shipped per refresh)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    params = Table3Params(db_size=args.db_size, v_avg=args.v_avg,
                          n_interstates=args.interstates)
    db = generate_table3_db(params, seed=args.seed)
    sigma = max(2, int(args.min_support_frac * len(db)))
    if args.window is not None and args.hosts > 1:
        return _sharded_stream_main(args, db, sigma)
    if args.window is not None:
        return _stream_main(args, db, sigma)
    if args.hosts > 1:
        return _cluster_main(args, db, sigma)
    print(f"[serve] mining |DB|={len(db)} sigma={sigma} "
          f"max_len={args.max_len}")
    miner = AcceleratedMiner(db)
    t0 = time.time()
    res = miner.mine_rs(sigma, max_len=args.max_len,
                        checkpoint_path=args.checkpoint,
                        resume=args.resume)
    bank = compile_bank(res, top=args.top_patterns)
    print(f"[serve] bank: {bank.n_patterns} rFTSs "
          f"(max {bank.max_steps} TRs, {bank.nv} vertices) "
          f"mined in {time.time()-t0:.2f}s")
    trie = None
    from ..serving.layouts import get_layout
    if get_layout(args.bank_layout).uses_trie:
        from ..serving.trie import build_trie
        trie = build_trie(bank)
        print(f"[serve] trie: {trie.n_nodes} nodes, depth {trie.depth},"
              f" sharing x{trie.sharing_ratio:.2f}")

    srv = PatternServer(bank, emax=args.emax, max_batch=args.max_batch,
                        topk=args.topk, use_kernel=args.use_kernel,
                        bank_layout=args.bank_layout, trie=trie)
    qparams = Table3Params(db_size=args.queries, v_avg=args.v_avg,
                           n_interstates=args.interstates)
    queries = generate_table3_db(qparams, seed=args.seed + 1)
    srv.query(queries[: min(len(queries), args.max_batch)])  # warm jit
    srv._cache.clear()
    t0 = time.time()
    results = srv.query(queries)
    dt = time.time() - t0
    n_hits = sum(len(r.pattern_ids) for r in results)
    print(f"[serve] {len(queries)} queries in {dt:.3f}s "
          f"({len(queries)/max(dt, 1e-9):.0f} qps), "
          f"{n_hits} containments, stats={srv.stats}")
    best = results[0]
    print(f"[serve] sample top-{args.topk} for query 0:")
    for pid, sup in best.topk:
        print(f"    [{sup:3d}] {pattern_str(bank.patterns[pid])}")
    # second pass: everything cache-served
    t0 = time.time()
    srv.query(queries)
    print(f"[serve] cached pass {time.time()-t0:.3f}s, "
          f"cache_hits={srv.stats['cache_hits']}")


def _cluster_main(args, db, sigma):
    """Multi-host serving demo: shard the mined bank across simulated
    hosts, spread the query stream round-robin over arrival hosts, and
    route it through shared per-shard device batches."""
    from ..serving.cluster import ServingCluster

    print(f"[serve] cluster: mining |DB|={len(db)} sigma={sigma} "
          f"max_len={args.max_len}, {args.hosts} hosts")
    miner = AcceleratedMiner(db)
    res = miner.mine_rs(sigma, max_len=args.max_len)
    bank = compile_bank(res, top=args.top_patterns)
    cl = ServingCluster(
        bank, args.hosts, bank_layout=args.bank_layout,
        topk=args.topk, emax=args.emax, max_batch=args.max_batch,
        use_kernel=args.use_kernel,
    )
    sizes = [len(h.rows) for h in cl.hosts]
    print(f"[serve] bank: {bank.n_patterns} rFTSs sharded "
          f"{sizes} across {args.hosts} hosts ({args.bank_layout})")
    qparams = Table3Params(db_size=args.queries, v_avg=args.v_avg,
                           n_interstates=args.interstates)
    queries = generate_table3_db(qparams, seed=args.seed + 1)
    reqs = {h: [] for h in range(args.hosts)}
    for i, s in enumerate(queries):
        reqs[i % args.hosts].append(s)
    cl.query_multi(reqs)  # warm jit
    cl.router.clear_caches()
    t0 = time.time()
    got = cl.query_multi(reqs)
    dt = time.time() - t0
    n_hits = sum(len(r.pattern_ids) for rs in got.values() for r in rs)
    print(f"[serve] routed {len(queries)} queries in {dt:.3f}s "
          f"({len(queries)/max(dt, 1e-9):.0f} qps), {n_hits} "
          f"containments, stats={cl.router.stats}")
    # replay from the *other* hosts: everything L2- or L1-served
    reqs2 = {(h + 1) % args.hosts: v for h, v in reqs.items()}
    t0 = time.time()
    cl.query_multi(reqs2)
    print(f"[serve] cross-host replay {time.time()-t0:.3f}s, "
          f"l1={cl.router.stats['l1_hits']} "
          f"l2={cl.router.stats['l2_hits']}")


def _sharded_stream_main(args, db, sigma):
    """Sharded-window streaming demo: per-host ring slices, routed
    arrival joins, supports all-reduced at each refresh."""
    from ..serving.cluster import ShardedStreamingBank

    # ring slices must divide the window evenly; round up so a window
    # smaller than the host count still yields one slot per host
    window = max(1, -(-args.window // args.hosts)) * args.hosts
    print(f"[serve] sharded window: |DB|={len(db)} sigma={sigma} "
          f"window={window} over {args.hosts} hosts")
    t0 = time.time()
    sb = ShardedStreamingBank.from_db(
        db, minsup=sigma, n_hosts=args.hosts, window=window,
        max_len=args.max_len, bank_layout=args.bank_layout,
        emax=args.emax, use_kernel=args.use_kernel,
    )
    print(f"[serve] seeded in {time.time()-t0:.2f}s: "
          f"{sb.bank.n_patterns} rFTSs")
    qparams = Table3Params(db_size=args.queries, v_avg=args.v_avg,
                           n_interstates=args.interstates)
    stream = generate_table3_db(qparams, seed=args.seed + 1)
    t0 = time.time()
    for i in range(0, len(stream), args.stream_batch):
        sb.observe(stream[i: i + args.stream_batch])
        if (i // args.stream_batch + 1) % args.refresh_every == 0:
            sb.refresh()
    freq = sb.refresh()
    dt = time.time() - t0
    print(f"[serve] streamed {len(stream)} arrivals in {dt:.3f}s "
          f"({len(stream)/max(dt, 1e-9):.0f} updates/s), "
          f"{len(freq)} frequent after final refresh; stats={sb.stats}")
    top = sorted(freq.items(), key=lambda ps: -ps[1])[: args.topk]
    print(f"[serve] top-{args.topk} by all-reduced window support:")
    for p, sup in top:
        print(f"    [{sup:3d}] {pattern_str(p)}")


def _stream_main(args, db, sigma):
    """Streaming-mode demo: seed a window, observe the query stream,
    reconcile on a cadence, report support drift and frontier stats."""
    print(f"[serve] streaming: mining seed window |DB|={len(db)} "
          f"sigma={sigma} max_len={args.max_len}")
    t0 = time.time()
    sb = StreamingBank.from_db(
        db, minsup=sigma, window=args.window, max_len=args.max_len,
        bank_layout=args.bank_layout, refresh_every=args.refresh_every,
        emax=args.emax, use_kernel=args.use_kernel,
    )
    group = None
    if args.replicas:
        from ..serving.cluster import ReplicaGroup
        group = ReplicaGroup(sb, args.replicas)
        print(f"[serve] writer + {args.replicas} read replicas")
    print(f"[serve] seeded in {time.time()-t0:.2f}s: "
          f"{sb.bank.n_patterns} rFTSs, {len(sb.frequent())} frequent "
          f"over the {args.window}-seq window")
    qparams = Table3Params(db_size=args.queries, v_avg=args.v_avg,
                           n_interstates=args.interstates)
    stream = generate_table3_db(qparams, seed=args.seed + 1)
    t0 = time.time()
    for i in range(0, len(stream), args.stream_batch):
        batch = stream[i: i + args.stream_batch]
        r = sb.observe(batch)
        print(f"[serve] batch {i // args.stream_batch}: "
              f"+{r.arrived}/-{r.evicted} seqs, "
              f"{r.tombstoned} tombstoned"
              + (", refreshed" if r.refreshed else ""))
    freq = sb.refresh()
    dt = time.time() - t0
    print(f"[serve] streamed {len(stream)} arrivals in {dt:.3f}s "
          f"({len(stream)/max(dt, 1e-9):.0f} updates/s), "
          f"{len(freq)} frequent after final refresh; stats={sb.stats}")
    top = sorted(freq.items(), key=lambda ps: -ps[1])[: args.topk]
    print(f"[serve] top-{args.topk} by live window support:")
    for p, sup in top:
        print(f"    [{sup:3d}] {pattern_str(p)}")
    if group is not None:
        sample = stream[: min(len(stream), 8)]
        print(f"[serve] replica lag before ship: "
              f"{group.lag(0)} deltas")
        group.sync()
        got = group.query(sample, replica=0, k=args.topk)
        n_hits = sum(len(r.pattern_ids) for r in got)
        print(f"[serve] replica 0 serves {len(sample)} sample queries "
              f"after ship: {n_hits} containments")


if __name__ == "__main__":
    main()
