"""Training launcher.

On a real TPU cluster this is the per-host entry point: it builds the
production mesh, shards params/opt-state per the arch's rules, and runs
the pjit'd train step with checkpoint/restart.  On CPU (this container) it
runs the reduced smoke config so the loop is exercisable end-to-end.

XLA collective-overlap flags we ship for real runs (latency-hiding
scheduler; recorded here so the launch configuration is part of the
repo):

    --xla_tpu_enable_async_collective_fusion=true
    --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
    --xla_tpu_overlap_compute_collective_tc=true
    --xla_enable_async_all_gather=true
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.registry import get_arch
from ..data.lm import token_batches
from ..training.optimizer import AdamW, cosine_schedule
from ..training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    assert arch.family == "lm", "train.py drives the LM archs"
    cfg = arch.smoke_cfg
    import jax.numpy as jnp

    from ..models import transformer as tf

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batches = token_batches(0, cfg.vocab, args.batch, args.seq)
    batches = (
        {k: jnp.asarray(v) for k, v in b.items()} for b in batches
    )
    opt = AdamW(lr=cosine_schedule(1e-3, 30, args.steps), weight_decay=0.01)
    _, _, losses = train(
        lambda p, b: tf.lm_loss(p, b, cfg),
        params, batches, args.steps, opt=opt,
        grad_accum=args.grad_accum,
        checkpoint_path=args.checkpoint, resume=args.resume,
    )
    print(f"[train] first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
