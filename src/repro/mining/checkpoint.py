"""Fault-tolerant mining state: checkpoint / restore / resume.

Reverse search has no cross-subtree state, so the full miner state is
(mined results so far, remaining work stack).  We serialize both with
msgpack+zstd and write atomically (tmp + rename), so a crash at any point
leaves either the previous or the new checkpoint intact.  On restore the
driver resumes from the stack; subtree supports are recomputed
idempotently, so a re-enqueued subtree (e.g. after a lost worker) cannot
corrupt results.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List, Tuple

import zlib

import msgpack

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib
    zstandard = None

from ..core.enumerate_host import Emb
from ..core.graphseq import Pattern, TR, TRType

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(data: bytes) -> bytes:
    if data[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but zstandard is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)


def _pattern_to_wire(p: Pattern):
    return [sorted([list(tr) for tr in s]) for s in p]


def _pattern_from_wire(w) -> Pattern:
    return tuple(
        frozenset(TR(TRType(t[0]), t[1], t[2], t[3]) for t in s) for s in w
    )


def _emb_to_wire(e: Emb):
    gid, phi, psi = e
    return [gid, list(phi), [list(x) for x in psi]]


def _emb_from_wire(w) -> Emb:
    return (w[0], tuple(w[1]), tuple((a, b) for a, b in w[2]))


def save_state(
    path: str,
    patterns: Dict[Pattern, int],
    stack: List[Tuple[Pattern, List[Emb]]],
    meta: dict | None = None,
) -> None:
    payload = {
        "version": 1,
        "meta": meta or {},
        "patterns": [[_pattern_to_wire(p), s] for p, s in patterns.items()],
        "stack": [
            [_pattern_to_wire(p), [_emb_to_wire(e) for e in embs]]
            for p, embs in stack
        ],
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    data = _compress(raw)
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".ckpt-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_state(path: str):
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    assert payload["version"] == 1
    patterns = {
        _pattern_from_wire(w): s for w, s in payload["patterns"]
    }
    stack = [
        (_pattern_from_wire(w), [_emb_from_wire(e) for e in embs])
        for w, embs in payload["stack"]
    ]
    return patterns, stack, payload["meta"]
