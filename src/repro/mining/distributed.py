"""Distributed extension scans over the production mesh.

Sharding layout (see launch/mesh.py):

* DB token tensor [G, T, 6] - sequences sharded over ("pod","data")
  (disjoint gid ranges per shard), tokens sharded over "model" (the match
  compute is embarrassingly parallel over tokens).
* embeddings [E, ...]       - co-sharded with their gid's DB shard.
* output: a replicated candidate table (uniq signatures [k] + distinct-gid
  supports [k]).

Collective schedule (the whole cross-device traffic of one scan):

1. all_gather of the int32 signature matrix over "model" - brings each
   data shard's full [E_loc, T] signature matrix together (the matrix is
   ~NV+NI times smaller than the match compute, so sharding compute over
   "model" and gathering results is a bandwidth win).
2. local sort + segment reduction -> per-shard (sig, count) table, exact
   because gid ranges are disjoint.
3. all_gather of the [k,2] tables over ("pod","data") + a local
   merge-by-signature.  At 512 chips this is k*512*8B ~ 16 MB, amortized
   over E_loc*T match work: the mining step stays compute-bound, which is
   why the reverse-search design scales to O(1000) nodes.

Straggler note: the driver issues embedding batches in fixed-size chunks;
a chunk not acknowledged within a deadline is reassigned (supports are
idempotent set-unions, so duplicated work is harmless).  Elasticity:
resharding the DB is a pure gid-hash repartition of ``tokens``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map_compat
from .encoding import INVALID_SIG
from .engine import match_signatures_ref


def _dedup_pairs(flat_sig, flat_gid, kp: int):
    """Unique (sig, gid) pairs, fixed size kp (pads sig=-1, gid=-1)."""
    order = jnp.lexsort((flat_gid, flat_sig))
    ss, gg = flat_sig[order], flat_gid[order]
    prev_s = jnp.concatenate([jnp.full((1,), -7, ss.dtype), ss[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -7, gg.dtype), gg[:-1]])
    keep = ((ss != prev_s) | (gg != prev_g)) & (ss >= 0)
    # stable compaction into kp slots + one dump slot for drops/overflow
    pos = jnp.cumsum(keep) - 1
    idx = jnp.where(keep & (pos < kp), pos, kp)
    out_s = jnp.full((kp + 1,), INVALID_SIG, ss.dtype)
    out_g = jnp.full((kp + 1,), -1, gg.dtype)
    out_s = out_s.at[idx].set(jnp.where(keep, ss, INVALID_SIG))
    out_g = out_g.at[idx].set(jnp.where(keep, gg, -1))
    n_pairs = keep.sum()  # caller checks n_pairs <= kp (else re-run)
    return out_s[:kp], out_g[:kp], n_pairs


def _local_candidate_table(sigs, gid_global, k: int):
    """Exact per-shard (sig -> distinct-gid count) via sort + segments."""
    E, T = sigs.shape
    flat_sig = sigs.reshape(-1)
    flat_gid = jnp.broadcast_to(gid_global[:, None], (E, T)).reshape(-1)
    order = jnp.lexsort((flat_gid, flat_sig))
    ss, gg = flat_sig[order], flat_gid[order]
    prev_s = jnp.concatenate([jnp.full((1,), -7, ss.dtype), ss[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -7, gg.dtype), gg[:-1]])
    contrib = ((ss != prev_s) | (gg != prev_g)) & (ss >= 0)
    n_distinct = ((ss != prev_s) & (ss >= 0)).sum()
    uniq, inv = jnp.unique(ss, size=k, fill_value=INVALID_SIG,
                           return_inverse=True)
    counts = jax.ops.segment_sum(contrib.astype(jnp.int32), inv,
                                 num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts, n_distinct


def _flat_candidate_table(flat_sig, flat_gid, k: int):
    """(sig -> distinct-gid count) over flat pair arrays (may contain
    duplicate pairs, e.g. after a cross-token-shard merge)."""
    order = jnp.lexsort((flat_gid, flat_sig))
    ss, gg = flat_sig[order], flat_gid[order]
    prev_s = jnp.concatenate([jnp.full((1,), -7, ss.dtype), ss[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -7, gg.dtype), gg[:-1]])
    contrib = ((ss != prev_s) | (gg != prev_g)) & (ss >= 0) & (gg >= 0)
    n_distinct = ((ss != prev_s) & (ss >= 0)).sum()
    uniq, inv = jnp.unique(ss, size=k, fill_value=INVALID_SIG,
                           return_inverse=True)
    counts = jax.ops.segment_sum(contrib.astype(jnp.int32), inv,
                                 num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts, n_distinct


def _merge_tables(sig_tables, cnt_tables, k: int):
    """[S,k] tables -> merged [k] table (counts add: disjoint gids)."""
    allsig = sig_tables.reshape(-1)
    allcnt = cnt_tables.reshape(-1)
    uniq, inv = jnp.unique(allsig, size=k, fill_value=INVALID_SIG,
                           return_inverse=True)
    counts = jax.ops.segment_sum(allcnt, inv, num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts


def make_mining_step(
    mesh: Mesh,
    k: int = 4096,
    db_axes: Tuple[str, ...] = ("data",),
    tok_axis: str = "model",
    prededup: bool = True,
):
    """Build the jitted, shard_mapped extension-scan step.

    Returns ``step(tokens, gid, phi, psi, valid, existing, nv, n_pat,
    mode) -> (uniq [k], counts [k], n_distinct)`` with a replicated output
    table.  ``gid`` must hold *local* indices into the caller's DB shard.

    ``prededup=True`` dedups (sig, gid) pairs per token shard *before* the
    "model"-axis gather: collective bytes drop from E*T*4 to k*8 per shard
    (the §Perf/mining hillclimb; False keeps the measured baseline).
    """
    n_db_shards = int(np.prod([mesh.shape[a] for a in db_axes]))

    def local_step(tokens, gid, phi, psi, valid, existing, nv, n_pat, mode):
        sigs = match_signatures_ref(
            tokens, gid, phi, psi, valid, existing, nv, n_pat, mode
        )
        # global gid offset for this data shard
        shard = jax.lax.axis_index(db_axes[0])
        for a in db_axes[1:]:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        g_loc = tokens.shape[0]
        gid_global = gid + shard * g_loc

        if prededup:
            # 1) dedup local pairs, gather only the k-sized pair tables
            E, T = sigs.shape
            flat_sig = sigs.reshape(-1)
            flat_gid = jnp.broadcast_to(
                gid_global[:, None], (E, T)).reshape(-1)
            ps, pg, _ = _dedup_pairs(flat_sig, flat_gid, k)
            all_s = jax.lax.all_gather(ps, tok_axis).reshape(-1)
            all_g = jax.lax.all_gather(pg, tok_axis).reshape(-1)
            sigs2, gids2 = all_s, all_g  # may contain cross-shard dups
            uniq, counts, n_distinct = _flat_candidate_table(
                sigs2, gids2, k)
        else:
            # 1) reassemble each data shard's full signature matrix
            sigs = jax.lax.all_gather(sigs, tok_axis, axis=1, tiled=True)
            uniq, counts, n_distinct = _local_candidate_table(
                sigs, gid_global, k)
        # 2) merge candidate tables across DB shards
        uniq_all = jax.lax.all_gather(uniq, db_axes, tiled=False)
        cnt_all = jax.lax.all_gather(counts, db_axes, tiled=False)
        uniq, counts = _merge_tables(uniq_all, cnt_all, k)
        n_distinct = jax.lax.pmax(n_distinct, db_axes)
        return uniq, counts, n_distinct

    db_dim = tuple(db_axes) if len(db_axes) > 1 else db_axes[0]
    specs_in = (
        P(db_dim, tok_axis, None),  # tokens
        P(db_dim),                  # gid (local indices)
        P(db_dim, None),            # phi
        P(db_dim, None),            # psi
        P(db_dim),                  # valid
        P(),                        # existing
        P(), P(), P(),              # nv, n_pat, mode
    )
    step = shard_map_compat(
        local_step, mesh, specs_in, (P(), P(), P())
    )
    return jax.jit(step)
