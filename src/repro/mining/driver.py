"""Accelerated miners: host frontier + device extension scans.

The reverse-search frontier (tiny, independent subtrees) stays on the
host; every DB scan - the >95% hot loop - is a batched device call to
``match_signatures``.  Outputs are bit-identical to the pure-host
reference miners in ``repro.core`` (property-tested).

The expansion loop is an explicit work stack, which makes the miner
checkpointable (see checkpoint.py): any prefix of the traversal plus the
remaining stack fully determines the final result, so a lost worker or a
restart just re-enqueues its subtree - supports are per-subtree and
idempotent.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.canonical import canonical_form, canonical_map
from ..core.enumerate_host import Emb, apply_extension
from ..core.gtrace import MiningResult
from ..core.graphseq import Pattern, TRSeq, pattern_length, pattern_vertices
from ..core.reverse_search import parent
from .encoding import (
    PAD_PHI,
    PAD_PSI,
    TokenDB,
    encode_db,
    encode_embeddings,
    encode_pattern_trs,
    signature_to_extkey,
)
from .engine import (
    MODE_EDGE_PHASE,
    MODE_ROOT,
    MODE_TAIL,
    MODE_VERTEX_PHASE,
    aggregate_host,
    match_signatures,
)

MAX_PATTERN_TRS = 64


class AcceleratedMiner:
    def __init__(
        self,
        db: Sequence[TRSeq],
        max_itemsets: int = 16,
        max_vertices: int = 12,
        e_batch: int = 1024,
    ):
        self.db = db
        self.ni = max_itemsets
        self.nv = max_vertices
        self.e_batch = e_batch
        self.tdb: TokenDB = encode_db(db)
        self.tokens = jnp.asarray(self.tdb.tokens)
        self.device_seconds = 0.0
        self.n_device_calls = 0

    # ------------------------------------------------------------- scans
    def _scan(self, pattern: Pattern, embs: List[Emb], mode: int):
        """Run the device scan over all embeddings; return
        {sig: (gid_set, (e,t) rows into the global embedding list)}."""
        nv = len(pattern_vertices(pattern))
        n_pat = len(pattern)
        existing = encode_pattern_trs(pattern, MAX_PATTERN_TRS)
        merged: Dict[int, Tuple[Set[int], List[np.ndarray]]] = {}
        for start in range(0, len(embs), self.e_batch):
            chunk = embs[start : start + self.e_batch]
            E = len(chunk)
            # pad to a power-of-two bucket to bound recompilation
            Epad = min(self.e_batch, 1 << max(0, math.ceil(math.log2(E))))
            Epad = max(Epad, E)
            gid, phi, psi = encode_embeddings(chunk, self.ni, self.nv)
            if Epad > E:
                gid = np.pad(gid, (0, Epad - E))
                phi = np.pad(phi, ((0, Epad - E), (0, 0)),
                             constant_values=PAD_PHI)
                psi = np.pad(psi, ((0, Epad - E), (0, 0)),
                             constant_values=PAD_PSI)
            valid = np.zeros((Epad,), np.int32)
            valid[:E] = 1
            t0 = time.perf_counter()
            sigs = match_signatures(
                self.tokens,
                jnp.asarray(gid), jnp.asarray(phi), jnp.asarray(psi),
                jnp.asarray(valid), jnp.asarray(existing),
                jnp.int32(nv), jnp.int32(n_pat), jnp.int32(mode),
            )
            sigs = np.asarray(sigs)
            self.device_seconds += time.perf_counter() - t0
            self.n_device_calls += 1
            for sig, (gset, et) in aggregate_host(sigs, gid).items():
                et = et.copy()
                et[:, 0] += start
                if sig in merged:
                    merged[sig][0].update(gset)
                    merged[sig][1].append(et)
                else:
                    merged[sig] = (gset, [et])
        return merged

    # -------------------------------------------------- embedding rebuild
    def _rebuild_embeddings(
        self,
        pattern: Pattern,
        embs: List[Emb],
        sig: int,
        et_rows: List[np.ndarray],
        child_raw: Pattern,
    ) -> List[Emb]:
        (slot_kind, slot_idx), ptr = signature_to_extkey(sig)
        nv = len(pattern_vertices(pattern))
        vmap = canonical_map(child_raw)
        out: List[Emb] = []
        seen = set()
        for rows in et_rows:
            for e_i, t_i in rows:
                gid, phi, psi = embs[e_i]
                tok = self.tdb.tokens[gid, t_i]
                ty, u1, u2, lab, j, _ = (int(x) for x in tok)
                if slot_kind == "in":
                    new_phi = phi
                else:
                    new_phi = phi[:slot_idx] + (j,) + phi[slot_idx:]
                psi_d = dict(psi)
                variants: List[Dict[int, int]]
                if ptr.is_vertex:
                    if ptr.u1 == nv:  # fresh vertex
                        variants = [{**psi_d, nv: u1}]
                    else:
                        variants = [psi_d]
                else:
                    if ptr.u2 == nv + 1:  # both endpoints fresh
                        variants = [
                            {**psi_d, nv: u1, nv + 1: u2},
                            {**psi_d, nv: u2, nv + 1: u1},
                        ]
                    elif ptr.u2 == nv:  # one fresh endpoint
                        mapped_dv = psi_d[ptr.u1]
                        fresh_dv = u2 if mapped_dv == u1 else u1
                        variants = [{**psi_d, nv: fresh_dv}]
                    else:
                        variants = [psi_d]
                for v in variants:
                    emb: Emb = (
                        gid,
                        new_phi,
                        tuple(sorted((vmap[pv], dv) for pv, dv in v.items())),
                    )
                    if emb not in seen:
                        seen.add(emb)
                        out.append(emb)
        return out

    # -------------------------------------------------- child expansion
    def expand_children(
        self,
        pattern: Pattern,
        embs: List[Emb],
        min_support: int,
        *,
        rs: bool = True,
        want_embs: Optional[Callable[[Pattern], bool]] = None,
    ) -> List[Tuple[Pattern, Set[int], List[Emb]]]:
        """One reverse-search (or baseline tail-growth) expansion: scan
        the DB for one-TR extensions of ``pattern`` and return its
        frequent children as ``(child, gids, child_embs)``.  ``gids`` is
        the exact set of DB sequences containing the child (supports are
        ``len(gids)``; the streaming layer turns these into window
        containment bitmaps without a separate join).

        With ``rs=True`` children are filtered by the spanning-tree
        membership test (``parent(child) == pattern``) exactly as the
        full miner does, so iterating this from the root reproduces
        ``mine_rs`` - and iterating it from a *frontier* of known
        patterns is the incremental re-mine (mining.incremental).
        ``want_embs(child)`` lets callers skip the embedding rebuild for
        children whose subtree they will not descend into (the
        clean-subtree prune); such children come back with ``[]``.
        Respects the miner's itemset/vertex capacity guards."""
        if len(pattern) >= self.ni:
            return []  # capacity guard (configurable)
        if rs:
            if not pattern:
                mode = MODE_ROOT
            elif any(tr.is_vertex for s in pattern for tr in s):
                mode = MODE_VERTEX_PHASE
            else:
                mode = MODE_EDGE_PHASE
        else:
            mode = MODE_TAIL
        merged = self._scan(pattern, embs, mode)
        by_child: Dict[Pattern, Tuple[Set[int], int, List[np.ndarray]]] = {}
        for sig, (gset, et_rows) in merged.items():
            key = signature_to_extkey(sig)
            if max(key[1].u1, key[1].u2) >= self.nv:
                continue  # vertex-capacity guard
            child_raw = apply_extension(pattern, key)
            child = canonical_form(child_raw)
            if child in by_child:
                by_child[child][0].update(gset)
            else:
                by_child[child] = (set(gset), sig, et_rows)
        out: List[Tuple[Pattern, Set[int], List[Emb]]] = []
        for child, (gids, sig, et_rows) in by_child.items():
            if len(gids) < min_support:
                continue
            if rs and parent(child) != pattern:
                continue  # reverse-search membership test
            if want_embs is not None and not want_embs(child):
                out.append((child, gids, []))
                continue
            key = signature_to_extkey(sig)
            child_raw = apply_extension(pattern, key)
            child_embs = self._rebuild_embeddings(
                pattern, embs, sig, et_rows, child_raw
            )
            out.append((child, gids, child_embs))
        return out

    # ------------------------------------------------------------ mining
    def _mine(
        self,
        min_support: int,
        max_len: Optional[int],
        rs: bool,
        checkpoint_path: Optional[str] = None,
        checkpoint_every: int = 50,
        resume: bool = False,
    ) -> MiningResult:
        from .checkpoint import load_state, save_state

        res = MiningResult()
        root: Tuple[Pattern, List[Emb]] = (
            (), [(g, (), ()) for g in range(len(self.db))]
        )
        stack = [root]
        if resume and checkpoint_path:
            patterns, stack, meta = load_state(checkpoint_path)
            res.patterns.update(patterns)
            res.n_enumerated = meta.get("n_enumerated", len(patterns))
        expansions_since_ckpt = 0
        while stack:
            pattern, embs = stack.pop()
            if max_len is not None and pattern_length(pattern) >= max_len:
                continue
            if len(pattern) >= self.ni:
                continue  # capacity guard (configurable)
            res.n_extension_scans += 1
            # canonical dedup is baseline-only (rs children are unique
            # by the membership test); skip their embedding rebuilds too
            want = (
                None if rs
                else (lambda child: child not in res.patterns)
            )
            for child, gids, child_embs in self.expand_children(
                pattern, embs, min_support, rs=rs, want_embs=want
            ):
                if not rs and child in res.patterns:
                    continue
                res.patterns[child] = len(gids)
                res.n_enumerated += 1
                stack.append((child, child_embs))
            expansions_since_ckpt += 1
            if (
                checkpoint_path
                and expansions_since_ckpt >= checkpoint_every
            ):
                save_state(
                    checkpoint_path, res.patterns, stack,
                    meta={"min_support": min_support, "rs": rs,
                          "n_enumerated": res.n_enumerated},
                )
                expansions_since_ckpt = 0
        if checkpoint_path:
            save_state(
                checkpoint_path, res.patterns, [],
                meta={"min_support": min_support, "rs": rs,
                      "n_enumerated": res.n_enumerated, "done": True},
            )
        return res

    def mine_rs(self, min_support: int, max_len: int | None = None,
                **kw) -> MiningResult:
        """GTRACE-RS with device-side extension scans."""
        return self._mine(min_support, max_len, rs=True, **kw)

    def mine_gtrace(self, min_support: int, max_len: int | None = None,
                    **kw) -> MiningResult:
        """Original-GTRACE baseline with device-side extension scans."""
        return self._mine(min_support, max_len, rs=False, **kw)
