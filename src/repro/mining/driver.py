"""Accelerated miners: host frontier + wavefront-batched device scans.

The reverse-search frontier (tiny, independent subtrees) stays on the
host; every DB scan - the >95% hot loop - is a batched device call to
the embedding-join engine.  Outputs are bit-identical to the pure-host
reference miners in ``repro.core`` (property-tested).

The wavefront scheduler
-----------------------
Reverse search makes enumeration subtrees independent, so nothing
orders the pending expansions: any set of frontier patterns can be
scanned together.  The default ``dispatch="wavefront"`` exploits that:
the work pool is drained in *slices* of many patterns at once, their
embeddings are packed into shared pow-2-bucketed device batches with a
per-row ``pattern_id`` axis (stacked ``existing`` tables and per-row
``nv``/``n_pat``/``mode`` vectors, gathered inside the jit - see
``engine.match_signatures_batch``), and ONE dispatch covers the whole
chunk instead of one per pattern.  Signatures come back namespaced by
``pattern_id`` (``engine.aggregate_host_batch``), so the host finalize
splits per pattern exactly as before; child embeddings are rebuilt with
numpy scatter/stack ops over the whole (e,t) row set rather than a
Python loop per row.  ``dispatch="pattern"`` keeps the seed's
one-pattern-at-a-time traversal (same code path, slices of size one) as
the benchmark baseline; both dispatch modes return bit-equal
``MiningResult``s.

A wavefront is just a reordered work stack, so the miner stays
checkpointable (see checkpoint.py): the pending slice items plus the
accumulated next wave serialize exactly like the seed stack, and a
resume re-enqueues them - supports are per-subtree and idempotent.

Device timing: jax dispatch is async, so the launch and the execution
are timed separately - ``dispatch_seconds`` stops when the call
returns (launch cost only), ``device_seconds`` after
``block_until_ready()`` (the real device time).
"""
from __future__ import annotations

import math
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.canonical import canonical_form, canonical_map
from ..obs import trace
from ..obs.metrics import MetricsRegistry
from ..core.enumerate_host import Emb, apply_extension
from ..core.gtrace import MiningResult
from ..core.graphseq import Pattern, TRSeq, pattern_length, pattern_vertices
from ..core.reverse_search import parent
from .encoding import (
    PAD_PHI,
    PAD_PSI,
    TokenDB,
    encode_db,
    encode_embeddings,
    encode_pattern_trs,
    signature_to_extkey,
)
from .engine import (
    MODE_EDGE_PHASE,
    MODE_ROOT,
    MODE_TAIL,
    MODE_VERTEX_PHASE,
    aggregate_host_batch,
    match_signatures_batch,
)

MAX_PATTERN_TRS = 64

# encoded row arrays of one pattern's embedding list: (gid, phi, psi)
Enc = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _pow2_pad(n: int, cap: Optional[int] = None) -> int:
    """Smallest power of two >= n (clamped to cap when given, but never
    below n) - bounds the set of jit shapes."""
    p = 1 << max(0, math.ceil(math.log2(max(n, 1))))
    if cap is not None:
        p = min(p, cap)
    return max(p, n)


class AcceleratedMiner:
    def __init__(
        self,
        db: Sequence[TRSeq],
        max_itemsets: int = 16,
        max_vertices: int = 12,
        e_batch: int = 1024,
        dispatch: str = "wavefront",
        wave_patterns: int = 256,
        wave_rows: Optional[int] = None,
        metrics: Optional[MetricsRegistry] = None,
        metrics_ns: str = "mining",
    ):
        assert dispatch in ("wavefront", "pattern"), dispatch
        self.db = db
        self.ni = max_itemsets
        self.nv = max_vertices
        self.e_batch = e_batch
        self.dispatch = dispatch
        # wavefront slice bounds: at most this many patterns / embedding
        # rows per batched expansion (the checkpoint granularity; pow-2
        # padding of the pattern axis keeps jit shapes bounded)
        self.wave_patterns = wave_patterns
        self.wave_rows = 4 * e_batch if wave_rows is None else wave_rows
        self.tdb: TokenDB = encode_db(db)
        self.tokens = jnp.asarray(self.tdb.tokens)
        # counters live in a registry (private by default; pass
        # ``metrics=`` to accumulate across miner rebuilds, e.g. the
        # streaming bank's incremental refreshes)
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._c_device_s = self.metrics.counter(
            f"{metrics_ns}.device_seconds")
        self._c_dispatch_s = self.metrics.counter(
            f"{metrics_ns}.dispatch_seconds")
        self._c_calls = self.metrics.counter(
            f"{metrics_ns}.n_device_calls")
        self._h_wave = self.metrics.histogram(
            f"{metrics_ns}.wave_patterns")
        # always-on latency percentiles: wall (launch + blocked) per
        # packed device chunk, log-scale buckets
        self._h_wave_s = self.metrics.bucket_histogram(
            f"{metrics_ns}.wave_seconds")

    # registry-backed views of the historical timing attributes
    @property
    def device_seconds(self) -> float:
        """Launch + execution (blocked)."""
        return self._c_device_s.value

    @property
    def dispatch_seconds(self) -> float:
        """Async launch only."""
        return self._c_dispatch_s.value

    @property
    def n_device_calls(self) -> int:
        return self._c_calls.value

    # ------------------------------------------------------------- phases
    @staticmethod
    def _phase_mode(pattern: Pattern, rs: bool) -> int:
        if not rs:
            return MODE_TAIL
        if not pattern:
            return MODE_ROOT
        if any(tr.is_vertex for s in pattern for tr in s):
            return MODE_VERTEX_PHASE
        return MODE_EDGE_PHASE

    # ------------------------------------------------------------- scans
    def _scan_batch(
        self, items: List[Tuple[Pattern, List[Emb]]], modes: List[int]
    ) -> Tuple[List[Dict[int, Tuple[Set[int], List[np.ndarray]]]],
               List[Enc]]:
        """Run the device scans for a wavefront slice: all items' rows
        are packed into shared pow-2 chunks (a chunk freely spans
        pattern boundaries) and each chunk is ONE device dispatch.
        Returns, per item, ``{sig: (gid_set, (e,t) rows)}`` with ``e``
        local to the item's embedding list, plus the item's encoded row
        arrays for the vectorized embedding rebuild."""
        n = len(items)
        n_pad = _pow2_pad(n)
        nv_stack = np.zeros(n_pad, np.int32)
        npat_stack = np.zeros(n_pad, np.int32)
        mode_stack = np.zeros(n_pad, np.int32)
        ex_stack = np.full((n_pad, MAX_PATTERN_TRS, 5), -9, np.int32)
        for i, (pattern, _) in enumerate(items):
            nv_stack[i] = len(pattern_vertices(pattern))
            npat_stack[i] = len(pattern)
            mode_stack[i] = modes[i]
            ex_stack[i] = encode_pattern_trs(pattern, MAX_PATTERN_TRS)
        ex_j = jnp.asarray(ex_stack)
        nv_j = jnp.asarray(nv_stack)
        npat_j = jnp.asarray(npat_stack)
        mode_j = jnp.asarray(mode_stack)

        enc: List[Enc] = [
            encode_embeddings(embs, self.ni, self.nv)
            for _, embs in items
        ]
        lens = np.asarray([len(embs) for _, embs in items], np.int64)
        offs = np.cumsum(lens) - lens
        R = int(lens.sum())
        merged: List[Dict[int, Tuple[Set[int], List[np.ndarray]]]] = [
            {} for _ in items
        ]
        if R == 0:
            return merged, enc
        gid_all = np.concatenate([e[0] for e in enc])
        phi_all = np.concatenate([e[1] for e in enc])
        psi_all = np.concatenate([e[2] for e in enc])
        pid_all = np.repeat(np.arange(n, dtype=np.int32), lens)

        for start in range(0, R, self.e_batch):
            E = min(self.e_batch, R - start)
            Epad = _pow2_pad(E, cap=self.e_batch)
            sl = slice(start, start + E)
            gid = gid_all[sl]
            phi = phi_all[sl]
            psi = psi_all[sl]
            pid = pid_all[sl]
            if Epad > E:
                gid = np.pad(gid, (0, Epad - E))
                phi = np.pad(phi, ((0, Epad - E), (0, 0)),
                             constant_values=PAD_PHI)
                psi = np.pad(psi, ((0, Epad - E), (0, 0)),
                             constant_values=PAD_PSI)
                pid = np.pad(pid, (0, Epad - E))
            valid = np.zeros((Epad,), np.int32)
            valid[:E] = 1
            t0 = time.perf_counter()
            sigs = match_signatures_batch(
                self.tokens,
                jnp.asarray(gid), jnp.asarray(phi), jnp.asarray(psi),
                jnp.asarray(valid), jnp.asarray(pid),
                ex_j, nv_j, npat_j, mode_j,
            )
            t1 = time.perf_counter()
            self._c_dispatch_s.inc(t1 - t0)
            sigs.block_until_ready()  # async dispatch: launch != done
            t2 = time.perf_counter()
            self._c_device_s.inc(t2 - t0)
            self._c_calls.inc()
            self._h_wave_s.observe(t2 - t0)
            # intervals are measured above regardless of tracing, so
            # recording them cannot perturb the timing they describe
            trace.add_complete("mining.dispatch", "dispatch",
                               t0, t1 - t0, rows=int(Epad))
            trace.add_complete("mining.device", "device", t1, t2 - t1)
            for (pi, sig), (gset, et) in aggregate_host_batch(
                np.asarray(sigs), gid, pid
            ).items():
                et = et.copy()
                # chunk-local row -> this item's embedding index
                et[:, 0] += start - offs[pi]
                got = merged[pi].get(sig)
                if got is None:
                    merged[pi][sig] = (gset, [et])
                else:
                    got[0].update(gset)
                    got[1].append(et)
        return merged, enc

    # -------------------------------------------------- embedding rebuild
    def _rebuild_embeddings(
        self,
        pattern: Pattern,
        enc: Enc,
        sig: int,
        et_rows: List[np.ndarray],
        child_raw: Pattern,
    ) -> List[Emb]:
        """Vectorized child-embedding rebuild: phi insertion, the psi
        variant construction, canonical remap, and first-seen dedup are
        numpy column ops over the whole (e,t) row set (the extension key
        - and therefore the variant case - is constant per signature, so
        the only per-row Python left is materializing the final Emb
        tuples from the deduped rows)."""
        (slot_kind, slot_idx), ptr = signature_to_extkey(sig)
        nv = len(pattern_vertices(pattern))
        n_pat = len(pattern)
        vmap = canonical_map(child_raw)
        gid_all, phi_all, psi_all = enc
        et = np.concatenate(et_rows, axis=0)
        e_i, t_i = et[:, 0], et[:, 1]
        gids_r = gid_all[e_i].astype(np.int64)
        tok = self.tdb.tokens[gids_r, t_i]
        u1, u2, j = tok[:, 1], tok[:, 2], tok[:, 4]
        phi_r = phi_all[e_i]
        if slot_kind == "in":
            new_phi = phi_r[:, :n_pat]
        else:
            new_phi = np.concatenate(
                [phi_r[:, :slot_idx], j[:, None],
                 phi_r[:, slot_idx:n_pat]], axis=1)
        psi_r = psi_all[e_i][:, :nv]
        if ptr.is_vertex:
            if ptr.u1 == nv:  # fresh vertex
                psis = [np.concatenate([psi_r, u1[:, None]], axis=1)]
            else:
                psis = [psi_r]
        elif ptr.u2 == nv + 1:  # both endpoints fresh: two bindings
            psis = [
                np.concatenate([psi_r, u1[:, None], u2[:, None]], axis=1),
                np.concatenate([psi_r, u2[:, None], u1[:, None]], axis=1),
            ]
        elif ptr.u2 == nv:  # one fresh endpoint
            mapped_dv = psi_r[:, ptr.u1]
            fresh_dv = np.where(mapped_dv == u1, u2, u1)
            psis = [np.concatenate([psi_r, fresh_dv[:, None]], axis=1)]
        else:
            psis = [psi_r]
        nv_child = psis[0].shape[1]
        perm = np.asarray([vmap[pv] for pv in range(nv_child)])
        n_phi = new_phi.shape[1]
        variants = []
        for ps in psis:
            canon = np.empty_like(ps)
            canon[:, perm] = ps  # scatter into canonical vertex order
            variants.append(np.concatenate(
                [gids_r[:, None], new_phi, canon], axis=1))
        if len(variants) == 2:  # interleave bindings per row
            rows = np.stack(variants, axis=1).reshape(
                2 * len(et), 1 + n_phi + nv_child)
        else:
            rows = variants[0]
        _, first = np.unique(rows, axis=0, return_index=True)
        rows = rows[np.sort(first)]  # dedup, first-seen order
        return [
            (
                int(r[0]),
                tuple(int(x) for x in r[1:1 + n_phi]),
                tuple(enumerate(int(x) for x in r[1 + n_phi:])),
            )
            for r in rows
        ]

    # -------------------------------------------------- child expansion
    def _children_from_merged(
        self,
        pattern: Pattern,
        enc: Enc,
        merged: Dict[int, Tuple[Set[int], List[np.ndarray]]],
        min_support: int,
        rs: bool,
        want_embs: Optional[Callable[[Pattern], bool]],
    ) -> List[Tuple[Pattern, Set[int], List[Emb]]]:
        by_child: Dict[Pattern, Tuple[Set[int], int, List[np.ndarray]]] = {}
        for sig, (gset, et_rows) in merged.items():
            key = signature_to_extkey(sig)
            if max(key[1].u1, key[1].u2) >= self.nv:
                continue  # vertex-capacity guard
            child_raw = apply_extension(pattern, key)
            child = canonical_form(child_raw)
            if child in by_child:
                by_child[child][0].update(gset)
            else:
                by_child[child] = (set(gset), sig, et_rows)
        out: List[Tuple[Pattern, Set[int], List[Emb]]] = []
        for child, (gids, sig, et_rows) in by_child.items():
            if len(gids) < min_support:
                continue
            if rs and parent(child) != pattern:
                continue  # reverse-search membership test
            if want_embs is not None and not want_embs(child):
                out.append((child, gids, []))
                continue
            key = signature_to_extkey(sig)
            child_raw = apply_extension(pattern, key)
            child_embs = self._rebuild_embeddings(
                pattern, enc, sig, et_rows, child_raw
            )
            out.append((child, gids, child_embs))
        return out

    def expand_children_batch(
        self,
        items: Sequence[Tuple[Pattern, List[Emb]]],
        min_support: int,
        *,
        rs: bool = True,
        want_embs: Optional[Callable[[Pattern], bool]] = None,
    ) -> List[List[Tuple[Pattern, Set[int], List[Emb]]]]:
        """One batched expansion of a whole wavefront slice: every
        item's DB scan shares the packed device chunks (see
        ``_scan_batch``); the result is per-item, aligned with
        ``items``, each entry exactly what ``expand_children`` would
        have returned for that item alone.  Items at the itemset
        capacity come back empty (same guard as the single-item path)."""
        out: List[List[Tuple[Pattern, Set[int], List[Emb]]]] = [
            [] for _ in items
        ]
        live = [
            (i, p, e) for i, (p, e) in enumerate(items)
            if len(p) < self.ni
        ]
        if not live:
            return out
        modes = [self._phase_mode(p, rs) for _, p, _ in live]
        merged, enc = self._scan_batch([(p, e) for _, p, e in live], modes)
        for (i, p, _), m, enc_i in zip(live, merged, enc):
            out[i] = self._children_from_merged(
                p, enc_i, m, min_support, rs, want_embs
            )
        return out

    def expand_children(
        self,
        pattern: Pattern,
        embs: List[Emb],
        min_support: int,
        *,
        rs: bool = True,
        want_embs: Optional[Callable[[Pattern], bool]] = None,
    ) -> List[Tuple[Pattern, Set[int], List[Emb]]]:
        """One reverse-search (or baseline tail-growth) expansion: scan
        the DB for one-TR extensions of ``pattern`` and return its
        frequent children as ``(child, gids, child_embs)``.  ``gids`` is
        the exact set of DB sequences containing the child (supports are
        ``len(gids)``; the streaming layer turns these into window
        containment bitmaps without a separate join).

        With ``rs=True`` children are filtered by the spanning-tree
        membership test (``parent(child) == pattern``) exactly as the
        full miner does, so iterating this from the root reproduces
        ``mine_rs`` - and iterating it from a *frontier* of known
        patterns is the incremental re-mine (mining.incremental; batch
        the frontier through ``expand_children_batch`` to share device
        chunks across patterns).  ``want_embs(child)`` lets callers skip
        the embedding rebuild for children whose subtree they will not
        descend into (the clean-subtree prune); such children come back
        with ``[]``.  Respects the miner's itemset/vertex capacity
        guards."""
        return self.expand_children_batch(
            [(pattern, embs)], min_support, rs=rs, want_embs=want_embs
        )[0]

    # ------------------------------------------------------------ mining
    def _take_slice(
        self,
        pending: "deque[Tuple[Pattern, List[Emb]]]",
        max_len: Optional[int],
        wavefront: bool,
    ) -> List[Tuple[Pattern, List[Emb]]]:
        """Pop the next expansion slice off the work pool, applying the
        length/capacity guards exactly as the seed stack loop did.
        Wavefront mode drains FIFO up to the slice bounds (many
        patterns, one batched call); pattern mode pops LIFO one at a
        time (the seed's per-pattern dispatch, kept as the benchmark
        baseline)."""
        items: List[Tuple[Pattern, List[Emb]]] = []
        rows = 0
        while pending:
            pattern, embs = (
                pending.popleft() if wavefront else pending.pop()
            )
            if max_len is not None and pattern_length(pattern) >= max_len:
                continue
            if len(pattern) >= self.ni:
                continue  # capacity guard (configurable)
            items.append((pattern, embs))
            rows += len(embs)
            if (
                not wavefront
                or len(items) >= self.wave_patterns
                or rows >= self.wave_rows
            ):
                break
        return items

    def _mine(
        self,
        min_support: int,
        max_len: Optional[int],
        rs: bool,
        checkpoint_path: Optional[str] = None,
        checkpoint_every: int = 50,
        resume: bool = False,
    ) -> MiningResult:
        from .checkpoint import load_state, save_state

        res = MiningResult()
        root: Tuple[Pattern, List[Emb]] = (
            (), [(g, (), ()) for g in range(len(self.db))]
        )
        pending: "deque[Tuple[Pattern, List[Emb]]]" = deque([root])
        if resume and checkpoint_path:
            patterns, stack, meta = load_state(checkpoint_path)
            res.patterns.update(patterns)
            res.n_enumerated = meta.get("n_enumerated", len(patterns))
            pending = deque(stack)
        # canonical dedup is baseline-only (rs children are unique by
        # the membership test); skip their embedding rebuilds too
        want = (
            None if rs else (lambda child: child not in res.patterns)
        )
        wavefront = self.dispatch == "wavefront"
        expansions_since_ckpt = 0
        with trace.root_or_span("mining.mine", rs=rs,
                                min_support=min_support):
            while pending:
                items = self._take_slice(pending, max_len, wavefront)
                if not items:
                    break  # guards drained the pool
                res.n_extension_scans += len(items)
                self._h_wave.observe(len(items))
                with trace.span("mining.wavefront",
                                patterns=len(items)):
                    for kids in self.expand_children_batch(
                        items, min_support, rs=rs, want_embs=want
                    ):
                        for child, gids, child_embs in kids:
                            if not rs and child in res.patterns:
                                continue
                            res.patterns[child] = len(gids)
                            res.n_enumerated += 1
                            pending.append((child, child_embs))
                expansions_since_ckpt += len(items)
                if (
                    checkpoint_path
                    and expansions_since_ckpt >= checkpoint_every
                ):
                    with trace.span("mining.checkpoint"):
                        save_state(
                            checkpoint_path, res.patterns,
                            list(pending),
                            meta={"min_support": min_support, "rs": rs,
                                  "n_enumerated": res.n_enumerated},
                        )
                    expansions_since_ckpt = 0
        if checkpoint_path:
            save_state(
                checkpoint_path, res.patterns, [],
                meta={"min_support": min_support, "rs": rs,
                      "n_enumerated": res.n_enumerated, "done": True},
            )
        return res

    def mine_rs(self, min_support: int, max_len: int | None = None,
                **kw) -> MiningResult:
        """GTRACE-RS with device-side extension scans."""
        return self._mine(min_support, max_len, rs=True, **kw)

    def mine_gtrace(self, min_support: int, max_len: int | None = None,
                    **kw) -> MiningResult:
        """Original-GTRACE baseline with device-side extension scans."""
        return self._mine(min_support, max_len, rs=False, **kw)
