"""Dense tensor encoding of transformation-sequence databases.

The device engine operates on fixed-shape int32 tensors:

* ``tokens``   [G, T, 6]  - one row per TR: (type, u1, u2, label, j, valid)
  where ``j`` is the itemset (intrastate) index within its sequence.
* embeddings of the current pattern: ``gid`` [E], ``phi`` [E, NI]
  (data itemset index per pattern itemset, ``PAD_PHI`` beyond n),
  ``psi`` [E, NV] (data vertex per pattern vertex, ``PAD_PSI`` beyond m).

Extension *signatures* pack a candidate one-TR extension in pattern
coordinates into one int64 so that discovery + support counting reduce to
elementwise compares and sort/segment reductions (see engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core.enumerate_host import Emb, ExtKey, Slot
from ..core.graphseq import NO_LABEL, NO_VERTEX, Pattern, TR, TRSeq, TRType

PAD_PHI = np.int32(0x3FFFFFF)
PAD_PSI = np.int32(-2)
SENT_V = 15  # pu2 sentinel for vertex TRs inside signatures
INVALID_SIG = np.int32(-1)

# signature bit layout: 31 bits of an int32 (JAX default itype).
# slot_kind(1) | slot_idx(5) | type(3) | pu1(4) | pu2(4) | label+1(14)
# => caps: <=31 pattern itemsets, <=14 pattern vertices, <=16382 labels.
_LAB_BITS = 14
_PU_BITS = 4
_TY_BITS = 3
_SL_BITS = 5


@dataclasses.dataclass
class TokenDB:
    tokens: np.ndarray  # [G, T, 6] int32
    n_itemsets: np.ndarray  # [G] int32
    n_labels: int

    @property
    def n_seq(self) -> int:
        return self.tokens.shape[0]

    @property
    def max_tokens(self) -> int:
        return self.tokens.shape[1]


def encode_db(db: Sequence[TRSeq], pad_to: int | None = None,
              pad_seqs_to: int | None = None) -> TokenDB:
    # one flat row list + a single scatter: serving encodes a fresh
    # batch per cache-miss chunk, so this path is throughput-critical
    flat: List[Tuple[int, ...]] = []
    lens: List[int] = []
    for s in db:
        n0 = len(flat)
        for j, itemset in enumerate(s):
            flat += [tr + (j, 1) for tr in itemset]
        lens.append(len(flat) - n0)
    T = max(lens, default=1)
    if pad_to is not None:
        assert pad_to >= T, (pad_to, T)
        T = pad_to
    G0 = len(db)
    G = G0
    if pad_seqs_to is not None:
        assert pad_seqs_to >= G
        G = pad_seqs_to
    tokens = np.zeros((G, max(T, 1), 6), dtype=np.int32)
    tokens[..., 1] = NO_VERTEX
    tokens[..., 2] = NO_VERTEX
    tokens[..., 3] = NO_LABEL
    if flat:
        arr = np.asarray(flat, dtype=np.int32)
        lens_a = np.asarray(lens)
        off = np.cumsum(lens_a) - lens_a
        idx_g = np.repeat(np.arange(G0), lens_a)
        idx_t = np.arange(len(flat)) - np.repeat(off, lens_a)
        tokens[idx_g, idx_t] = arr
        max_label = int(arr[:, 3].max(initial=0))
    else:
        max_label = 0
    n_itemsets = np.array(
        [len(s) for s in db] + [0] * (G - G0), dtype=np.int32
    )
    assert max_label + 1 < (1 << _LAB_BITS) - 1, "label space too large"
    return TokenDB(tokens=tokens, n_itemsets=n_itemsets,
                   n_labels=max_label + 1)


def encode_embeddings(
    embs: Sequence[Emb], ni: int, nv: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    E = len(embs)
    gid = np.zeros((E,), dtype=np.int32)
    phi = np.full((E, ni), PAD_PHI, dtype=np.int32)
    psi = np.full((E, nv), PAD_PSI, dtype=np.int32)
    for i, (g, ph, ps) in enumerate(embs):
        gid[i] = g
        assert len(ph) <= ni and len(ps) <= nv, (len(ph), len(ps))
        phi[i, : len(ph)] = ph
        for pv, dv in ps:
            psi[i, pv] = dv
    return gid, phi, psi


def encode_pattern_trs(p: Pattern, max_rows: int) -> np.ndarray:
    """[(itemset, type, pu1, pu2, label)] rows, padded with -9."""
    rows = []
    for i, itemset in enumerate(p):
        for tr in itemset:
            pu2 = SENT_V if tr.is_vertex else tr.u2
            rows.append((i, int(tr.type), tr.u1, pu2, tr.label))
    assert len(rows) <= max_rows, (len(rows), max_rows)
    out = np.full((max_rows, 5), -9, dtype=np.int32)
    for i, r in enumerate(rows):
        out[i] = r
    return out


def pack_signature(slot_kind: int, slot_idx: int, ty: int, pu1: int,
                   pu2: int, label: int) -> int:
    """Pure-python mirror of the device packing (for tests/decoding)."""
    assert slot_idx < (1 << _SL_BITS) and pu1 < (1 << _PU_BITS)
    assert pu2 < (1 << _PU_BITS) and label + 1 < (1 << _LAB_BITS)
    lab = label + 1  # NO_LABEL -> 0
    v = slot_kind
    v = (v << _SL_BITS) | slot_idx
    v = (v << _TY_BITS) | ty
    v = (v << _PU_BITS) | pu1
    v = (v << _PU_BITS) | pu2
    v = (v << _LAB_BITS) | lab
    return int(v)


def unpack_signature(sig: int) -> Tuple[int, int, int, int, int, int]:
    lab = sig & ((1 << _LAB_BITS) - 1)
    sig >>= _LAB_BITS
    pu2 = sig & ((1 << _PU_BITS) - 1)
    sig >>= _PU_BITS
    pu1 = sig & ((1 << _PU_BITS) - 1)
    sig >>= _PU_BITS
    ty = sig & ((1 << _TY_BITS) - 1)
    sig >>= _TY_BITS
    slot_idx = sig & ((1 << _SL_BITS) - 1)
    sig >>= _SL_BITS
    return (sig, slot_idx, ty, pu1, pu2, lab - 1)


def signature_to_extkey(sig: int) -> ExtKey:
    slot_kind, slot_idx, ty, pu1, pu2, label = unpack_signature(sig)
    slot: Slot = ("in" if slot_kind == 0 else "gap", slot_idx)
    if pu2 == SENT_V:
        tr = TR(TRType(ty), pu1, NO_VERTEX, label)
    else:
        tr = TR(TRType(ty), pu1, pu2, label)
    return (slot, tr)
