"""Vectorized embedding-join extension discovery (the device hot loop).

This is the TPU-native realization of the paper's Sec. 4.3 insight: once a
pattern occurrence fixes the vertex-ID mapping psi, checking whether a data
TR extends the pattern is an O(1) token comparison - no isomorphism test.
We evaluate that comparison for every (embedding x data-TR) pair on the
VPU and reduce to per-candidate supports with sort/segment primitives.

``match_signatures`` computes, for each (embedding e, token t), a packed
int32 *extension signature* describing the one-TR extension (slot + TR in
pattern coordinates) that the token would realize, or -1 when the token
cannot extend the embedding under the current search phase:

* mode 0 (RS root)        - anything, incl. fresh-vertex / fresh-edge TRs
* mode 1 (RS, node has vertex TRs)   - vertex TRs on mapped vertices only
* mode 2 (RS, edge-only node)        - vertex TRs on mapped vertices,
  edge TRs with >=1 mapped endpoint (P2/P3-class children)
* mode 3 (GTRACE baseline)           - anything, tail slots only

Supports are distinct-gid counts per signature; `aggregate_host` is the
exact numpy finalize, `candidate_table_device` the fixed-size on-device
variant used by the distributed step (see distributed.py).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import INVALID_SIG, PAD_PHI, PAD_PSI, SENT_V

MODE_ROOT = 0
MODE_VERTEX_PHASE = 1
MODE_EDGE_PHASE = 2
MODE_TAIL = 3


def match_signatures_ref(tokens, gid, phi, psi, emb_valid, existing, nv,
                         n_pat, mode):
    """Gather per-embedding token rows and evaluate the embedding-join
    predicate (shared oracle in repro.kernels.match_count.ref).

    tokens [G,T,6] int32, gid [E], phi [E,NI], psi [E,NV],
    emb_valid [E] int32 (0 = padded row), existing [P,5] int32,
    nv/n_pat/mode scalars (int32).  Returns sigs [E,T] int32.
    """
    from ..kernels.match_count.ref import match_core

    tok = tokens[gid]  # [E,T,6]
    return match_core(tok, phi, psi, emb_valid, existing, nv, n_pat, mode)


match_signatures = jax.jit(
    match_signatures_ref, static_argnames=(), donate_argnums=()
)


def aggregate_host(
    sigs: np.ndarray, gids: np.ndarray
) -> Dict[int, Tuple[Set[int], np.ndarray]]:
    """Exact finalize: signature -> (distinct gid set, (e,t) index array)."""
    E, T = sigs.shape
    flat = sigs.reshape(-1)
    ok = flat >= 0
    if not ok.any():
        return {}
    idx = np.nonzero(ok)[0]
    svals = flat[idx]
    e_idx = (idx // T).astype(np.int32)
    t_idx = (idx % T).astype(np.int32)
    g = gids[e_idx]
    order = np.lexsort((t_idx, e_idx, svals))
    svals, e_idx, t_idx, g = (x[order] for x in (svals, e_idx, t_idx, g))
    out: Dict[int, Tuple[Set[int], np.ndarray]] = {}
    bounds = np.nonzero(np.diff(svals))[0] + 1
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [len(svals)]])
    for s, e in zip(starts, ends):
        sig = int(svals[s])
        out[sig] = (
            set(g[s:e].tolist()),
            np.stack([e_idx[s:e], t_idx[s:e]], axis=1),
        )
    return out


@functools.partial(jax.jit, static_argnames=("k",))
def candidate_table_device(sigs, gids, k: int):
    """Fixed-size on-device candidate table.

    Returns (uniq_sigs [k] int64, distinct_gid_counts [k] int32).  Exact
    when the number of distinct signatures in this shard is < k (the
    driver checks and re-runs with larger k otherwise; -1 rows are pads).
    """
    E, T = sigs.shape
    flat_sig = sigs.reshape(-1)
    flat_gid = jnp.broadcast_to(gids[:, None], (E, T)).reshape(-1)
    order = jnp.lexsort((flat_gid, flat_sig))
    ss = flat_sig[order]
    gg = flat_gid[order]
    prev_s = jnp.concatenate([jnp.full((1,), -2, ss.dtype), ss[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -2, gg.dtype), gg[:-1]])
    new_pair = (ss != prev_s) | (gg != prev_g)
    contrib = (new_pair & (ss >= 0)).astype(jnp.int32)
    uniq, inv = jnp.unique(
        ss, size=k, fill_value=INVALID_SIG, return_inverse=True
    )
    counts = jax.ops.segment_sum(contrib, inv, num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts


def merge_tables(uniq_list, counts_list, k: int):
    """Merge per-shard (sig,count) tables by summing counts per signature
    (gid shards are disjoint so distinct-gid counts add)."""
    allsig = jnp.concatenate(uniq_list)
    allcnt = jnp.concatenate(counts_list)
    uniq, inv = jnp.unique(
        allsig, size=k, fill_value=INVALID_SIG, return_inverse=True
    )
    counts = jax.ops.segment_sum(allcnt, inv, num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts
