"""Vectorized embedding-join extension discovery (the device hot loop).

This is the TPU-native realization of the paper's Sec. 4.3 insight: once a
pattern occurrence fixes the vertex-ID mapping psi, checking whether a data
TR extends the pattern is an O(1) token comparison - no isomorphism test.
We evaluate that comparison for every (embedding x data-TR) pair on the
VPU and reduce to per-candidate supports with sort/segment primitives.

``match_signatures`` computes, for each (embedding e, token t), a packed
int32 *extension signature* describing the one-TR extension (slot + TR in
pattern coordinates) that the token would realize, or -1 when the token
cannot extend the embedding under the current search phase:

* mode 0 (RS root)        - anything, incl. fresh-vertex / fresh-edge TRs
* mode 1 (RS, node has vertex TRs)   - vertex TRs on mapped vertices only
* mode 2 (RS, edge-only node)        - vertex TRs on mapped vertices,
  edge TRs with >=1 mapped endpoint (P2/P3-class children)
* mode 3 (GTRACE baseline)           - anything, tail slots only

Supports are distinct-gid counts per signature; `aggregate_host` is the
exact numpy finalize (vectorized: one sort + boundary split, no
per-signature python), `candidate_table_device` the fixed-size
on-device variant used by the distributed step (see distributed.py).

``match_signatures_batch`` / ``aggregate_host_batch`` are the wavefront
forms: rows of *different* patterns share one dispatch, carrying a
per-row ``pattern_id`` that indexes stacked per-pattern tables on the
way in and namespaces the signatures on the way out (the 64-bit
``pattern_id << 32 | sig`` key) - see mining.driver's wavefront
scheduler.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import INVALID_SIG, PAD_PHI, PAD_PSI, SENT_V

MODE_ROOT = 0
MODE_VERTEX_PHASE = 1
MODE_EDGE_PHASE = 2
MODE_TAIL = 3


def match_signatures_ref(tokens, gid, phi, psi, emb_valid, existing, nv,
                         n_pat, mode):
    """Gather per-embedding token rows and evaluate the embedding-join
    predicate (shared oracle in repro.kernels.match_count.ref).

    tokens [G,T,6] int32, gid [E], phi [E,NI], psi [E,NV],
    emb_valid [E] int32 (0 = padded row), existing [P,5] int32,
    nv/n_pat/mode scalars (int32).  Returns sigs [E,T] int32.
    """
    from ..kernels.match_count.ref import match_core

    tok = tokens[gid]  # [E,T,6]
    return match_core(tok, phi, psi, emb_valid, existing, nv, n_pat, mode)


match_signatures = jax.jit(
    match_signatures_ref, static_argnames=(), donate_argnums=()
)


def match_signatures_batch_ref(tokens, gid, phi, psi, emb_valid, pid,
                               ex_stack, nv_stack, npat_stack,
                               mode_stack):
    """Wavefront variant of ``match_signatures_ref``: rows belonging to
    *different* patterns share one device scan.  ``pid`` [E] indexes the
    per-pattern tables ``ex_stack`` [NP,P,5] and ``nv_stack`` /
    ``npat_stack`` / ``mode_stack`` [NP]; the gathers happen inside the
    jit so one dispatch covers the whole packed chunk."""
    from ..kernels.match_count.ref import match_core

    tok = tokens[gid]  # [E,T,6]
    return match_core(
        tok, phi, psi, emb_valid, ex_stack[pid],
        nv_stack[pid], npat_stack[pid], mode_stack[pid],
    )


match_signatures_batch = jax.jit(match_signatures_batch_ref)


def _group_finalize(svals, e_idx, t_idx, g):
    """Shared vectorized finalize core: sort the surviving
    (signature, e, t, gid) rows once by signature, split the (e,t) rows
    at the signature boundaries, and dedup (signature, gid) pairs with a
    second sort - no per-signature ``set(tolist())`` over the
    duplicate-heavy raw rows (the old host bottleneck).  Returns
    (signature keys ascending, per-key distinct-gid arrays, per-key
    (e,t) row arrays ordered by (e,t))."""
    order = np.lexsort((t_idx, e_idx, svals))
    svals = svals[order]
    e_idx = e_idx[order]
    t_idx = t_idx[order]
    g = g[order]
    bounds = np.nonzero(np.diff(svals))[0] + 1
    et_groups = np.split(np.stack([e_idx, t_idx], axis=1), bounds)
    gorder = np.lexsort((g, svals))
    s2, g2 = svals[gorder], g[gorder]
    keep = np.empty(len(s2), bool)
    keep[:1] = True
    keep[1:] = (s2[1:] != s2[:-1]) | (g2[1:] != g2[:-1])
    s2, g2 = s2[keep], g2[keep]
    gid_groups = np.split(g2, np.nonzero(np.diff(s2))[0] + 1)
    keys = svals[np.concatenate([[0], bounds])]
    return keys, gid_groups, et_groups


def aggregate_host(
    sigs: np.ndarray, gids: np.ndarray
) -> Dict[int, Tuple[Set[int], np.ndarray]]:
    """Exact finalize: signature -> (distinct gid set, (e,t) index array)."""
    E, T = sigs.shape
    flat = sigs.reshape(-1)
    idx = np.nonzero(flat >= 0)[0]
    if not len(idx):
        return {}
    svals = flat[idx]
    e_idx = (idx // T).astype(np.int32)
    t_idx = (idx % T).astype(np.int32)
    g = np.asarray(gids)[e_idx]
    keys, gid_groups, et_groups = _group_finalize(svals, e_idx, t_idx, g)
    return {
        int(s): (set(gg.tolist()), et)
        for s, gg, et in zip(keys, gid_groups, et_groups)
    }


def aggregate_host_batch(
    sigs: np.ndarray, gids: np.ndarray, pids: np.ndarray
) -> Dict[Tuple[int, int], Tuple[Set[int], np.ndarray]]:
    """Namespaced finalize for wavefront scans: each row carries the
    pattern id it belongs to (``pids`` [E]), so signatures of different
    patterns in the same packed batch are disambiguated by composing a
    64-bit ``pattern_id << 32 | sig`` sort key.  Returns
    {(pattern_id, sig): (distinct gid set, (e,t) rows)} with ``e``
    indexing the packed batch rows (the driver maps them back to
    per-pattern embedding indices)."""
    E, T = sigs.shape
    flat = sigs.reshape(-1).astype(np.int64)
    idx = np.nonzero(flat >= 0)[0]
    if not len(idx):
        return {}
    e_idx = (idx // T).astype(np.int32)
    t_idx = (idx % T).astype(np.int32)
    svals = (np.asarray(pids, np.int64)[e_idx] << 32) | flat[idx]
    g = np.asarray(gids)[e_idx]
    keys, gid_groups, et_groups = _group_finalize(svals, e_idx, t_idx, g)
    return {
        (int(k >> 32), int(k & 0xFFFFFFFF)): (set(gg.tolist()), et)
        for k, gg, et in zip(keys, gid_groups, et_groups)
    }


@functools.partial(jax.jit, static_argnames=("k",))
def candidate_table_device(sigs, gids, k: int):
    """Fixed-size on-device candidate table.

    Returns (uniq_sigs [k] int64, distinct_gid_counts [k] int32).  Exact
    when the number of distinct signatures in this shard is < k (the
    driver checks and re-runs with larger k otherwise; -1 rows are pads).
    """
    E, T = sigs.shape
    flat_sig = sigs.reshape(-1)
    flat_gid = jnp.broadcast_to(gids[:, None], (E, T)).reshape(-1)
    order = jnp.lexsort((flat_gid, flat_sig))
    ss = flat_sig[order]
    gg = flat_gid[order]
    prev_s = jnp.concatenate([jnp.full((1,), -2, ss.dtype), ss[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -2, gg.dtype), gg[:-1]])
    new_pair = (ss != prev_s) | (gg != prev_g)
    contrib = (new_pair & (ss >= 0)).astype(jnp.int32)
    uniq, inv = jnp.unique(
        ss, size=k, fill_value=INVALID_SIG, return_inverse=True
    )
    counts = jax.ops.segment_sum(contrib, inv, num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts


def merge_tables(uniq_list, counts_list, k: int):
    """Merge per-shard (sig,count) tables by summing counts per signature
    (gid shards are disjoint so distinct-gid counts add)."""
    allsig = jnp.concatenate(uniq_list)
    allcnt = jnp.concatenate(counts_list)
    uniq, inv = jnp.unique(
        allsig, size=k, fill_value=INVALID_SIG, return_inverse=True
    )
    counts = jax.ops.segment_sum(allcnt, inv, num_segments=k)
    counts = jnp.where(uniq >= 0, counts, 0)
    return uniq, counts
