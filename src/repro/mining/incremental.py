"""Incremental frontier re-mining over a sliding window (streaming).

The serving layer (repro.serving.streaming) maintains exact supports
for its *active* bank patterns under a sliding window of sequences, and
records which active patterns the *arriving* sequences touched - i.e.
the arrival contained them.  That dirtiness signal makes re-mining
incremental, because containment is monotone along the reverse-search
``parent()`` chain (a sequence containing a pattern contains every
ancestor):

    If no arrival since the last reconcile contained pattern ``p``,
    then no pattern below ``p`` *gained* any support (a sequence
    containing a descendant contains ``p``).  Every non-active
    descendant was below ``minsup`` at the last reconcile and its
    support has only decreased since, so it is still infrequent; every
    active descendant's support is maintained exactly by the streaming
    layer regardless (arrivals counted by the join, expiries
    decremented from stored bitmaps).  ``p``'s subtree is *clean*: its
    active frequent descendants are retained at their maintained
    supports, and no scan below ``p`` can discover anything new.
    Expiries never dirty anything - they only shrink supports, which
    maintenance already accounts for.

``refresh_frontier`` therefore walks the reverse-search tree from the
root exactly like ``AcceleratedMiner.mine_rs`` (same scans, same
membership test, bit-equal supports) but prunes every clean subtree: a
clean active child is retained together with its active frequent
descendants (looked up by walking ``parent()`` chains) without a single
DB scan.  Dirty or unknown (new / previously tombstoned) children are
scanned and descended normally - the *boundary frontier* of the ISSUE:
children of still-frequent patterns re-expanded via reverse search.
The result is exactly what a full re-mine of the window would produce
(property-tested in tests/test_streaming.py); a periodic full re-mine
(``StreamingBank.refresh(full=True)``) stays available as the
belt-and-braces exactness escape hatch and as bank compaction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

from ..core.graphseq import Pattern, TRSeq, pattern_length
from ..core.reverse_search import parent
from .driver import AcceleratedMiner


@dataclasses.dataclass
class FrontierResult:
    """Outcome of one frontier refresh: the exact frequent-pattern map
    over the window plus the work accounting that makes the incremental
    claim measurable (``scans`` vs ``scans_skipped``)."""

    patterns: Dict[Pattern, int]
    # exact containing-sequence sets (window gid -> bool) for every
    # *scanned* pattern - the streaming layer backfills recovered/new
    # rows' window bitmaps from these, no separate containment join.
    # Retained (clean) patterns are absent: their ring bitmaps are
    # already exact.
    gids: Dict[Pattern, Set[int]] = dataclasses.field(
        default_factory=dict)
    scans: int = 0            # extension scans actually run
    scans_skipped: int = 0    # clean frequent subtree roots pruned
    retained: int = 0         # patterns kept from maintained supports
    discovered: int = 0       # patterns found by scanning (new or dirty)


def _ancestor_chains(
    patterns: Sequence[Pattern],
) -> Dict[Pattern, List[Pattern]]:
    """Each pattern's reverse-search ancestor chain (excluding the
    root), memoized across the batch - used to retain a clean pattern's
    known frequent descendants without scanning."""
    chains: Dict[Pattern, List[Pattern]] = {}

    def chain(p: Pattern) -> List[Pattern]:
        got = chains.get(p)
        if got is not None:
            return got
        q = parent(p)
        out: List[Pattern] = [] if q is None or not q else chain(q) + [q]
        chains[p] = out
        return out

    for p in patterns:
        chain(p)
    return chains


def refresh_frontier(
    db: Sequence[TRSeq],
    min_support: int,
    *,
    active: Dict[Pattern, int],
    dirty: Set[Pattern],
    any_change: bool = True,
    max_len: Optional[int] = None,
    miner: Optional[AcceleratedMiner] = None,
    **miner_kw,
) -> FrontierResult:
    """Re-mine the window ``db`` incrementally.

    ``active`` maps the maintained (exactly counted) frequent patterns
    to their current window supports; ``dirty`` is the subset contained
    in at least one *arrival* since the supports were last reconciled
    (the only events that can add support anywhere below a pattern).
    Patterns outside ``active`` (new or tombstoned) have unknown
    supports and are always treated as dirty.  ``any_change=False``
    asserts no window change at all happened, making the whole walk a
    no-op retention.

    Returns the exact ``{pattern: support}`` map a full
    ``mine_rs(min_support, max_len)`` over ``db`` would produce.  The
    miner's capacity guards (``max_itemsets``/``max_vertices``) apply
    identically - pass ``miner`` or ``miner_kw`` to match the miner that
    built the bank."""
    res = FrontierResult(patterns={})
    frequent_active = {
        p: s for p, s in active.items() if s >= min_support
    }
    if not any_change:
        res.patterns.update(frequent_active)
        res.retained = len(frequent_active)
        return res
    if miner is None:
        miner = AcceleratedMiner(db, **miner_kw)
    assert len(miner.db) == len(db), "miner must be bound to the window"
    chains = _ancestor_chains(list(frequent_active))
    # descendants[c] = active frequent patterns strictly below c
    descendants: Dict[Pattern, List[Pattern]] = {}
    for p in frequent_active:
        for anc in chains[p]:
            descendants.setdefault(anc, []).append(p)

    def is_clean(p: Pattern) -> bool:
        return p in active and p not in dirty

    root: Pattern = ()
    stack = [(root, [(g, (), ()) for g in range(len(db))])]
    while stack:
        pattern, embs = stack.pop()
        if max_len is not None and pattern_length(pattern) >= max_len:
            continue
        if len(pattern) >= miner.ni:
            continue  # capacity guard, mirrors AcceleratedMiner._mine
        res.scans += 1

        def want_embs(child: Pattern) -> bool:
            # clean children are retained, never descended - skip the
            # embedding rebuild (the expensive host part of a scan)
            return not is_clean(child)

        for child, gids, child_embs in miner.expand_children(
            pattern, embs, min_support, rs=True, want_embs=want_embs
        ):
            res.patterns[child] = len(gids)
            if is_clean(child):
                # clean subtree: no window change touched child, so no
                # descendant's support changed - retain the known
                # frequent ones, prune the scan
                res.scans_skipped += 1
                res.retained += 1
                for q in descendants.get(child, ()):
                    res.patterns[q] = active[q]
                    res.retained += 1
            else:
                res.gids[child] = gids
                res.discovered += 1
                stack.append((child, child_embs))
    return res
