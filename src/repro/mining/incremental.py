"""Incremental frontier re-mining over a sliding window (streaming).

The serving layer (repro.serving.streaming) maintains exact supports
for its *active* bank patterns under a sliding window of sequences, and
records which active patterns the *arriving* sequences touched - i.e.
the arrival contained them.  That dirtiness signal makes re-mining
incremental, because containment is monotone along the reverse-search
``parent()`` chain (a sequence containing a pattern contains every
ancestor):

    If no arrival since the last reconcile contained pattern ``p``,
    then no pattern below ``p`` *gained* any support (a sequence
    containing a descendant contains ``p``).  Every non-active
    descendant was below ``minsup`` at the last reconcile and its
    support has only decreased since, so it is still infrequent; every
    active descendant's support is maintained exactly by the streaming
    layer regardless (arrivals counted by the join, expiries
    decremented from stored bitmaps).  ``p``'s subtree is *clean*: its
    active frequent descendants are retained at their maintained
    supports, and no scan below ``p`` can discover anything new.
    Expiries never dirty anything - they only shrink supports, which
    maintenance already accounts for.

``refresh_frontier`` therefore walks the reverse-search tree from the
root exactly like ``AcceleratedMiner.mine_rs`` (same scans, same
membership test, bit-equal supports) but prunes every clean subtree: a
clean active child is retained together with its active frequent
descendants (looked up by walking ``parent()`` chains) without a single
DB scan.  Dirty or unknown (new / previously tombstoned) children are
scanned and descended normally - the *boundary frontier* of the ISSUE:
children of still-frequent patterns re-expanded via reverse search.
The result is exactly what a full re-mine of the window would produce
(property-tested in tests/test_streaming.py); a periodic full re-mine
(``StreamingBank.refresh(full=True)``) stays available as the
belt-and-braces exactness escape hatch and as bank compaction.

The per-child dirtiness index
-----------------------------
The dirtiness signal is *slot-granular* on the streaming side: the
ring's per-sequence containment bitmaps double as the dirtiness record,
and a per-slot ``fresh`` flag marks arrivals since the last reconcile.
``dirty`` is then "patterns contained in a fresh arrival *still in the
window*" - overwriting a ring slot drops its dirt, so under heavy churn
an arrival that transits the window entirely between two reconciles
dirties nothing, and ``refresh_frontier`` prunes subtrees an
accumulated dirty-bit scheme would have rescanned.

The same index coarsens to the per-child (depth-1 subtree) level:
``depth1_root(p)`` maps any pattern to its depth-1 reverse-search
ancestor, and ``subtree_dirty_rows`` widens a set of dirty depth-1
roots back to a per-row mask.  The coarse form is what the multi-host
sharded-window protocol (serving.cluster) all-reduces at ``refresh()``:
O(#depth-1 subtrees) flags instead of a bank-width bit row per host.
It is sound because containment is anti-monotone along the ``parent()``
chain - an arrival touching any pattern touches its depth-1 root, so a
clean root certifies a clean subtree - and refresh_frontier stays exact
under any dirty *superset* (it only ever scans more).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from ..core.graphseq import Pattern, TRSeq, pattern_length
from ..core.reverse_search import parent
from .driver import AcceleratedMiner


@functools.lru_cache(maxsize=1 << 16)
def depth1_root(p: Pattern) -> Pattern:
    """The depth-1 reverse-search ancestor of ``p`` (``p`` itself when
    it is depth 1).  Containment is anti-monotone along the ``parent()``
    chain, so any sequence containing ``p`` contains its depth-1 root -
    the soundness of subtree-level dirtiness.  Memoized process-wide:
    ``parent()`` re-canonicalizes at every chain link, and the sharded
    refresh asks for every bank pattern's root on each reconcile (the
    recursion memoizes every ancestor along the way)."""
    up = parent(p)
    if up is None or not up:
        return p
    return depth1_root(up)


def subtree_dirty_rows(
    patterns: Sequence[Pattern], dirty_roots: Set[Pattern]
) -> np.ndarray:
    """Widen a set of dirty depth-1 subtree roots to a per-bank-row
    bool mask (True = the row's subtree was touched).  The coarse,
    all-reducible form of the dirtiness index - see the module
    docstring."""
    return np.asarray(
        [depth1_root(p) in dirty_roots for p in patterns], bool
    )


@dataclasses.dataclass
class FrontierResult:
    """Outcome of one frontier refresh: the exact frequent-pattern map
    over the window plus the work accounting that makes the incremental
    claim measurable (``scans`` vs ``scans_skipped``)."""

    patterns: Dict[Pattern, int]
    # exact containing-sequence sets (window gid -> bool) for every
    # *scanned* pattern - the streaming layer backfills recovered/new
    # rows' window bitmaps from these, no separate containment join.
    # Retained (clean) patterns are absent: their ring bitmaps are
    # already exact.
    gids: Dict[Pattern, Set[int]] = dataclasses.field(
        default_factory=dict)
    scans: int = 0            # extension scans actually run
    scans_skipped: int = 0    # clean frequent subtree roots pruned
    retained: int = 0         # patterns kept from maintained supports
    discovered: int = 0       # patterns found by scanning (new or dirty)
    # per-child accounting: of the root's frequent children, how many
    # whole depth-1 subtrees were pruned clean vs descended dirty
    depth1_clean: int = 0
    depth1_dirty: int = 0


def _ancestor_chains(
    patterns: Sequence[Pattern],
) -> Dict[Pattern, List[Pattern]]:
    """Each pattern's reverse-search ancestor chain (excluding the
    root), memoized across the batch - used to retain a clean pattern's
    known frequent descendants without scanning."""
    chains: Dict[Pattern, List[Pattern]] = {}

    def chain(p: Pattern) -> List[Pattern]:
        got = chains.get(p)
        if got is not None:
            return got
        q = parent(p)
        out: List[Pattern] = [] if q is None or not q else chain(q) + [q]
        chains[p] = out
        return out

    for p in patterns:
        chain(p)
    return chains


def refresh_frontier(
    db: Sequence[TRSeq],
    min_support: int,
    *,
    active: Dict[Pattern, int],
    dirty: Set[Pattern],
    any_change: bool = True,
    max_len: Optional[int] = None,
    miner: Optional[AcceleratedMiner] = None,
    **miner_kw,
) -> FrontierResult:
    """Re-mine the window ``db`` incrementally.

    ``active`` maps the maintained (exactly counted) frequent patterns
    to their current window supports; ``dirty`` is the subset contained
    in at least one *arrival* since the supports were last reconciled
    (the only events that can add support anywhere below a pattern).
    Patterns outside ``active`` (new or tombstoned) have unknown
    supports and are always treated as dirty.  ``any_change=False``
    asserts no window change at all happened, making the whole walk a
    no-op retention.

    Returns the exact ``{pattern: support}`` map a full
    ``mine_rs(min_support, max_len)`` over ``db`` would produce.  The
    miner's capacity guards (``max_itemsets``/``max_vertices``) apply
    identically - pass ``miner`` or ``miner_kw`` to match the miner that
    built the bank."""
    res = FrontierResult(patterns={})
    frequent_active = {
        p: s for p, s in active.items() if s >= min_support
    }
    if not any_change:
        res.patterns.update(frequent_active)
        res.retained = len(frequent_active)
        return res
    if miner is None:
        miner = AcceleratedMiner(db, **miner_kw)
    assert len(miner.db) == len(db), "miner must be bound to the window"
    chains = _ancestor_chains(list(frequent_active))
    # descendants[c] = active frequent patterns strictly below c
    descendants: Dict[Pattern, List[Pattern]] = {}
    for p in frequent_active:
        for anc in chains[p]:
            descendants.setdefault(anc, []).append(p)

    def is_clean(p: Pattern) -> bool:
        return p in active and p not in dirty

    def want_embs(child: Pattern) -> bool:
        # clean children are retained, never descended - skip the
        # embedding rebuild (the expensive host part of a scan)
        return not is_clean(child)

    # same wavefront scheduling as AcceleratedMiner._mine: the dirty
    # frontier is drained in slices and every slice's scans share
    # packed device chunks, so streaming refresh() and the sharded
    # reconcile get the cross-pattern batching for free
    root: Pattern = ()
    pending = deque([(root, [(g, (), ()) for g in range(len(db))])])
    while pending:
        items = miner._take_slice(pending, max_len, wavefront=True)
        if not items:
            break  # guards drained the pool
        res.scans += len(items)
        for (pattern, _), kids in zip(items, miner.expand_children_batch(
            items, min_support, rs=True, want_embs=want_embs
        )):
            for child, gids, child_embs in kids:
                res.patterns[child] = len(gids)
                if pattern == root:
                    if is_clean(child):
                        res.depth1_clean += 1
                    else:
                        res.depth1_dirty += 1
                if is_clean(child):
                    # clean subtree: no window change touched child, so
                    # no descendant's support changed - retain the known
                    # frequent ones, prune the scan
                    res.scans_skipped += 1
                    res.retained += 1
                    for q in descendants.get(child, ()):
                        res.patterns[q] = active[q]
                        res.retained += 1
                else:
                    res.gids[child] = gids
                    res.discovered += 1
                    pending.append((child, child_embs))
    return res
