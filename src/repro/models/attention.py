"""Attention: blockwise (flash-style) causal training path, GQA, and a
KV-cache decode path that stays correct under sequence-sharded caches.

The training path never materializes the [S, S] score matrix: queries are
processed against key/value blocks with an online-softmax accumulator
(lax.scan over KV blocks), bounding the per-layer activation footprint to
O(S * block) - the same memory shape a fused TPU attention kernel gives,
expressed at the XLA level so it shards under pjit.

The decode path computes softmax over the cache axis with plain reductions
so the SPMD partitioner can insert the (max, sum) all-reduces when the
cache sequence axis is sharded (flash-decoding / split-KV semantics for
free - see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def constrain_dims(x, dim_axes):
    """Pin shardings of selected dims (XLA's SPMD propagation loses
    batch/head sharding through scan carries; without this the attention
    accumulators and saved remat activations replicate across the DP/TP
    axes - measured 16x activation-bytes blowup, see EXPERIMENTS.md).
    Dims whose size the axes don't divide are left unconstrained."""
    if not dim_axes:
        return x
    spec = [None] * x.ndim
    any_set = False
    for dim, axes in dim_axes.items():
        if not axes:
            continue
        axes = axes if isinstance(axes, tuple) else (axes,)
        if x.shape[dim] % _axes_size(axes) != 0:
            continue
        spec[dim] = axes if len(axes) > 1 else axes[0]
        any_set = True
    if not any_set:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_batch(x, batch_axes, dim: int = 0):
    return constrain_dims(x, {dim: batch_axes})


def _axes_size(axes):
    import numpy as np
    try:
        if hasattr(jax.sharding, "get_abstract_mesh"):
            mesh = jax.sharding.get_abstract_mesh()
        else:  # pre-0.5: the thread-resources physical mesh
            from jax._src import mesh as _mesh_lib

            mesh = _mesh_lib.thread_resources.env.physical_mesh
        return int(np.prod([mesh.shape[a] for a in axes]))
    except Exception:
        return 1 << 30  # unknown mesh: skip constraint


def _gqa_expand(q, n_kv):
    """[B,S,H,hd] -> [B,S,KV,H/KV,hd]."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def blockwise_causal_attention(q, k, v, *, block_q: int = 512,
                               block_kv: int = 512, scale=None,
                               schedule: str = "triangular",
                               batch_axes=(), model_axes=("model",)):
    """Causal GQA attention without materializing S x S scores.

    q [B,S,H,hd], k/v [B,S,KV,hd]; H % KV == 0.  Returns [B,S,H,hd].

    schedule:
    * "triangular" - one sequential scan over the statically-enumerated
      lower-triangular (q-block, kv-block) pairs: fully-masked pairs are
      never computed (the naive grid wastes ~2x FLOPs at long S) and the
      single flat scan avoids the batched-while buffers XLA creates when
      vectorizing a map-of-scans (a multi-GiB pred carry; see
      EXPERIMENTS.md §Perf).
    * "full" - the naive all-pairs grid (kept as the measured baseline).

    Masking is an additive [block_q, block_kv] penalty - small, hoistable,
    and fused into the score add; a boolean where-mask broadcast to score
    shape gets hoisted by XLA into a score-sized pred buffer.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    nq, nk = s // block_q, s // block_kv

    qg = _gqa_expand(q, kv).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    q_blocks = qg.reshape(b, nq, block_q, kv, g, hd)

    # TP placement: shard the q-head-group dim when it divides, else the
    # kv-head dim (MHA), else leave heads unconstrained (tiny models)
    if batch_axes:
        hdim_scores = {2: model_axes} if g % _axes_size(model_axes) == 0 \
            else {3: model_axes}
        q_blocks = constrain_dims(
            q_blocks, {0: batch_axes,
                       4 if g % _axes_size(model_axes) == 0 else 3:
                       model_axes})
        kf = constrain_dims(kf, {0: batch_axes, 2: model_axes})
        vf = constrain_dims(vf, {0: batch_axes, 2: model_axes})
    else:
        hdim_scores = {}

    if schedule == "triangular":
        pairs = [
            (qi, ki)
            for qi in range(nq)
            for ki in range(nk)
            if ki * block_kv <= qi * block_q + block_q - 1
        ]
        pair_arr = jnp.asarray(pairs, jnp.int32)  # [P, 2]
        is_last = jnp.asarray(
            [i + 1 == len(pairs) or pairs[i + 1][0] != qi
             for i, (qi, _) in enumerate(pairs)], jnp.bool_
        )

        def body(carry, xs):
            m, l, o, out = carry
            (qi, ki), last = xs
            qb = jax.lax.dynamic_index_in_dim(q_blocks, qi, 1,
                                              keepdims=False)
            kb = jax.lax.dynamic_slice_in_dim(kf, ki * block_kv,
                                              block_kv, 1)
            vb = jax.lax.dynamic_slice_in_dim(vf, ki * block_kv,
                                              block_kv, 1)
            sc = constrain_dims(
                jnp.einsum("bqkgd,bskd->bqgks", qb, kb),
                {0: batch_axes, **hdim_scores})
            # additive causal penalty for the (possibly) diagonal block
            dq = qi * block_q + jnp.arange(block_q)
            dk = ki * block_kv + jnp.arange(block_kv)
            pen = jnp.where(dq[:, None] >= dk[None, :], 0.0, NEG_INF)
            sc = sc + pen[None, :, None, None, :]
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqgks,bskd->bqgkd", p, vb
            )
            done = o_new / jnp.maximum(l_new, 1e-30)[..., None]
            out = jax.lax.cond(
                last,
                lambda: jax.lax.dynamic_update_index_in_dim(
                    out, done.astype(out.dtype), qi, 0),
                lambda: out,
            )
            # reset accumulators when a q block completes
            m_new = jnp.where(last, NEG_INF, m_new)
            l_new = jnp.where(last, 0.0, l_new)
            o_new = jnp.where(last, 0.0, o_new)
            return (m_new, l_new, o_new, out), None

        hacc = ({2: model_axes} if g % _axes_size(model_axes) == 0
                else {3: model_axes})
        m0 = constrain_dims(
            jnp.full((b, block_q, g, kv), NEG_INF, jnp.float32),
            {0: batch_axes, **hacc})
        l0 = constrain_dims(
            jnp.zeros((b, block_q, g, kv), jnp.float32),
            {0: batch_axes, **hacc})
        o0 = constrain_dims(
            jnp.zeros((b, block_q, g, kv, hd), jnp.float32),
            {0: batch_axes, **hacc})
        outbuf = constrain_dims(
            jnp.zeros((nq, b, block_q, g, kv, hd), q.dtype),
            {1: batch_axes,
             **({k + 1: v for k, v in hacc.items()})})
        (_, _, _, outbuf), _ = jax.lax.scan(
            body, (m0, l0, o0, outbuf), (pair_arr, is_last)
        )
        outs = jnp.moveaxis(outbuf, 0, 1).reshape(b, s, g, kv, hd)
        outs = outs.transpose(0, 1, 3, 2, 4).reshape(b, s, h, hd)
        return outs.astype(q.dtype)

    # ---- "full" baseline schedule (all block pairs) ----
    def per_qblock(qi, qb):
        q_pos = qi * block_q + jnp.arange(block_q)

        def body(carry, ki):
            m, l, o = carry
            kb = jax.lax.dynamic_slice_in_dim(kf, ki * block_kv, block_kv, 1)
            vb = jax.lax.dynamic_slice_in_dim(vf, ki * block_kv, block_kv, 1)
            sc = jnp.einsum("bqkgd,bskd->bqgks", qb, kb)
            k_pos = ki * block_kv + jnp.arange(block_kv)
            mask = q_pos[:, None] >= k_pos[None, :]
            sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqgks,bskd->bqgkd", p, vb
            )
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, block_q, g, kv), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, block_q, g, kv), jnp.float32)
        o0 = jnp.zeros((b, block_q, g, kv, hd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            lambda c, ki: body(c, ki), (m0, l0, o0), jnp.arange(nk)
        )
        return o / jnp.maximum(l, 1e-30)[..., None]

    outs = jax.lax.map(
        lambda i: per_qblock(i, q_blocks[:, i]), jnp.arange(nq)
    )
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, s, g, kv, hd)
    outs = outs.transpose(0, 1, 3, 2, 4).reshape(b, s, h, hd)
    return outs.astype(q.dtype)


def naive_causal_attention(q, k, v, scale=None):
    """Reference O(S^2)-memory attention (tests only)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = _gqa_expand(q, kv).astype(jnp.float32) * scale
    sc = jnp.einsum("bqkgd,bskd->bqgks", qg, k.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqgks,bskd->bqgkd", p, v.astype(jnp.float32))
    out = out.transpose(0, 1, 3, 2, 4).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, scale=None):
    """One-step decode: q [B,1,H,hd] against cache [B,S,KV,hd].

    Positions >= cache_len are masked.  Reductions over the cache axis are
    plain max/sum, so a sequence-sharded cache lowers to partial reduce +
    all-reduce (split-KV) under pjit.
    """
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = _gqa_expand(q, kv).astype(jnp.float32) * scale  # [B,1,KV,G,hd]
    sc = jnp.einsum("bqkgd,bskd->bqgks", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, :] < cache_len[:, None]  # [B,S]
    sc = jnp.where(mask[:, None, None, None, :], sc, NEG_INF)
    m = sc.max(-1, keepdims=True)
    p = jnp.exp(sc - m)
    l = p.sum(-1, keepdims=True)
    out = jnp.einsum("bqgks,bskd->bqgkd", p / l, v_cache.astype(jnp.float32))
    out = out.transpose(0, 1, 3, 2, 4).reshape(b, 1, h, hd)
    return out.astype(q.dtype)
