"""BERT4Rec (arXiv:1904.06690): bidirectional self-attention over item
sequences with a masked-item (Cloze) objective.

Production-scale choices for a 10^6-item catalog:
* training uses sampled softmax over the masked positions (gold + shared
  negatives with logQ correction) - a [B,M,V] logits tensor at V=10^6 is
  not materializable;
* serving never materializes [B, V] scores either: scoring is a chunked
  top-k scan over the item-embedding table (``chunked_topk_scores``),
  which is also the retrieval_cand path (1 query x 1M candidates).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import normal_init
from .layers import layer_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str
    n_items: int = 1_000_000     # catalog size (retrieval_cand = 1M)
    d_model: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: int = 256
    n_masked: int = 20           # masked positions per sequence
    n_negatives: int = 1024      # shared sampled-softmax negatives
    topk: int = 100
    v_chunk: int = 65536         # scoring chunk over the catalog
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def vocab(self) -> int:
        return self.n_items + 2  # 0 = PAD, n_items+1 = MASK

    @property
    def mask_id(self) -> int:
        return self.n_items + 1


def init_params(rng, cfg: Bert4RecConfig) -> PyTree:
    d = cfg.d_model
    keys = iter(jax.random.split(rng, 8))
    params: Dict[str, Any] = {
        "item_emb": normal_init(next(keys), (cfg.vocab, d), 0.02,
                                cfg.param_dtype),
        "pos_emb": normal_init(next(keys), (cfg.seq_len, d), 0.02,
                               cfg.param_dtype),
        "ln_f_w": jnp.ones((d,), cfg.param_dtype),
        "ln_f_b": jnp.zeros((d,), cfg.param_dtype),
        "out_bias": jnp.zeros((), cfg.param_dtype),
    }
    n = cfg.n_blocks
    params["blocks"] = {
        "wqkv": normal_init(next(keys), (n, d, 3 * d), d ** -0.5,
                            cfg.param_dtype),
        "wo": normal_init(next(keys), (n, d, d), d ** -0.5,
                          cfg.param_dtype),
        "ln1_w": jnp.ones((n, d), cfg.param_dtype),
        "ln1_b": jnp.zeros((n, d), cfg.param_dtype),
        "ln2_w": jnp.ones((n, d), cfg.param_dtype),
        "ln2_b": jnp.zeros((n, d), cfg.param_dtype),
        "w1": normal_init(next(keys), (n, d, cfg.d_ff), d ** -0.5,
                          cfg.param_dtype),
        "b1": jnp.zeros((n, cfg.d_ff), cfg.param_dtype),
        "w2": normal_init(next(keys), (n, cfg.d_ff, d),
                          cfg.d_ff ** -0.5, cfg.param_dtype),
        "b2": jnp.zeros((n, d), cfg.param_dtype),
    }
    return params


def encode(params, seq, cfg: Bert4RecConfig):
    """seq [B,S] item ids (0=PAD) -> hidden [B,S,D]."""
    b, s = seq.shape
    x = params["item_emb"][seq].astype(cfg.compute_dtype)
    x = x + params["pos_emb"][None, :s].astype(cfg.compute_dtype)
    pad = seq == 0  # [B,S]
    h = cfg.n_heads
    dh = cfg.d_model // h

    def block(x, bp):
        bp = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), bp)
        y = layer_norm(x, bp["ln1_w"], bp["ln1_b"])
        qkv = jnp.einsum("bsd,dk->bsk", y, bp["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, h, dh)
        k = k.reshape(b, s, h, dh)
        v = v.reshape(b, s, h, dh)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
        sc = jnp.where(pad[:, None, None, :], -1e30, sc)
        p = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, cfg.d_model)
        x = x + jnp.einsum("bsd,dk->bsk", o, bp["wo"])
        y = layer_norm(x, bp["ln2_w"], bp["ln2_b"])
        y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, bp["w1"]) + bp["b1"])
        x = x + jnp.einsum("bsf,fd->bsd", y, bp["w2"]) + bp["b2"]
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"])
    return layer_norm(x, params["ln_f_w"].astype(cfg.compute_dtype),
                      params["ln_f_b"].astype(cfg.compute_dtype))


def masked_item_loss(params, batch, cfg: Bert4RecConfig):
    """batch: seq [B,S] (with MASK tokens already placed),
    masked_pos [B,M], masked_ids [B,M], negatives [K] shared ids."""
    hidden = encode(params, batch["seq"], cfg)  # [B,S,D]
    hm = jnp.take_along_axis(
        hidden, batch["masked_pos"][..., None], axis=1
    )  # [B,M,D]
    emb = params["item_emb"].astype(cfg.compute_dtype)
    gold_e = emb[batch["masked_ids"]]            # [B,M,D]
    neg_e = emb[batch["negatives"]]              # [K,D]
    gold_logit = jnp.sum(hm * gold_e, -1, dtype=jnp.float32)  # [B,M]
    neg_logit = jnp.einsum("bmd,kd->bmk", hm, neg_e).astype(jnp.float32)
    # sampled softmax: gold vs negatives (uniform logQ cancels up to gold)
    logits = jnp.concatenate([gold_logit[..., None], neg_logit], -1)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    nll = lse - gold_logit
    valid = batch["masked_ids"] > 0
    return jnp.sum(nll * valid) / jnp.maximum(valid.sum(), 1)


def chunked_topk_scores(params, query, cfg: Bert4RecConfig):
    """query [B,D] -> (top-k scores [B,k], ids [B,k]) without a [B,V]
    intermediate: lax.scan over catalog chunks with a running top-k."""
    k = cfg.topk
    v = cfg.n_items + 1  # score real items 1..n_items (skip PAD row 0)
    chunk = cfg.v_chunk
    n_chunks = -(-v // chunk)
    vpad = n_chunks * chunk
    emb = params["item_emb"].astype(cfg.compute_dtype)
    emb = jnp.pad(emb[:v], ((0, vpad - v), (0, 0)))
    b = query.shape[0]

    def body(carry, ci):
        best_s, best_i = carry
        tbl = jax.lax.dynamic_slice_in_dim(emb, ci * chunk, chunk, 0)
        sc = jnp.einsum("bd,cd->bc", query, tbl).astype(jnp.float32)
        ids = ci * chunk + jnp.arange(chunk)
        ids = jnp.broadcast_to(ids[None], (b, chunk))
        sc = jnp.where((ids >= 1) & (ids <= cfg.n_items), sc, -jnp.inf)
        cat_s = jnp.concatenate([best_s, sc], -1)
        cat_i = jnp.concatenate([best_i, ids], -1)
        s, idx = jax.lax.top_k(cat_s, k)
        return (s, jnp.take_along_axis(cat_i, idx, -1)), None

    init = (jnp.full((b, k), -jnp.inf, jnp.float32),
            jnp.zeros((b, k), jnp.int32))
    (s, i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return s, i


def serve_scores(params, batch, cfg: Bert4RecConfig):
    """Next-item scoring: encode session, score last position vs catalog."""
    hidden = encode(params, batch["seq"], cfg)
    # last non-pad position per row
    lengths = jnp.sum((batch["seq"] > 0).astype(jnp.int32), -1)
    last = jnp.take_along_axis(
        hidden, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1
    )[:, 0]
    return chunked_topk_scores(params, last, cfg)


def make_sharded_serve(cfg: Bert4RecConfig, mesh, dp_axes):
    """shard_map scoring: each "model" shard scores only its local vocab
    shard and keeps a local top-k; the only cross-shard traffic is the
    [model, B, k] candidate merge (the pjit auto-sharded version
    all-gathers table chunks per scan step - measured collective-bound,
    see EXPERIMENTS.md §Perf/bert4rec)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape["model"]
    dp_dim = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
    vocab = cfg.vocab
    assert vocab % tp == 0
    vshard = vocab // tp

    def local(params, seq):
        emb_local = params["item_emb"]  # [V/tp, D]
        # vocab-sharded embedding lookup: partial take + psum
        shard = jax.lax.axis_index("model")
        offset = shard * vshard
        ids = seq - offset
        ok = (ids >= 0) & (ids < vshard)
        rows = jnp.take(emb_local, jnp.clip(ids, 0, vshard - 1), axis=0)
        x = jnp.where(ok[..., None], rows, 0.0)
        x = jax.lax.psum(x, "model").astype(cfg.compute_dtype)

        # encoder on full (replicated-over-model) activations
        p_rep = {k: v for k, v in params.items() if k != "item_emb"}
        b, s = seq.shape
        x = x + p_rep["pos_emb"][None, :s].astype(cfg.compute_dtype)
        pad = seq == 0
        h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads

        def block(x, bp):
            bp = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), bp)
            y = layer_norm(x, bp["ln1_w"], bp["ln1_b"])
            qkv = jnp.einsum("bsd,dk->bsk", y, bp["wqkv"])
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(b, s, h, dh)
            k = k.reshape(b, s, h, dh)
            v = v.reshape(b, s, h, dh)
            sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
            sc = jnp.where(pad[:, None, None, :], -1e30, sc)
            pr = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(x.dtype)
            o = jnp.einsum("bhqk,bkhd->bqhd", pr, v).reshape(
                b, s, cfg.d_model)
            x = x + jnp.einsum("bsd,dk->bsk", o, bp["wo"])
            y = layer_norm(x, bp["ln2_w"], bp["ln2_b"])
            y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, bp["w1"])
                            + bp["b1"])
            x = x + jnp.einsum("bsf,fd->bsd", y, bp["w2"]) + bp["b2"]
            return x, None

        x, _ = jax.lax.scan(block, x, params["blocks"])
        x = layer_norm(x, p_rep["ln_f_w"].astype(cfg.compute_dtype),
                       p_rep["ln_f_b"].astype(cfg.compute_dtype))
        lengths = jnp.sum((seq > 0).astype(jnp.int32), -1)
        query = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]

        # local-vocab chunked top-k
        kk = cfg.topk
        chunk = min(cfg.v_chunk, vshard)
        n_chunks = -(-vshard // chunk)
        vpad = n_chunks * chunk
        tbl = jnp.pad(emb_local, ((0, vpad - vshard), (0, 0))).astype(
            cfg.compute_dtype)
        bq = query.shape[0]

        def body(carry, ci):
            bs, bi = carry
            t = jax.lax.dynamic_slice_in_dim(tbl, ci * chunk, chunk, 0)
            sc = jnp.einsum("bd,cd->bc", query, t).astype(jnp.float32)
            ids = offset + ci * chunk + jnp.arange(chunk)
            ids = jnp.broadcast_to(ids[None], (bq, chunk))
            sc = jnp.where((ids >= 1) & (ids <= cfg.n_items), sc, -jnp.inf)
            cs = jnp.concatenate([bs, sc], -1)
            cidx = jnp.concatenate([bi, ids], -1)
            s_, ix = jax.lax.top_k(cs, kk)
            return (s_, jnp.take_along_axis(cidx, ix, -1)), None

        init = (jnp.full((bq, kk), -jnp.inf, jnp.float32),
                jnp.zeros((bq, kk), jnp.int32))
        (ls, li), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))

        # merge the tp local top-k lists (the only non-psum collective)
        all_s = jax.lax.all_gather(ls, "model")  # [tp, B, k]
        all_i = jax.lax.all_gather(li, "model")
        all_s = jnp.moveaxis(all_s, 0, 1).reshape(bq, tp * kk)
        all_i = jnp.moveaxis(all_i, 0, 1).reshape(bq, tp * kk)
        s_, ix = jax.lax.top_k(all_s, kk)
        return s_, jnp.take_along_axis(all_i, ix, -1)

    in_specs = (
        {
            "item_emb": P("model", None),
            "pos_emb": P(), "ln_f_w": P(), "ln_f_b": P(), "out_bias": P(),
            "blocks": jax.tree.map(lambda _: P(),
                                   {"wqkv": 0, "wo": 0, "ln1_w": 0,
                                    "ln1_b": 0, "ln2_w": 0, "ln2_b": 0,
                                    "w1": 0, "b1": 0, "w2": 0, "b2": 0}),
        },
        P(dp_dim, None),
    )
    from ..compat import shard_map_compat

    fn = shard_map_compat(
        local, mesh, in_specs, (P(dp_dim, None), P(dp_dim, None))
    )

    def serve(params, batch):
        return fn(params, batch["seq"])

    return serve
