"""Shared model plumbing: configs, param-spec rules, init helpers.

Params are nested dicts of jnp arrays.  Sharding is expressed as an
ordered list of (path-regex, PartitionSpec-template) rules; templates may
reference the symbolic axes "DATA" (all pure-DP axes: ("pod","data") on
the multi-pod mesh, ("data",) on a single pod - used for FSDP/ZeRO
sharding) and "MODEL" (tensor/expert-parallel axis).  ``resolve_specs``
instantiates them for a concrete mesh.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
Rules = List[Tuple[str, Tuple]]  # (regex, axis template tuple)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _resolve_axis(ax, mesh: Mesh):
    if isinstance(ax, tuple):
        out = []
        for a in ax:
            r = _resolve_axis(a, mesh)
            if isinstance(r, tuple):
                out.extend(r)
            elif r is not None:
                out.append(r)
        return tuple(out)
    if ax == "DATA":
        axes = dp_axes(mesh)
        return axes if len(axes) > 1 else axes[0]
    if ax == "MODEL":
        return "model"
    return ax


def resolve_template(tpl: Sequence, mesh: Mesh) -> P:
    return P(*[_resolve_axis(a, mesh) for a in tpl])


def path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def tree_param_specs(tree: PyTree, rules: Rules, mesh: Mesh) -> PyTree:
    """Map every leaf to a PartitionSpec via the first matching rule."""

    def leaf_spec(path, leaf):
        p = path_str(path)
        for pat, tpl in rules:
            if re.search(pat, p):
                spec = resolve_template(tpl, mesh)
                if len(spec) > leaf.ndim:
                    spec = P(*spec[: leaf.ndim])
                # size-1 / indivisible dims fall back to replication
                # (e.g. quantized-optimizer scale tensors)
                fixed = []
                for dim, ax in enumerate(
                    tuple(spec) + (None,) * (leaf.ndim - len(spec))
                ):
                    if ax is None:
                        fixed.append(None)
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    fixed.append(ax if leaf.shape[dim] % size == 0 else None)
                return P(*fixed)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


def guard_tree_specs(args: PyTree, specs: PyTree, mesh: Mesh) -> PyTree:
    """Replace spec axes that do not evenly divide the argument dim with
    replication (applied to batch/cache specs after template resolve)."""

    def fix(leaf, spec):
        if not isinstance(spec, P):
            return spec
        entries = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        fixed = []
        for dim, ax in enumerate(entries[: leaf.ndim]):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            fixed.append(ax if leaf.shape[dim] % size == 0 else None)
        return P(*fixed)

    return jax.tree.map(
        fix, args, specs, is_leaf=lambda x: isinstance(x, P)
    )


def tree_shardings(tree: PyTree, rules: Rules, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_param_specs(tree, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------------------ init
def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale
                              ).astype(dtype)


def normal_init(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def count_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
