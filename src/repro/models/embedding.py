"""EmbeddingBag for recsys: JAX has no native EmbeddingBag or CSR sparse,
so the lookup-and-reduce over ragged multi-hot bags is built from
``jnp.take`` + ``jax.ops.segment_sum`` - this IS the hot path of recsys
serving and is the substrate the retrieval pipeline uses.

Bags are given in "flat + segment" form: ``indices`` [NNZ] row ids into
the table, ``segments`` [NNZ] bag ids (sorted), optional ``weights``.
Padding entries use index 0 with weight 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(
    table,          # [V, D]
    indices,        # [NNZ] int32
    segments,       # [NNZ] int32 (bag id per entry)
    n_bags: int,
    weights=None,   # [NNZ] or None
    mode: str = "sum",
):
    rows = jnp.take(table, indices, axis=0)  # [NNZ, D]
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    if mode == "sum":
        return jax.ops.segment_sum(rows, segments, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segments, num_segments=n_bags)
        ones = (weights if weights is not None
                else jnp.ones_like(indices, rows.dtype))
        cnt = jax.ops.segment_sum(ones.astype(rows.dtype), segments,
                                  num_segments=n_bags)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segments, num_segments=n_bags)
    raise ValueError(mode)
