"""GNN message passing via edge-index scatter (segment ops).

JAX has no sparse CSR: message passing IS ``jax.ops.segment_sum`` /
``segment_max`` over an edge list, which is also the layout that shards:
edges split across the DP axes (disjoint partial aggregates + psum),
features optionally split across "model".

Covers the three assigned kernel regimes' SpMM family: GCN (sym-norm
SpMM), GIN (sum-agg + MLP), GAT (SDDMM edge scores -> segment softmax ->
weighted SpMM).  Self-loops are expected in the edge list (the data
pipeline adds them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import normal_init
from .layers import cross_entropy_loss

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # gcn | gat | gin
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    n_heads: int = 1          # gat
    gin_eps_learnable: bool = True
    dropout: float = 0.0      # (kept 0 in dry-runs; losses are determin.)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32


def init_params(rng, cfg: GNNConfig) -> PyTree:
    keys = iter(jax.random.split(rng, 4 * cfg.n_layers + 4))
    params: Dict[str, Any] = {"layers": []}
    d_prev = cfg.d_in
    for li in range(cfg.n_layers):
        last = li == cfg.n_layers - 1
        if cfg.kind == "gat":
            heads = 1 if last else cfg.n_heads
            d_out = cfg.n_classes if last else cfg.d_hidden
            lp = {
                "w": normal_init(next(keys), (d_prev, heads * d_out),
                                 d_prev ** -0.5, cfg.param_dtype),
                "a_src": normal_init(next(keys), (heads, d_out), 0.1,
                                     cfg.param_dtype),
                "a_dst": normal_init(next(keys), (heads, d_out), 0.1,
                                     cfg.param_dtype),
            }
            d_prev = heads * d_out if not last else d_out
        elif cfg.kind == "gin":
            d_out = cfg.n_classes if last else cfg.d_hidden
            lp = {
                "eps": jnp.zeros((), cfg.param_dtype),
                "w1": normal_init(next(keys), (d_prev, cfg.d_hidden),
                                  d_prev ** -0.5, cfg.param_dtype),
                "b1": jnp.zeros((cfg.d_hidden,), cfg.param_dtype),
                "w2": normal_init(next(keys), (cfg.d_hidden, d_out),
                                  cfg.d_hidden ** -0.5, cfg.param_dtype),
                "b2": jnp.zeros((d_out,), cfg.param_dtype),
            }
            d_prev = d_out
        else:  # gcn
            d_out = cfg.n_classes if last else cfg.d_hidden
            lp = {
                "w": normal_init(next(keys), (d_prev, d_out),
                                 d_prev ** -0.5, cfg.param_dtype),
                "b": jnp.zeros((d_out,), cfg.param_dtype),
            }
            d_prev = d_out
        params["layers"].append(lp)
    return params


def _gcn_layer(lp, x, src, dst, n, deg_isqrt):
    msg = x[src] * (deg_isqrt[src] * deg_isqrt[dst])[:, None]
    agg = jax.ops.segment_sum(msg, dst, num_segments=n)
    return agg @ lp["w"] + lp["b"]


def _gin_layer(lp, x, src, dst, n):
    agg = jax.ops.segment_sum(x[src], dst, num_segments=n)
    h = (1.0 + lp["eps"]) * x + agg
    h = jax.nn.relu(h @ lp["w1"] + lp["b1"])
    return h @ lp["w2"] + lp["b2"]


def _gat_layer(lp, x, src, dst, n, last: bool):
    heads, d_out = lp["a_src"].shape
    z = (x @ lp["w"]).reshape(n, heads, d_out)
    e = jnp.einsum("ehd,hd->eh", z[src], lp["a_src"]) + jnp.einsum(
        "ehd,hd->eh", z[dst], lp["a_dst"]
    )
    e = jax.nn.leaky_relu(e, 0.2)
    m = jax.ops.segment_max(e, dst, num_segments=n)
    p = jnp.exp(e - m[dst])
    s = jax.ops.segment_sum(p, dst, num_segments=n)
    w = p / jnp.maximum(s[dst], 1e-9)
    agg = jax.ops.segment_sum(z[src] * w[..., None], dst, num_segments=n)
    if last:
        return agg.mean(1)
    return jax.nn.elu(agg.reshape(n, heads * d_out))


def forward(params, batch, cfg: GNNConfig):
    """batch: x [N,F], edges [2,E] int32 (incl. self loops, both dirs),
    optionally edge_mask [E] (0 pads).  Returns logits [N, n_classes]."""
    x = batch["x"].astype(cfg.compute_dtype)
    src, dst = batch["edges"][0], batch["edges"][1]
    if "edge_mask" in batch:
        # padded edges point at node n (a dummy row is appended)
        pad = batch["edge_mask"] == 0
        src = jnp.where(pad, x.shape[0], src)
        dst = jnp.where(pad, x.shape[0], dst)
    n = x.shape[0] + (1 if "edge_mask" in batch else 0)
    if "edge_mask" in batch:
        x = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], 0)

    deg = jax.ops.segment_sum(jnp.ones_like(dst, x.dtype), dst,
                              num_segments=n)
    deg_isqrt = jax.lax.rsqrt(jnp.maximum(deg, 1.0))

    for li, lp in enumerate(params["layers"]):
        last = li == len(params["layers"]) - 1
        lp = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), lp)
        if cfg.kind == "gcn":
            x = _gcn_layer(lp, x, src, dst, n, deg_isqrt)
        elif cfg.kind == "gin":
            x = _gin_layer(lp, x, src, dst, n)
        else:
            x = _gat_layer(lp, x, src, dst, n, last)
        if not last and cfg.kind != "gat":  # gat applies elu inside
            x = jax.nn.relu(x)
    if "edge_mask" in batch:
        x = x[:-1]
    return x


def node_classification_loss(params, batch, cfg: GNNConfig):
    logits = forward(params, batch, cfg)
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def graph_classification_loss(params, batch, cfg: GNNConfig):
    """GIN on batched small graphs: sum-pool node embeddings per graph."""
    logits = forward(params, batch, cfg)  # [N, C]
    pooled = jax.ops.segment_sum(
        logits, batch["graph_id"], num_segments=batch["n_graphs"]
    )
    return cross_entropy_loss(pooled, batch["graph_labels"])
