"""Elementary layers shared across the model zoo."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, H, hd]; positions [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [...,S,hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [...,S,1,hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, targets, mask=None):
    """logits [..., V] (any dtype), integer targets; mean over mask."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, targets[..., None], axis=-1
    ).squeeze(-1)
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
