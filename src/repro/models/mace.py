"""MACE-style E(3)-equivariant message passing (l_max=2, correlation 3).

Higher-order equivariant message passing per MACE (arXiv:2206.07697):
radial Bessel basis, spherical-harmonic edge attributes up to l=2,
many-body product basis of correlation order 3, two interaction layers.

TPU adaptation note (DESIGN.md §Arch-applicability): the full Clebsch-
Gordan product basis is replaced by an *exactly equivariant* subset -
scalar x tensor couplings (CG = identity), the l=1 x l=1 -> l=1 cross
product, and per-l inner products for invariants.  This preserves the
correlation-3 many-body structure and exact E(3) equivariance (unit
tested via random rotations/translations) while keeping the contraction a
dense channelwise einsum, which is the MXU-friendly layout; the O(L^6)
general CG contraction is exactly the part eSCN-style methods also
restructure on accelerators.

Feature layout: [N, 9, C] with components [l0 | l1(x,y,z) | l2(5)] in the
orthonormal real spherical-harmonic basis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import normal_init

PyTree = Any

_L_SLICES = {0: slice(0, 1), 1: slice(1, 4), 2: slice(4, 9)}


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    n_species: int = 10
    r_cut: float = 5.0
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32


def real_sph_harm_l2(rhat):
    """rhat [E,3] unit vectors -> [E,9] orthonormal real SH (l<=2)."""
    x, y, z = rhat[:, 0], rhat[:, 1], rhat[:, 2]
    c0 = 0.28209479177387814
    c1 = 0.4886025119029199
    c2a = 1.0925484305920792
    c2b = 0.31539156525252005
    c2c = 0.5462742152960396
    return jnp.stack(
        [
            jnp.full_like(x, c0),
            c1 * x, c1 * y, c1 * z,
            c2a * x * y,
            c2a * y * z,
            c2b * (3 * z * z - 1.0),
            c2a * x * z,
            c2c * (x * x - y * y),
        ],
        axis=-1,
    )


def bessel_rbf(d, n_rbf: int, r_cut: float):
    """Radial Bessel basis with smooth cutoff; d [E] -> [E, n_rbf]."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(
        n[None, :] * jnp.pi * d[:, None] / r_cut
    ) / d[:, None]
    # polynomial cutoff envelope
    u = jnp.clip(d / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5
    return rb * env[:, None]


def _cross(a, b):
    """l1 x l1 -> l1 (exact CG coupling up to scale); [.. ,3,C]."""
    ax, ay, az = a[..., 0, :], a[..., 1, :], a[..., 2, :]
    bx, by, bz = b[..., 0, :], b[..., 1, :], b[..., 2, :]
    return jnp.stack(
        [ay * bz - az * by, az * bx - ax * bz, ax * by - ay * bx], axis=-2
    )


def product_basis(A):
    """A [N, 9, C] -> (equivariant features [N, 9, C*3],
    invariants [N, C*k]).  Correlation order up to 3 via exact couplings:
    nu=1: A;  nu=2: A0*A, A1 x A1, per-l dots;  nu=3: (A.A)*A, A0^2*A."""
    A0 = A[:, _L_SLICES[0], :]          # [N,1,C]
    A1 = A[:, _L_SLICES[1], :]          # [N,3,C]
    dots = jnp.concatenate(
        [jnp.sum(A[:, s, :] ** 2, axis=1) for s in _L_SLICES.values()],
        axis=-1,
    )  # [N, 3C] invariants (nu=2)
    norm2 = jnp.sum(A * A, axis=1, keepdims=True)  # [N,1,C] invariant
    eq2 = A0 * A                        # scalar x tensor  (nu=2)
    eq3 = norm2 * A                     # invariant x tensor (nu=3)
    cross = _cross(A1, eq2[:, _L_SLICES[1], :])  # nu=3, l=1 block
    eq3 = eq3.at[:, _L_SLICES[1], :].add(cross)
    feats = jnp.concatenate([A, eq2, eq3], axis=-1)  # [N,9,3C]
    inv3 = (A0[:, 0, :] ** 2) * A0[:, 0, :]
    invs = jnp.concatenate([dots, norm2[:, 0, :], inv3], axis=-1)
    return feats, invs


def init_params(rng, cfg: MACEConfig) -> PyTree:
    keys = iter(jax.random.split(rng, 8 * cfg.n_layers + 4))
    C = cfg.d_hidden
    params: Dict[str, Any] = {
        "embed": normal_init(next(keys), (cfg.n_species, C), 1.0,
                             cfg.param_dtype),
        "layers": [],
        "readout_w1": normal_init(next(keys), (C, C), C ** -0.5,
                                  cfg.param_dtype),
        "readout_w2": normal_init(next(keys), (C, 1), C ** -0.5,
                                  cfg.param_dtype),
        # invariant (many-body) readout: 5C invariants per layer
        "readout_inv": normal_init(
            next(keys), (cfg.n_layers * 5 * C, 1), (5 * C) ** -0.5,
            cfg.param_dtype,
        ),
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                # radial MLP: n_rbf -> C (per-channel edge weights)
                "r1": normal_init(next(keys), (cfg.n_rbf, C),
                                  cfg.n_rbf ** -0.5, cfg.param_dtype),
                "r2": normal_init(next(keys), (C, C), C ** -0.5,
                                  cfg.param_dtype),
                # channel mixing of the product basis (per l, shared)
                "mix": normal_init(next(keys), (3 * C, C),
                                   (3 * C) ** -0.5, cfg.param_dtype),
                "self": normal_init(next(keys), (C, C), C ** -0.5,
                                    cfg.param_dtype),
            }
        )
    return params


def forward(params, batch, cfg: MACEConfig):
    """batch: species [N], pos [N,3], edges [2,E], graph_id [N],
    n_graphs int, optional edge_mask [E].  Returns per-graph energy [G]."""
    species = batch["species"]
    pos = batch["pos"].astype(cfg.compute_dtype)
    src, dst = batch["edges"][0], batch["edges"][1]
    n = species.shape[0]
    emask = batch.get("edge_mask")

    h = params["embed"][species]  # [N, C] scalar features
    C = h.shape[-1]
    # lift to [N, 9, C]
    H = jnp.zeros((n, 9, C), h.dtype).at[:, 0, :].set(h)

    rvec = pos[dst] - pos[src]
    d = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    rhat = rvec / jnp.maximum(d, 1e-6)[:, None]
    Y = real_sph_harm_l2(rhat)          # [E, 9]
    rbf = bessel_rbf(d, cfg.n_rbf, cfg.r_cut)  # [E, n_rbf]
    # degenerate (zero-length / self-loop) edges carry no geometric
    # information and their SH values are basis artifacts (e.g. Y20(0) =
    # -c): masking them is required for exact E(3) equivariance.
    ok = (d > 1e-6).astype(Y.dtype)
    Y = Y * ok[:, None]
    if emask is not None:
        Y = Y * emask[:, None]
        rbf = rbf * emask[:, None]

    all_invs = []
    for lp in params["layers"]:
        R = jax.nn.silu(rbf @ lp["r1"]) @ lp["r2"]  # [E, C]
        # messages: R_c * Y_lm * h_src[0,c] + R_c * Y_l0m0 * H_src[lm,c]
        msg = (
            R[:, None, :] * Y[:, :, None] * H[src][:, 0:1, :]
            + R[:, None, :] * H[src] * Y[:, 0:1, None]
        )  # [E, 9, C]
        A = jax.ops.segment_sum(msg, dst, num_segments=n)  # [N,9,C]
        feats, invs = product_basis(A)
        H = jnp.einsum("nlk,kc->nlc", feats, lp["mix"])
        H = H + jnp.einsum("nlc,cd->nld", A, lp["self"])
        all_invs.append(invs)
    # readout: scalar channels + many-body invariants
    scal = H[:, 0, :]
    e_node = jax.nn.silu(scal @ params["readout_w1"]) @ params["readout_w2"]
    e_node = e_node + jnp.concatenate(all_invs, -1) @ params["readout_inv"]
    e_graph = jax.ops.segment_sum(
        e_node[:, 0], batch["graph_id"], num_segments=batch["n_graphs"]
    )
    return e_graph


def energy_loss(params, batch, cfg: MACEConfig):
    e = forward(params, batch, cfg)
    return jnp.mean((e - batch["targets"]) ** 2)
