"""Mixture-of-Experts FFN with top-k routing and capacity (EP-shardable).

Sort-based dispatch: tokens are ranked within their routed expert, tokens
past the capacity are dropped (their combine weight is zero), features are
scattered into an [E, C, D] buffer, expert FFNs run as one grouped einsum,
and outputs are combined back with the router weights.  Under pjit with
experts sharded over "model", the scatter/gather lower to all-to-alls -
the standard EP collective pattern.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import act_fn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    act: str = "silu"
    n_shared: int = 0  # shared (always-on) experts, DeepSeek/Llama-4 style


def moe_ffn(params, x, cfg: MoEConfig):
    """x [B,S,D] -> [B,S,D].  params: wr [D,E], wi/wg [E,D,F], wo [E,F,D]
    (+ shared_wi/wg/wo when n_shared>0); aux load-balance loss returned."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    import math

    cap = max(1, math.ceil(n * k / e * cfg.capacity_factor))
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        params["wr"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)  # [n,k]
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): e * sum_e f_e * p_e
    density = jnp.mean(
        (jax.nn.one_hot(idx_k, e).sum(1) > 0).astype(jnp.float32), 0
    )
    aux = e * jnp.sum(density * probs.mean(0))

    flat_expert = idx_k.reshape(-1)          # [n*k]
    flat_gate = gate_k.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n), k)

    # rank of each routed token inside its expert
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # [n*k, e]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)
    rank = jnp.take_along_axis(pos_in_e, flat_expert[:, None], 1)[:, 0]
    keep = rank < cap
    flat_gate = jnp.where(keep, flat_gate, 0.0)
    slot = jnp.where(keep, flat_expert * cap + rank, e * cap)  # drop slot

    # scatter tokens into [E*C(+1), D]
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[flat_tok])
    buf = buf[: e * cap].reshape(e, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    if "wg" in params:
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
        h = act_fn(cfg.act)(g) * h
    else:
        h = act_fn(cfg.act)(h)
    out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"])  # [e,cap,d]

    # gather back and combine with gates
    flat_out = out_e.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], flat_out[jnp.clip(slot, 0, e * cap - 1)], 0.0
    )
    combined = jnp.zeros((n, d), xf.dtype).at[flat_tok].add(
        gathered * flat_gate[:, None].astype(xf.dtype)
    )

    if cfg.n_shared:
        hs = jnp.einsum("nd,df->nf", xf, params["shared_wi"])
        if "shared_wg" in params:
            gs = jnp.einsum("nd,df->nf", xf, params["shared_wg"])
            hs = act_fn(cfg.act)(gs) * hs
        else:
            hs = act_fn(cfg.act)(hs)
        combined = combined + jnp.einsum("nf,fd->nd", hs, params["shared_wo"])

    return combined.reshape(b, s, d), aux
