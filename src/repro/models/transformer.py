"""Decoder / encoder transformer LM covering the five assigned LM archs
(dense GQA: glm4-9b, gemma-7b, smollm-135m; MoE: llama4-maverick, olmoe).

Layers are scanned (one superblock of ``moe_period`` sublayers per scan
step) with configurable remat, so HLO size and compile time stay flat in
depth and the activation footprint is one block deep.  Llama-4-style
dense/MoE interleaving is the ``moe_period=2`` case: the last sublayer of
each superblock is the MoE one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    blockwise_causal_attention,
    decode_attention,
    naive_causal_attention,
)
from .common import normal_init
from .layers import act_fn, apply_rope, cross_entropy_loss, rms_norm
from .moe import MoEConfig, moe_ffn

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    moe_period: int = 1
    causal: bool = True
    tie_embeddings: bool = False
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"  # full | none
    block_q: int = 512
    block_kv: int = 1024
    aux_loss_weight: float = 0.01
    logit_softcap: float = 0.0
    loss_chunk: int = 1024  # sequence chunking of the vocab projection
    attn_schedule: str = "triangular"  # or "full" (measured baseline)
    batch_axes: tuple = ()  # DP mesh axes for sharding constraints

    @property
    def n_super(self) -> int:
        assert self.n_layers % self.moe_period == 0
        return self.n_layers // self.moe_period

    def sublayer_is_moe(self, i: int) -> bool:
        return self.moe is not None and i == self.moe_period - 1


# ------------------------------------------------------------------ init
def init_params(rng, cfg: TransformerConfig) -> PyTree:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    n = cfg.n_super
    std = d ** -0.5
    keys = iter(jax.random.split(rng, 64))

    def w(shape, scale=std):
        return normal_init(next(keys), shape, scale, cfg.param_dtype)

    params: Dict[str, Any] = {
        # d^-0.5 keeps tied-embedding logits at unit variance
        "embed": w((cfg.vocab, d)),
        "ln_f": jnp.ones((d,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = w((d, cfg.vocab))
    for i in range(cfg.moe_period):
        sub: Dict[str, Any] = {
            "ln1": jnp.ones((n, d), cfg.param_dtype),
            "ln2": jnp.ones((n, d), cfg.param_dtype),
            "wq": w((n, d, h * hd)),
            "wk": w((n, d, kv * hd)),
            "wv": w((n, d, kv * hd)),
            "wo": w((n, h * hd, d)),
        }
        if cfg.sublayer_is_moe(i):
            m = cfg.moe
            sub["moe"] = {
                "wr": w((n, d, m.n_experts)),
                "wi": w((n, m.n_experts, d, m.d_ff)),
                "wo": w((n, m.n_experts, m.d_ff, d)),
            }
            if cfg.gated_mlp:
                sub["moe"]["wg"] = w((n, m.n_experts, d, m.d_ff))
            if m.n_shared:
                sub["moe"]["shared_wi"] = w((n, d, m.d_ff * m.n_shared))
                sub["moe"]["shared_wo"] = w((n, m.d_ff * m.n_shared, d))
                if cfg.gated_mlp:
                    sub["moe"]["shared_wg"] = w((n, d, m.d_ff * m.n_shared))
        else:
            sub["mlp"] = {
                "wi": w((n, d, f)),
                "wo": w((n, f, d)),
            }
            if cfg.gated_mlp:
                sub["mlp"]["wg"] = w((n, d, f))
        params[f"sub{i}"] = sub
    return params


def abstract_params(cfg: TransformerConfig) -> PyTree:
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )


# --------------------------------------------------------------- forward
def _attn(x, sp, cfg: TransformerConfig, positions):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, sp["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dk->bsk", x, sp["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dk->bsk", x, sp["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.causal:
        o = blockwise_causal_attention(
            q, k, v, block_q=cfg.block_q, block_kv=cfg.block_kv,
            schedule=cfg.attn_schedule, batch_axes=cfg.batch_axes,
        )
    else:
        # bidirectional (encoder): small-S archs use the direct path
        o = _full_bidir_attention(q, k, v)
    o = o.reshape(b, s, h * hd)
    return jnp.einsum("bsk,kd->bsd", o, sp["wo"])


def _full_bidir_attention(q, k, v):
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, hd).astype(jnp.float32)
    sc = jnp.einsum("bqkgd,bskd->bqgks", qg / jnp.sqrt(hd),
                    k.astype(jnp.float32))
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bqgks,bskd->bqgkd", p, v.astype(jnp.float32))
    return o.transpose(0, 1, 3, 2, 4).reshape(b, s, h, hd).astype(q.dtype)


def _mlp(x, mp, cfg: TransformerConfig):
    h = jnp.einsum("bsd,df->bsf", x, mp["wi"])
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, mp["wg"])
        h = act_fn(cfg.act)(g) * h
    else:
        h = act_fn(cfg.act)(h)
    return jnp.einsum("bsf,fd->bsd", h, mp["wo"])


def _superblock(x, blk, cfg: TransformerConfig, positions):
    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.moe_period):
        sp = blk[f"sub{i}"]
        x = x + _attn(rms_norm(x, sp["ln1"]), sp, cfg, positions)
        hnorm = rms_norm(x, sp["ln2"])
        if cfg.sublayer_is_moe(i):
            y, a = moe_ffn(sp["moe"], hnorm, cfg.moe)
            aux = aux + a
        else:
            y = _mlp(hnorm, sp["mlp"], cfg)
        x = x + y
    return x, aux


def forward(params, tokens, cfg: TransformerConfig):
    """tokens [B,S] -> hidden [B,S,D] (pre-head), aux loss."""
    b, s = tokens.shape
    from .attention import constrain_batch
    x = constrain_batch(
        params["embed"][tokens].astype(cfg.compute_dtype), cfg.batch_axes)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    stacked = {
        f"sub{i}": params[f"sub{i}"] for i in range(cfg.moe_period)
    }

    def block(carry, blk):
        x, aux = carry
        blk = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), blk)
        x, a = _superblock(x, blk, cfg, positions)
        # keep the residual stream batch-sharded through the scan carry
        from .attention import constrain_batch
        x = constrain_batch(x, cfg.batch_axes)
        return (x, aux + a), None

    block_fn = block
    if cfg.remat == "full":
        block_fn = jax.checkpoint(block)
    (x, aux), _ = jax.lax.scan(block_fn, (x, jnp.zeros((), jnp.float32)),
                               stacked)
    x = rms_norm(x, params["ln_f"].astype(cfg.compute_dtype))
    return x, aux


def logits_fn(params, hidden, cfg: TransformerConfig):
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = jnp.einsum(
        "bsd,dv->bsv", hidden, head.astype(cfg.compute_dtype)
    )
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def lm_loss(params, batch, cfg: TransformerConfig):
    """batch: {"tokens": [B,S], "targets": [B,S]}; next-token CE.

    The [B,S,V] logits tensor is never materialized: the vocab projection
    + CE run per sequence chunk inside a scan (151k-256k vocabs would
    otherwise dominate the activation footprint)."""
    hidden, aux = forward(params, batch["tokens"], cfg)
    b, s, d = hidden.shape
    ck = cfg.loss_chunk or s
    ck = min(ck, s)
    if s % ck:
        ck = s  # fallback: un-chunked
    nchunk = s // ck
    hc = hidden.reshape(b, nchunk, ck, d).transpose(1, 0, 2, 3)
    tc = batch["targets"].reshape(b, nchunk, ck).transpose(1, 0, 2)

    def chunk_nll(carry, xt):
        h, t = xt
        logits = logits_fn(params, h, cfg).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(chunk_nll, jnp.zeros((), jnp.float32),
                            (hc, tc))
    loss = total / (b * s)
    return loss + cfg.aux_loss_weight * aux


# ----------------------------------------------------------------- decode
def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> PyTree:
    dtype = dtype or cfg.compute_dtype
    kvs = {}
    for i in range(cfg.moe_period):
        kvs[f"sub{i}"] = {
            "k": jnp.zeros(
                (cfg.n_super, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                dtype,
            ),
            "v": jnp.zeros(
                (cfg.n_super, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                dtype,
            ),
        }
    return {"kv": kvs, "len": jnp.zeros((batch,), jnp.int32)}


def abstract_cache(cfg, batch, max_len, dtype=None):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """One autoregressive step: tokens [B,1] -> (logits [B,1,V], cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    pos = cache["len"]  # [B]
    positions = pos[:, None]

    stacked = {
        f"sub{i}": {
            "p": params[f"sub{i}"],
            "k": cache["kv"][f"sub{i}"]["k"],
            "v": cache["kv"][f"sub{i}"]["v"],
        }
        for i in range(cfg.moe_period)
    }

    def block(x, blk):
        new_kv = {}
        for i in range(cfg.moe_period):
            sp = jax.tree.map(
                lambda p: p.astype(cfg.compute_dtype), blk[f"sub{i}"]["p"]
            )
            kc, vc = blk[f"sub{i}"]["k"], blk[f"sub{i}"]["v"]
            h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            xin = rms_norm(x, sp["ln1"])
            q = jnp.einsum("bsd,dk->bsk", xin, sp["wq"]).reshape(
                b, 1, h, hd)
            k = jnp.einsum("bsd,dk->bsk", xin, sp["wk"]).reshape(
                b, 1, kv, hd)
            v = jnp.einsum("bsd,dk->bsk", xin, sp["wv"]).reshape(
                b, 1, kv, hd)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            # write the new KV at position len (same for all rows here)
            oh = (jnp.arange(kc.shape[1])[None, :] == pos[:, None]).astype(
                kc.dtype
            )  # [B,S]
            kc = kc * (1 - oh)[..., None, None] + oh[..., None, None] * k
            vc = vc * (1 - oh)[..., None, None] + oh[..., None, None] * v
            o = decode_attention(q, kc, vc, pos + 1)
            x = x + jnp.einsum(
                "bsk,kd->bsd", o.reshape(b, 1, h * hd), sp["wo"]
            )
            hnorm = rms_norm(x, sp["ln2"])
            if cfg.sublayer_is_moe(i):
                y, _ = moe_ffn(sp["moe"], hnorm, cfg.moe)
            else:
                y = _mlp(hnorm, sp["mlp"], cfg)
            x = x + y
            new_kv[f"sub{i}"] = {"k": kc, "v": vc}
        return x, new_kv

    x, new_kvs = jax.lax.scan(block, x, stacked)
    x = rms_norm(x, params["ln_f"].astype(cfg.compute_dtype))
    logits = logits_fn(params, x, cfg)
    return logits, {"kv": new_kvs, "len": cache["len"] + 1}
