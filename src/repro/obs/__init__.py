"""Observability: the always-on tracing + metrics + SLO layer.

Every layer of the system - mining wavefront slices, serving join
levels and the escalation ladder, streaming refresh/reconcile phases,
cluster routing rounds - reports through this package:

* ``metrics``  - ``MetricsRegistry``: typed counters / gauges /
                 histograms under dotted namespaces, with cheap
                 ``snapshot()`` / ``delta()`` / explicit-only
                 ``reset()``.  ``BucketHistogram`` adds fixed
                 log-scale-bucket latency percentiles (p50/p95/p99
                 quantile bounds, constant memory) - the always-on
                 store behind every ``*_seconds`` metric.  The old
                 ad-hoc ``stats`` dicts are ``StatsView`` facades over
                 a registry, so counters survive component rebuilds
                 and BENCH artifacts export a ``metrics`` block that
                 ``scripts/check_bench.py`` gates on.
* ``trace``    - the span tracer: ``trace.span("serving.trie_level",
                 cat="dispatch", level=k)`` regions bucketed into
                 host / dispatch / device / cache, per-query and
                 per-wavefront trace ids threaded through
                 ``ClusterRouter.route -> ClusterHost.call ->
                 PatternServer -> kernel dispatch`` by contextvar,
                 Chrome-trace JSON + JSONL export.  Disabled by
                 default with a property-tested no-op fast path; full
                 ``enable()`` fences device spans, and the production
                 mode ``enable_sampling(rate, latency_threshold=...)``
                 keeps a deterministic fraction of root trees plus
                 every tail-latency / ``mark()``-ed anomalous root,
                 never fencing - results stay bit-identical and
                 overhead inside the <= 5% budget.
* ``flight``   - ``FlightRecorder``: a ring buffer of the last N kept
                 query span-trees + prefix-scoped metric deltas,
                 dumped to JSONL on demand, on anomaly, or by the
                 watchdog on an SLO breach.
* ``export``   - ``prometheus_text()`` exposition of any registry +
                 the strict ``validate_exposition()`` grammar check
                 CI gates on, and ``MetricsExporter`` for periodic
                 JSONL snapshot shipping (injectable clock).
* ``slo``      - declarative ``SloRule``s (quantile / rate / gauge /
                 counter bounds) shared by the in-process
                 ``SloWatchdog`` (registry deltas, breach counter,
                 flight-recorder dumps) and the
                 ``trace_report --slo`` CI gate.

``scripts/trace_report.py`` renders a phase-attribution table (self
time per bucket, per subsystem, top spans), a percentile block from
the bucket histograms, and doubles as the CI tier-6 trace-schema +
SLO gate.
"""
from . import trace  # noqa: F401
from .export import (  # noqa: F401
    MetricsExporter,
    prometheus_text,
    validate_exposition,
)
from .flight import FlightRecorder  # noqa: F401
from .metrics import (  # noqa: F401
    BucketHistogram,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    StatsView,
    global_registry,
)
from .slo import (  # noqa: F401
    Breach,
    SloRule,
    SloWatchdog,
    evaluate,
    load_rules,
)
