"""Observability: the unified tracing + metrics layer.

Every layer of the system - mining wavefront slices, serving join
levels and the escalation ladder, streaming refresh/reconcile phases,
cluster routing rounds - reports through this package:

* ``metrics``  - ``MetricsRegistry``: typed counters / gauges /
                 histograms under dotted namespaces, with cheap
                 ``snapshot()`` / ``delta()`` / explicit-only
                 ``reset()``.  The old ad-hoc ``stats`` dicts are now
                 ``StatsView`` facades over a registry, so counters
                 survive component rebuilds (a streaming
                 ``refresh(full=True)`` recompile no longer zeroes its
                 server's counters) and BENCH artifacts export a
                 ``metrics`` block that ``scripts/check_bench.py``
                 gates on.
* ``trace``    - the span tracer: ``trace.span("serving.trie_level",
                 cat="dispatch", level=k)`` regions bucketed into
                 host / dispatch / device / cache, per-query and
                 per-wavefront trace ids threaded through
                 ``ClusterRouter.route -> ClusterHost.call ->
                 PatternServer -> kernel dispatch`` by contextvar,
                 Chrome-trace JSON + JSONL export.  Disabled by
                 default with a property-tested no-op fast path:
                 tracing on/off never changes results or device
                 dispatch counts.

``scripts/trace_report.py`` renders a phase-attribution table (self
time per bucket, per subsystem, top spans) from a saved trace and
doubles as the CI tier-6 trace-schema gate.
"""
from . import trace  # noqa: F401
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    StatsView,
    global_registry,
)
