"""Metrics export: Prometheus text exposition + periodic JSONL ship.

The registry's ``snapshot()`` is the in-repo currency (BENCH metrics
blocks, deltas); this module is the edge where those numbers leave the
process:

* ``prometheus_text(registry)`` renders any registry in the Prometheus
  text exposition format (version 0.0.4): dotted names sanitized to
  underscores, ``Counter`` -> ``counter`` with the ``_total`` suffix,
  ``Gauge`` -> ``gauge``, plain ``Histogram`` -> ``summary``
  (``_sum``/``_count``), ``BucketHistogram`` -> ``histogram`` with
  cumulative ``_bucket{le="..."}`` lines up to ``+Inf``.
* ``validate_exposition(text)`` is the strict grammar check tier-6
  gates on: TYPE-before-samples, legal metric names, parseable values,
  cumulative non-decreasing histogram buckets terminated by ``+Inf``
  whose count equals ``_count``.  Returns a list of problems (empty =
  valid) so CI can print every violation, not just the first.
* ``MetricsExporter`` ships periodic JSONL snapshots
  (``{"t": ..., "metrics": {...}}`` per line, append-mode) against an
  injectable clock - ``maybe_ship()`` is safe to call from any hot-ish
  path (one float compare when the interval has not elapsed).
"""
from __future__ import annotations

import json
import re
import time
from typing import Dict, List, Optional, Tuple

from .metrics import (
    BucketHistogram,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"          # metric name
    r"(?:\{([^}]*)\})?"                     # optional labels
    r" (-?(?:[0-9.eE+-]+|Inf|NaN))$"        # value
)
_LABEL_RE = re.compile(r'^([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"$')


def _sanitize(name: str) -> str:
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not out or not _NAME_RE.match(out):
        out = "_" + out
    return out


def _fmt(v) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


def prometheus_text(registry: MetricsRegistry,
                    prefix: str = "") -> str:
    """Render every metric under ``prefix`` as Prometheus text
    exposition (0.0.4).  Deterministic: families sorted by name."""
    lines: List[str] = []
    for name, m in sorted(registry._metrics.items()):
        if prefix and not name.startswith(prefix):
            continue
        base = _sanitize(name)
        if isinstance(m, Counter):
            fam = base + "_total"
            lines.append(f"# TYPE {fam} counter")
            lines.append(f"{fam} {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {base} gauge")
            lines.append(f"{base} {_fmt(m.value)}")
        elif isinstance(m, BucketHistogram):
            lines.append(f"# TYPE {base} histogram")
            cum = 0
            for bound, c in zip(m.BOUNDS, m.counts):
                cum += c
                lines.append(
                    f'{base}_bucket{{le="{_fmt(bound)}"}} {cum}'
                )
            lines.append(f'{base}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{base}_sum {_fmt(m.sum)}")
            lines.append(f"{base}_count {m.count}")
        elif isinstance(m, Histogram):
            lines.append(f"# TYPE {base} summary")
            lines.append(f"{base}_sum {_fmt(m.sum)}")
            lines.append(f"{base}_count {m.count}")
    return "\n".join(lines) + "\n" if lines else ""


def validate_exposition(text: str) -> List[str]:
    """Strict structural validation of a text exposition.  Returns all
    problems found ([] = valid)."""
    problems: List[str] = []
    typed: Dict[str, str] = {}
    # histogram family -> list of (le, value) in order, _sum/_count seen
    hist: Dict[str, Dict] = {}
    seen_samples: Dict[str, bool] = {}

    def family_of(name: str) -> Tuple[str, str]:
        for suf in ("_bucket", "_sum", "_count", "_total"):
            if name.endswith(suf):
                return name[: -len(suf)], suf
        return name, ""

    for i, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 2 and parts[1] == "TYPE":
                if len(parts) != 4:
                    problems.append(f"line {i}: malformed TYPE line")
                    continue
                _, _, name, mtype = parts
                if mtype not in ("counter", "gauge", "histogram",
                                 "summary", "untyped"):
                    problems.append(
                        f"line {i}: unknown metric type {mtype!r}")
                if name in typed:
                    problems.append(
                        f"line {i}: duplicate TYPE for {name!r}")
                if seen_samples.get(name):
                    problems.append(
                        f"line {i}: TYPE for {name!r} after samples")
                typed[name] = mtype
                if mtype == "histogram":
                    hist[name] = {"buckets": [], "sum": None,
                                  "count": None}
            elif len(parts) >= 2 and parts[1] == "HELP":
                pass
            else:
                problems.append(f"line {i}: malformed comment line")
            continue
        mt = _SAMPLE_RE.match(line)
        if not mt:
            problems.append(f"line {i}: unparseable sample: {line!r}")
            continue
        name, labels, value = mt.group(1), mt.group(2), mt.group(3)
        try:
            val = float(value.replace("Inf", "inf"))
        except ValueError:
            problems.append(f"line {i}: bad value {value!r}")
            continue
        le = None
        if labels:
            for pair in labels.split(","):
                lm = _LABEL_RE.match(pair)
                if not lm:
                    problems.append(
                        f"line {i}: malformed label {pair!r}")
                elif lm.group(1) == "le":
                    le = lm.group(2)
        fam, suffix = family_of(name)
        # a sample must belong to a declared family (strict mode)
        owner = None
        for cand in (name, fam):
            if cand in typed:
                owner = cand
                break
        if owner is None:
            problems.append(
                f"line {i}: sample {name!r} has no TYPE declaration")
            continue
        seen_samples[owner] = True
        mtype = typed[owner]
        if mtype == "counter":
            if not name.endswith("_total"):
                problems.append(
                    f"line {i}: counter sample {name!r} must end in"
                    " _total")
            if val < 0:
                problems.append(
                    f"line {i}: counter {name!r} is negative")
        if mtype == "histogram" and owner == fam:
            h = hist.setdefault(fam, {"buckets": [], "sum": None,
                                      "count": None})
            if suffix == "_bucket":
                if le is None:
                    problems.append(
                        f"line {i}: histogram bucket without le label")
                else:
                    h["buckets"].append((i, le, val))
            elif suffix == "_sum":
                h["sum"] = val
            elif suffix == "_count":
                h["count"] = val

    for fam, h in hist.items():
        buckets = h["buckets"]
        if not buckets:
            problems.append(f"histogram {fam!r}: no buckets")
            continue
        if buckets[-1][1] != "+Inf":
            problems.append(
                f"histogram {fam!r}: last bucket must be le=\"+Inf\"")
        prev = -1.0
        for i, le, val in buckets:
            if val < prev:
                problems.append(
                    f"line {i}: histogram {fam!r} buckets not"
                    " cumulative (le={le})")
            prev = val
        if h["count"] is None:
            problems.append(f"histogram {fam!r}: missing _count")
        elif buckets[-1][1] == "+Inf" and buckets[-1][2] != h["count"]:
            problems.append(
                f"histogram {fam!r}: +Inf bucket != _count")
        if h["sum"] is None:
            problems.append(f"histogram {fam!r}: missing _sum")
    return problems


class MetricsExporter:
    """Periodic JSONL snapshot shipper.  ``maybe_ship()`` is the
    always-on call site hook: one clock read + compare until the
    interval elapses, then one snapshot appended to ``path``."""

    def __init__(self, registry: MetricsRegistry, path: str,
                 interval: float = 10.0, *,
                 prefix: str = "", clock=None):
        self.registry = registry
        self.path = path
        self.interval = interval
        self.prefix = prefix
        self.clock = time.monotonic if clock is None else clock
        self.ships = 0
        self._last: Optional[float] = None

    def ship(self) -> Dict[str, float]:
        """Append one snapshot line now; returns the snapshot."""
        snap = self.registry.snapshot(self.prefix)
        with open(self.path, "a") as f:
            f.write(json.dumps({"t": self.clock(),
                                "metrics": snap}) + "\n")
        self.ships += 1
        self._last = self.clock()
        return snap

    def maybe_ship(self) -> bool:
        """Ship if the interval elapsed since the last ship (the first
        call ships immediately).  Returns whether it shipped."""
        now = self.clock()
        if self._last is not None and now - self._last < self.interval:
            return False
        self.ship()
        return True
