"""FlightRecorder: a ring buffer of the last N completed query traces.

Production incidents are diagnosed after the fact: by the time a p99
alarm fires, the interesting queries are gone.  The flight recorder
keeps them - every trace the sampler keeps (sampled tree, tail breach,
``mark()``-ed anomaly) lands here as a completed *entry*: the root
name, duration, kind, the span tree, and the registry metric movement
since the previous entry (prefix-scoped, nonzero keys only, so an
entry costs one small snapshot + diff - cheap enough for always-on).

``dump(path)`` writes the buffer as JSONL - one header line (reason,
capacity, entry count, dropped total) then one entry per line, oldest
first - either on demand (an operator asking "what just happened") or
automatically: the ``SloWatchdog`` calls ``dump`` when a rule
breaches, and ``autodump_path`` dumps on the first anomalous entry.

Deterministic by construction: entries carry only what callers pass
plus the injectable ``clock`` reading, so tests drive it with a fake
clock and assert byte-identical dumps.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry


class FlightRecorder:
    """Bounded deque of kept-trace entries + metric deltas.

    ``metrics``/``metrics_prefix`` scope the per-entry delta snapshot
    (e.g. ``"cluster.router"``) - pass a narrow prefix in production;
    an unscoped snapshot of a big registry would eat the overhead
    budget.  ``clock`` defaults to ``time.monotonic`` and is
    injectable for deterministic tests.
    """

    def __init__(self, capacity: int = 64, *,
                 metrics: Optional[MetricsRegistry] = None,
                 metrics_prefix: str = "",
                 clock=None,
                 autodump_path: Optional[str] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.entries: deque = deque(maxlen=capacity)
        self.metrics = metrics
        self.metrics_prefix = metrics_prefix
        self.clock = time.monotonic if clock is None else clock
        self.autodump_path = autodump_path
        self.total = 0       # entries ever recorded (dropped = total - len)
        self.dumps = 0
        self._prev_snap: Dict[str, float] = {}
        if metrics is not None:
            self._prev_snap = metrics.snapshot(metrics_prefix)

    # ------------------------------------------------------- recording
    def record(self, name: str, dur_s: float,
               spans: List[Dict[str, Any]], *,
               anomaly: Optional[str] = None,
               kind: str = "sampled",
               trace: Optional[int] = None) -> None:
        entry: Dict[str, Any] = {
            "t": self.clock(),
            "name": name,
            "dur_s": dur_s,
            "kind": kind,
            "trace": trace,
            "spans": list(spans),
        }
        if anomaly:
            entry["anomaly"] = anomaly
        if self.metrics is not None:
            snap = self.metrics.snapshot(self.metrics_prefix)
            delta = {k: v - self._prev_snap.get(k, 0)
                     for k, v in snap.items()
                     if v != self._prev_snap.get(k, 0)}
            self._prev_snap = snap
            entry["metric_delta"] = delta
        self.entries.append(entry)
        self.total += 1
        if anomaly and self.autodump_path:
            self.dump(self.autodump_path, reason=f"anomaly:{anomaly}")

    # --------------------------------------------------------- export
    def dump(self, path: str, reason: str = "manual") -> int:
        """Write the buffer as JSONL (header line + one entry per
        line, oldest first).  Returns the number of entries written."""
        entries = list(self.entries)
        header = {
            "flight_recorder": True,
            "reason": reason,
            "capacity": self.capacity,
            "entries": len(entries),
            "total_recorded": self.total,
            "dropped": self.total - len(entries),
        }
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for e in entries:
                f.write(json.dumps(e) + "\n")
        self.dumps += 1
        return len(entries)

    def clear(self) -> None:
        self.entries.clear()
        self.total = 0
        if self.metrics is not None:
            self._prev_snap = self.metrics.snapshot(self.metrics_prefix)
