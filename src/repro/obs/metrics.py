"""MetricsRegistry: the one namespaced home for every runtime counter.

Before this module each subsystem grew its own ad-hoc ``stats`` dict
(``PatternServer.stats``, ``ClusterRouter.stats``,
``StreamingBank.stats``) or bare attributes (the wavefront miner's
``n_device_calls`` / ``device_seconds``), with no shared snapshot,
delta, or reset story - and inconsistent survival across recompiles
(a ``refresh(full=True)`` rebuilt some components and silently zeroed
their counters while others accumulated).  The registry fixes both:

* **Typed metrics** - ``Counter`` (monotone int/float adds),
  ``Gauge`` (last-set value), ``Histogram`` (count/sum/min/max
  aggregate, constant memory), ``BucketHistogram`` (fixed log-scale
  buckets with exact quantile-*bound* queries - the always-on latency
  percentile store, still constant memory) - all keyed by dotted
  namespaced names (``"serving.server.joined_steps"``,
  ``"cluster.router.e2e_seconds"``).
* **Snapshot / delta / reset** - ``snapshot()`` is a cheap flat
  ``{name: number}`` dict (histograms expand to ``name.count`` etc.),
  ``delta(before)`` subtracts two snapshots, ``reset(prefix)`` zeroes.
  These feed the BENCH ``metrics`` blocks that ``check_bench.py``
  gates on.
* **One reset semantics** - metrics live in the *registry*, not in the
  component.  A component that is rebuilt (a streaming
  ``refresh(full=True)`` recompiling its ``PatternServer``, the
  sharded-window protocol re-planning its router) re-attaches to the
  same registry and its counters *accumulate*; the only way to zero a
  metric is an explicit ``reset()``.  Components own a registry by
  default and accept one (``metrics=``) to opt into a longer-lived
  scope.
* **StatsView** - a ``MutableMapping`` facade over one namespace so the
  existing ``self.stats["joined_steps"] += n`` call sites (and every
  test reading ``server.stats[...]``) keep working verbatim while the
  storage moves into the registry.

The registry is pure host-side Python bookkeeping: it never touches
jax, adds zero device dispatches, and is cheap enough to stay on in
production (a few dict/int ops per already-expensive device batch).
"""
from __future__ import annotations

import bisect
import warnings
from collections.abc import MutableMapping
from typing import Dict, Iterable, Iterator, List, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotone additive metric (int or float).  ``inc`` only - a
    counter that needs to go down is a ``Gauge``."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def set(self, v: Number) -> None:
        """Assignment is NOT a counter operation: counters are monotone
        (rates, deltas and the Prometheus exposition all assume it).
        Setting any non-zero value raises; setting 0 still works (it is
        a reset) but warns - route resets through
        ``MetricsRegistry.reset(prefix)``, the one sanctioned zeroing
        path."""
        if v != 0:
            raise ValueError(
                f"counter {self.name!r}: direct assignment of {v!r} "
                "breaks monotonicity - use inc(), or a Gauge for a "
                "value that moves both ways"
            )
        warnings.warn(
            f"counter {self.name!r}: reset-by-assignment is deprecated"
            " - use MetricsRegistry.reset(prefix) instead",
            stacklevel=3,
        )
        self.value = 0

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written value (queue depths, live fractions, knobs)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Constant-memory aggregate of an observed distribution:
    count / sum / min / max (enough for mean + extremes in reports
    without storing samples)."""

    __slots__ = ("name", "count", "sum", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.reset()

    def observe(self, v: Number) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        self.count = 0
        self.sum = 0
        self.min = float("inf")
        self.max = float("-inf")

    def summary(self) -> Dict[str, Number]:
        out = {"count": self.count, "sum": self.sum}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            out["mean"] = self.sum / self.count
        return out


def _log_bounds(lo: float, hi: float, per_decade: int) -> List[float]:
    import math

    n = int(round(math.log10(hi / lo) * per_decade))
    return [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]


class BucketHistogram(Histogram):
    """Fixed log-scale-bucket histogram: the always-on latency
    percentile store.  Memory is constant (one int per bucket) and
    ``observe`` is one ``bisect`` + three compares, so it can sit on
    the per-query hot path.

    ``quantile(q)`` returns an exact *bound*: the upper edge of the
    bucket containing the q-th observation (the true value is within
    one bucket width, ~33% at 8 buckets/decade; for the overflow
    bucket the tracked exact ``max`` is returned).  ``summary()`` adds
    ``p50``/``p95``/``p99`` to the base count/sum/min/max/mean, so
    registry ``snapshot()`` expands it into the BENCH metrics blocks
    with no registry changes."""

    # 1 µs .. 100 s at 8 buckets per decade: 64 finite buckets + one
    # overflow - covers every latency this repo measures (a device
    # dispatch is ~100 µs, a full cluster drain tens of ms).
    BOUNDS: List[float] = _log_bounds(1e-6, 1e2, 8)

    __slots__ = ("counts",)

    def observe(self, v: Number) -> None:
        super().observe(v)
        self.counts[bisect.bisect_left(self.BOUNDS, v)] += 1

    def reset(self) -> None:
        super().reset()
        self.counts = [0] * (len(self.BOUNDS) + 1)

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-th observation
        (0 <= q <= 1); 0.0 when empty, exact max for overflow."""
        if not self.count:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i < len(self.BOUNDS):
                    return self.BOUNDS[i]
                return self.max
        return self.max

    def summary(self) -> Dict[str, Number]:
        out = super().summary()
        if self.count:
            out["p50"] = self.quantile(0.50)
            out["p95"] = self.quantile(0.95)
            out["p99"] = self.quantile(0.99)
        return out


class MetricsRegistry:
    """A flat namespace of typed metrics.  Name collisions within one
    registry return the *same* metric object (that is what makes
    counters survive component rebuilds: the new component re-attaches
    by name), but a name registered as one type cannot be re-registered
    as another."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}

    def _get(self, name: str, cls):
        got = self._metrics.get(name)
        if got is None:
            got = self._metrics[name] = cls(name)
        elif not isinstance(got, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(got).__name__}, not {cls.__name__}"
            )
        return got

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def bucket_histogram(self, name: str) -> BucketHistogram:
        return self._get(name, BucketHistogram)

    def view(self, namespace: str,
             keys: Iterable[str] = ()) -> "StatsView":
        """A dict-like facade over ``{namespace}.{key}`` counters -
        the drop-in replacement for the old ad-hoc ``stats`` dicts."""
        return StatsView(self, namespace, keys)

    # ---------------------------------------------------------- export
    def snapshot(self, prefix: str = "") -> Dict[str, Number]:
        """Flat ``{name: value}`` dict of every metric under
        ``prefix`` (histograms expand to ``name.count`` / ``.sum`` /
        ``.min`` / ``.max`` / ``.mean``).  JSON-ready: this is the
        BENCH artifacts' ``metrics`` block."""
        out: Dict[str, Number] = {}
        for name, m in sorted(self._metrics.items()):
            if prefix and not name.startswith(prefix):
                continue
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        return out

    def delta(self, before: Dict[str, Number],
              prefix: str = "") -> Dict[str, Number]:
        """``snapshot() - before``, per key (keys absent from
        ``before`` count from 0) - per-phase attribution without
        resetting anything."""
        now = self.snapshot(prefix)
        return {k: v - before.get(k, 0) for k, v in now.items()}

    def reset(self, prefix: str = "") -> None:
        """THE reset semantics: metrics zero here and nowhere else.
        Component rebuilds (recompiles, re-plans) must re-attach, never
        zero."""
        for name, m in self._metrics.items():
            if not prefix or name.startswith(prefix):
                m.reset()


class StatsView(MutableMapping):
    """Mutable-mapping facade over one registry namespace: the
    component keeps writing ``stats["key"] += n`` and tests keep
    reading ``stats["key"]``, while the values live in (and persist
    with) the registry's ``Counter``s.  Declared ``keys`` pre-register
    so iteration shows zeros; assigning an unknown key registers it."""

    __slots__ = ("_registry", "_ns", "_keys")

    def __init__(self, registry: MetricsRegistry, namespace: str,
                 keys: Iterable[str] = ()):
        self._registry = registry
        self._ns = namespace
        self._keys = list(dict.fromkeys(keys))
        for k in self._keys:
            registry.counter(f"{namespace}.{k}")

    def _full(self, key: str) -> str:
        return f"{self._ns}.{key}"

    def __getitem__(self, key: str) -> Number:
        if key not in self._keys:
            raise KeyError(key)
        return self._registry.counter(self._full(key)).value

    def __setitem__(self, key: str, value: Number) -> None:
        if key not in self._keys:
            self._keys.append(key)
        c = self._registry.counter(self._full(key))
        # ``stats[k] += n`` arrives here as setitem(k, old + n): apply
        # the non-negative delta as an inc.  A decrease is either the
        # deprecated reset-to-0 idiom (Counter.set warns) or a
        # monotonicity violation (Counter.set raises).
        if value >= c.value:
            c.inc(value - c.value)
        else:
            c.set(value)

    def __delitem__(self, key: str) -> None:  # pragma: no cover
        raise TypeError("registry-backed stats cannot drop keys")

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __repr__(self) -> str:
        return f"StatsView({self._ns}, {dict(self)})"


_global: Optional[MetricsRegistry] = None


def global_registry() -> MetricsRegistry:
    """The process-wide registry - for code with no natural owner
    (launch scripts, ad-hoc probes).  Components default to a private
    registry instead, so unrelated instances never share counters."""
    global _global
    if _global is None:
        _global = MetricsRegistry()
    return _global
