"""Declarative SLOs + the watchdog that enforces them in-process.

A rule is data, not code, so the same JSON file drives three
consumers: the ``SloWatchdog`` riding the admission pipeline, the
``scripts/trace_report.py --slo`` CI gate reading a BENCH metrics
block, and an operator eyeballing the file.  Rule kinds map onto what
the registry snapshot exposes:

* ``quantile`` - a bucket-histogram percentile bound:
  ``{"kind": "quantile", "metric": "cluster.router.e2e_seconds",
  "q": 0.99, "max": 0.5}`` fails when the snapshot's ``...p99``
  exceeds ``max``.
* ``rate`` - a counter-over-counter ratio bound (evaluated on deltas
  by the watchdog, on absolutes by the report):
  ``{"kind": "rate", "metric": "cluster.router.shed_prescreen",
  "den": "cluster.router.queries", "max": 0.05}``.
* ``gauge`` - an instantaneous bound on a gauge
  (``cluster.router.queue_depth``, the queue/ticket age gauges).
* ``counter`` - a bound on a counter's movement since the last check
  (watchdog) or its absolute value (report) - e.g. "no more than 0
  shed answers, ever".

``SloWatchdog.check()`` evaluates every rule against the registry,
increments ``cluster.router.slo_breaches`` per breaching rule, and -
wired to a ``FlightRecorder`` - dumps the ring buffer so the traces
*leading up to* the breach are preserved.  ``maybe_check()`` is the
hot-path hook: one clock compare until ``min_interval`` elapses.  The
clock is injectable, so tests fire the watchdog deterministically.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from .flight import FlightRecorder
from .metrics import MetricsRegistry, Number

KINDS = ("quantile", "rate", "gauge", "counter")


@dataclass
class SloRule:
    name: str
    kind: str           # one of KINDS
    metric: str         # registry metric name (histogram base for quantile)
    max: float          # the bound (inclusive: value > max breaches)
    q: float = 0.99     # quantile rules only
    den: str = ""       # rate rules: denominator counter

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"rule {self.name!r}: unknown kind"
                             f" {self.kind!r} (want one of {KINDS})")
        if self.kind == "rate" and not self.den:
            raise ValueError(f"rule {self.name!r}: rate needs 'den'")


@dataclass
class Breach:
    rule: str
    metric: str
    value: float
    bound: float

    def __str__(self) -> str:
        return (f"SLO breach [{self.rule}]: {self.metric}"
                f" = {self.value:.6g} > {self.bound:.6g}")


def load_rules(path: str) -> List[SloRule]:
    """Load rules from JSON: either a list of rule objects or
    ``{"rules": [...]}``."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        data = data["rules"]
    return [SloRule(**r) for r in data]


def _quantile_key(rule: SloRule) -> str:
    return f"{rule.metric}.p{int(round(rule.q * 100))}"


def evaluate(rules: List[SloRule], snap: Dict[str, Number],
             prev: Optional[Dict[str, Number]] = None) -> List[Breach]:
    """Evaluate rules against a flat metrics snapshot.  With ``prev``,
    rate/counter rules look at movement since ``prev`` (the watchdog
    mode); without, at absolute values (the report / CI gate mode).
    Quantile and gauge rules always read the current snapshot - the
    bucket histograms are already time-windowed by reset semantics."""
    breaches: List[Breach] = []
    for rule in rules:
        if rule.kind == "quantile":
            val = snap.get(_quantile_key(rule))
            if val is None:
                continue  # histogram empty / absent: nothing to bound
        elif rule.kind == "gauge":
            val = snap.get(rule.metric)
            if val is None:
                continue
        elif rule.kind == "counter":
            cur = snap.get(rule.metric, 0)
            val = cur - prev.get(rule.metric, 0) if prev is not None \
                else cur
        else:  # rate
            num = snap.get(rule.metric, 0)
            den = snap.get(rule.den, 0)
            if prev is not None:
                num -= prev.get(rule.metric, 0)
                den -= prev.get(rule.den, 0)
            if den <= 0:
                continue  # no traffic in the window: no verdict
            val = num / den
        if val > rule.max:
            breaches.append(Breach(rule.name, rule.metric,
                                   float(val), rule.max))
    return breaches


class SloWatchdog:
    """Evaluates rules against registry deltas on a rate-limited
    clock, counts breaches, and triggers flight-recorder dumps.

    Designed to ride ``ClusterRouter._note_depth`` (already called on
    every submit/poll/collect): ``maybe_check()`` costs one clock read
    + compare until ``min_interval`` elapses.
    """

    def __init__(self, registry: MetricsRegistry,
                 rules: List[SloRule], *,
                 clock=None,
                 min_interval: float = 1.0,
                 flight: Optional[FlightRecorder] = None,
                 dump_path: Optional[str] = None,
                 breach_counter: str = "cluster.router.slo_breaches"):
        self.registry = registry
        self.rules = list(rules)
        self.clock = time.monotonic if clock is None else clock
        self.min_interval = min_interval
        self.flight = flight
        self.dump_path = dump_path
        self._breaches = registry.counter(breach_counter)
        self.last_breaches: List[Breach] = []
        self.checks = 0
        self._last_t: Optional[float] = None
        self._prev_snap: Dict[str, Number] = registry.snapshot()

    def check(self) -> List[Breach]:
        """Evaluate all rules now.  Returns (and stores) the breaches;
        increments the breach counter per breaching rule and dumps the
        flight recorder on any breach."""
        snap = self.registry.snapshot()
        breaches = evaluate(self.rules, snap, prev=self._prev_snap)
        self._prev_snap = snap
        self.checks += 1
        self._last_t = self.clock()
        self.last_breaches = breaches
        if breaches:
            self._breaches.inc(len(breaches))
            if self.flight is not None and self.dump_path:
                self.flight.dump(
                    self.dump_path,
                    reason="slo:" + ",".join(b.rule for b in breaches),
                )
        return breaches

    def maybe_check(self) -> Optional[List[Breach]]:
        """Rate-limited ``check()``: runs only if ``min_interval``
        elapsed since the last one (first call checks immediately).
        Returns None when skipped."""
        now = self.clock()
        if self._last_t is not None and \
                now - self._last_t < self.min_interval:
            return None
        return self.check()
