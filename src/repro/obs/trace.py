"""Span tracer: phase-attributed wall time across mine/serve/stream/
cluster, exported as Chrome-trace JSON or JSONL.

The repo's performance questions ("where did the H4 cluster qps go?")
need wall time *attributed*: how much of a routed drain was host
bookkeeping vs kernel launch vs actual device execution vs cache
lookups.  This module is that substrate:

* ``span(name, cat=..., **args)`` - a context manager recording one
  timed region.  ``cat`` is the attribution bucket (``"host"``,
  ``"dispatch"``, ``"device"``, ``"cache"``); ``scripts/trace_report.py``
  sums *self time* (duration minus nested child spans) per bucket, so
  nesting never double-counts.
* ``root_or_span(name, **args)`` - public entry points
  (``ClusterRouter.route``, ``PatternServer.query``,
  ``StreamingBank.observe/refresh``, ``AcceleratedMiner.mine_rs``)
  open a *root* span (``cat="wall"``) carrying a fresh trace id when no
  trace is active, and a plain nested span when one is - so a routed
  query owns one trace id that threads through
  ``ClusterRouter.route -> ClusterHost.call -> PatternServer ->
  kernel dispatch`` via a contextvar, with zero plumbing in signatures.
* ``add_complete(name, cat, start, duration)`` - record an
  already-measured interval (the miner times dispatch vs
  ``block_until_ready()`` with its own ``perf_counter`` pairs; the
  tracer must not perturb that measurement).

**Disabled is the default and the fast path**: ``span()`` returns a
shared no-op context manager, nothing is recorded, no clocks are read,
and - property-tested in tests/test_obs.py - results and device
dispatch counts are bit-identical with tracing on, off, or absent.
Tracing only ever *observes*: the one behavioural difference when
enabled is extra ``block_until_ready()`` fences inside device spans
(needed to split launch from execution time; they change timing, never
results or dispatch counts).

Export: ``save(path)`` writes Chrome ``traceEvents`` JSON for ``.json``
paths (load in ``chrome://tracing`` / Perfetto) and one-span-per-line
JSONL otherwise; ``scripts/trace_report.py`` reads both.
"""
from __future__ import annotations

import contextvars
import json
import time
from typing import Any, Dict, List, Optional

# attribution buckets trace_report.py understands; "wall" is reserved
# for root spans (their duration IS the denominator of the report)
CATEGORIES = ("host", "dispatch", "device", "cache", "wall")

_current_trace: contextvars.ContextVar[Optional[int]] = \
    contextvars.ContextVar("repro_obs_trace", default=None)


class _NoopSpan:
    """The disabled-tracing fast path: one shared, stateless context
    manager."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_token")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any], new_trace: bool):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        # a root span installs a fresh trace id for everything nested
        self._token = (
            _current_trace.set(tracer._next_trace_id())
            if new_trace else None
        )
        self._t0 = time.perf_counter()

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tracer._record(
            self.name, self.cat, self._t0, t1 - self._t0, self.args
        )
        if self._token is not None:
            _current_trace.reset(self._token)
        return False


class Tracer:
    """Event buffer + clock base.  One module-level instance
    (``tracer``) serves the whole process; everything here is plain
    host Python."""

    # runaway guard: a forgotten enabled tracer must not eat the heap
    MAX_EVENTS = 2_000_000

    def __init__(self) -> None:
        self.enabled = False
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self._t_base = time.perf_counter()
        self._trace_seq = 0

    # ------------------------------------------------------- lifecycle
    def enable(self) -> None:
        self.enabled = True
        if not self.events:
            self._t_base = time.perf_counter()

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self.events = []
        self.dropped = 0
        self._trace_seq = 0
        self._t_base = time.perf_counter()

    def _next_trace_id(self) -> int:
        self._trace_seq += 1
        return self._trace_seq

    # ------------------------------------------------------- recording
    def _record(self, name: str, cat: str, t0: float, dur: float,
                args: Dict[str, Any]) -> None:
        if len(self.events) >= self.MAX_EVENTS:
            self.dropped += 1
            return
        ev = {
            "name": name,
            "cat": cat,
            # Chrome-trace convention: microseconds
            "ts": (t0 - self._t_base) * 1e6,
            "dur": dur * 1e6,
            "trace": _current_trace.get(),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def add_complete(self, name: str, cat: str, start: float,
                     duration: float, **args: Any) -> None:
        """Record an interval measured by the caller (``start`` is a
        ``time.perf_counter()`` value, so it nests consistently with
        context-manager spans)."""
        if self.enabled:
            self._record(name, cat, start, duration, args)

    # --------------------------------------------------------- export
    def chrome_events(self) -> List[Dict[str, Any]]:
        out = []
        for ev in self.events:
            args = dict(ev.get("args", {}))
            if ev["trace"] is not None:
                args["trace"] = ev["trace"]
            out.append({
                "name": ev["name"], "cat": ev["cat"], "ph": "X",
                "ts": ev["ts"], "dur": ev["dur"],
                "pid": 0, "tid": 0, "args": args,
            })
        return out

    def save(self, path: str) -> None:
        """Chrome ``traceEvents`` JSON for ``.json`` paths, JSONL (one
        span object per line) otherwise."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump({"traceEvents": self.chrome_events(),
                           "displayTimeUnit": "ms"}, f)
        else:
            with open(path, "w") as f:
                for ev in self.events:
                    f.write(json.dumps(ev) + "\n")


tracer = Tracer()


def enabled() -> bool:
    return tracer.enabled


def enable() -> None:
    tracer.enable()


def disable() -> None:
    tracer.disable()


def clear() -> None:
    tracer.clear()


def save(path: str) -> None:
    tracer.save(path)


def current_trace() -> Optional[int]:
    """The active trace id (None outside any root span)."""
    return _current_trace.get()


def span(name: str, cat: str = "host", **args: Any):
    """A timed region attributed to bucket ``cat``.  No-op (shared
    singleton, no clock read) while tracing is disabled."""
    if not tracer.enabled:
        return _NOOP
    return _Span(tracer, name, cat, args, new_trace=False)


def root_or_span(name: str, **args: Any):
    """Entry-point span: opens a new trace (``cat="wall"``) when none
    is active - per-query / per-wavefront trace ids are minted here -
    and nests as a plain host span inside an existing trace (a routed
    query reaching ``PatternServer.query`` stays in the route's
    trace)."""
    if not tracer.enabled:
        return _NOOP
    if _current_trace.get() is None:
        return _Span(tracer, name, "wall", args, new_trace=True)
    return _Span(tracer, name, "host", args, new_trace=False)


def add_complete(name: str, cat: str, start: float, duration: float,
                 **args: Any) -> None:
    tracer.add_complete(name, cat, start, duration, **args)
