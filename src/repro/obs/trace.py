"""Span tracer: phase-attributed wall time across mine/serve/stream/
cluster, exported as Chrome-trace JSON or JSONL.

The repo's performance questions ("where did the H4 cluster qps go?")
need wall time *attributed*: how much of a routed drain was host
bookkeeping vs kernel launch vs actual device execution vs cache
lookups.  This module is that substrate:

* ``span(name, cat=..., **args)`` - a context manager recording one
  timed region.  ``cat`` is the attribution bucket (``"host"``,
  ``"dispatch"``, ``"device"``, ``"cache"``); ``scripts/trace_report.py``
  sums *self time* (duration minus nested child spans) per bucket, so
  nesting never double-counts.
* ``root_or_span(name, **args)`` - public entry points
  (``ClusterRouter.route``, ``PatternServer.query``,
  ``StreamingBank.observe/refresh``, ``AcceleratedMiner.mine_rs``)
  open a *root* span (``cat="wall"``) carrying a fresh trace id when no
  trace is active, and a plain nested span when one is - so a routed
  query owns one trace id that threads through
  ``ClusterRouter.route -> ClusterHost.call -> PatternServer ->
  kernel dispatch`` via a contextvar, with zero plumbing in signatures.
* ``add_complete(name, cat, start, duration)`` - record an
  already-measured interval (the miner times dispatch vs
  ``block_until_ready()`` with its own ``perf_counter`` pairs; the
  tracer must not perturb that measurement).

**Disabled is the default and the fast path**: ``span()`` returns a
shared no-op context manager, nothing is recorded, no clocks are read,
and - property-tested in tests/test_obs.py - results and device
dispatch counts are bit-identical with tracing on, off, or absent.
Tracing only ever *observes*: the one behavioural difference when
fully enabled is extra ``block_until_ready()`` fences inside device
spans (needed to split launch from execution time; they change timing,
never results or dispatch counts).

**Sampled mode** (``enable_sampling(rate, ...)``) is the always-on
production middle ground.  A deterministic systematic sampler (an
accumulator, no RNG - reproducible run to run) keeps roughly
``rate`` of root spans with their full child trees; the rest become
*tail* roots: two clock reads and nothing recorded, unless the query
breaches ``latency_threshold`` or a layer flagged it anomalous via
``mark()`` (shed, ``exact=False``, overflow escalation), in which case
the root span is kept with ``tail=True``.  Sampled mode NEVER fences:
``server._fence`` consults ``fencing()`` and records the dispatch half
only, so the async pipeline (PR 7/8) keeps its overlap - which is why
sampled results stay bit-identical and overhead stays within the <= 5%
budget ``check_bench.py`` gates.  Kept traces are counted
(``obs.sampled_spans`` / ``obs.sampled_traces`` / ``obs.tail_traces``
in the registry passed to ``enable_sampling``) and fed to the optional
``FlightRecorder``.

Export: ``save(path)`` writes Chrome ``traceEvents`` JSON for ``.json``
paths (load in ``chrome://tracing`` / Perfetto) and one-span-per-line
JSONL otherwise; ``scripts/trace_report.py`` reads both.
"""
from __future__ import annotations

import contextvars
import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

# attribution buckets trace_report.py understands; "wall" is reserved
# for root spans (their duration IS the denominator of the report)
CATEGORIES = ("host", "dispatch", "device", "cache", "wall")

_current_trace: contextvars.ContextVar[Optional[int]] = \
    contextvars.ContextVar("repro_obs_trace", default=None)


class _NoopSpan:
    """The disabled-tracing fast path: one shared, stateless context
    manager."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_token")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any], new_trace: bool):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        # a root span installs a fresh trace id for everything nested
        self._token = (
            _current_trace.set(tracer._next_trace_id())
            if new_trace else None
        )
        self._t0 = tracer.clock()

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer.clock()
        self._tracer._record(
            self.name, self.cat, self._t0, t1 - self._t0, self.args
        )
        if self._token is not None:
            _current_trace.reset(self._token)
        return False


@dataclass
class SamplingConfig:
    """Knobs for sampled tracing.  ``rate`` is the head-sampling
    fraction (deterministic systematic sampler - every ``1/rate``-th
    root keeps its full tree); ``latency_threshold`` (seconds) is the
    tail-keep bound: unsampled roots that run longer are kept anyway
    (root span only, flagged ``tail=True``)."""

    rate: float
    latency_threshold: Optional[float] = None


class _SampledRoot:
    """A root span whose whole child tree is recorded.  Temporarily
    flips ``tracer.enabled`` so nested ``span()`` calls record (the
    serving stack is single-threaded; the flag is restored on exit),
    WITHOUT setting ``_full`` - so ``_fence`` stays async."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_token", "_ev0",
                 "anomaly")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.anomaly: Optional[str] = None
        self._token = _current_trace.set(tracer._next_trace_id())
        self._ev0 = len(tracer.events)
        tracer.enabled = True
        tracer._root = self
        self._t0 = tracer.clock()

    def __enter__(self) -> "_SampledRoot":
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = tr.clock()
        dur = t1 - self._t0
        args = dict(self.args)
        if self.anomaly:
            args["anomaly"] = self.anomaly
        tr._record(self.name, "wall", self._t0, dur, args)
        spans = tr.events[self._ev0:]
        tr.enabled = tr._full
        tr._root = None
        trace_id = _current_trace.get()
        _current_trace.reset(self._token)
        tr._on_keep(spans, dur, self.name, self.anomaly, "sampled",
                    trace_id)
        return False


class _TailRoot:
    """The unsampled-root path: two clock reads, a trace id so nested
    entry points stay no-ops, and a record only if the root breached
    the latency threshold or was ``mark()``-ed anomalous."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_token", "anomaly")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.anomaly: Optional[str] = None
        self._token = _current_trace.set(tracer._next_trace_id())
        tracer._root = self
        self._t0 = tracer.clock()

    def __enter__(self) -> "_TailRoot":
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = tr.clock()
        dur = t1 - self._t0
        s = tr.sampling
        thr = s.latency_threshold if s is not None else None
        keep = self.anomaly is not None or (
            thr is not None and dur >= thr
        )
        tr._root = None
        trace_id = _current_trace.get()
        _current_trace.reset(self._token)
        if keep:
            args = dict(self.args)
            args["tail"] = True
            if self.anomaly:
                args["anomaly"] = self.anomaly
            ev0 = len(tr.events)
            # _current_trace is reset already; stamp the id explicitly
            tok = _current_trace.set(trace_id)
            tr._record(self.name, "wall", self._t0, dur, args)
            _current_trace.reset(tok)
            tr._on_keep(tr.events[ev0:], dur, self.name, self.anomaly,
                        "tail", trace_id)
        return False


class Tracer:
    """Event buffer + clock base.  One module-level instance
    (``tracer``) serves the whole process; everything here is plain
    host Python."""

    # runaway guard: a forgotten enabled tracer must not eat the heap
    MAX_EVENTS = 2_000_000

    def __init__(self) -> None:
        self.enabled = False
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self.clock = time.perf_counter  # injectable (tests, replay)
        self._t_base = self.clock()
        self._trace_seq = 0
        # sampled-mode state
        self.sampling: Optional[SamplingConfig] = None
        self._full = False     # True only under enable(): fences on
        self._acc = 0.0        # systematic-sampler accumulator
        self._root = None      # active sampled/tail root (mark target)
        self.metrics = None    # Optional[MetricsRegistry]
        self.flight = None     # Optional[FlightRecorder]

    # ------------------------------------------------------- lifecycle
    def enable(self) -> None:
        """Full tracing: every span recorded, device spans fenced."""
        self.enabled = True
        self._full = True
        self.sampling = None
        if not self.events:
            self._t_base = self.clock()

    def enable_sampling(self, rate: float, *,
                        latency_threshold: Optional[float] = None,
                        metrics=None, flight=None) -> None:
        """Always-on mode: keep ~``rate`` of root-span trees plus every
        tail/anomalous root, never fence.  ``metrics`` (a
        ``MetricsRegistry``) receives the ``obs.*`` keep counters;
        ``flight`` (a ``FlightRecorder``) receives kept traces."""
        self.sampling = SamplingConfig(
            rate=float(rate), latency_threshold=latency_threshold
        )
        self._acc = 0.0
        self.metrics = metrics
        self.flight = flight
        self.enabled = False
        self._full = False
        if not self.events:
            self._t_base = self.clock()

    def disable(self) -> None:
        self.enabled = False
        self._full = False
        self.sampling = None
        self._root = None
        self.metrics = None
        self.flight = None

    def clear(self) -> None:
        self.events = []
        self.dropped = 0
        self._trace_seq = 0
        self._acc = 0.0
        self._t_base = self.clock()

    def _next_trace_id(self) -> int:
        self._trace_seq += 1
        return self._trace_seq

    # ------------------------------------------------------- recording
    def _record(self, name: str, cat: str, t0: float, dur: float,
                args: Dict[str, Any]) -> None:
        if len(self.events) >= self.MAX_EVENTS:
            self.dropped += 1
            return
        ev = {
            "name": name,
            "cat": cat,
            # Chrome-trace convention: microseconds
            "ts": (t0 - self._t_base) * 1e6,
            "dur": dur * 1e6,
            "trace": _current_trace.get(),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def _on_keep(self, spans: List[Dict[str, Any]], dur: float,
                 name: str, anomaly: Optional[str], kind: str,
                 trace_id: Optional[int]) -> None:
        """A sampled/tail root completed and was kept: count it and
        hand the span tree to the flight recorder.  Runs only on kept
        traces, so a dict lookup per keep is fine."""
        if self.metrics is not None:
            self.metrics.counter("obs.sampled_spans").inc(len(spans))
            self.metrics.counter(
                "obs.sampled_traces" if kind == "sampled"
                else "obs.tail_traces"
            ).inc()
        if self.flight is not None:
            self.flight.record(name, dur, spans, anomaly=anomaly,
                               kind=kind, trace=trace_id)

    def add_complete(self, name: str, cat: str, start: float,
                     duration: float, **args: Any) -> None:
        """Record an interval measured by the caller (``start`` is a
        ``time.perf_counter()`` value, so it nests consistently with
        context-manager spans)."""
        if self.enabled:
            self._record(name, cat, start, duration, args)

    # --------------------------------------------------------- export
    def chrome_events(self) -> List[Dict[str, Any]]:
        out = []
        for ev in self.events:
            args = dict(ev.get("args", {}))
            if ev["trace"] is not None:
                args["trace"] = ev["trace"]
            out.append({
                "name": ev["name"], "cat": ev["cat"], "ph": "X",
                "ts": ev["ts"], "dur": ev["dur"],
                "pid": 0, "tid": 0, "args": args,
            })
        return out

    def save(self, path: str) -> None:
        """Chrome ``traceEvents`` JSON for ``.json`` paths, JSONL (one
        span object per line) otherwise."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump({"traceEvents": self.chrome_events(),
                           "displayTimeUnit": "ms"}, f)
        else:
            with open(path, "w") as f:
                for ev in self.events:
                    f.write(json.dumps(ev) + "\n")


tracer = Tracer()


def enabled() -> bool:
    return tracer.enabled


def fencing() -> bool:
    """True only under full tracing (``enable()``): device spans may
    ``block_until_ready`` to split launch from execution.  Sampled mode
    returns False - the fence would serialize the async pipeline, so
    sampled traces record the dispatch half only."""
    return tracer._full


def sampling() -> Optional[SamplingConfig]:
    """The active sampling config, or None (disabled / full mode)."""
    return tracer.sampling


def enable() -> None:
    tracer.enable()


def enable_sampling(rate: float, *,
                    latency_threshold: Optional[float] = None,
                    metrics=None, flight=None) -> None:
    tracer.enable_sampling(rate, latency_threshold=latency_threshold,
                           metrics=metrics, flight=flight)


def disable() -> None:
    tracer.disable()


def mark(reason: str) -> None:
    """Flag the active root span as anomalous (shed, ``exact=False``,
    overflow escalation, ...).  In sampled mode an anomalous root is
    always kept, even unsampled; everywhere else this is a no-op."""
    root = tracer._root
    if root is not None:
        root.anomaly = reason


def clear() -> None:
    tracer.clear()


def save(path: str) -> None:
    tracer.save(path)


def current_trace() -> Optional[int]:
    """The active trace id (None outside any root span)."""
    return _current_trace.get()


def span(name: str, cat: str = "host", **args: Any):
    """A timed region attributed to bucket ``cat``.  No-op (shared
    singleton, no clock read) while tracing is disabled."""
    if not tracer.enabled:
        return _NOOP
    return _Span(tracer, name, cat, args, new_trace=False)


def root_or_span(name: str, **args: Any):
    """Entry-point span: opens a new trace (``cat="wall"``) when none
    is active - per-query / per-wavefront trace ids are minted here -
    and nests as a plain host span inside an existing trace (a routed
    query reaching ``PatternServer.query`` stays in the route's
    trace).  Under sampled mode, a new root draws from the systematic
    sampler: kept roots record their full tree (``_SampledRoot``),
    the rest become cheap ``_TailRoot``s kept only on threshold breach
    or ``mark()``."""
    if tracer.enabled:
        if _current_trace.get() is None:
            return _Span(tracer, name, "wall", args, new_trace=True)
        return _Span(tracer, name, "host", args, new_trace=False)
    s = tracer.sampling
    if s is None or _current_trace.get() is not None:
        return _NOOP
    tracer._acc += s.rate
    if tracer._acc >= 1.0:
        tracer._acc -= 1.0
        return _SampledRoot(tracer, name, args)
    return _TailRoot(tracer, name, args)


def add_complete(name: str, cat: str, start: float, duration: float,
                 **args: Any) -> None:
    tracer.add_complete(name, cat, start, duration, **args)
