"""Three-term roofline from a compiled dry-run artifact.

compute    = FLOPs_per_chip / peak_FLOPs
memory     = HBM_bytes_per_chip / HBM_bw
collective = collective_bytes_per_chip / link_bw

FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition
module).  Collective bytes are parsed from the post-SPMD HLO text
(``compiled.as_text()``): the summed result sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e per chip
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?\S+\s*=\s*(\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[\s(]",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result bytes per collective op kind."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        d = out.setdefault(kind, {"bytes": 0.0, "count": 0})
        d["bytes"] += b
        d["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    n_chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful work / (chips x peak x achievable step time).  The
        achievable step time is the max of the three terms (perfect
        overlap assumption)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.n_chips * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        extra = {}
        if hasattr(self, "raw_cost_analysis"):
            extra["raw_cost_analysis"] = self.raw_cost_analysis
        if hasattr(self, "collectives_by_kind"):
            extra["collectives_by_kind"] = self.collectives_by_kind
        return {
            **extra,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, n_chips: int, model_flops: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    from . import hlo_cost

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # trip-count-aware walk (XLA cost_analysis counts scan bodies once)
    walked = hlo_cost.analyze(text)
    flops = max(raw_flops, walked["flops"])
    byts = max(raw_bytes, walked["bytes"])
    coll_bytes = walked["collective_bytes"]
    if coll_bytes == 0.0:
        coll = parse_collectives(text)
        coll_bytes = sum(d["bytes"] for d in coll.values())
    r = Roofline(
        flops_per_chip=flops,
        hbm_bytes_per_chip=byts,
        collective_bytes_per_chip=coll_bytes,
        n_chips=n_chips,
        model_flops=model_flops,
    )
    r.raw_cost_analysis = {"flops": raw_flops,  # type: ignore[attr-defined]
                           "bytes": raw_bytes}
    r.collectives_by_kind = walked["collectives"]  # type: ignore
    return r
