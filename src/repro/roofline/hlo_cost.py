"""Trip-count-aware cost extraction from post-optimization HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which
undercounts every scanned-layer model by ~n_layers.  This analyzer walks
the HLO call graph instead:

* flops  - every ``dot`` contributes 2 * prod(result_dims) *
  prod(contracting_dims), multiplied by the product of enclosing while
  trip counts (parsed from ``backend_config={"known_trip_count"...}``).
* bytes  - XLA's fusion memory model: each *top-level* instruction of a
  computation reads its operands and writes its result once; fusion
  interiors are free.  Bookkeeping ops (tuple/gte/parameter/constant/
  bitcast) are free.
* collectives - result bytes per op kind, trip-count multiplied.

This is a text-level analyzer: it is deliberately conservative and only
needs shapes, operand names, called computations and trip counts, all of
which are stable in HLO dumps.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count"?[=:]\{"n":"(\d+)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _dims(shape_txt: str) -> List[Tuple[str, List[int]]]:
    return [
        (dt, [int(x) for x in dims.split(",") if x])
        for dt, dims in _ARRAY_RE.findall(shape_txt)
    ]


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _dims(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Instr:
    __slots__ = ("name", "shape", "op", "line")

    def __init__(self, name, shape, op, line):
        self.name, self.shape, self.op, self.line = name, shape, op, line


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: List[Instr] = []
        self.shapes: Dict[str, str] = {}


def _split_shape_op(rest: str):
    """'f32[2,3]{1,0} dot(...)' or '(s32[], f32[..]) while(...)' ->
    (shape_text, remainder-starting-at-op)."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:]
        return rest, ""
    i = rest.find(" ")
    if i < 0:
        return rest, ""
    return rest[:i], rest[i:]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        if not line.startswith(" ") and "{" in stripped and "->" in stripped:
            m = _COMP_HDR_RE.match(stripped.lstrip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if stripped.strip() == "}":
            continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shape, tail = _split_shape_op(rest)
        mo = _OP_RE.match(tail)
        if not mo:
            continue
        op = mo.group(1)
        cur.shapes[name] = shape
        cur.instrs.append(Instr(name, shape, op, line))
    comps["__entry__"] = comps.get(entry)  # type: ignore[assignment]
    return comps


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out = 1
        for _, dims in _dims(ins.shape):
            for d in dims:
                out *= d
        m = _CONTRACT_RE.search(ins.line)
        contract = 1
        if m:
            # operand list: text between 'dot(' and ')'
            call = ins.line.split("dot(", 1)[1]
            ops = _OPERAND_RE.findall(call.split(")")[0])
            if ops:
                lhs_shape = comp.shapes.get(ops[0], "")
                darr = _dims(lhs_shape)
                if darr:
                    dims = darr[0][1]
                    for idx in m.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
        return 2.0 * out * contract

    def _operand_bytes(self, comp: Computation, ins: Instr) -> int:
        inner = ins.line.split(ins.op + "(", 1)
        if len(inner) < 2:
            return 0
        args = inner[1].split(")")[0]
        total = 0
        for op_name in _OPERAND_RE.findall(args):
            if op_name in comp.shapes:
                total += _shape_bytes(comp.shapes[op_name])
        return total

    def comp_cost(self, name: str) -> Tuple[float, float, Dict[str, float]]:
        """(flops, bytes, collective_bytes_by_kind) with trip counts."""
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return (0.0, 0.0, {})
        flops = 0.0
        byts = 0.0
        coll: Dict[str, float] = {}
        self._memo[name] = (0.0, 0.0, {})  # cycle guard
        for ins in comp.instrs:
            if ins.op == "while":
                trips = 1
                m = _TRIP_RE.search(ins.line)
                if m:
                    trips = int(m.group(1))
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                for sub in (body, cond):
                    if sub:
                        f, b, c = self.comp_cost(sub.group(1))
                        flops += trips * f
                        byts += trips * b
                        for k, v in c.items():
                            coll[k] = coll.get(k, 0.0) + trips * v
                continue
            if ins.op in ("call", "conditional", "custom-call"):
                m = _CALLS_RE.search(ins.line)
                if m:
                    f, b, c = self.comp_cost(m.group(1))
                    flops += f
                    byts += b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v
                byts += _shape_bytes(ins.shape)
                continue
            if ins.op == "fusion":
                # fused interior flops: count dots inside the fused comp
                m = _CALLS_RE.search(ins.line)
                fcomp = self.comps.get(m.group(1)) if m else None
                if m:
                    f, _, _ = self.comp_cost(m.group(1))
                    flops += f
                dus = None
                if fcomp is not None:
                    for fi in fcomp.instrs:
                        if fi.op == "dynamic-update-slice":
                            dus = fi
                            break
                if dus is not None:
                    # in-place window update: traffic = 2x the window (the
                    # aliased full buffer passes through untouched)
                    upd = 0
                    inner = dus.line.split("dynamic-update-slice(", 1)
                    if len(inner) == 2:
                        ops = _OPERAND_RE.findall(inner[1].split(")")[0])
                        if len(ops) >= 2 and ops[1] in fcomp.shapes:
                            upd = _shape_bytes(fcomp.shapes[ops[1]])
                    res = _shape_bytes(ins.shape)
                    byts += 2 * (upd if upd else res)
                    continue
                byts += _shape_bytes(ins.shape) + self._operand_bytes(
                    comp, ins
                )
                continue
            if ins.op.startswith(COLLECTIVES):
                kind = next(k for k in COLLECTIVES if ins.op.startswith(k))
                b = _shape_bytes(ins.shape)
                coll[kind] = coll.get(kind, 0.0) + b
                byts += b + self._operand_bytes(comp, ins)
                continue
            if ins.op == "dot":
                flops += self._dot_flops(comp, ins)
                byts += _shape_bytes(ins.shape) + self._operand_bytes(
                    comp, ins
                )
                continue
            if ins.op in _FREE_OPS:
                continue
            if ins.op == "dynamic-slice":
                # reads only the slice (counting the full operand would
                # charge the whole stacked-weights array per scan trip)
                byts += 2 * _shape_bytes(ins.shape)
                continue
            if ins.op == "dynamic-update-slice":
                # traffic = the updated window (read-modify-write)
                inner = ins.line.split("dynamic-update-slice(", 1)
                upd_bytes = 0
                if len(inner) == 2:
                    ops = _OPERAND_RE.findall(inner[1].split(")")[0])
                    if len(ops) >= 2 and ops[1] in comp.shapes:
                        upd_bytes = _shape_bytes(comp.shapes[ops[1]])
                byts += 2 * upd_bytes
                continue
            # generic elementwise / copy / gather etc.
            byts += _shape_bytes(ins.shape) + self._operand_bytes(comp, ins)
        # fused computations' dots were counted through their callers; a
        # fused computation reached directly contributes only dots.
        self._memo[name] = (flops, byts, coll)
        return self._memo[name]

    def entry_cost(self) -> Tuple[float, float, Dict[str, float]]:
        entry = self.comps.get("__entry__")
        if entry is None:
            return (0.0, 0.0, {})
        return self.comp_cost(entry.name)


def analyze(text: str) -> Dict[str, float]:
    f, b, coll = HloCost(text).entry_cost()
    return {
        "flops": f,
        "bytes": b,
        "collective_bytes": sum(coll.values()),
        "collectives": coll,
    }
