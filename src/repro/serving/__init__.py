"""Query-time pattern serving: from ``MiningResult`` to production
containment queries.

Mining (repro.mining) produces the rFTS bank; this package answers the
deployment-side question - "which mined patterns does this incoming
graph sequence contain?" - as a batched device computation instead of a
per-sequence host backtrack.  The mining layer mirrors the same
batching discipline on the producer side: ``mining.driver``'s wavefront
scheduler packs the embeddings of many frontier patterns into shared
device scans (one dispatch per chunk, not per pattern - see driver.py's
docstring), and ``mining.incremental``'s frontier re-mine - the engine
behind ``StreamingBank.refresh()`` and the sharded-window reconcile -
drains its dirty frontier through the same batched expansion.

Module map:

* ``bank.py``    - compile a ``MiningResult`` into a packed pattern bank
                   (per-pattern int32 step programs + support/metadata
                   rows) and renaming-invariant canonical sequence
                   fingerprints (cache keys that hit for any vertex
                   bijection of a previously served sequence).
* ``trie.py``    - the prefix-trie re-layout of a bank: mined rFTSs are
                   nodes of the reverse-search spanning tree and share
                   program prefixes, so the trie stores each distinct
                   prefix once (LCP merging; one node per step row) and
                   carries per-node residual ``node_req`` prescreen rows
                   (min over the subtree's terminals) that prune whole
                   subtrees at their highest failing ancestor.  See its
                   docstring for when to prefer flat vs trie.
* ``batch.py``   - the jitted embedding-join scans: the flat
                   per-(sequence, pattern) layout (dense
                   ``batch_contains``, prescreen-compacted
                   ``pair_contains``) and the trie layout
                   (level-synchronous ``trie_contains`` /
                   ``trie_level_advance``, one frontier per
                   (sequence, trie node) seeded from its parent's
                   compacted frontier - bit-identical answers, shared
                   prefixes joined once); ``fused_trie_walk``, the jit
                   wrapper over ``repro.kernels.trie_walk`` that runs
                   the whole walk (all levels, in-kernel frontier
                   buffers + per-node prescreen) in ONE dispatch
                   gridded over (sequence, depth-1 subtree shard) -
                   see ``trie.pack_subtrees`` for the width-capped
                   spine-replicated shard layout; plus the sound
                   counts prescreens, inverted token index, frontier
                   compaction and overflow flags.  Delegates the
                   per-step predicate to ``repro.kernels.containment``
                   (Pallas kernel or jnp oracle).
* ``layouts.py`` - the ``Layout`` registry: each bank layout
                   (``"flat"``, ``"trie"``, ``"trie_fused"``) registers
                   its launch/finalize/shard hooks once and every
                   consumer (server, placement planner, CLI) resolves
                   by name via ``get_layout`` - adding a layout no
                   longer touches server/router/cluster plumbing.
* ``join.py``    - the unified Join API: ``JoinRequest -> JoinResult``
                   is the one protocol every backend speaks
                   (``PatternServer``, ``ClusterRouter``,
                   ``ServingCluster``, ``StreamingBank``); the legacy
                   entry points survive as thin wrappers.  ``Frontend``
                   is the backend-agnostic facade, including the
                   begin/finish split over async pipelines.  Exactness
                   propagation (``exact=False`` on every approximate
                   row) is part of the protocol.
* ``server.py``  - ``PatternServer``: request batching into pow-2
                   buckets, prescreen + join under any registered
                   ``bank_layout``, fingerprint-keyed LRU cache,
                   support-weighted top-k scoring, device escalation +
                   host-oracle fallback for overflow cells (results
                   always exactly match ``core.containment``); plus the
                   streaming layer's hooks - ``exact_rows`` (chunked,
                   cache-bypassing rows) and ``set_row_mask`` (tombstone
                   masking via ``REQ_MASKED`` prescreen rows).
* ``streaming.py`` - ``StreamingBank``: incremental support maintenance
                   over a sliding window.  Arrivals are counted by the
                   device containment join, expiries decremented from a
                   ring buffer of per-sequence containment bitmaps (no
                   re-join on eviction); sub-``minsup`` patterns are
                   tombstoned (prescreen-masked, trie subtrees pruned);
                   ``refresh()`` reconciles incrementally via the
                   frontier re-mine (``mining.incremental``), extending
                   the bank/trie in place, with ``refresh(full=True)``
                   as the re-mine-everything escape hatch.  After a
                   refresh the frequent map is bit-equal to a batch
                   re-mine of the window.
* ``sharded.py`` - shard-by-pattern (flat) / shard-by-subtree (trie)
                   serving steps for device meshes (zero-collective
                   shard_map).
* ``router.py``  - the cluster query plane: bank placement across hosts
                   (intact depth-1 trie subtrees / flat pattern ranges)
                   and ``ClusterRouter`` - queries arriving on any host
                   are deduped by canonical fingerprint, resolved
                   through the two-level cache (host-local L1,
                   fingerprint-owner L2), and the misses batched into
                   shared pow-2 device batches per shard; merged
                   answers are bit-equal to a single-host server.
                   Two driving modes share that machinery: synchronous
                   ``route`` (one blocking drain) and the async
                   admission pipeline ``submit -> [queue] -> flush ->
                   [in-flight] -> collect`` (continuous batching: the
                   queries are encoded once per flush, every shard's
                   join launches before any is fenced, and later
                   drains keep admitting while earlier batches compute
                   on device; repeats piggyback on queued/in-flight
                   joins).  Flushes trigger on queue length
                   (``flush_batch``), head-of-queue age (``max_wait``,
                   against an injectable clock), or a blocked
                   ``collect``; past ``shed_depth`` new misses get
                   host-prescreen-only answers - sound supersets
                   flagged ``exact=False``, never cached (off by
                   default: exactness stays the contract).
* ``cluster.py`` - the multi-host topologies over router.py:
                   ``ServingCluster`` (static sharded bank),
                   ``ShardedStreamingBank`` (the sharded-window
                   protocol: per-host ring slices + partial supports,
                   all-reduced with a depth-1-subtree dirtiness index
                   at ``refresh()``), and ``ReplicaGroup`` (single
                   writer shipping ``extend_bank``/``extend_trie``
                   deltas to read replicas).  Hosts are an abstraction
                   (in-process simulated hosts, optionally device-
                   pinned), so every protocol is property-tested
                   bit-equal to its single-host counterpart.
* ``faults.py``  - the failure model: ``FaultInjector`` (a seeded,
                   deterministic schedule of delays, transient errors
                   and host blackout windows at the ``ClusterHost.call``
                   boundary - no RNG at query time, so any chaos run
                   replays bit-identically), ``RetryPolicy`` (per-call
                   timeouts, capped exponential backoff, the
                   consecutive-failure circuit breaker), the typed
                   fault hierarchy (``HostFault`` and friends,
                   ``HostUnavailableError``, ``PipelineBusyError``),
                   and ``RecoveryLog`` (the writer-side sequenced
                   delta ring that replays a restarted replica back to
                   bit-equal state).

Fault tolerance (``serving.faults``, the failure model): every
cross-host access already flows through ``ClusterHost.call``, so the
fault seam is one boundary.  With a ``RetryPolicy`` armed the router
wraps every host call in per-call timeouts + capped-backoff retries;
``breaker_threshold`` consecutive failures open a per-host circuit
breaker (open -> short-circuit without touching the host -> half-open
single probe after ``breaker_cooldown`` -> close with wiped caches on
success).  While a host is down its column block degrades down a
two-rung ladder: a registered failover replica
(``ServingCluster.attach_failover_replica``) serves bit-equal
``exact=True`` rows; otherwise the router answers from the host-side
prescreen mirror - a sound superset flagged ``exact=False`` (the shed
tier's protocol), never cached.  ``collect(timeout=...)`` bounds the
async drain the same way: past the deadline stragglers degrade instead
of blocking.  Strict entry points (``joined_rows``/``exact_rows``)
refuse with ``HostUnavailableError`` rather than degrade.  Streaming
deltas carry monotone sequence ids; a crashed replica restarts by
replaying the writer's ``RecoveryLog`` from its last applied seq
(verified bit-equal catch-up, full resync when the ring evicted the
gap).  The whole ladder is off by default and the idle-injector run is
property-tested bit-identical to the pre-fault cluster
(tests/test_faults.py); ``benchmarks/bench_faults.py`` gates
availability >= 0.99 with one of four hosts blacked out and zero
unflagged-inexact answers.  Counters:
``cluster.faults.{injected, retries, breaker_open, failovers,
degraded_answers, recoveries}`` + the ``cluster.faults.retry_seconds``
histogram; faulted calls ``trace.mark("host_fault")`` so sampled
traces keep them.

Observability (``repro.obs``, cross-cutting): every layer's counters
live in a ``MetricsRegistry`` (``server.stats``, ``router.stats``, the
streaming banks' ``stats`` are ``StatsView`` facades over it), so
counters survive component rebuilds - a ``refresh(full=True)`` that
recompiles the server or re-plans the router re-attaches by name and
keeps accumulating; ``registry.snapshot()/delta()`` feed the BENCH
artifacts' ``metrics`` blocks.  The admission pipeline adds
``cluster.router.{inflight_hits, shed_prescreen, flush_batch,
flush_deadline, flush_force}`` counters and the
``cluster.router.queue_depth`` gauge (queued + un-fenced in-flight
misses); per-shard servers count every join entry point under
``serving.server.h<hid>.queries``.  The span tracer (``repro.obs.trace``)
threads one trace id per routed query / wavefront through
``ClusterRouter.route -> ClusterHost.call -> PatternServer -> kernel
dispatch``, splitting launch from blocked device time; it is off by
default and property-tested to change nothing (tests/test_obs.py).
Render a saved trace with ``scripts/trace_report.py``.

**Always-on production telemetry** (cheap enough to leave on; the
benches gate the measured sampled-mode overhead <= 5%):

* **Latency percentiles** - ``BucketHistogram`` (fixed log-scale
  buckets, constant memory, exact quantile bounds) records per-query
  end-to-end latency and queue time at every admission seam:
  ``serving.{flat,trie,fused}.query_seconds``,
  ``serving.*.batch_seconds``, ``cluster.router.{e2e_seconds,
  queue_wait_seconds, flush_seconds, route_seconds}``,
  ``streaming.{bank,sharded}.{observe,refresh}_seconds``,
  ``mining.*.wave_seconds``; plus the ``cluster.router.{queue_age,
  oldest_ticket_age}`` aging gauges.  Snapshots expose
  ``<name>.p50/.p95/.p99``.
* **Sampled tracing** - ``trace.enable_sampling(rate)`` keeps every
  ``1/rate``-th root span *tree* (deterministic systematic sampler -
  no RNG, so results stay bit-reproducible) plus every tail root that
  breaches ``latency_threshold`` or was ``trace.mark()``-ed anomalous
  (shed, inexact, overflow-escalated).  Unlike full ``enable()``,
  sampled mode never fences - device spans record launch only, so the
  async pipeline keeps its overlap.  Keeps count under
  ``obs.{sampled_spans, sampled_traces, tail_traces}``.
* **Flight recorder** - ``FlightRecorder`` rings the last N kept
  traces with per-entry metric deltas; dumped as JSONL on demand, on
  an anomalous entry, or by the watchdog on an SLO breach.
* **SLO watchdog** - declarative rules (``scripts/slo_rules.json``:
  quantile / rate / gauge / counter bounds) evaluated by
  ``SloWatchdog`` riding ``ClusterRouter._note_depth`` (attach via
  ``ServingCluster.attach_watchdog``); breaches increment
  ``cluster.router.slo_breaches`` and trigger a flight dump.  The
  same rules file drives the ``scripts/trace_report.py --slo`` CI
  gate against BENCH metrics blocks.
* **Export** - ``prometheus_text`` / ``validate_exposition`` (strict
  0.0.4 text exposition) and ``MetricsExporter`` (periodic JSONL
  snapshots on an injectable clock).
"""
from .bank import (  # noqa: F401
    BankCapacityError,
    PatternBank,
    canonical_sequence_map,
    compile_bank,
    extend_bank,
    sequence_fingerprint,
    slice_bank,
)
from .batch import (  # noqa: F401
    batch_contains,
    index_and_node_prescreen,
    index_and_prescreen,
    max_key_bucket,
    pair_contains,
    pair_contains_indexed,
    prescreen_counts,
    trie_contains,
    trie_level_advance,
)
from .cluster import (  # noqa: F401
    BankReplica,
    ClusterHost,
    ReplicaGroup,
    ServingCluster,
    ShardedStreamingBank,
)
from .faults import (  # noqa: F401
    FaultInjector,
    HostDownError,
    HostFault,
    HostTimeoutError,
    HostUnavailableError,
    PipelineBusyError,
    RecoveryLog,
    RetryPolicy,
    TransientHostError,
)
from .join import (  # noqa: F401
    Frontend,
    JoinRequest,
    JoinResult,
)
from .layouts import (  # noqa: F401
    Layout,
    get_layout,
    layout_names,
    register_layout,
)
from .router import (  # noqa: F401
    BankPlacement,
    ClusterRouter,
    DrainTicket,
    plan_placement,
)
from .server import (  # noqa: F401
    PatternServer,
    QueryResult,
    SharedEncoding,
    encode_queries,
)
from .sharded import (  # noqa: F401
    make_serving_step,
    make_trie_serving_step,
    stack_trie_shards,
)
from .streaming import ObserveResult, StreamingBank  # noqa: F401
from .trie import (  # noqa: F401
    SubtreePack,
    TrieBank,
    build_trie,
    compile_trie_bank,
    extend_trie,
    masked_node_req,
    pack_subtrees,
    parent_prefix_hits,
)
