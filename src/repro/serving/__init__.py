"""Query-time pattern serving: from ``MiningResult`` to production
containment queries.

Mining (repro.mining) produces the rFTS bank; this package answers the
deployment-side question - "which mined patterns does this incoming
graph sequence contain?" - as a batched device computation instead of a
per-sequence host backtrack.

Module map:

* ``bank.py``    - compile a ``MiningResult`` into a packed pattern bank
                   (per-pattern int32 step programs + support/metadata
                   rows) and canonical sequence fingerprints.
* ``batch.py``   - the jitted embedding-join scan over
                   (sequence, pattern) cells: dense ``batch_contains``,
                   prescreen-compacted ``pair_contains``, the sound
                   counts prescreen, inverted token index, frontier
                   compaction and overflow flags; delegates the per-step
                   predicate to ``repro.kernels.containment`` (Pallas
                   kernel or jnp oracle).
* ``server.py``  - ``PatternServer``: request batching into pow-2
                   buckets, prescreen + pair join, fingerprint-keyed LRU
                   cache, support-weighted top-k scoring, host-oracle
                   fallback for overflow cells (results always exactly
                   match ``core.containment``).
* ``sharded.py`` - shard-by-pattern / shard-by-sequence serving step for
                   device meshes (zero-collective shard_map).
"""
from .bank import PatternBank, compile_bank, sequence_fingerprint  # noqa: F401
from .batch import (  # noqa: F401
    batch_contains,
    index_and_prescreen,
    max_key_bucket,
    pair_contains,
    pair_contains_indexed,
    prescreen_counts,
)
from .server import PatternServer, QueryResult  # noqa: F401
from .sharded import make_serving_step  # noqa: F401
