"""Pattern bank: a ``MiningResult`` compiled for query-time containment.

Each mined rFTS becomes a fixed-shape *step program*: its canonical
itemsets in order, TRs sorted within each itemset, one int32 row per TR.
Replaying the program against a data sequence with the embedding join
(repro.serving.batch) grows exactly the prefix embeddings the host
oracle backtracks over, so "frontier non-empty after the last step" is
the Def-4 containment test.

Step row layout (``STEP_FIELDS`` columns, int32):
  0 type, 1 pu1, 2 pu2 (0 for vertex TRs), 3 label,
  4 new_itemset (1 = first TR of its itemset), 5 itemset index,
  6 step_valid (0 = padding row),
  7 token key = type * n_label_keys + label + 1 (the inverted-index
    bucket the step's candidate tokens live in, see batch.py)

Banks also carry per-pattern metadata rows (support, #steps, #itemsets,
#vertices, valid flag) used for top-k scoring and shard-by-pattern
serving (see sharded.py), plus the per-pattern token-key requirement
counts ``req`` [P, 6*n_label_keys] that drive the server's
necessary-condition prescreen: psi injectivity + strictly increasing phi
force distinct pattern TRs onto distinct data tokens, so a sequence can
only contain a pattern if it has at least ``req[p, k]`` tokens of every
key k.
"""
from __future__ import annotations

import array
import dataclasses
import functools
import hashlib
import itertools
from typing import Dict, List, Mapping, Sequence, Tuple, Union

import numpy as np

from ..core.canonical import canonical_code, canonical_form
from ..core.graphseq import Pattern, TRSeq, pattern_length, pattern_vertices
from ..core.gtrace import MiningResult

STEP_FIELDS = 8


@dataclasses.dataclass
class PatternBank:
    steps: np.ndarray          # [P, L, STEP_FIELDS] int32
    support: np.ndarray        # [P] int32 (0 on padding rows)
    n_steps: np.ndarray        # [P] int32
    n_itemsets: np.ndarray     # [P] int32
    n_vertices: np.ndarray     # [P] int32
    pattern_valid: np.ndarray  # [P] int32 (0 = padding row)
    req: np.ndarray            # [P, 6*n_label_keys] int32 prescreen rows
    patterns: List[Pattern]    # the n_patterns real patterns, bank order
    nv: int                    # max vertices over the bank (psi width)
    n_label_keys: int          # label slots per TR type (max label + 2)

    @property
    def n_patterns(self) -> int:
        return len(self.patterns)

    @property
    def n_rows(self) -> int:
        return self.steps.shape[0]

    @property
    def max_steps(self) -> int:
        return self.steps.shape[1]

    def shard(self, n_shards: int) -> List["PatternBank"]:
        """Split by pattern rows into ``n_shards`` equal banks (row count
        must divide; use ``pad_patterns_to`` at compile time)."""
        P = self.n_rows
        assert P % n_shards == 0, (P, n_shards)
        loc = P // n_shards
        out = []
        for i in range(n_shards):
            sl = slice(i * loc, (i + 1) * loc)
            n_real = int(self.pattern_valid[sl].sum())
            out.append(PatternBank(
                steps=self.steps[sl],
                support=self.support[sl],
                n_steps=self.n_steps[sl],
                n_itemsets=self.n_itemsets[sl],
                n_vertices=self.n_vertices[sl],
                pattern_valid=self.pattern_valid[sl],
                req=self.req[sl],
                patterns=self.patterns[i * loc : i * loc + n_real],
                nv=self.nv,
                n_label_keys=self.n_label_keys,
            ))
        return out


def pattern_steps(
    p: Pattern, n_label_keys: int
) -> List[Tuple[int, ...]]:
    """The step program of one canonical pattern."""
    rows = []
    for i, itemset in enumerate(p):
        for t_i, tr in enumerate(sorted(itemset)):
            pu2 = 0 if tr.is_vertex else tr.u2
            key = int(tr.type) * n_label_keys + tr.label + 1
            rows.append((int(tr.type), tr.u1, pu2, tr.label,
                         int(t_i == 0), i, 1, key))
    return rows


def compile_bank(
    result: Union[MiningResult, Mapping[Pattern, int]],
    *,
    max_steps: int | None = None,
    pad_patterns_to: int | None = None,
    min_support: int = 0,
    top: int | None = None,
) -> PatternBank:
    """Pack mined patterns (canonicalized) into a PatternBank.

    Patterns are ordered by (-support, canonical code) so the bank layout
    is deterministic; ``top`` keeps only the strongest patterns and
    ``pad_patterns_to`` rounds the row count up (padding rows have
    ``pattern_valid=0`` and never report containment).
    """
    items = result.patterns if isinstance(result, MiningResult) else result
    chosen = [
        (canonical_form(p), int(s))
        for p, s in items.items()
        if len(p) > 0 and s >= min_support
    ]
    chosen.sort(key=lambda ps: (-ps[1], canonical_code(ps[0])))
    if top is not None:
        chosen = chosen[:top]
    patterns = [p for p, _ in chosen]
    max_label = max(
        (tr.label for p in patterns for s in p for tr in s), default=-1
    )
    n_label_keys = max_label + 2  # labels -1..max_label
    progs = [pattern_steps(p, n_label_keys) for p in patterns]
    L = max((len(r) for r in progs), default=1)
    if max_steps is not None:
        assert max_steps >= L, (max_steps, L)
        L = max_steps
    P = len(patterns)
    rows = P
    if pad_patterns_to is not None:
        assert pad_patterns_to >= P, (pad_patterns_to, P)
        rows = pad_patterns_to
    rows = max(rows, 1)
    steps = np.zeros((rows, max(L, 1), STEP_FIELDS), dtype=np.int32)
    for pi, prog in enumerate(progs):
        for si, row in enumerate(prog):
            steps[pi, si] = row
    meta = {
        "support": [s for _, s in chosen],
        "n_steps": [len(r) for r in progs],
        "n_itemsets": [len(p) for p in patterns],
        "n_vertices": [len(pattern_vertices(p)) for p in patterns],
        "pattern_valid": [1] * P,
    }
    pad = rows - P
    arrays = {
        k: np.array(v + [0] * pad, dtype=np.int32) for k, v in meta.items()
    }
    req = np.zeros((rows, 6 * n_label_keys), dtype=np.int32)
    for pi, prog in enumerate(progs):
        for row in prog:
            req[pi, row[7]] += 1
    nv = int(arrays["n_vertices"].max(initial=0))
    assert all(pattern_length(p) <= steps.shape[1] for p in patterns)
    return PatternBank(steps=steps, patterns=patterns, nv=max(nv, 1),
                       req=req, n_label_keys=n_label_keys, **arrays)


class BankCapacityError(ValueError):
    """An incremental bank extension does not fit the compiled capacity
    (label space / vertex width).  Callers fall back to a full
    ``compile_bank`` recompile - the streaming layer's exactness escape
    hatch."""


def slice_bank(bank: PatternBank, rows: Sequence[int]) -> PatternBank:
    """A flat sub-bank over the given pattern rows (no padding rows;
    global ``nv``/``n_label_keys`` preserved so token keys and psi
    widths stay consistent with the parent bank)."""
    idx = np.asarray(list(rows), np.int64)
    if len(idx) == 0:
        empty = compile_bank({})
        return PatternBank(
            steps=np.zeros((1, bank.max_steps, STEP_FIELDS), np.int32),
            support=empty.support, n_steps=empty.n_steps,
            n_itemsets=empty.n_itemsets, n_vertices=empty.n_vertices,
            pattern_valid=empty.pattern_valid,
            req=np.zeros((1, bank.req.shape[1]), np.int32),
            patterns=[], nv=bank.nv, n_label_keys=bank.n_label_keys,
        )
    return PatternBank(
        steps=bank.steps[idx],
        support=bank.support[idx],
        n_steps=bank.n_steps[idx],
        n_itemsets=bank.n_itemsets[idx],
        n_vertices=bank.n_vertices[idx],
        pattern_valid=bank.pattern_valid[idx],
        req=bank.req[idx],
        patterns=[bank.patterns[i] for i in idx],
        nv=bank.nv,
        n_label_keys=bank.n_label_keys,
    )


def extend_bank(
    bank: PatternBank, new: Mapping[Pattern, int]
) -> PatternBank:
    """Append new patterns (canonicalized, ordered by (-support, code)
    for determinism) to a compiled bank without recompiling the existing
    rows: old row indices - and therefore window bitmaps, support
    arrays, and trie terminals over them - stay valid.

    The bank-wide support ordering invariant is *not* maintained across
    the append (streamed supports drift anyway); streaming callers score
    from their live support array.  Raises ``BankCapacityError`` when a
    new pattern needs a label outside the compiled ``n_label_keys``
    space (token keys would change for every existing row - that is a
    full recompile).  ``max_steps`` and ``nv`` grow as needed (padding
    columns only; existing rows are unchanged)."""
    items = [(canonical_form(p), int(s)) for p, s in new.items()]
    items.sort(key=lambda ps: (-ps[1], canonical_code(ps[0])))
    if not items:
        return bank
    max_label = max(
        (tr.label for p, _ in items for s in p for tr in s), default=-1
    )
    if max_label + 2 > bank.n_label_keys:
        raise BankCapacityError(
            f"label {max_label} outside compiled key space "
            f"(n_label_keys={bank.n_label_keys})"
        )
    progs = [pattern_steps(p, bank.n_label_keys) for p, _ in items]
    L = max(bank.max_steps, max(len(r) for r in progs))
    P_old, P_new = bank.n_rows, len(items)
    assert P_old == bank.n_patterns, \
        "extend_bank requires an unpadded bank"
    steps = np.zeros((P_old + P_new, L, STEP_FIELDS), np.int32)
    steps[:P_old, : bank.max_steps] = bank.steps
    for pi, prog in enumerate(progs):
        for si, row in enumerate(prog):
            steps[P_old + pi, si] = row
    req = np.zeros((P_old + P_new, bank.req.shape[1]), np.int32)
    req[:P_old] = bank.req
    for pi, prog in enumerate(progs):
        for row in prog:
            req[P_old + pi, row[7]] += 1
    cat = lambda old, vals: np.concatenate(  # noqa: E731
        [old, np.asarray(vals, np.int32)]
    )
    n_vertices = [len(pattern_vertices(p)) for p, _ in items]
    return PatternBank(
        steps=steps,
        support=cat(bank.support, [s for _, s in items]),
        n_steps=cat(bank.n_steps, [len(r) for r in progs]),
        n_itemsets=cat(bank.n_itemsets, [len(p) for p, _ in items]),
        n_vertices=cat(bank.n_vertices, n_vertices),
        pattern_valid=cat(bank.pattern_valid, [1] * P_new),
        req=req,
        patterns=bank.patterns + [p for p, _ in items],
        nv=max(bank.nv, max(n_vertices, default=1)),
        n_label_keys=bank.n_label_keys,
    )


def _relabeled_bytes(s: TRSeq, m: Dict[int, int]) -> bytes:
    """The canonical byte encoding of ``s`` under vertex relabeling
    ``m``: TRs sorted within each itemset after relabeling (edge
    endpoints reordered), empty itemsets dropped - they can never host
    a pattern itemset, so containment is invariant either way.  The
    encoding reconstructs the relabeled sequence (4 int64 fields per
    TR, a -9 separator per itemset; every field is >= -1), so equal
    bytes certify a vertex bijection between the underlying sequences.
    """
    out: List[int] = []
    for itemset in s:
        if not itemset:
            continue
        rows = []
        for tr in itemset:
            if tr.type <= 2:  # vertex TR
                rows.append((int(tr.type), m[tr.u1], -1, tr.label))
            else:
                a, b = m[tr.u1], m[tr.u2]
                if a > b:
                    a, b = b, a
                rows.append((int(tr.type), a, b, tr.label))
        rows.sort()
        for row in rows:
            out.extend(row)
        out.append(-9)
    # array.array beats np.asarray by ~10x on these ~100-int lists
    return array.array("q", out).tobytes()


def canonical_sequence_map(
    s: TRSeq, max_candidates: int = 5040
) -> Dict[int, int]:
    """A canonical vertex relabeling of a data sequence, invariant under
    vertex bijections: containment (Def 4) only sees vertex identity
    through psi, so two sequences differing by a bijective renaming have
    identical containment rows - canonical cache keys make them hit the
    same server LRU entry.

    Vertices are partitioned by iterated signature refinement (a
    temporal Weisfeiler-Leman over TR occurrences: each round folds in
    the refined classes of the vertices each TR connects to) and
    ordered by final class; remaining ties are resolved *exactly* by
    minimizing the encoded bytes over the product of within-class
    permutations.  If that product exceeds ``max_candidates``
    (pathologically symmetric inputs) we fall back to raw-id order -
    the key is then no longer renaming-invariant but stays *sound*:
    any relabeled encoding equal between two sequences certifies they
    are bijective renamings of each other, so a cache hit never serves
    a wrong row."""
    # per-vertex occurrence lists, split into the color-independent
    # part (vertex TRs: computed once) and the part folding in the
    # refined class of the opposite endpoint (edge TRs: re-keyed each
    # round)
    vfix: Dict[int, List[Tuple[int, int, int]]] = {}
    edyn: Dict[int, List[Tuple[int, int, int, int]]] = {}

    def slot(v: int) -> Tuple[list, list]:
        f = vfix.get(v)
        if f is None:
            vfix[v] = f = []
            edyn[v] = []
        return f, edyn[v]

    j = 0
    for itemset in s:
        if not itemset:
            continue
        for tr in itemset:
            if tr.type <= 2:  # vertex TR
                slot(tr.u1)[0].append((j, int(tr.type), tr.label))
            else:
                row = (j, int(tr.type), tr.label)
                slot(tr.u1)[1].append(row + (tr.u2,))
                slot(tr.u2)[1].append(row + (tr.u1,))
        j += 1
    vs = sorted(vfix)
    if not vs:
        return {}
    n = len(vs)
    vid = {v: i for i, v in enumerate(vs)}
    static = [tuple(sorted(vfix[v])) for v in vs]
    dyn = [
        [(j, t, lab, vid[o]) for (j, t, lab, o) in edyn[v]] for v in vs
    ]
    color = [0] * n
    for _ in range(n):
        sig = [
            (color[i], static[i],
             tuple(sorted(
                 (j, t, lab, color[o]) for (j, t, lab, o) in dyn[i]
             )))
            for i in range(n)
        ]
        uniq = sorted(set(sig))
        ranks = {sg: r for r, sg in enumerate(uniq)}
        new = [ranks[sg] for sg in sig]
        if len(uniq) == n:  # discrete: nothing left to refine
            color = new
            break
        if new == color:
            break
        color = new
    classes: Dict[int, List[int]] = {}
    for i, c in enumerate(color):
        classes.setdefault(c, []).append(vs[i])
    ordered = [sorted(classes[c]) for c in sorted(classes)]
    if all(len(c) == 1 for c in ordered):
        return {c[0]: i for i, c in enumerate(ordered)}
    n_cand = 1
    for c in ordered:
        n_cand *= functools.reduce(lambda a, b: a * b,
                                   range(1, len(c) + 1), 1)
        if n_cand > max_candidates:
            return {v: i for i, v in enumerate(vs)}  # sound fallback
    best_bytes = None
    best_m: Dict[int, int] = {}
    for perms in itertools.product(
        *(itertools.permutations(c) for c in ordered)
    ):
        m: Dict[int, int] = {}
        i = 0
        for perm in perms:
            for v in perm:
                m[v] = i
                i += 1
        enc = _relabeled_bytes(s, m)
        if best_bytes is None or enc < best_bytes:
            best_bytes, best_m = enc, m
    return best_m


@functools.lru_cache(maxsize=1 << 12)
def sequence_fingerprint(s: TRSeq, canonical: bool = True) -> str:
    """Cache key for a data sequence: blake2b over the canonical byte
    encoding under ``canonical_sequence_map`` - invariant under vertex
    bijections (equal rows served from one LRU entry) and sound (equal
    fingerprints only for sequences with identical containment rows).
    ``canonical=False`` keys on raw vertex IDs (the pre-trie behavior;
    still sound, lower hit rate).

    The memo is a process-global LRU that retains its keyed sequences
    (canonicalization costs ~0.1ms/seq, so replays of hot queries skip
    it); its 4096 entries bound that retention independently of any
    ``PatternServer.cache_size``, and ``sequence_fingerprint
    .cache_clear()`` drops it (cold-path benchmarks do this alongside
    the server's row cache)."""
    if canonical:
        m = canonical_sequence_map(s)
    else:
        m = {}
        for itemset in s:
            for tr in itemset:
                for v in tr.vertices():
                    m.setdefault(v, v)
    h = hashlib.blake2b(digest_size=16)
    h.update(_relabeled_bytes(s, m))
    return h.hexdigest()
