"""Pattern bank: a ``MiningResult`` compiled for query-time containment.

Each mined rFTS becomes a fixed-shape *step program*: its canonical
itemsets in order, TRs sorted within each itemset, one int32 row per TR.
Replaying the program against a data sequence with the embedding join
(repro.serving.batch) grows exactly the prefix embeddings the host
oracle backtracks over, so "frontier non-empty after the last step" is
the Def-4 containment test.

Step row layout (``STEP_FIELDS`` columns, int32):
  0 type, 1 pu1, 2 pu2 (0 for vertex TRs), 3 label,
  4 new_itemset (1 = first TR of its itemset), 5 itemset index,
  6 step_valid (0 = padding row),
  7 token key = type * n_label_keys + label + 1 (the inverted-index
    bucket the step's candidate tokens live in, see batch.py)

Banks also carry per-pattern metadata rows (support, #steps, #itemsets,
#vertices, valid flag) used for top-k scoring and shard-by-pattern
serving (see sharded.py), plus the per-pattern token-key requirement
counts ``req`` [P, 6*n_label_keys] that drive the server's
necessary-condition prescreen: psi injectivity + strictly increasing phi
force distinct pattern TRs onto distinct data tokens, so a sequence can
only contain a pattern if it has at least ``req[p, k]`` tokens of every
key k.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Mapping, Sequence, Tuple, Union

import numpy as np

from ..core.canonical import canonical_code, canonical_form
from ..core.graphseq import Pattern, TRSeq, pattern_length, pattern_vertices
from ..core.gtrace import MiningResult

STEP_FIELDS = 8


@dataclasses.dataclass
class PatternBank:
    steps: np.ndarray          # [P, L, STEP_FIELDS] int32
    support: np.ndarray        # [P] int32 (0 on padding rows)
    n_steps: np.ndarray        # [P] int32
    n_itemsets: np.ndarray     # [P] int32
    n_vertices: np.ndarray     # [P] int32
    pattern_valid: np.ndarray  # [P] int32 (0 = padding row)
    req: np.ndarray            # [P, 6*n_label_keys] int32 prescreen rows
    patterns: List[Pattern]    # the n_patterns real patterns, bank order
    nv: int                    # max vertices over the bank (psi width)
    n_label_keys: int          # label slots per TR type (max label + 2)

    @property
    def n_patterns(self) -> int:
        return len(self.patterns)

    @property
    def n_rows(self) -> int:
        return self.steps.shape[0]

    @property
    def max_steps(self) -> int:
        return self.steps.shape[1]

    def shard(self, n_shards: int) -> List["PatternBank"]:
        """Split by pattern rows into ``n_shards`` equal banks (row count
        must divide; use ``pad_patterns_to`` at compile time)."""
        P = self.n_rows
        assert P % n_shards == 0, (P, n_shards)
        loc = P // n_shards
        out = []
        for i in range(n_shards):
            sl = slice(i * loc, (i + 1) * loc)
            n_real = int(self.pattern_valid[sl].sum())
            out.append(PatternBank(
                steps=self.steps[sl],
                support=self.support[sl],
                n_steps=self.n_steps[sl],
                n_itemsets=self.n_itemsets[sl],
                n_vertices=self.n_vertices[sl],
                pattern_valid=self.pattern_valid[sl],
                req=self.req[sl],
                patterns=self.patterns[i * loc : i * loc + n_real],
                nv=self.nv,
                n_label_keys=self.n_label_keys,
            ))
        return out


def pattern_steps(
    p: Pattern, n_label_keys: int
) -> List[Tuple[int, ...]]:
    """The step program of one canonical pattern."""
    rows = []
    for i, itemset in enumerate(p):
        for t_i, tr in enumerate(sorted(itemset)):
            pu2 = 0 if tr.is_vertex else tr.u2
            key = int(tr.type) * n_label_keys + tr.label + 1
            rows.append((int(tr.type), tr.u1, pu2, tr.label,
                         int(t_i == 0), i, 1, key))
    return rows


def compile_bank(
    result: Union[MiningResult, Mapping[Pattern, int]],
    *,
    max_steps: int | None = None,
    pad_patterns_to: int | None = None,
    min_support: int = 0,
    top: int | None = None,
) -> PatternBank:
    """Pack mined patterns (canonicalized) into a PatternBank.

    Patterns are ordered by (-support, canonical code) so the bank layout
    is deterministic; ``top`` keeps only the strongest patterns and
    ``pad_patterns_to`` rounds the row count up (padding rows have
    ``pattern_valid=0`` and never report containment).
    """
    items = result.patterns if isinstance(result, MiningResult) else result
    chosen = [
        (canonical_form(p), int(s))
        for p, s in items.items()
        if len(p) > 0 and s >= min_support
    ]
    chosen.sort(key=lambda ps: (-ps[1], canonical_code(ps[0])))
    if top is not None:
        chosen = chosen[:top]
    patterns = [p for p, _ in chosen]
    max_label = max(
        (tr.label for p in patterns for s in p for tr in s), default=-1
    )
    n_label_keys = max_label + 2  # labels -1..max_label
    progs = [pattern_steps(p, n_label_keys) for p in patterns]
    L = max((len(r) for r in progs), default=1)
    if max_steps is not None:
        assert max_steps >= L, (max_steps, L)
        L = max_steps
    P = len(patterns)
    rows = P
    if pad_patterns_to is not None:
        assert pad_patterns_to >= P, (pad_patterns_to, P)
        rows = pad_patterns_to
    rows = max(rows, 1)
    steps = np.zeros((rows, max(L, 1), STEP_FIELDS), dtype=np.int32)
    for pi, prog in enumerate(progs):
        for si, row in enumerate(prog):
            steps[pi, si] = row
    meta = {
        "support": [s for _, s in chosen],
        "n_steps": [len(r) for r in progs],
        "n_itemsets": [len(p) for p in patterns],
        "n_vertices": [len(pattern_vertices(p)) for p in patterns],
        "pattern_valid": [1] * P,
    }
    pad = rows - P
    arrays = {
        k: np.array(v + [0] * pad, dtype=np.int32) for k, v in meta.items()
    }
    req = np.zeros((rows, 6 * n_label_keys), dtype=np.int32)
    for pi, prog in enumerate(progs):
        for row in prog:
            req[pi, row[7]] += 1
    nv = int(arrays["n_vertices"].max(initial=0))
    assert all(pattern_length(p) <= steps.shape[1] for p in patterns)
    return PatternBank(steps=steps, patterns=patterns, nv=max(nv, 1),
                       req=req, n_label_keys=n_label_keys, **arrays)


def sequence_fingerprint(s: TRSeq) -> str:
    """Cache key for a data sequence: blake2b over a canonical byte
    encoding (TRs sorted within each itemset, empty itemsets dropped -
    they can never host a pattern itemset, so containment is invariant).
    Vertex IDs enter raw; renaming-invariant fingerprints are a
    follow-on (see ROADMAP)."""
    h = hashlib.blake2b(digest_size=16)
    for itemset in s:
        if not itemset:
            continue
        for tr in sorted(itemset):
            h.update(b"%d,%d,%d,%d;" % (tr.type, tr.u1, tr.u2, tr.label))
        h.update(b"|")
    return h.hexdigest()
