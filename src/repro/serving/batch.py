"""Batched on-device containment: TRSeq batch x pattern bank -> bool.

The Def-4 containment test is replayed as an *embedding join*: per
(sequence, pattern) cell we scan the pattern's step program (bank.py)
and maintain a fixed-capacity frontier of partial embeddings (phi over
claimed data itemsets, psi over bound data vertices).  One step
evaluates the match predicate for every
(frontier row x window token x orientation) candidate - the containment
kernel or its jnp oracle - then compacts the accepted candidates back
into the ``emax`` frontier slots.  The pattern is contained iff its
frontier is non-empty after its last step.

Three query-time reductions keep the join off the B*P*T dense wall:

* **inverted token index** - tokens are bucketed per sequence by
  (type, label) key; a step only ever scans its own bucket, a ``tmax``
  window instead of all T tokens,
* **counts prescreen** (``prescreen_counts``) - psi injectivity +
  strictly increasing phi force distinct pattern TRs onto distinct data
  tokens, so ``counts[b] >= bank.req[p]`` (per key) is a sound
  necessary condition; the server joins only surviving pairs
  (``pair_contains``), typically a small fraction,
* **sort compaction** - frontier selection is "first emax accepted
  candidates", computed with one small sort per cell (top_k is an order
  of magnitude slower on CPU backends).

Exactness: every kept embedding is a genuine prefix embedding, so
``contained=True`` is always exact - truncation (frontier or token
window) can only lose matches, and any step that may have lost one sets
the cell's ``overflow`` flag.  Only ``overflow & ~contained`` cells are
undecided; the server re-checks just those against the host oracle.

The whole scan is one jitted program (the step loop unrolls - L is
small), so a serving step costs L kernel launches regardless of bank
size, and shapes are static per (batch bucket, bank) pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.containment.containment import contain_step_blocked
from ..kernels.containment.ref import contain_step_core
from ..mining.encoding import PAD_PHI, PAD_PSI


def token_keys_np(tokens: np.ndarray, n_label_keys: int) -> np.ndarray:
    """Host mirror of the device key computation ([B,T] int, 6*NL =
    out-of-bank dump key)."""
    NL = n_label_keys
    ty, lab, val = tokens[..., 0], tokens[..., 3], tokens[..., 5]
    lab1 = lab + 1
    ok = (val > 0) & (lab1 >= 0) & (lab1 < NL)
    return np.where(ok, ty * NL + lab1, 6 * NL)


def max_key_bucket(tokens: np.ndarray, n_label_keys: int) -> int:
    """Largest same-key token bucket in the batch: the exact ``tmax``
    (no window overflow).  Host-side helper for callers of the jitted
    entry points."""
    key = token_keys_np(np.asarray(tokens), n_label_keys)
    K = 6 * n_label_keys
    B = key.shape[0]
    rowed = (key + np.arange(B)[:, None] * (K + 1)).ravel()
    rowed = rowed[(key < K).ravel()]
    if not rowed.size:
        return 1
    return max(int(np.bincount(rowed).max()), 1)


def build_token_index(tokens, *, n_label_keys: int):
    """[B,T,6] -> (order [B,T], start [B,K], count [B,K]); bucket k of
    sequence b is order[b, start[b,k] : start[b,k]+count[b,k]].  Tokens
    whose label falls outside the bank's label space go to a dump bucket
    - they can never match a bank step."""
    NL = n_label_keys
    K = 6 * NL
    B, T, _ = tokens.shape
    ty = tokens[..., 0]
    lab1 = tokens[..., 3] + 1
    ok = (tokens[..., 5] > 0) & (lab1 >= 0) & (lab1 < NL)
    key = jnp.where(ok, ty * NL + lab1, K).astype(jnp.int32)
    # composite sort key makes the order unique hence fully deterministic
    t_ids = jnp.arange(T, dtype=jnp.int32)
    order = jnp.argsort(key * T + t_ids[None, :], axis=1)
    kcol = jnp.arange(K, dtype=jnp.int32)
    count = (key[:, :, None] == kcol[None, None, :]).sum(1)
    start = jnp.cumsum(count, -1) - count
    return order.astype(jnp.int32), start.astype(jnp.int32), \
        count.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_label_keys",))
def prescreen_counts(tokens, req, *, n_label_keys: int):
    """Sound necessary condition: possible[b,p] = counts_b >= req_p
    elementwise over token keys (see bank.req)."""
    _, _, count = build_token_index(tokens, n_label_keys=n_label_keys)
    return (count[:, None, :] >= req[None, :, :]).all(-1)


@functools.partial(jax.jit, static_argnames=("n_label_keys",))
def index_and_prescreen(tokens, req, *, n_label_keys: int):
    """One pass producing both the inverted token index and the
    prescreen matrix, so a serving batch builds the index once and
    shares it across the per-group ``pair_contains_indexed`` calls."""
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    possible = (count[:, None, :] >= req[None, :, :]).all(-1)
    return order, start, count, possible


def _join(tokens, order, start, count, cell_b, cell_steps, *,
          nv, emax, tmax, use_kernel, block_g, uniform_length=False):
    """The embedding-join scan over N cells (cell i = sequence
    cell_b[i] vs step program cell_steps[i]).  ``uniform_length``
    promises every cell's program is exactly L steps (no padding rows),
    which drops the pass-through selects and lets the final step skip
    compaction and the state update entirely.  Returns
    (contained [N] bool, overflow [N] bool)."""
    B, T, _ = tokens.shape
    N, L, _ = cell_steps.shape
    NI = L  # a pattern has at most as many itemsets as steps
    NV = nv
    E, Tm = emax, tmax
    tokens = tokens.astype(jnp.int32)
    cell_steps = cell_steps.astype(jnp.int32)
    cell_b = cell_b.astype(jnp.int32)

    nv_ids = jnp.arange(NV, dtype=jnp.int32)
    ni_ids = jnp.arange(NI, dtype=jnp.int32)
    m_ids = jnp.arange(Tm, dtype=jnp.int32)

    # step 0 always joins against the single root embedding, so the
    # initial frontier is one row; compaction widens it to E rows
    phi0 = jnp.full((N, 1, NI), PAD_PHI, jnp.int32)
    psi0 = jnp.full((N, 1, NV), PAD_PSI, jnp.int32)
    valid0 = jnp.ones((N, 1), jnp.bool_)
    overflow0 = jnp.zeros((N,), jnp.bool_)

    def body(state, step_k, final):
        # NOTE: called from an unrolled python loop, not lax.scan - the
        # scan + shard_map combination miscompiles on the jax 0.4 CPU
        # backend (dropped matches on non-zero data shards), and L is
        # small enough that unrolling is also the faster choice.
        # ``final`` (uniform-length callers only, where every cell ends
        # at step L-1) short-circuits the step: containment just needs
        # "any candidate accepted", so frontier compaction and the
        # phi/psi update are skipped entirely.
        phi, psi, valid, overflow = state
        Ein = psi.shape[1]  # 1 on step 0, E afterwards
        C = Ein * Tm * 2  # candidates: frontier rows x window x orient
        cand_ids = jnp.arange(C, dtype=jnp.int32)
        ty_s, pu1_s, pu2_s, lab_s, new_s, idx_s, sval_s, key_s = (
            step_k[:, c] for c in range(8)
        )

        # ---- per-cell token window for this step's (type,label) bucket
        st_sel = start[cell_b, key_s]   # [N]
        ct_sel = count[cell_b, key_s]
        wpos = jnp.minimum(st_sel[:, None] + m_ids[None, :], T - 1)
        wvalid = m_ids[None, :] < ct_sel[:, None]
        tpos = order[cell_b[:, None], wpos]       # [N, Tm]
        tok_w = tokens[cell_b[:, None], tpos]     # [N, Tm, 6]
        tok_w = tok_w.at[..., 5].set(
            jnp.where(wvalid, tok_w[..., 5], 0)
        )

        # ---- per-row step table for the predicate
        idx_b = jnp.broadcast_to(idx_s[:, None, None], (N, Ein, 1))
        cur_phi = jnp.take_along_axis(phi, idx_b, axis=-1)[..., 0]
        prev_b = jnp.clip(idx_b - 1, 0, NI - 1)
        prev_phi = jnp.take_along_axis(phi, prev_b, axis=-1)[..., 0]
        prev_phi = jnp.where(idx_s[:, None] > 0, prev_phi, -1)
        if uniform_length:
            row_valid = valid  # every step row is a real step
        else:
            row_valid = valid & (sval_s[:, None] > 0)

        def bro(x):  # [N] -> [N, Ein]
            return jnp.broadcast_to(x[:, None], (N, Ein))

        srow = jnp.stack(
            [bro(ty_s), bro(pu1_s), bro(pu2_s), bro(lab_s), bro(new_s),
             prev_phi, cur_phi, row_valid.astype(jnp.int32)],
            axis=-1,
        )

        # ---- match predicate over (cell, row, window token)
        if use_kernel:
            bits = contain_step_blocked(tok_w, psi, srow, block_g=block_g)
        else:
            bits = contain_step_core(tok_w, psi, srow)

        # ---- compact accepted candidates into the emax frontier slots:
        # first E in (row, token, orientation) order, by iterative
        # min-extraction - E passes of trivial ops beat a [N, C] sort by
        # a wide margin on CPU and keep everything in VREG-sized tiles
        flags = (
            jnp.stack([bits & 1, (bits >> 1) & 1], -1) > 0
        ).reshape(N, C)
        # a truncated window may lose matches only if the frontier was
        # still live going into the step
        window_ovf = (ct_sel > Tm) & valid.any(-1)
        if final:
            return flags.any(-1), overflow | window_ovf
        cand_row = cand_ids[None, :]
        sels = []
        last = jnp.full((N, 1), -1, jnp.int32)
        for _ in range(E):
            cur = jnp.min(
                jnp.where(flags & (cand_row > last), cand_row, C),
                -1, keepdims=True,
            )
            sels.append(cur)
            last = cur
        # anything still flagged past the E extracted slots was dropped
        frontier_ovf = jnp.min(
            jnp.where(flags & (cand_row > last), cand_row, C), -1
        ) < C
        sel = jnp.concatenate(sels, -1)  # [N, E] ascending, C = empty
        new_valid = sel < C
        sel = jnp.minimum(sel, C - 1)
        e_old = sel // (Tm * 2)
        t_w = (sel // 2) % Tm
        var = sel % 2

        phi_src = jnp.take_along_axis(phi, e_old[..., None], axis=1)
        psi_src = jnp.take_along_axis(psi, e_old[..., None], axis=1)

        def wfield(f):  # [N, E] gather of tok_w[n, t_w, f]
            return jnp.take_along_axis(tok_w[..., f], t_w, axis=1)

        u1_g, u2_g, j_g = wfield(1), wfield(2), wfield(4)

        # phi: the first TR of a new pattern itemset claims data itemset j
        claim = (new_s[:, None] > 0) & new_valid
        onehot_ni = ni_ids[None, None, :] == idx_s[:, None, None]
        phi_new = jnp.where(
            onehot_ni & claim[..., None], j_g[..., None], phi_src
        )

        # psi: fresh pattern vertices bind per the matched orientation
        a_g = jnp.where(var == 0, u1_g, u2_g)
        b_g = jnp.where(var == 0, u2_g, u1_g)
        is_v = (ty_s <= 2)[:, None]
        pu1_b = jnp.broadcast_to(pu1_s[:, None, None], (N, E, 1))
        pu2_b = jnp.broadcast_to(pu2_s[:, None, None], (N, E, 1))
        fresh1 = jnp.take_along_axis(psi_src, pu1_b, axis=-1)[..., 0] < 0
        fresh2 = jnp.take_along_axis(psi_src, pu2_b, axis=-1)[..., 0] < 0
        onehot1 = nv_ids[None, None, :] == pu1_b
        onehot2 = nv_ids[None, None, :] == pu2_b
        assign1 = jnp.where(is_v, u1_g, a_g)
        psi_new = jnp.where(
            onehot1 & (fresh1 & new_valid)[..., None],
            assign1[..., None], psi_src,
        )
        psi_new = jnp.where(
            onehot2 & ((~is_v) & fresh2 & new_valid)[..., None],
            b_g[..., None], psi_new,
        )

        ovf_step = frontier_ovf | window_ovf
        if uniform_length:
            return (phi_new, psi_new, new_valid, ovf_step | overflow), None
        # ---- pass-through for cells already past their last step
        alive = sval_s > 0
        phi = jnp.where(alive[:, None, None], phi_new, phi)
        psi = jnp.where(alive[:, None, None], psi_new, psi)
        valid = jnp.where(alive[:, None], new_valid, valid)
        overflow = jnp.where(alive, ovf_step | overflow, overflow)
        return (phi, psi, valid, overflow), None

    state = (phi0, psi0, valid0, overflow0)
    for k in range(L):
        final = uniform_length and k == L - 1
        out = body(state, cell_steps[:, k], final)
        if final:
            return out
        state, _ = out
    _, _, valid, overflow = state
    return valid.any(-1), overflow


@functools.partial(
    jax.jit,
    static_argnames=(
        "nv", "n_label_keys", "emax", "tmax", "use_kernel", "block_g",
        "uniform_length",
    ),
)
def pair_contains(
    tokens,   # [B, T, 6] int32
    steps,    # [P, L, STEP_FIELDS] int32
    b_idx,    # [N] int32: sequence per cell
    p_idx,    # [N] int32: pattern row per cell
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
    uniform_length: bool = False,
):
    """Containment over a compacted (sequence, pattern) pair list - the
    server's post-prescreen path.  Returns (contained [N], overflow [N])."""
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    return _join(
        tokens, order, start, count, b_idx, steps[p_idx],
        nv=nv, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
        uniform_length=uniform_length,
    )


@functools.partial(
    jax.jit,
    static_argnames=("nv", "emax", "tmax", "use_kernel", "block_g",
                     "uniform_length"),
)
def pair_contains_indexed(
    tokens, order, start, count,  # tokens + prebuilt inverted index
    steps, b_idx, p_idx,
    *,
    nv: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
    uniform_length: bool = False,
):
    """``pair_contains`` with the token index precomputed (see
    ``index_and_prescreen``)."""
    return _join(
        tokens, order, start, count, b_idx, steps[p_idx],
        nv=nv, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
        uniform_length=uniform_length,
    )


def batch_contains_ref(
    tokens,         # [B, T, 6] int32 (encode_db layout)
    steps,          # [P, L, STEP_FIELDS] int32 (bank.steps)
    pattern_valid,  # [P] int32 (bank.pattern_valid)
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
):
    """Dense batch x bank containment (every cell joined; unjitted body,
    traceable inside shard_map - use ``batch_contains`` standalone).
    Returns (contained [B,P] bool, overflow [B,P] bool)."""
    B = tokens.shape[0]
    P = steps.shape[0]
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    cell_b = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)
    cell_steps = jnp.broadcast_to(
        steps[None], (B,) + steps.shape
    ).reshape(B * P, *steps.shape[1:])
    contained, overflow = _join(
        tokens, order, start, count, cell_b, cell_steps,
        nv=nv, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
    )
    real = (pattern_valid > 0)[None, :]
    return (contained.reshape(B, P) & real,
            overflow.reshape(B, P) & real)


batch_contains = functools.partial(
    jax.jit,
    static_argnames=(
        "nv", "n_label_keys", "emax", "tmax", "use_kernel", "block_g",
    ),
)(batch_contains_ref)
