"""Batched on-device containment: TRSeq batch x pattern bank -> bool.

The Def-4 containment test is replayed as an *embedding join*: per
(sequence, pattern) cell we scan the pattern's step program (bank.py)
and maintain a fixed-capacity frontier of partial embeddings (phi over
claimed data itemsets, psi over bound data vertices).  One step
evaluates the match predicate for every
(frontier row x window token x orientation) candidate - the containment
kernel or its jnp oracle - then compacts the accepted candidates back
into the ``emax`` frontier slots.  The pattern is contained iff its
frontier is non-empty after its last step.

Three query-time reductions keep the join off the B*P*T dense wall:

* **inverted token index** - tokens are bucketed per sequence by
  (type, label) key; a step only ever scans its own bucket, a ``tmax``
  window instead of all T tokens,
* **counts prescreen** (``prescreen_counts``) - psi injectivity +
  strictly increasing phi force distinct pattern TRs onto distinct data
  tokens, so ``counts[b] >= bank.req[p]`` (per key) is a sound
  necessary condition; the server joins only surviving pairs
  (``pair_contains``), typically a small fraction.  The streaming
  layer's tombstone mask rides on this: a masked pattern's ``req`` row
  (or a dead trie subtree's ``node_req``) is set to ``trie.REQ_MASKED``,
  which no count vector satisfies, so tombstoned rows are pruned here
  at zero join cost,
* **sort compaction** - frontier selection is "first emax accepted
  candidates", computed with one small sort per cell (top_k is an order
  of magnitude slower on CPU backends).

Exactness: every kept embedding is a genuine prefix embedding, so
``contained=True`` is always exact - truncation (frontier or token
window) can only lose matches, and any step that may have lost one sets
the cell's ``overflow`` flag.  Only ``overflow & ~contained`` cells are
undecided; the server re-checks just those against the host oracle.

The whole scan is one jitted program (the step loop unrolls - L is
small), so a serving step costs L kernel launches regardless of bank
size, and shapes are static per (batch bucket, bank) pair.

**Trie layout** (trie.py): the same step dynamics, but one frontier per
(sequence, trie *node*) instead of per (sequence, pattern) - a
level-synchronous scan over trie depth where a node's frontier is
seeded from its parent's compacted frontier, so patterns sharing a
program prefix share its join work.  Entry points: dense
``trie_contains`` (shard_map-able via ``trie_contains_ref``), and the
server's per-level ``trie_root_advance`` /
``trie_level_advance_gather`` (seed gather fused into the jitted
program - one dispatch per level) with the per-node residual-``req``
prescreen ``index_and_node_prescreen``.  Because ``_step_once`` is
shared and deterministic, trie and flat joins are bit-identical in both
``contained`` and ``overflow``; the soundness contract above carries
over unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.containment.containment import contain_step_blocked
from ..kernels.containment.ref import contain_step_core
from ..kernels.trie_walk import ref as _fused_ref
from ..kernels.trie_walk import trie_walk_blocked, trie_walk_core
from ..mining.encoding import PAD_PHI, PAD_PSI
from .trie import REQ_MASKED

# the kernels layer mirrors the serving constants locally (it stays
# import-free of repro.serving); pin the mirrors here so a drift breaks
# loudly at import instead of silently de-synchronizing the fused walk
assert _fused_ref.PAD_PHI == int(PAD_PHI)
assert _fused_ref.PAD_PSI == int(PAD_PSI)
assert _fused_ref.REQ_MASKED == REQ_MASKED


def token_keys_np(tokens: np.ndarray, n_label_keys: int) -> np.ndarray:
    """Host mirror of the device key computation ([B,T] int, 6*NL =
    out-of-bank dump key)."""
    NL = n_label_keys
    ty, lab, val = tokens[..., 0], tokens[..., 3], tokens[..., 5]
    lab1 = lab + 1
    ok = (val > 0) & (lab1 >= 0) & (lab1 < NL)
    return np.where(ok, ty * NL + lab1, 6 * NL)


def max_key_bucket(tokens: np.ndarray, n_label_keys: int) -> int:
    """Largest same-key token bucket in the batch: the exact ``tmax``
    (no window overflow).  Host-side helper for callers of the jitted
    entry points."""
    key = token_keys_np(np.asarray(tokens), n_label_keys)
    K = 6 * n_label_keys
    B = key.shape[0]
    rowed = (key + np.arange(B)[:, None] * (K + 1)).ravel()
    rowed = rowed[(key < K).ravel()]
    if not rowed.size:
        return 1
    return max(int(np.bincount(rowed).max()), 1)


def build_token_index(tokens, *, n_label_keys: int):
    """[B,T,6] -> (order [B,T], start [B,K], count [B,K]); bucket k of
    sequence b is order[b, start[b,k] : start[b,k]+count[b,k]].  Tokens
    whose label falls outside the bank's label space go to a dump bucket
    - they can never match a bank step."""
    NL = n_label_keys
    K = 6 * NL
    B, T, _ = tokens.shape
    ty = tokens[..., 0]
    lab1 = tokens[..., 3] + 1
    ok = (tokens[..., 5] > 0) & (lab1 >= 0) & (lab1 < NL)
    key = jnp.where(ok, ty * NL + lab1, K).astype(jnp.int32)
    # composite sort key makes the order unique hence fully deterministic
    t_ids = jnp.arange(T, dtype=jnp.int32)
    order = jnp.argsort(key * T + t_ids[None, :], axis=1)
    kcol = jnp.arange(K, dtype=jnp.int32)
    count = (key[:, :, None] == kcol[None, None, :]).sum(1)
    start = jnp.cumsum(count, -1) - count
    return order.astype(jnp.int32), start.astype(jnp.int32), \
        count.astype(jnp.int32)


# jitted alias of build_token_index: the index depends on the query
# batch alone (never on the bank), so the cluster router builds it once
# per flush and ships it to every shard (server.encode_queries)
token_index = jax.jit(
    build_token_index, static_argnames=("n_label_keys",)
)


@functools.partial(jax.jit, static_argnames=("n_label_keys",))
def prescreen_counts(tokens, req, *, n_label_keys: int):
    """Sound necessary condition: possible[b,p] = counts_b >= req_p
    elementwise over token keys (see bank.req)."""
    _, _, count = build_token_index(tokens, n_label_keys=n_label_keys)
    return (count[:, None, :] >= req[None, :, :]).all(-1)


@functools.partial(jax.jit, static_argnames=("n_label_keys",))
def index_and_prescreen(tokens, req, *, n_label_keys: int):
    """One pass producing both the inverted token index and the
    prescreen matrix, so a serving batch builds the index once and
    shares it across the per-group ``pair_contains_indexed`` calls."""
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    possible = (count[:, None, :] >= req[None, :, :]).all(-1)
    return order, start, count, possible


def _step_once(tokens, order, start, count, cell_b, step_k, phi, psi,
               valid, *, emax, tmax, use_kernel, block_g, uniform,
               compact, count_frontier_ovf=False):
    """One embedding-join step for N cells: evaluate the match predicate
    for every (frontier row x window token x orientation) candidate of
    step row ``step_k[i]`` against sequence ``cell_b[i]``, then compact
    the accepted candidates into ``emax`` frontier slots.

    This is the shared core of both bank layouts: the flat per-pattern
    scan (``_join``) replays each pattern's whole program through it,
    the trie join advances one frontier per (sequence, trie node) and
    calls it once per trie level.  ``uniform`` promises every step row
    is real (no ``step_valid=0`` padding), dropping one select.

    Returns ``(phi_new, psi_new, new_valid, step_ovf)`` with
    ``step_ovf = frontier_ovf | window_ovf``; with ``compact=False``
    (terminal steps, where only "any candidate accepted" is needed)
    skips compaction entirely and returns ``(accepted, step_ovf)``.
    There ``count_frontier_ovf`` picks the overflow semantics: False
    omits frontier overflow (exact and cheaper - nothing follows a
    terminal step, so dropped candidates cannot lose anything; the
    uniform-length flat path and the server's trie leaves do this),
    True folds in ``#accepted > emax``, which equals the compacted
    path's frontier flag bit-for-bit (dense ``trie_contains`` uses it
    to stay bit-identical to dense ``batch_contains``, whose unpadded
    final steps do run compaction).
    """
    T = tokens.shape[1]
    N, Ein, NI = phi.shape  # Ein: 1 on the root frontier, E afterwards
    NV = psi.shape[2]
    E, Tm = emax, tmax
    C = Ein * Tm * 2  # candidates: frontier rows x window x orient
    nv_ids = jnp.arange(NV, dtype=jnp.int32)
    ni_ids = jnp.arange(NI, dtype=jnp.int32)
    m_ids = jnp.arange(Tm, dtype=jnp.int32)
    cand_ids = jnp.arange(C, dtype=jnp.int32)
    ty_s, pu1_s, pu2_s, lab_s, new_s, idx_s, sval_s, key_s = (
        step_k[:, c] for c in range(8)
    )

    # ---- per-cell token window for this step's (type,label) bucket
    st_sel = start[cell_b, key_s]   # [N]
    ct_sel = count[cell_b, key_s]
    wpos = jnp.minimum(st_sel[:, None] + m_ids[None, :], T - 1)
    wvalid = m_ids[None, :] < ct_sel[:, None]
    tpos = order[cell_b[:, None], wpos]       # [N, Tm]
    tok_w = tokens[cell_b[:, None], tpos]     # [N, Tm, 6]
    tok_w = tok_w.at[..., 5].set(
        jnp.where(wvalid, tok_w[..., 5], 0)
    )

    # ---- per-row step table for the predicate
    idx_b = jnp.broadcast_to(idx_s[:, None, None], (N, Ein, 1))
    cur_phi = jnp.take_along_axis(phi, idx_b, axis=-1)[..., 0]
    prev_b = jnp.clip(idx_b - 1, 0, NI - 1)
    prev_phi = jnp.take_along_axis(phi, prev_b, axis=-1)[..., 0]
    prev_phi = jnp.where(idx_s[:, None] > 0, prev_phi, -1)
    if uniform:
        row_valid = valid  # every step row is a real step
    else:
        row_valid = valid & (sval_s[:, None] > 0)

    def bro(x):  # [N] -> [N, Ein]
        return jnp.broadcast_to(x[:, None], (N, Ein))

    srow = jnp.stack(
        [bro(ty_s), bro(pu1_s), bro(pu2_s), bro(lab_s), bro(new_s),
         prev_phi, cur_phi, row_valid.astype(jnp.int32)],
        axis=-1,
    )

    # ---- match predicate over (cell, row, window token)
    if use_kernel:
        bits = contain_step_blocked(tok_w, psi, srow, block_g=block_g)
    else:
        bits = contain_step_core(tok_w, psi, srow)

    # ---- compact accepted candidates into the emax frontier slots:
    # first E in (row, token, orientation) order, by iterative
    # min-extraction - E passes of trivial ops beat a [N, C] sort by
    # a wide margin on CPU and keep everything in VREG-sized tiles
    flags = (
        jnp.stack([bits & 1, (bits >> 1) & 1], -1) > 0
    ).reshape(N, C)
    # a truncated window may lose matches only if the frontier was
    # still live going into the step
    window_ovf = (ct_sel > Tm) & valid.any(-1)
    if not compact:
        if count_frontier_ovf:
            # equals the compacted path's frontier flag: the first-E
            # extraction leaves a flagged candidate iff #accepted > E
            frontier_ovf = flags.sum(-1) > E
            return flags.any(-1), window_ovf | frontier_ovf
        return flags.any(-1), window_ovf
    cand_row = cand_ids[None, :]
    sels = []
    last = jnp.full((N, 1), -1, jnp.int32)
    for _ in range(E):
        cur = jnp.min(
            jnp.where(flags & (cand_row > last), cand_row, C),
            -1, keepdims=True,
        )
        sels.append(cur)
        last = cur
    # anything still flagged past the E extracted slots was dropped
    frontier_ovf = jnp.min(
        jnp.where(flags & (cand_row > last), cand_row, C), -1
    ) < C
    sel = jnp.concatenate(sels, -1)  # [N, E] ascending, C = empty
    new_valid = sel < C
    sel = jnp.minimum(sel, C - 1)
    e_old = sel // (Tm * 2)
    t_w = (sel // 2) % Tm
    var = sel % 2

    phi_src = jnp.take_along_axis(phi, e_old[..., None], axis=1)
    psi_src = jnp.take_along_axis(psi, e_old[..., None], axis=1)

    def wfield(f):  # [N, E] gather of tok_w[n, t_w, f]
        return jnp.take_along_axis(tok_w[..., f], t_w, axis=1)

    u1_g, u2_g, j_g = wfield(1), wfield(2), wfield(4)

    # phi: the first TR of a new pattern itemset claims data itemset j
    claim = (new_s[:, None] > 0) & new_valid
    onehot_ni = ni_ids[None, None, :] == idx_s[:, None, None]
    phi_new = jnp.where(
        onehot_ni & claim[..., None], j_g[..., None], phi_src
    )

    # psi: fresh pattern vertices bind per the matched orientation
    a_g = jnp.where(var == 0, u1_g, u2_g)
    b_g = jnp.where(var == 0, u2_g, u1_g)
    is_v = (ty_s <= 2)[:, None]
    pu1_b = jnp.broadcast_to(pu1_s[:, None, None], (N, E, 1))
    pu2_b = jnp.broadcast_to(pu2_s[:, None, None], (N, E, 1))
    fresh1 = jnp.take_along_axis(psi_src, pu1_b, axis=-1)[..., 0] < 0
    fresh2 = jnp.take_along_axis(psi_src, pu2_b, axis=-1)[..., 0] < 0
    onehot1 = nv_ids[None, None, :] == pu1_b
    onehot2 = nv_ids[None, None, :] == pu2_b
    assign1 = jnp.where(is_v, u1_g, a_g)
    psi_new = jnp.where(
        onehot1 & (fresh1 & new_valid)[..., None],
        assign1[..., None], psi_src,
    )
    psi_new = jnp.where(
        onehot2 & ((~is_v) & fresh2 & new_valid)[..., None],
        b_g[..., None], psi_new,
    )
    return phi_new, psi_new, new_valid, frontier_ovf | window_ovf


def _join(tokens, order, start, count, cell_b, cell_steps, *,
          nv, emax, tmax, use_kernel, block_g, uniform_length=False):
    """The embedding-join scan over N cells (cell i = sequence
    cell_b[i] vs step program cell_steps[i]).  ``uniform_length``
    promises every cell's program is exactly L steps (no padding rows),
    which drops the pass-through selects and lets the final step skip
    compaction and the state update entirely.  Returns
    (contained [N] bool, overflow [N] bool)."""
    N, L, _ = cell_steps.shape
    NI = L  # a pattern has at most as many itemsets as steps
    tokens = tokens.astype(jnp.int32)
    cell_steps = cell_steps.astype(jnp.int32)
    cell_b = cell_b.astype(jnp.int32)

    # step 0 always joins against the single root embedding, so the
    # initial frontier is one row; compaction widens it to E rows
    phi = jnp.full((N, 1, NI), PAD_PHI, jnp.int32)
    psi = jnp.full((N, 1, nv), PAD_PSI, jnp.int32)
    valid = jnp.ones((N, 1), jnp.bool_)
    overflow = jnp.zeros((N,), jnp.bool_)

    # NOTE: an unrolled python loop, not lax.scan - the scan + shard_map
    # combination miscompiles on the jax 0.4 CPU backend (dropped
    # matches on non-zero data shards, see the gated repro in
    # tests/test_scan_shardmap.py), and L is small enough that
    # unrolling is also the faster choice.
    for k in range(L):
        step_k = cell_steps[:, k]
        if uniform_length and k == L - 1:
            # every cell ends at step L-1: containment just needs "any
            # candidate accepted", so compaction is skipped entirely
            accepted, window_ovf = _step_once(
                tokens, order, start, count, cell_b, step_k,
                phi, psi, valid, emax=emax, tmax=tmax,
                use_kernel=use_kernel, block_g=block_g,
                uniform=True, compact=False,
            )
            return accepted, overflow | window_ovf
        phi_new, psi_new, new_valid, ovf_step = _step_once(
            tokens, order, start, count, cell_b, step_k,
            phi, psi, valid, emax=emax, tmax=tmax,
            use_kernel=use_kernel, block_g=block_g,
            uniform=uniform_length, compact=True,
        )
        if uniform_length:
            phi, psi, valid = phi_new, psi_new, new_valid
            overflow = overflow | ovf_step
        else:
            # ---- pass-through for cells already past their last step
            alive = step_k[:, 6] > 0
            phi = jnp.where(alive[:, None, None], phi_new, phi)
            psi = jnp.where(alive[:, None, None], psi_new, psi)
            valid = jnp.where(alive[:, None], new_valid, valid)
            overflow = jnp.where(alive, ovf_step | overflow, overflow)
    return valid.any(-1), overflow


@functools.partial(
    jax.jit,
    static_argnames=(
        "nv", "n_label_keys", "emax", "tmax", "use_kernel", "block_g",
        "uniform_length",
    ),
)
def pair_contains(
    tokens,   # [B, T, 6] int32
    steps,    # [P, L, STEP_FIELDS] int32
    b_idx,    # [N] int32: sequence per cell
    p_idx,    # [N] int32: pattern row per cell
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
    uniform_length: bool = False,
):
    """Containment over a compacted (sequence, pattern) pair list - the
    server's post-prescreen path.  Returns (contained [N], overflow [N])."""
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    return _join(
        tokens, order, start, count, b_idx, steps[p_idx],
        nv=nv, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
        uniform_length=uniform_length,
    )


@functools.partial(
    jax.jit,
    static_argnames=("nv", "emax", "tmax", "use_kernel", "block_g",
                     "uniform_length"),
)
def pair_contains_indexed(
    tokens, order, start, count,  # tokens + prebuilt inverted index
    steps, b_idx, p_idx,
    *,
    nv: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
    uniform_length: bool = False,
):
    """``pair_contains`` with the token index precomputed (see
    ``index_and_prescreen``)."""
    return _join(
        tokens, order, start, count, b_idx, steps[p_idx],
        nv=nv, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
        uniform_length=uniform_length,
    )


# --------------------------------------------------------------- trie join
#
# The trie layout (trie.py) deduplicates shared prefix work: instead of
# one frontier per (sequence, pattern) replaying the whole program, the
# join advances one frontier per (sequence, trie node) in a
# level-synchronous scan over trie depth - a node's frontier is seeded
# from its parent's compacted frontier, so sibling patterns pay for
# their common prefix exactly once.  The per-step dynamics are the same
# ``_step_once`` as the flat join (same candidate order, same first-emax
# compaction, same overflow flags), so for every pattern the frontier
# sequence along its root-to-terminal path is *bit-identical* to the
# flat join's - contained AND overflow agree exactly, and the
# overflow-soundness contract carries over unchanged.


def trie_root_state(n: int, ni: int, nv: int):
    """The seed state for depth-1 trie cells: one root embedding per
    cell, exactly the flat join's step-0 frontier."""
    phi = jnp.full((n, 1, ni), PAD_PHI, jnp.int32)
    psi = jnp.full((n, 1, nv), PAD_PSI, jnp.int32)
    valid = jnp.ones((n, 1), jnp.bool_)
    ovf = jnp.zeros((n,), jnp.bool_)
    return phi, psi, valid, ovf


def trie_level_advance_ref(
    tokens, order, start, count,   # tokens + prebuilt inverted index
    seed_phi, seed_psi, seed_valid, seed_ovf,  # [N,Ein,*], [N,Ein], [N]
    cell_b, cell_step,             # [N], [N, STEP_FIELDS]
    *,
    emax: int,
    tmax: int,
    use_kernel: bool = False,
    block_g: int = 64,
    compact: bool = True,
    count_frontier_ovf: bool = False,
):
    """Advance N (sequence, trie node) cells one step from their seeded
    parent frontiers - the server's per-level entry point.  Returns
    ``(phi, psi, valid, accepted [N], ovf_state [N], ovf_term [N])``;
    with ``compact=False`` (leaf cells) just ``(accepted, ovf)``, where
    ``count_frontier_ovf`` selects the terminal-step overflow semantics
    (see ``_step_once``).  ``ovf_state`` (path frontier + window
    losses) is what children must inherit; ``ovf_term`` drops this
    step's own frontier overflow - the accept bit is exact no matter
    what compaction dropped, so a terminal ending *here* is undecided
    only via ``ovf_term`` (exactly the flat uniform-length semantics;
    using ``ovf_state`` for terminals would spuriously escalate).
    Padding cells carry ``step_valid=0`` rows, ``accepted=False``."""
    tokens = tokens.astype(jnp.int32)
    cell_step = cell_step.astype(jnp.int32)
    cell_b = cell_b.astype(jnp.int32)
    if not compact:
        accepted, step_ovf = _step_once(
            tokens, order, start, count, cell_b, cell_step,
            seed_phi, seed_psi, seed_valid, emax=emax, tmax=tmax,
            use_kernel=use_kernel, block_g=block_g,
            uniform=False, compact=False,
            count_frontier_ovf=count_frontier_ovf,
        )
        return accepted, seed_ovf | step_ovf
    phi, psi, valid, ovf_step = _step_once(
        tokens, order, start, count, cell_b, cell_step,
        seed_phi, seed_psi, seed_valid, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
        uniform=False, compact=True,
    )
    ct_sel = count[cell_b, cell_step[:, 7]]
    window_ovf = (ct_sel > tmax) & seed_valid.any(-1)
    return (phi, psi, valid, valid.any(-1), seed_ovf | ovf_step,
            seed_ovf | window_ovf)


trie_level_advance = functools.partial(
    jax.jit,
    static_argnames=("emax", "tmax", "use_kernel", "block_g", "compact",
                     "count_frontier_ovf"),
)(trie_level_advance_ref)


@functools.partial(
    jax.jit,
    static_argnames=("ni", "nv", "emax", "tmax", "use_kernel", "block_g",
                     "compact"),
)
def trie_root_advance(
    tokens, order, start, count, cells,
    *,
    ni: int,
    nv: int,
    emax: int,
    tmax: int,
    use_kernel: bool = False,
    block_g: int = 64,
    compact: bool = True,
):
    """``trie_level_advance`` for depth-1 cells: the root seed (one
    root embedding per cell) is built inside the jitted program, so the
    whole level costs a single dispatch.  ``cells`` packs
    ``[cell_b, parent_idx(unused), step row]`` as one [N, 2+F] int32
    upload (the server's per-call host->device traffic)."""
    seed = trie_root_state(cells.shape[0], ni, nv)
    return trie_level_advance_ref(
        tokens, order, start, count, *seed, cells[:, 0], cells[:, 2:],
        emax=emax, tmax=tmax, use_kernel=use_kernel, block_g=block_g,
        compact=compact,
    )


@functools.partial(
    jax.jit,
    static_argnames=("emax", "tmax", "use_kernel", "block_g", "compact"),
)
def trie_level_advance_gather(
    tokens, order, start, count,
    prev_phi, prev_psi, prev_valid, prev_ovf,  # previous level's cells
    cells,  # [N, 2+F] int32: cell_b, parent cell index, step row
    *,
    emax: int,
    tmax: int,
    use_kernel: bool = False,
    block_g: int = 64,
    compact: bool = True,
):
    """``trie_level_advance`` with the parent-frontier gather fused into
    the jitted program (cell i seeds from the previous level's cell
    ``cells[i, 1]``) - one dispatch and one host upload per level
    instead of four eager gathers plus three uploads plus the advance.
    """
    pidx = cells[:, 1]
    seed = (prev_phi[pidx], prev_psi[pidx], prev_valid[pidx],
            prev_ovf[pidx])
    return trie_level_advance_ref(
        tokens, order, start, count, *seed, cells[:, 0], cells[:, 2:],
        emax=emax, tmax=tmax, use_kernel=use_kernel, block_g=block_g,
        compact=compact,
    )


@functools.partial(jax.jit, static_argnames=("n_label_keys",))
def index_and_node_prescreen(tokens, node_req, *, n_label_keys: int):
    """Inverted token index plus the per-node residual-``req`` prescreen
    (trie.py): ``possible[b, n] = counts_b >= node_req_n`` elementwise.
    Monotone up the trie, so a failing node prunes its whole subtree at
    its highest failing ancestor."""
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    possible = (count[:, None, :] >= node_req[None, :, :]).all(-1)
    return order, start, count, possible


@functools.partial(
    jax.jit,
    static_argnames=("ni", "nv", "emax", "tmax", "use_kernel", "block_n"),
)
def fused_trie_walk(
    tokens, order, start, count,  # tokens + prebuilt inverted index
    cells,      # [N, 2] int32: (sequence index, packed subtree index)
    steps_s,    # [Sp, Nmax, STEP_FIELDS] int32 (SubtreePack.steps)
    parent_s,   # [Sp, Nmax] int32 (SubtreePack.parent)
    req_s,      # [Sp, Nmax, K] int32 (SubtreePack.pack_req)
    *,
    ni: int,
    nv: int,
    emax: int,
    tmax: int,
    use_kernel: bool = False,
    block_n: int = 8,
):
    """The fused megakernel's serving entry point: walk N (sequence,
    depth-1 subtree) cells through their *entire* subtree in one jitted
    program - the per-cell gathers (the sequence's token table + index
    rows by ``cells[:, 0]``, the packed subtree tables by
    ``cells[:, 1]``) are fused in front of the walk, so the whole batch
    costs a single dispatch regardless of trie depth.  Returns
    ``(acc [N, Nmax] bool, ovf_term [N, Nmax] bool)`` per subtree slot,
    bit-identical to the per-level ``trie_root_advance`` /
    ``trie_level_advance_gather`` ladder (kernels.trie_walk.ref has the
    exact contract).  ``ni`` must be the *global* trie depth (same as
    the per-level path) for bitwise frontier-state identity."""
    cell_b = cells[:, 0]
    s_idx = cells[:, 1]
    tokens = tokens.astype(jnp.int32)
    tok_c = tokens[cell_b]
    order_c = order[cell_b]
    start_c = start[cell_b]
    count_c = count[cell_b]
    steps_c = steps_s[s_idx]
    parent_c = parent_s[s_idx]
    req_c = req_s[s_idx]
    if use_kernel:
        acc, ovft = trie_walk_blocked(
            tok_c, order_c, start_c, count_c, steps_c, parent_c, req_c,
            emax=emax, tmax=tmax, ni=ni, nv=nv, block_n=block_n,
        )
        return acc > 0, ovft > 0
    return trie_walk_core(
        tok_c, order_c, start_c, count_c, steps_c, parent_c, req_c,
        emax=emax, tmax=tmax, ni=ni, nv=nv,
    )


def trie_contains_ref(
    tokens,          # [B, T, 6] int32 (encode_db layout)
    lvl_steps,       # [D, Mh, STEP_FIELDS] int32 (TrieLevels.steps)
    lvl_parent_pos,  # [D, Mh] int32
    term_level,      # [P] int32 (TrieLevels.term_level)
    term_pos,        # [P] int32
    pattern_valid,   # [P] int32
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
):
    """Dense level-synchronous trie containment: every (sequence, trie
    node) cell advances once per level, seeded from its parent's
    compacted frontier; pattern answers are read off at their terminal
    (level, position).  Unjitted body, traceable inside shard_map - use
    ``trie_contains`` standalone.  Bit-identical to ``batch_contains``
    over the same bank.  Returns (contained [B,P] bool, ovf [B,P] bool).
    """
    B = tokens.shape[0]
    D, Mh, _ = lvl_steps.shape
    P = pattern_valid.shape[0]
    NI = D  # a pattern has at most as many itemsets as trie levels
    tokens = tokens.astype(jnp.int32)
    lvl_steps = lvl_steps.astype(jnp.int32)
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    cell_b = jnp.repeat(jnp.arange(B, dtype=jnp.int32), Mh)
    # virtual root level: one root embedding per sequence
    phi, psi, valid, _ = trie_root_state(B, NI, nv)
    phi = phi[:, None]          # [B, Mprev=1, Ein=1, NI]
    psi = psi[:, None]
    valid = valid[:, None]
    ovf = jnp.zeros((B, 1), jnp.bool_)
    accs, ovfs = [], []
    for d in range(D):
        pp = lvl_parent_pos[d]  # [Mh] (all zeros on level 0)
        seed_phi = phi[:, pp].reshape(B * Mh, *phi.shape[2:])
        seed_psi = psi[:, pp].reshape(B * Mh, *psi.shape[2:])
        seed_valid = valid[:, pp].reshape(B * Mh, valid.shape[2])
        seed_ovf = ovf[:, pp].reshape(B * Mh)
        step_d = jnp.broadcast_to(
            lvl_steps[d][None], (B, Mh, lvl_steps.shape[2])
        ).reshape(B * Mh, lvl_steps.shape[2])
        if d == D - 1:
            # the deepest level is all leaves: skip compaction but keep
            # the compacted path's frontier-overflow semantics so the
            # dense outputs stay bit-identical to batch_contains
            accepted, lovf = trie_level_advance_ref(
                tokens, order, start, count,
                seed_phi, seed_psi, seed_valid, seed_ovf,
                cell_b, step_d, emax=emax, tmax=tmax,
                use_kernel=use_kernel, block_g=block_g, compact=False,
                count_frontier_ovf=True,
            )
        else:
            # dense outputs use the full path overflow (ovf_state) for
            # terminals too: that is what batch_contains reports (its
            # unpadded final steps run compaction), and the dense
            # contract is bit-identity with it
            nphi, npsi, nvalid, accepted, lovf, _ = \
                trie_level_advance_ref(
                    tokens, order, start, count,
                    seed_phi, seed_psi, seed_valid, seed_ovf,
                    cell_b, step_d, emax=emax, tmax=tmax,
                    use_kernel=use_kernel, block_g=block_g,
                    compact=True,
                )
            phi = nphi.reshape(B, Mh, *nphi.shape[1:])
            psi = npsi.reshape(B, Mh, *npsi.shape[1:])
            valid = nvalid.reshape(B, Mh, nvalid.shape[1])
            ovf = lovf.reshape(B, Mh)
        accs.append(accepted.reshape(B, Mh))
        ovfs.append(lovf.reshape(B, Mh))
    if not accs:  # empty trie: nothing is ever contained
        zero = jnp.zeros((B, P), jnp.bool_)
        return zero, zero
    A = jnp.stack(accs)   # [D, B, Mh]
    O = jnp.stack(ovfs)
    real = (pattern_valid > 0)[None, :]
    contained = A[term_level, :, term_pos].T & real
    overflow = O[term_level, :, term_pos].T & real
    return contained, overflow


trie_contains = functools.partial(
    jax.jit,
    static_argnames=(
        "nv", "n_label_keys", "emax", "tmax", "use_kernel", "block_g",
    ),
)(trie_contains_ref)


def batch_contains_ref(
    tokens,         # [B, T, 6] int32 (encode_db layout)
    steps,          # [P, L, STEP_FIELDS] int32 (bank.steps)
    pattern_valid,  # [P] int32 (bank.pattern_valid)
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    use_kernel: bool = False,
    block_g: int = 64,
):
    """Dense batch x bank containment (every cell joined; unjitted body,
    traceable inside shard_map - use ``batch_contains`` standalone).
    Returns (contained [B,P] bool, overflow [B,P] bool)."""
    B = tokens.shape[0]
    P = steps.shape[0]
    order, start, count = build_token_index(
        tokens, n_label_keys=n_label_keys
    )
    cell_b = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)
    cell_steps = jnp.broadcast_to(
        steps[None], (B,) + steps.shape
    ).reshape(B * P, *steps.shape[1:])
    contained, overflow = _join(
        tokens, order, start, count, cell_b, cell_steps,
        nv=nv, emax=emax, tmax=tmax,
        use_kernel=use_kernel, block_g=block_g,
    )
    real = (pattern_valid > 0)[None, :]
    return (contained.reshape(B, P) & real,
            overflow.reshape(B, P) & real)


batch_contains = functools.partial(
    jax.jit,
    static_argnames=(
        "nv", "n_label_keys", "emax", "tmax", "use_kernel", "block_g",
    ),
)(batch_contains_ref)
