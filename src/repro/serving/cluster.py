"""Multi-host serving cluster: sharded bank, routed queries, and the
sharded-window streaming protocol.

GTRACE-RS decomposes the pattern space into independent reverse-search
subtrees, so the mined bank shards with *zero cross-shard joins* -
``sharded.py`` exploits that on a single-host device mesh; this module
lifts it to a cluster of hosts.  Three topologies:

* ``ServingCluster`` - a static bank split across hosts
  (``router.plan_placement``: depth-1 trie subtrees stay intact per
  host; flat banks split by pattern range).  Queries arrive on any
  host; ``ClusterRouter`` drains them together, resolves the two-level
  cache (host-local L1, fingerprint-owner L2 - both keyed by the
  renaming-invariant ``sequence_fingerprint``), batches the misses into
  shared pow-2 device batches per shard, and merges per-shard rows into
  global bank order.  Routed answers (containment bits, top-k, resolved
  overflow) are bit-equal to a single-host ``PatternServer``.

* ``ShardedStreamingBank`` - the sharded-window protocol.  Each host
  owns a *slice of the ring buffer* (arrival ``i`` lands on host ``i %
  n_hosts``, so the union of slices is always the window's most recent
  ``window`` sequences) plus its bank shard.  An arrival is joined once
  against every bank shard *on the shard's owner* (the routed
  containment batch), and the merged row is stored on the arrival's
  ring owner, which maintains *partial* supports - increments on
  arrival, decrements from the stored bitmap on eviction, no re-join.
  ``refresh()`` is the only synchronisation point: partial supports are
  **all-reduced** (summed across ring slices - exact because the slices
  partition the window, the Campagna-Pagh stream decomposition), the
  per-child dirtiness index is all-reduced at depth-1-subtree
  granularity (O(#subtrees) flags per host instead of a bank-width bit
  row; sound because dirt is anti-monotone up the parent chain), and
  the incremental frontier re-mine + tombstone cut run against exact
  global supports.  Between refreshes nothing is masked, so per-host
  partial supports stay exact for every active row; post-refresh the
  frequent map is bit-equal to a batch re-mine of the window (and hence
  to the single-host ``StreamingBank`` on the same arrivals).

* ``ReplicaGroup`` - single-writer / read-replica mode.  One writer
  runs the ordinary ``StreamingBank`` (observe / tombstone / refresh);
  replicas serve the masked bank and apply the writer's shipped deltas
  (``StreamingBank.delta_sink``): support updates, tombstone masks, and
  - after an incremental refresh - ``extend_bank``/``extend_trie``
  appends instead of a recompile.  Until a replica syncs it keeps
  serving its previous masked bank, so reads never block on a writer
  refresh.

Choosing between the streaming topologies: **read replicas** scale
*query* throughput (every replica serves the whole bank; arrivals still
funnel through the one writer) and replicas lag by the unshipped
deltas.  The **sharded window** scales *arrival* throughput too (the
per-arrival join fans out across shards, ring upkeep is per-host) and
serves exact containment at every moment, but support freshness for
tombstoning is per-refresh, and every query touches all shards.  Use
replicas for read-heavy/low-churn traffic, the sharded window when the
arrival stream itself is the load.

Hosts are an abstraction: ``ClusterHost.call`` is the host boundary.
The in-process ``ClusterHost`` (optionally pinned to one jax device -
the subprocess smoke test runs 8 virtual CPU devices, one per host)
just calls; a ``jax.distributed``-style process group implements the
same interface with RPCs, following the subprocess pattern in
tests/test_distributed.py.  Everything above the boundary is therefore
property-testable on CPU: after any routed batch or sharded refresh,
results and the frequent map must be bit-equal to the single-host
``PatternServer``/``StreamingBank`` on the same inputs
(tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

import jax

from ..core.graphseq import Pattern, TRSeq
from ..mining.driver import AcceleratedMiner
from ..obs import trace
from ..obs.metrics import MetricsRegistry
from ..mining.incremental import depth1_root, refresh_frontier, \
    subtree_dirty_rows
from .bank import BankCapacityError, PatternBank, compile_bank, \
    extend_bank, slice_bank
from .faults import HostDownError, RecoveryLog
from .layouts import get_layout
from .router import BankPlacement, ClusterRouter, plan_placement
from .server import PatternServer, QueryResult, score_topk
from .streaming import StreamingBank
from .trie import TrieBank, build_trie, extend_trie


@dataclasses.dataclass
class ClusterHost:
    """One simulated host: its bank shard server, owned global rows,
    and the two cache levels.  ``call`` is the host boundary - every
    cross-host access in this module goes through it.  An installed
    ``FaultInjector`` (serving.faults) is consulted *before* the
    wrapped function runs, so an injected fault never half-executes a
    call - exactly the semantics of a dropped RPC."""

    hid: int
    rows: np.ndarray               # owned global bank rows
    server: PatternServer          # over slice_bank(bank, rows)
    l1: "OrderedDict[str, np.ndarray]"
    l2: "OrderedDict[str, np.ndarray]"
    l1_size: int
    l2_size: int
    device: Optional[object] = None  # jax device pin (None = default)
    injector: Optional[object] = None  # FaultInjector (None = never)

    def call(self, fn, *args, **kw):
        if self.injector is not None:
            self.injector.on_call(self.hid)
        with trace.span("cluster.host_call", host=self.hid):
            if self.device is None:
                return fn(*args, **kw)
            with jax.default_device(self.device):
                return fn(*args, **kw)


def _make_hosts(
    bank: PatternBank,
    placement: BankPlacement,
    *,
    bank_layout: str,
    l1_size: int,
    l2_size: int,
    devices: Optional[Sequence] = None,
    server_kw: Optional[dict] = None,
    metrics: Optional[MetricsRegistry] = None,
) -> List[ClusterHost]:
    hosts = []
    for hid, rows in enumerate(placement.rows):
        shard = slice_bank(bank, rows)
        # per-host namespaces on the shared registry: shard counters
        # stay separate (ServingCluster.stats sums them), yet survive
        # re-planning because the registry outlives the servers
        srv = PatternServer(shard, bank_layout=bank_layout,
                            metrics=metrics,
                            metrics_ns=f"serving.server.h{hid}",
                            **(server_kw or {}))
        hosts.append(ClusterHost(
            hid=hid, rows=rows, server=srv,
            l1=OrderedDict(), l2=OrderedDict(),
            l1_size=l1_size, l2_size=l2_size,
            device=None if devices is None else
            devices[hid % len(devices)],
        ))
    return hosts


class ServingCluster:
    """A static pattern bank served by ``n_hosts`` hosts - see the
    module docstring for the placement/routing/caching protocol."""

    def __init__(
        self,
        bank: PatternBank,
        n_hosts: int,
        *,
        bank_layout: str = "flat",
        trie: Optional[TrieBank] = None,
        topk: int = 10,
        l1_size: int = 4096,
        l2_size: int = 8192,
        devices: Optional[Sequence] = None,
        metrics: Optional[MetricsRegistry] = None,
        max_wait: Optional[float] = None,
        flush_batch: Optional[int] = None,
        shed_depth: Optional[int] = None,
        clock=None,
        injector=None,
        fault_policy=None,
        sleep=None,
        **server_kw,
    ):
        self.bank = bank
        self.n_hosts = n_hosts
        self.bank_layout = bank_layout
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._mk = dict(l1_size=l1_size, l2_size=l2_size,
                        devices=devices, server_kw=server_kw,
                        metrics=self.metrics)
        self.placement = plan_placement(
            bank, n_hosts, layout=bank_layout, trie=trie
        )
        self.hosts = _make_hosts(bank, self.placement,
                                 bank_layout=bank_layout, **self._mk)
        # fault semantics (serving.faults): the injector sits at every
        # host's call boundary; the policy arms the router's retry /
        # breaker / failover ladder.  Both default off - the pre-fault
        # fast path is bit-identical
        self.injector = injector
        if injector is not None:
            injector.bind(self.metrics)
            for h in self.hosts:
                h.injector = injector
        self.router = ClusterRouter(
            self.hosts, n_patterns=bank.n_patterns,
            support=bank.support[: bank.n_patterns].astype(np.int64),
            topk=topk, metrics=self.metrics,
            max_wait=max_wait, flush_batch=flush_batch,
            shed_depth=shed_depth, clock=clock,
            fault_policy=fault_policy, sleep=sleep,
        )

    # ------------------------------------------------------------ serving
    def join(self, req) -> "JoinResult":
        """The unified entry point (serving.join): delegates to the
        router, so exactness semantics (including the ``exact=False``
        approximate tier) are the router's."""
        return self.router.join(req)

    def query(
        self, seqs: Sequence[TRSeq], host: int = 0,
        k: Optional[int] = None,
    ) -> List[QueryResult]:
        """Queries arriving on one host."""
        from .join import JoinRequest
        return self.join(JoinRequest(
            seqs=tuple(seqs), k=k, host=host)).results

    def query_multi(
        self, requests: Mapping[int, Sequence[TRSeq]],
        k: Optional[int] = None,
    ) -> Dict[int, List[QueryResult]]:
        """One drain of queries that arrived on different hosts -
        misses share per-shard device batches."""
        return self.router.route(requests, k=k)

    def exact_rows(self, seqs: Sequence[TRSeq]) -> np.ndarray:
        """Cache-bypassing merged containment rows (global bank
        order)."""
        return self.router.joined_rows(seqs)

    # --------------------------------------------- async ingestion
    def submit(self, requests, k: Optional[int] = None):
        """Admit one drain into the continuous-batching pipeline
        without blocking (``ClusterRouter.submit``); redeem the
        returned ticket with ``collect``.  Configure the flush/shed
        policy via the constructor's ``max_wait`` / ``flush_batch`` /
        ``shed_depth``."""
        return self.router.submit(requests, k=k)

    def poll(self) -> None:
        """Deadline pump between sparse submits."""
        self.router.poll()

    def attach_watchdog(self, watchdog) -> None:
        """Wire an ``obs.slo.SloWatchdog`` into the admission pipeline
        (delegates to ``ClusterRouter.attach_watchdog``): every
        submit/poll/collect gives it a rate-limited rules check."""
        self.router.attach_watchdog(watchdog)

    def collect(self, ticket=None, timeout=None):
        """Fence + finalize one ticket (or all outstanding ones).
        ``timeout`` bounds the drain on the injectable clock: past the
        deadline, unresolved joins degrade through the shed tier
        (``exact=False``) instead of blocking forever - see
        ``ClusterRouter.collect``."""
        return self.router.collect(ticket, timeout=timeout)

    # ------------------------------------------------------- fault ladder
    def attach_failover_replica(self, hid: int, replica) -> None:
        """Register a ``BankReplica`` (over the FULL bank) as host
        ``hid``'s failover: while that host's breaker is open its
        column block is answered from the replica's cache-bypassing
        exact rows - bit-equal, still ``exact=True``.  Hosts without a
        registered replica degrade to the prescreen instead."""
        self.router.set_failover_replica(
            hid, lambda seqs: replica.server.exact_rows(seqs))

    # ------------------------------------------------------------ masking
    def set_row_mask(self, active: Optional[np.ndarray]) -> None:
        """Install a global tombstone mask: each shard server masks its
        slice of ``active``; the router reconciles its caches per-row
        (pure tombstones patch newly-dead columns in place, recoveries
        fall back to a full drop - see ``ClusterRouter.apply_row_mask``).
        The router goes first: its quiescence check (no uncollected
        tickets) must refuse before any shard server is touched."""
        self.router.apply_row_mask(active)
        for h in self.hosts:
            if not len(h.rows):
                continue
            h.call(h.server.set_row_mask,
                   None if active is None else active[h.rows])

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        """Router counters plus the summed shard-server counters."""
        out = dict(self.router.stats)
        for h in self.hosts:
            for key, val in h.server.stats.items():
                out[f"shards_{key}"] = out.get(f"shards_{key}", 0) + val
        return out


# --------------------------------------------------------------- streaming
@dataclasses.dataclass
class RingSlice:
    """Host-local sliding-window state: this host's slice of the ring
    (arrivals ``i`` with ``i % n_hosts == hid``), its per-sequence
    containment bitmaps, freshness flags (the slot-granular dirtiness
    index - see serving.streaming), and *partial* supports (column sums
    of the local bitmaps; the all-reduce at refresh sums them into
    exact global supports)."""

    bits: np.ndarray              # [w_local, P] bool
    seqs: List[Optional[TRSeq]]
    gidx: np.ndarray              # [w_local] int64 global arrival id, -1 empty
    fresh: np.ndarray             # [w_local] bool, written since reconcile
    psum: np.ndarray              # [P] int64 partial supports

    @classmethod
    def empty(cls, w_local: int, n_patterns: int) -> "RingSlice":
        return cls(
            bits=np.zeros((w_local, n_patterns), bool),
            seqs=[None] * w_local,
            gidx=np.full(w_local, -1, np.int64),
            fresh=np.zeros(w_local, bool),
            psum=np.zeros(n_patterns, np.int64),
        )

    def grow(self, n_patterns: int) -> None:
        pad = n_patterns - self.bits.shape[1]
        self.bits = np.pad(self.bits, ((0, 0), (0, pad)))
        self.psum = np.concatenate(
            [self.psum, np.zeros(pad, np.int64)])

    def reset_rows(self, n_patterns: int) -> None:
        """Drop all bitmaps/supports (full refresh recounts them); the
        stored sequences and arrival ids stay - the window itself is
        unchanged."""
        self.bits = np.zeros((self.bits.shape[0], n_patterns), bool)
        self.psum = np.zeros(n_patterns, np.int64)


class ShardedStreamingBank:
    """``StreamingBank`` under the sharded-window protocol (module
    docstring): ring slices + partial supports per host, one support
    all-reduce and one depth-1-subtree dirtiness all-reduce per
    ``refresh()``.  Tombstoning is *refresh-grained* (between refreshes
    nothing is masked, so partial supports stay exact for every active
    row); after any refresh the frequent map is bit-equal to a batch
    re-mine of the window."""

    def __init__(
        self,
        bank: PatternBank,
        *,
        n_hosts: int,
        window: int,
        minsup: int,
        bank_layout: str = "flat",
        max_len: Optional[int] = None,
        tombstones: bool = True,
        miner_kw: Optional[dict] = None,
        devices: Optional[Sequence] = None,
        **server_kw,
    ):
        assert window > 0 and minsup > 0 and n_hosts > 0
        assert window % n_hosts == 0, \
            "window must divide evenly across ring slices"
        assert bank.n_rows == max(bank.n_patterns, 1), \
            "streaming requires an unpadded bank"
        self.window = window
        self.minsup = minsup
        self.n_hosts = n_hosts
        self.bank_layout = bank_layout
        self.max_len = max_len
        self.tombstones = tombstones
        self.miner_kw = dict(miner_kw or {})
        self.server_kw = dict(server_kw)
        self.devices = devices
        self.bank = bank
        self._w_local = window // n_hosts
        P = bank.n_patterns
        self.support = np.zeros(P, np.int64)  # last all-reduced view
        self.active = np.ones(P, bool)
        self.ring = [RingSlice.empty(self._w_local, P)
                     for _ in range(n_hosts)]
        self._t = 0  # global arrival counter
        self._any_change = False
        # one registry for the whole topology: the serving plane
        # (shard servers + router) is rebuilt on every re-plan, but its
        # counters re-attach here and accumulate - refresh(full=True)
        # no longer zeroes router hit rates
        self.metrics = MetricsRegistry()
        self.cluster = self._make_cluster()
        self.stats = self.metrics.view("streaming.sharded", keys=[
            "arrivals", "evictions", "observe_batches",
            "tombstoned", "recovered", "added",
            "refreshes", "full_refreshes",
            "allreduces", "dirty_subtrees",
            "frontier_scans", "frontier_scans_skipped",
            "frontier_retained",
        ])
        # always-on latency percentiles (mirror StreamingBank's)
        self._h_observe = self.metrics.bucket_histogram(
            "streaming.sharded.observe_seconds")
        self._h_refresh = self.metrics.bucket_histogram(
            "streaming.sharded.refresh_seconds")

    # ------------------------------------------------------------ wiring
    def _make_cluster(self) -> ServingCluster:
        return ServingCluster(
            self.bank, self.n_hosts, bank_layout=self.bank_layout,
            devices=self.devices, metrics=self.metrics,
            **self.server_kw,
        )

    def _rebuild_serving(self) -> None:
        """New bank -> new placement, shard servers, and router; the
        ring slices (window state) survive untouched."""
        self.cluster = self._make_cluster()
        self.cluster.router.support = self.support

    def _apply_mask(self) -> None:
        if not self.tombstones:
            return
        mask = None if self.active.all() else self.active
        self.cluster.set_row_mask(mask)

    @classmethod
    def from_db(
        cls,
        db: Sequence[TRSeq],
        *,
        minsup: int,
        n_hosts: int,
        window: Optional[int] = None,
        max_len: Optional[int] = None,
        miner_kw: Optional[dict] = None,
        **kw,
    ) -> "ShardedStreamingBank":
        """Mine ``db`` into a bank and stream it in as the seed window.
        The seed arrivals stay *fresh* (unlike ``StreamingBank.from_db``
        there is no tombstone cut at seed time - tombstoning is
        refresh-grained here), so the first refresh treats them as
        dirty; exactness is unaffected."""
        miner = AcceleratedMiner(db, **(miner_kw or {}))
        result = miner.mine_rs(minsup, max_len=max_len)
        bank = compile_bank(result)
        w = window or max(len(db), 1)
        sb = cls(bank, n_hosts=n_hosts, window=w, minsup=minsup,
                 max_len=max_len, miner_kw=miner_kw, **kw)
        sb.observe(db)
        return sb

    # ----------------------------------------------------------- streams
    @property
    def n_patterns(self) -> int:
        return self.bank.n_patterns

    def _window_slots(self) -> List[Tuple[int, int, int]]:
        """Occupied (global arrival id, host, slot) triples in window
        (oldest-first) order - the strict round-robin placement makes
        the union of slices exactly the last ``window`` arrivals."""
        items = []
        for hid, r in enumerate(self.ring):
            for slot in range(self._w_local):
                if r.gidx[slot] >= 0:
                    items.append((int(r.gidx[slot]), hid, slot))
        items.sort()
        return items

    @property
    def window_seqs(self) -> List[TRSeq]:
        return [self.ring[h].seqs[s] for _, h, s in self._window_slots()]

    def _frequent_from(self, sup: np.ndarray) -> Dict[Pattern, int]:
        out = {}
        for i in np.nonzero(self.active & (sup >= self.minsup))[0]:
            out[self.bank.patterns[i]] = int(sup[i])
        return out

    def frequent(self) -> Dict[Pattern, int]:
        """Active frequent patterns at freshly all-reduced supports
        (between refreshes supports are only all-reduced on demand;
        the refresh paths score from their already-reduced view
        instead of paying a second collective)."""
        return self._frequent_from(self._allreduce_support())

    # ----------------------------------------------------------- observe
    def observe(self, batch: Sequence[TRSeq]):
        """Slide ``batch`` into the sharded window: one routed
        containment batch (each shard owner joins its slice), then each
        arrival's merged row lands on its ring owner, which updates its
        partial supports locally - evictions decrement from the stored
        bitmap, no re-join, no cross-host traffic."""
        batch = list(batch)
        if not batch:
            return
        t0 = time.perf_counter()
        try:
            self._observe_inner(batch)
        finally:
            self._h_observe.observe(time.perf_counter() - t0)

    def _observe_inner(self, batch: List[TRSeq]) -> None:
        with trace.root_or_span("streaming.observe", n=len(batch)):
            rows = self.cluster.exact_rows(batch)
            evicted = 0
            with trace.span("streaming.ring"):
                for seq, row in zip(batch, rows):
                    hid = self._t % self.n_hosts
                    slot = (self._t // self.n_hosts) % self._w_local
                    r = self.ring[hid]
                    if r.gidx[slot] >= 0:
                        r.psum -= r.bits[slot]
                        evicted += 1
                    r.seqs[slot] = seq
                    r.bits[slot] = row
                    r.gidx[slot] = self._t
                    r.fresh[slot] = True
                    r.psum += row
                    self._t += 1
            self._any_change = True
        self.stats["arrivals"] += len(batch)
        self.stats["evictions"] += evicted
        self.stats["observe_batches"] += 1

    # ----------------------------------------------------------- refresh
    def _allreduce_support(self) -> np.ndarray:
        self.stats["allreduces"] += 1
        out = np.zeros(self.bank.n_patterns, np.int64)
        for r in self.ring:
            out += r.psum
        return out

    def _allreduce_dirty_subtrees(self) -> Set[Pattern]:
        """The per-child dirtiness all-reduce: each host reduces its
        fresh slots' bitmaps to the depth-1 subtree roots they touched
        (O(#subtrees) flags), the union is the global dirty-subtree
        set.  Coarser than per-pattern dirt but a sound superset -
        refresh_frontier only ever scans more."""
        pats = self.bank.patterns
        roots: Set[Pattern] = set()
        for r in self.ring:
            if not r.fresh.any():
                continue
            local = r.bits[r.fresh].any(axis=0)
            roots |= {depth1_root(pats[i])
                      for i in np.nonzero(local)[0]}
        return roots

    def refresh(self, full: bool = False) -> Dict[Pattern, int]:
        """The protocol's synchronisation point: all-reduce partial
        supports and the dirty-subtree flags, frontier-re-mine against
        the exact global view, extend/recompile the bank, cut
        tombstones, and broadcast the new masks/placement to every
        host.  Returns the exact frequent map (== batch re-mine)."""
        t0 = time.perf_counter()
        try:
            return self._refresh_timed(full)
        finally:
            self._h_refresh.observe(time.perf_counter() - t0)

    def _refresh_timed(self, full: bool) -> Dict[Pattern, int]:
        with trace.root_or_span("streaming.refresh", full=full):
            with trace.span("cluster.allreduce"):
                self.support = self._allreduce_support()
            self.cluster.router.support = self.support
            win = self._window_slots()
            seqs = [self.ring[h].seqs[s] for _, h, s in win]
            if full:
                return self._refresh_full(seqs, win)
            if not self._any_change:
                return self._frequent_from(self.support)
            active_rows = self.active if self.tombstones else \
                np.ones_like(self.active)
            active_map = {
                self.bank.patterns[i]: int(self.support[i])
                for i in np.nonzero(active_rows)[0]
            }
            with trace.span("cluster.allreduce"):
                droots = self._allreduce_dirty_subtrees()
            self.stats["dirty_subtrees"] += len(droots)
            dirty_mask = subtree_dirty_rows(self.bank.patterns, droots)
            dirty_set = {
                self.bank.patterns[i]
                for i in np.nonzero(dirty_mask & active_rows)[0]
            }
            with trace.span("streaming.frontier"):
                fr = refresh_frontier(
                    seqs, self.minsup, active=active_map,
                    dirty=dirty_set, any_change=True,
                    max_len=self.max_len, metrics=self.metrics,
                    **self.miner_kw,
                )
            self.stats["refreshes"] += 1
            self.stats["frontier_scans"] += fr.scans
            self.stats["frontier_scans_skipped"] += fr.scans_skipped
            self.stats["frontier_retained"] += fr.retained
            return self._reconcile(seqs, win, fr.patterns, fr.gids)

    def _reconcile(self, seqs, win, mined, gids) -> Dict[Pattern, int]:
        with trace.span("streaming.reconcile"):
            return self._reconcile_inner(seqs, win, mined, gids)

    def _reconcile_inner(self, seqs, win, mined, gids
                         ) -> Dict[Pattern, int]:
        known = {p: i for i, p in enumerate(self.bank.patterns)}
        new = {p: s for p, s in mined.items() if p not in known}
        if new and not self.bank.n_patterns:
            return self._refresh_full(seqs, win, mined=mined)
        if new:
            try:
                bank2 = extend_bank(self.bank, new)
            except BankCapacityError:
                return self._refresh_full(seqs, win, mined=mined)
            grow = bank2.n_patterns - self.bank.n_patterns
            self.support = np.concatenate(
                [self.support, np.zeros(grow, np.int64)])
            self.active = np.concatenate(
                [self.active, np.zeros(grow, bool)])
            for r in self.ring:
                r.grow(bank2.n_patterns)
            self.bank = bank2
            known = {p: i for i, p in enumerate(bank2.patterns)}
            self.stats["added"] += grow
            # new rows re-plan the placement; ring state is global-row
            # indexed, so only the serving plane rebuilds
            self._rebuild_serving()
        mined_rows = np.zeros(self.bank.n_patterns, bool)
        for p in mined:
            mined_rows[known[p]] = True
        recount = np.nonzero(mined_rows & ~self.active)[0]
        if len(recount):
            # recovered/new rows: backfill window bitmaps from the
            # miner's exact containing-gid sets, scattered back to each
            # ring owner; partial supports recompute locally
            cols = np.zeros((len(seqs), len(recount)), bool)
            for j, rr in enumerate(recount):
                cols[sorted(gids[self.bank.patterns[rr]]), j] = True
            for g, (_, hid, slot) in enumerate(win):
                self.ring[hid].bits[slot, recount] = cols[g]
            for r in self.ring:
                r.psum[recount] = r.bits[:, recount].sum(0)
            self.support[recount] = cols.sum(0)
            self.stats["recovered"] += len(recount) - len(new)
        for p, s in mined.items():
            assert int(self.support[known[p]]) == s, (
                "support drift on", p, int(self.support[known[p]]), s)
        self.active = mined_rows if self.tombstones else \
            np.ones(self.bank.n_patterns, bool)
        # cache reconciliation is the mask's job now: _apply_mask
        # patches newly-tombstoned columns per-row and clears only on
        # recoveries (ClusterRouter.apply_row_mask); cached rows do not
        # depend on supports (scoring reads router.support at query
        # time) and the bank-extension path above rebuilt the serving
        # plane - so surviving entries are exact and stay.
        self._apply_mask()
        self.cluster.router.support = self.support
        for r in self.ring:
            r.fresh[:] = False
        self._any_change = False
        return self._frequent_from(self.support)

    def _refresh_full(self, seqs, win, mined=None) -> Dict[Pattern, int]:
        """Re-mine + recompile + recount everything (escape hatch /
        tombstone compaction), then recount every ring slice through
        the fresh unmasked shard servers."""
        with trace.span("streaming.full_refresh"):
            return self._refresh_full_inner(seqs, win, mined)

    def _refresh_full_inner(self, seqs, win, mined=None
                            ) -> Dict[Pattern, int]:
        self.stats["full_refreshes"] += 1
        if mined is None:
            if seqs:
                miner = AcceleratedMiner(
                    seqs, metrics=self.metrics, **self.miner_kw)
                mined = miner.mine_rs(
                    self.minsup, max_len=self.max_len).patterns
            else:
                mined = {}
        self.bank = compile_bank(mined)
        P = self.bank.n_patterns
        self.support = np.zeros(P, np.int64)
        self.active = np.ones(P, bool)
        for r in self.ring:
            r.reset_rows(P)
            r.fresh[:] = False
        self._rebuild_serving()
        if seqs and P:
            rows = self.cluster.exact_rows(seqs)
            for g, (_, hid, slot) in enumerate(win):
                self.ring[hid].bits[slot] = rows[g]
            for r in self.ring:
                r.psum = r.bits.sum(0).astype(np.int64)
            self.support = rows.sum(0).astype(np.int64)
            self.cluster.router.support = self.support
        assert np.array_equal(
            self.support, self.bank.support[:P].astype(np.int64)
        ), "full-refresh recount disagrees with mined supports"
        self._any_change = False
        return self._frequent_from(self.support)

    # ----------------------------------------------------------- serving
    def join(self, req) -> "JoinResult":
        """Unified entry point: all-reduce the live supports into the
        router's scorer, then delegate (exactness semantics are the
        router's - shed/approx rows stay flagged ``exact=False``)."""
        self.support = self._allreduce_support()
        self.cluster.router.support = self.support
        return self.cluster.join(req)

    def query(
        self, seqs: Sequence[TRSeq], host: int = 0, k: int = 10,
    ) -> List[QueryResult]:
        """Routed containment over the active bank with top-k scored by
        live supports (all-reduced on demand)."""
        from .join import JoinRequest
        return self.join(JoinRequest(
            seqs=tuple(seqs), k=k, host=host)).results


# ---------------------------------------------------------------- replicas
class BankReplica:
    """A read replica: serves the writer's (masked) bank and applies
    shipped deltas - ``extend_bank``/``extend_trie`` appends for
    incremental refreshes, a recompile only when the writer itself
    recompiled.  Queries rank top-k by the replica's last-applied live
    supports (compile-time bank order goes stale as supports drift)."""

    def __init__(
        self,
        bank: PatternBank,
        *,
        bank_layout: str = "flat",
        trie: Optional[TrieBank] = None,
        support: Optional[np.ndarray] = None,
        active: Optional[np.ndarray] = None,
        last_seq: int = 0,
        **server_kw,
    ):
        self.bank_layout = bank_layout
        self.server_kw = dict(server_kw)
        self._install(bank, trie)
        self.support = (
            bank.support[: bank.n_patterns].astype(np.int64)
            if support is None else np.asarray(support, np.int64).copy()
        )
        self.active = (
            np.ones(bank.n_patterns, bool) if active is None
            else np.asarray(active, bool).copy()
        )
        if not self.active.all():
            self.server.set_row_mask(self.active)
        self.applied = 0  # deltas applied so far
        # last applied delta sequence id: the replay cursor.  A
        # replica built from writer state at delta_seq=s starts there;
        # apply() skips any seq <= last_seq, so replaying an overlap
        # (restart catch-up) is idempotent
        self.last_seq = int(last_seq)

    def _install(self, bank: PatternBank,
                 trie: Optional[TrieBank] = None) -> None:
        self.bank = bank
        self.trie = None
        if get_layout(self.bank_layout).uses_trie:
            self.trie = trie if trie is not None else build_trie(bank)
        self.server = PatternServer(
            bank, bank_layout=self.bank_layout, trie=self.trie,
            **self.server_kw,
        )

    def apply(self, delta: Tuple) -> None:
        """Apply one writer delta ``(kind, seq, *payload)`` - see
        serving.streaming's delta kinds.  Deltas at or before the
        replay cursor (``seq <= last_seq``) are skipped, so replaying
        an overlapping recovery-log suffix is idempotent."""
        kind, seq = delta[0], int(delta[1])
        if seq <= self.last_seq:
            return
        if kind == "support":
            self.support = np.asarray(delta[2], np.int64)
        elif kind == "mask":
            active, support = delta[2:]
            self.active = np.asarray(active, bool)
            self.server.set_row_mask(
                None if active.all() else active)
            self.support = np.asarray(support, np.int64)
        elif kind == "extend":
            new, active, support = delta[2:]
            if new:
                bank2 = extend_bank(self.bank, new)
                trie2 = (extend_trie(self.trie, bank2)
                         if self.trie is not None else None)
                self._install(bank2, trie2)
            self.active = np.asarray(active, bool)
            self.server.set_row_mask(
                None if active.all() else active)
            self.support = np.asarray(support, np.int64)
        elif kind == "recompile":
            mined, support = delta[2:]
            self._install(compile_bank(mined))
            self.active = np.ones(self.bank.n_patterns, bool)
            self.support = np.asarray(support, np.int64)
        else:  # pragma: no cover - future delta kinds
            raise ValueError(f"unknown delta kind {kind!r}")
        self.applied += 1
        self.last_seq = seq

    def join(self, req) -> "JoinResult":
        """Unified entry point: the inner server join rescored by the
        replica's live supports (``exact`` flags pass through)."""
        from .join import JoinRequest, JoinResult
        k = 10 if req.k is None else req.k
        inner = self.server.join(JoinRequest(
            seqs=req.seqs, k=0, exact=req.exact,
            trace_id=req.trace_id))
        return JoinResult([
            dataclasses.replace(
                r, topk=score_topk(r.contained, self.support, k))
            for r in inner.results
        ])

    def query(self, seqs: Sequence[TRSeq], k: int = 10
              ) -> List[QueryResult]:
        from .join import JoinRequest
        return self.join(JoinRequest(seqs=tuple(seqs), k=k)).results


class ReplicaGroup:
    """Single-writer / read-replica topology: the writer is an ordinary
    ``StreamingBank``; every delta it emits is queued per replica and
    applied on ``sync()`` - the explicit "ship" step, so a replica
    keeps serving its previous masked bank while the writer refreshes
    (reads never block on the writer).

    **Crash / recovery** (serving.faults): every broadcast delta is
    also appended to a bounded ``RecoveryLog`` ring keyed by the
    writer's monotone delta sequence ids.  ``crash(rid)`` drops a
    replica's pending queue (a dead host loses its mailbox); a
    ``restart(rid)`` replays the log from the replica's last applied
    seq - or, when the ring already evicted that range, rebuilds the
    replica from current writer state (full state transfer) - then
    *verifies* catch-up bit-for-bit against the writer (patterns,
    supports, active mask; GTRACE-RS's reverse-search decomposition is
    what makes this cheap - all serving state is reconstructible from
    the delta stream) before the replica rejoins.  Verified recoveries
    count ``cluster.faults.recoveries`` on the writer's registry."""

    def __init__(self, writer: StreamingBank, n_replicas: int,
                 *, log_capacity: int = 256, **server_kw):
        assert n_replicas >= 1
        self.writer = writer
        self.server_kw = dict(server_kw)
        self.pending: List[List[Tuple]] = [[] for _ in range(n_replicas)]
        self.log = RecoveryLog(log_capacity)
        self.down: Set[int] = set()
        self.faults = writer.metrics.view(
            "cluster.faults", keys=["recoveries"])
        writer.delta_sink = self._broadcast
        self.replicas = [
            self._fresh_replica() for _ in range(n_replicas)
        ]

    def _fresh_replica(self) -> BankReplica:
        """A replica built from *current* writer state - its replay
        cursor starts at the writer's current delta seq (full state
        transfer: nothing older needs replaying)."""
        w = self.writer
        return BankReplica(
            w.bank, bank_layout=w.bank_layout, trie=w.trie,
            support=w.support,
            active=w.active if w.tombstones else None,
            last_seq=w.delta_seq,
            **self.server_kw,
        )

    def _broadcast(self, delta: Tuple) -> None:
        self.log.append(int(delta[1]), delta)
        for rid, q in enumerate(self.pending):
            if rid in self.down:
                continue  # a crashed replica's mailbox is gone
            # "support" deltas are full-state: a lagging replica only
            # needs the latest one, so consecutive ones coalesce and
            # the queue stays bounded by the structural-delta rate
            if (delta[0] == "support" and q
                    and q[-1][0] == "support"):
                q[-1] = delta
            else:
                q.append(delta)

    def lag(self, rid: int) -> int:
        """Deltas shipped by the writer but not yet applied here."""
        return len(self.pending[rid])

    def sync(self, rid: Optional[int] = None) -> None:
        """Ship (apply) all pending deltas to one replica, or all live
        ones.  Syncing a crashed replica raises ``HostDownError`` -
        restart it first."""
        if rid is not None and rid in self.down:
            raise HostDownError(rid, f"replica {rid} is down")
        rids = range(len(self.replicas)) if rid is None else [rid]
        for i in rids:
            if i in self.down:
                continue
            for delta in self.pending[i]:
                self.replicas[i].apply(delta)
            self.pending[i].clear()

    # ------------------------------------------------- crash / recovery
    def crash(self, rid: int) -> None:
        """Take one replica down: queries fail (``HostDownError``) and
        shipped deltas no longer reach it - its pending queue is lost,
        exactly like a host losing its mailbox on restart.  The
        replica's *applied* state survives (a restarted process reloads
        its checkpoint); ``restart`` replays the gap."""
        self.down.add(rid)
        self.pending[rid].clear()

    def restart(self, rid: int) -> int:
        """Recover one crashed replica: replay the writer's recovery
        log from the replica's last applied seq (``None`` from the ring
        means the range was evicted - rebuild from writer state
        instead), verify catch-up bit-for-bit, then rejoin.  Returns
        the number of deltas replayed (0 for a full state transfer)."""
        rep = self.replicas[rid]
        deltas = self.log.since(rep.last_seq)
        if deltas is None:
            # the ring evicted part of the needed range: a partial
            # replay would corrupt the replica, so transfer full state
            self.replicas[rid] = self._fresh_replica()
            replayed = 0
        else:
            for delta in deltas:
                rep.apply(delta)
            replayed = len(deltas)
        self._verify(rid)
        self.down.discard(rid)
        self.faults["recoveries"] += 1
        return replayed

    def _verify(self, rid: int) -> None:
        """The rejoin gate: a recovered replica must match the writer
        bit-for-bit - same pattern set, same live supports, same
        tombstone mask.  Raises ``RuntimeError`` on any mismatch (the
        replica must NOT rejoin routing with divergent state)."""
        rep, w = self.replicas[rid], self.writer
        w_active = (w.active if w.tombstones
                    else np.ones(w.bank.n_patterns, bool))
        if rep.bank.patterns != w.bank.patterns:
            raise RuntimeError(
                f"replica {rid} failed catch-up verification: "
                "pattern set diverges from writer")
        if not np.array_equal(
                rep.support, w.support[: w.bank.n_patterns]):
            raise RuntimeError(
                f"replica {rid} failed catch-up verification: "
                "supports diverge from writer")
        if not np.array_equal(
                rep.active[: w.bank.n_patterns],
                w_active[: w.bank.n_patterns]):
            raise RuntimeError(
                f"replica {rid} failed catch-up verification: "
                "tombstone mask diverges from writer")

    def query(self, seqs: Sequence[TRSeq], replica: int = 0,
              k: int = 10) -> List[QueryResult]:
        """Serve from a replica at whatever state it has applied.
        Crashed replicas raise ``HostDownError``."""
        if replica in self.down:
            raise HostDownError(
                replica, f"replica {replica} is down")
        return self.replicas[replica].query(seqs, k=k)
