"""Fault injection and fault semantics for the serving cluster.

``ClusterHost.call`` is the host boundary every cross-host access goes
through (serving.cluster), and until now every call was assumed to
succeed instantly - a single slow or dead host would wedge
``ClusterRouter.collect`` forever and silently lose queries.  This
module defines the failure model the router (and, later, real
process-group hosts) programs against:

* ``FaultInjector`` - a *deterministic* fault schedule installed at the
  ``ClusterHost.call`` boundary.  Every decision is a stateless hash of
  ``(seed, host, per-host call index)`` - no RNG object, no query-time
  entropy - so a faulted run replays **bit-identically**: the same
  queries see the same delays, the same transient errors, the same
  crash windows.  Crash/blackout windows are wall-clock intervals on
  the *injectable* clock, so tests drive them with a fake clock.  An
  idle injector (all rates 0, no blackouts) only counts calls: results
  are bit-identical to no injector at all.
* The **fault taxonomy** the router handles (all carry the host id):
  ``TransientHostError`` (retryable one-off), ``HostTimeoutError``
  (call exceeded the policy's per-call timeout; the result is
  discarded), ``HostDownError`` (the host is inside a crash/blackout
  window).  ``HostFault`` is their common base.
* ``HostUnavailableError`` - what the *router* raises after the ladder
  is exhausted: retries spent, or the host's circuit breaker is open.
  Callers with an exactness contract (``ClusterRouter.joined_rows``,
  hence the streaming window protocol) see this instead of silently
  degraded bits.
* ``RetryPolicy`` - per-call timeout, capped exponential backoff retry
  budget, and the circuit-breaker knobs (consecutive-failure threshold,
  open-state cooldown before a half-open probe).
* ``RecoveryLog`` - a bounded ring of the writer's sequenced deltas
  (serving.streaming ships ``(kind, seq, *payload)`` tuples) that a
  restarted replica replays from its last applied sequence number;
  ``since()`` returns None when the ring already evicted the needed
  range, forcing a full state transfer instead of a wrong partial one.
* ``PipelineBusyError`` - the typed quiescence refusal for
  ``apply_row_mask``/``set_row_mask``: names the queued / in-flight /
  uncollected-ticket counts instead of a bare ``assert`` (asserts
  vanish under ``python -O``; a survived re-mask would hand out stale
  cached rows).

Counter inventory (registered under ``cluster.faults`` by the router,
incremented here and in router.py): ``injected`` (faults the injector
raised or delayed), ``retries`` (backoff retries issued), ``breaker_open``
(circuit-breaker open transitions), ``failovers`` (batches answered by
a promoted read replica, exact), ``degraded_answers`` (queries answered
from the host-side prescreen, ``exact=False``), ``recoveries`` (hosts
that passed a half-open probe / replicas that completed a verified
catch-up), plus the ``cluster.faults.retry_seconds`` latency histogram.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ------------------------------------------------------------ exceptions
class HostFault(Exception):
    """Base of every injected/observed fault at the host boundary."""

    def __init__(self, hid: int, msg: str = ""):
        self.hid = hid
        super().__init__(msg or f"host {hid} fault")


class TransientHostError(HostFault):
    """A one-off failure (dropped RPC, OOM-killed worker retry-able at
    the caller): succeeds on retry unless the schedule says otherwise."""


class HostTimeoutError(HostFault):
    """The call exceeded ``RetryPolicy.call_timeout`` on the injectable
    clock; the (possibly computed) result is discarded - a timed-out
    answer must not be half-used."""


class HostDownError(HostFault):
    """The host is inside a crash/blackout window (or a crashed replica
    was queried): every call fails until the window ends and the host
    restarts."""


class HostUnavailableError(Exception):
    """The router exhausted the retry budget or the host's circuit
    breaker is open: the caller must fail over (replica / prescreen) or
    propagate.  Deliberately NOT a ``HostFault``: it is a router-side
    verdict, not a boundary event."""

    def __init__(self, hid: int, msg: str = ""):
        self.hid = hid
        super().__init__(msg or f"host {hid} unavailable")


class PipelineBusyError(RuntimeError):
    """Typed quiescence refusal: the admission pipeline still holds
    work launched against pre-mask state, so re-masking must wait.
    Carries the counts a caller needs to drain."""

    def __init__(self, queued: int, inflight: int, tickets: int):
        self.queued = queued
        self.inflight = inflight
        self.tickets = tickets
        super().__init__(
            f"admission pipeline not quiescent: {queued} queued "
            f"miss(es), {inflight} in-flight miss(es), {tickets} "
            "uncollected ticket(s) - collect every ticket before "
            "changing the row mask"
        )


# ---------------------------------------------------------- retry policy
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How the router treats host faults (see module docstring).

    ``call_timeout`` is measured on the router's injectable clock
    around each attempt (None = never time out).  A failed attempt
    retries up to ``retries`` times with capped exponential backoff
    (``backoff_base * 2^attempt``, clamped at ``backoff_cap``).
    ``breaker_threshold`` consecutive failures open the host's circuit
    breaker; after ``breaker_cooldown`` seconds one half-open probe is
    allowed - success closes the breaker (and counts a recovery),
    failure re-opens it."""

    call_timeout: Optional[float] = None
    retries: int = 2
    backoff_base: float = 0.05
    backoff_cap: float = 1.0
    breaker_threshold: int = 3
    breaker_cooldown: float = 5.0


# --------------------------------------------------------- fault injector
def _unit_hash(seed: int, hid: int, idx: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, host, call index) -
    a stateless hash, so schedules replay bit-identically and two
    injectors with the same seed agree without shared state."""
    h = hashlib.blake2b(
        f"{seed}:{hid}:{idx}".encode(), digest_size=8
    ).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


class FaultInjector:
    """Seeded fault schedule at the ``ClusterHost.call`` boundary.

    Install via ``ServingCluster(injector=...)`` (which sets it on
    every host and binds its counter to the cluster registry) or by
    assigning ``host.injector``.  Per call it draws one deterministic
    unit hash: ``u < error_rate`` raises ``TransientHostError``,
    ``u < error_rate + delay_rate`` sleeps ``delay`` seconds through
    the injectable ``sleep`` (tests pass a fake-clock advance; with a
    real clock it defaults to ``time.sleep``), otherwise the call
    proceeds.  Blackout windows ``(hid, t0, t1)`` are checked first
    against the injectable ``clock``: inside one, every call to that
    host raises ``HostDownError`` - the crash simulation.

    No RNG at query time: ``decide(hid, idx)`` is a pure function, so
    replaying the same traffic yields the same faults."""

    def __init__(
        self,
        seed: int = 0,
        *,
        error_rate: float = 0.0,
        delay_rate: float = 0.0,
        delay: float = 0.01,
        blackouts: Sequence[Tuple[int, float, float]] = (),
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
    ):
        assert 0.0 <= error_rate <= 1.0 and 0.0 <= delay_rate <= 1.0
        assert error_rate + delay_rate <= 1.0
        self.seed = seed
        self.error_rate = error_rate
        self.delay_rate = delay_rate
        self.delay = delay
        self.blackouts = tuple(
            (int(h), float(t0), float(t1)) for h, t0, t1 in blackouts
        )
        self.clock = time.monotonic if clock is None else clock
        # with an injected (fake) clock the default sleep is a no-op:
        # the test advances time itself; a real-clock injector really
        # sleeps so delay faults show up in the latency histograms
        self.sleep = sleep if sleep is not None else (
            time.sleep if clock is None else (lambda s: None)
        )
        self.calls: Dict[int, int] = {}   # per-host call counter
        self._c_injected = None           # bound by bind()

    def bind(self, metrics) -> None:
        """Attach the ``cluster.faults.injected`` counter to a
        registry (ServingCluster does this at construction)."""
        self._c_injected = metrics.counter("cluster.faults.injected")

    def _count(self) -> None:
        if self._c_injected is not None:
            self._c_injected.inc()

    def decide(self, hid: int, idx: int) -> str:
        """The pure schedule: ``"error"`` | ``"delay"`` | ``"ok"`` for
        the ``idx``-th call to host ``hid`` (blackouts are clock-based
        and checked separately in ``on_call``)."""
        u = _unit_hash(self.seed, hid, idx)
        if u < self.error_rate:
            return "error"
        if u < self.error_rate + self.delay_rate:
            return "delay"
        return "ok"

    def down(self, hid: int) -> bool:
        """True while ``hid`` is inside a blackout window now."""
        t = self.clock()
        return any(h == hid and t0 <= t < t1
                   for h, t0, t1 in self.blackouts)

    def on_call(self, hid: int) -> None:
        """The ``ClusterHost.call`` hook: raise/delay per the schedule
        (called before the wrapped function runs, so a failed call
        never half-executes)."""
        idx = self.calls.get(hid, 0)
        self.calls[hid] = idx + 1
        if self.down(hid):
            self._count()
            raise HostDownError(
                hid, f"host {hid} is inside a blackout window")
        verdict = self.decide(hid, idx)
        if verdict == "error":
            self._count()
            raise TransientHostError(
                hid, f"injected transient error (call #{idx})")
        if verdict == "delay":
            self._count()
            self.sleep(self.delay)

    def reset(self) -> None:
        """Forget the per-host call counters (restart the schedule)."""
        self.calls.clear()


# ----------------------------------------------------------- recovery log
class RecoveryLog:
    """Bounded ring of the writer's sequenced deltas, for replica
    restart replay.  ``append`` evicts oldest-first past ``capacity``;
    ``since(last_seq)`` returns every retained delta with a sequence
    number beyond ``last_seq``, or ``None`` when the ring has already
    evicted part of that range (the caller must full-resync - replaying
    a gapped suffix would silently corrupt the replica)."""

    def __init__(self, capacity: int = 256):
        assert capacity >= 1
        self.capacity = capacity
        self.entries: "deque[Tuple[int, Tuple]]" = deque()
        self.dropped_through = 0   # highest evicted sequence number

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def last_seq(self) -> int:
        return self.entries[-1][0] if self.entries else \
            self.dropped_through

    def append(self, seq: int, delta: Tuple) -> None:
        assert seq > self.last_seq, "delta sequence must be monotone"
        self.entries.append((seq, delta))
        while len(self.entries) > self.capacity:
            s, _ = self.entries.popleft()
            self.dropped_through = s

    def since(self, last_seq: int) -> Optional[List[Tuple]]:
        """Deltas with seq > ``last_seq``, oldest first; None when the
        range was (partially) evicted."""
        if last_seq < self.dropped_through:
            return None
        return [d for s, d in self.entries if s > last_seq]
