"""The unified Join API: ``JoinRequest -> JoinResult`` on every backend.

The serving stack grew one entry-point dialect per layer -
``PatternServer.query/query_one/exact_rows``, ``ClusterRouter.route/
submit/collect``, ``ServingCluster.query/query_multi``,
``StreamingBank.query`` - each with its own defaults for k, exactness
and batching.  This module is the one protocol they all speak now:

* ``JoinRequest`` - the sequences to join, the top-k depth, the
  exactness contract (``exact=False`` asks for the prescreen-only
  approximate tier: a sound overapproximation, flagged per-result,
  never cached), an optional trace id stitched into the obs layer, and
  the arrival host (cluster backends).
* ``JoinResult`` - the per-sequence ``QueryResult`` list in request
  order plus batch-level views (``rows``, ``exact``).
* every backend implements ``join(JoinRequest) -> JoinResult``; the
  legacy methods survive as thin wrappers over it, so existing callers
  and tests run unmodified.
* ``Frontend`` - a facade that speaks the protocol against any backend
  uniformly, including a begin/finish split for backends with an async
  pipeline (``submit``/``collect`` or ``launch_rows``/
  ``finalize_rows``).

Exactness propagation is part of the protocol: a backend must flag
every approximate row on the ``QueryResult`` (``exact=False``), no
matter which layer produced it - server approx tier, router shed tier,
or a streaming/replica rescore of either.  The differential tests
assert this on every entry point.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from ..core.graphseq import TRSeq
from ..obs import trace
from .bank import sequence_fingerprint
from .server import QueryResult


@dataclasses.dataclass(frozen=True)
class JoinRequest:
    """One containment-join request batch (see module docstring).

    ``k=None`` means the backend's configured top-k depth.  ``host``
    names the arrival host for cluster backends (single-host backends
    ignore it).  ``timeout`` bounds the async drain (``Frontend``'s
    begin/finish over a router/cluster backend): past the deadline the
    backend answers the stragglers from its degraded tier, flagged
    ``exact=False``, instead of blocking - see
    ``ClusterRouter.collect``.  Backends without a timeout notion
    ignore it."""

    seqs: Tuple[TRSeq, ...]
    k: Optional[int] = None
    exact: bool = True
    trace_id: Optional[str] = None
    host: int = 0
    timeout: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "seqs", tuple(self.seqs))

    def __len__(self) -> int:
        return len(self.seqs)


@dataclasses.dataclass
class JoinResult:
    """Per-sequence results in request order, plus batch views."""

    results: List[QueryResult]

    @property
    def exact(self) -> bool:
        """True iff every row honours the exact-containment contract."""
        return all(r.exact for r in self.results)

    @property
    def rows(self) -> np.ndarray:
        """[n_seqs, n_patterns] containment matrix, request order."""
        if not self.results:
            return np.zeros((0, 0), bool)
        return np.stack([r.contained for r in self.results])

    def __len__(self) -> int:
        return len(self.results)


def join_span(req: JoinRequest, backend: str):
    """The obs span stitching a request's ``trace_id`` into the trace
    stream; a no-op context when the request carries none."""
    if req.trace_id is None:
        return contextlib.nullcontext()
    return trace.span("serving.join", trace_id=req.trace_id,
                      backend=backend, n=len(req.seqs))


class Frontend:
    """One facade over any join backend (server, router, cluster,
    streaming bank, replica): speak ``JoinRequest``/``JoinResult``
    without caring which layer answers.

    ``begin``/``finish`` expose the backend's async pipeline when it
    has one: routers/clusters go through ``submit``/``collect``
    (continuous batching, shed tier and all), plain servers through the
    cache-bypassing ``launch_rows``/``finalize_rows`` split, and
    anything else falls back to computing at ``begin`` time - callers
    get overlap when the backend offers it and identical results when
    it does not."""

    def __init__(self, backend: Any):
        self.backend = backend

    # ------------------------------------------------------------- sync
    def join(self, req: JoinRequest) -> JoinResult:
        return self.backend.join(req)

    def query(self, seqs: Sequence[TRSeq], k: Optional[int] = None, *,
              exact: bool = True, host: int = 0,
              trace_id: Optional[str] = None) -> List[QueryResult]:
        return self.join(JoinRequest(
            seqs=tuple(seqs), k=k, exact=exact, host=host,
            trace_id=trace_id,
        )).results

    def query_one(self, seq: TRSeq, k: Optional[int] = None,
                  **kw) -> QueryResult:
        return self.query([seq], k, **kw)[0]

    def rows(self, seqs: Sequence[TRSeq], *, exact: bool = True,
             host: int = 0) -> np.ndarray:
        """[n_seqs, n_patterns] containment matrix."""
        return self.join(JoinRequest(
            seqs=tuple(seqs), k=0, exact=exact, host=host,
        )).rows

    # ------------------------------------------------------------ async
    def begin(self, req: JoinRequest):
        """Admit a request without blocking; redeem with ``finish``.
        Approximate requests compute immediately (the approx tier is
        host-only: there is nothing to overlap)."""
        backend = self.backend
        if req.exact and hasattr(backend, "submit"):
            ticket = backend.submit({req.host: list(req.seqs)}, k=req.k)
            return ("ticket", req, ticket)
        if req.exact and hasattr(backend, "launch_rows"):
            # cache-bypassing flights, chunked like exact_rows; results
            # are built (and cached) at finish time
            flights = []
            for c0 in range(0, len(req.seqs), backend.max_batch):
                chunk = list(req.seqs[c0 : c0 + backend.max_batch])
                flights.append(backend.launch_rows(chunk))
            return ("flights", req, flights)
        return ("done", req, self.join(req))

    def finish(self, handle) -> JoinResult:
        kind, req, payload = handle
        if kind == "done":
            return payload
        if kind == "ticket":
            results = self.backend.collect(
                payload, timeout=req.timeout)[req.host]
            return JoinResult(results)
        backend = self.backend
        k = backend.topk if req.k is None else req.k
        results: List[QueryResult] = []
        for flight in payload:
            got = backend.finalize_rows(flight)
            for i, s in enumerate(flight.seqs):
                row = got[i]
                results.append(QueryResult(
                    fingerprint=sequence_fingerprint(s),
                    contained=row, topk=backend._score(row, k),
                ))
        return JoinResult(results)
