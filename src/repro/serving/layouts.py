"""Bank-layout registry: pluggable launch/finalize/escalate strategies.

A *layout* is how the pattern bank is organised for the device join -
``"flat"`` (one frontier per (sequence, pattern) pair), ``"trie"``
(per-level scan over the prefix trie) and ``"trie_fused"`` (the whole
trie walk in one megakernel dispatch, repro.kernels.trie_walk).  The
server, router, cluster and streaming layers used to dispatch on the
layout *string* at every seam; this registry replaces those if/else
chains with one ``Layout`` record carrying the strategy hooks, so a new
layout registers itself instead of growing every call site:

* ``prepare(server)``          - build layout-side tables at server init
                                 (trie levels, packed subtrees, ...),
* ``launch(server, seqs, shared)``   - dispatch one batch, return the
                                 ``InFlightRows`` (the async split's
                                 launch half),
* ``finalize(server, flight)`` - read the deferred device outputs back
                                 into the flight's host accumulators
                                 (escalation/oracle resolution is
                                 layout-independent and stays in
                                 ``PatternServer.finalize_rows``),
* ``escalate(server, ...)``    - the wider-frontier replay for
                                 overflow-undecided cells,
* ``on_mask(server)``          - refresh layout-side prescreen tables
                                 after a tombstone-mask change,
* ``place(bank, n_hosts, trie)`` - partition bank rows into per-shard
                                 contiguous groups (the cluster
                                 router's placement strategy).

``PatternServer`` registers the three built-in layouts at import time
(bottom of server.py - the hooks are its own methods); everything else
resolves layouts by name through ``get_layout``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List


@dataclasses.dataclass(frozen=True)
class Layout:
    """One bank layout's strategy hooks (see module docstring).

    ``uses_trie`` gates trie construction at every layer that wires a
    server up (streaming, cluster replicas): trie-shaped layouts need a
    ``TrieBank`` built over the pattern bank before launch."""

    name: str
    uses_trie: bool
    prepare: Callable
    launch: Callable
    finalize: Callable
    escalate: Callable
    on_mask: Callable
    place: Callable


_REGISTRY: Dict[str, Layout] = {}


def register_layout(layout: Layout) -> Layout:
    """Register (or replace) a layout under ``layout.name``."""
    _REGISTRY[layout.name] = layout
    return layout


def get_layout(name: str) -> Layout:
    """Resolve a layout by name; raises the same ``ValueError`` the old
    string checks did, now with the registered names listed."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown bank_layout {name!r} (registered: "
            f"{', '.join(sorted(_REGISTRY)) or 'none'})"
        ) from None


def layout_names() -> List[str]:
    return sorted(_REGISTRY)
