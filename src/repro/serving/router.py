"""Cross-host request batching and result merging for the serving
cluster.

The reverse-search decomposition that makes mining parallel also makes
the mined bank *shardable with zero cross-shard joins*: containment of
sequence ``b`` in pattern ``p`` touches only ``b`` and ``p``, so a bank
split across hosts answers any query as the disjoint union of per-shard
answers.  This module is the query plane over such a split:

* ``plan_placement`` - which host owns which bank rows.  Trie banks
  place by depth-1 subtree (``TrieBank.shard_rows``: a subtree is never
  torn across hosts, so every host joins intact sub-tries and keeps the
  shared-prefix savings); flat banks place by contiguous pattern range.
* ``ClusterRouter.route`` - takes the queries that arrived on *all*
  hosts in one drain, dedups them by canonical fingerprint, resolves
  the two-level cache (host-local L1, then the fingerprint owner's L2),
  and joins every remaining miss in one batch per shard - requests that
  arrived on different hosts share device batches.  Per-shard rows
  scatter back into global bank order and the global top-k is scored
  over the merged row, so routed answers are bit-equal to a single-host
  ``PatternServer`` over the unsharded bank.
* ``ClusterRouter.submit/poll/collect`` - the async admission pipeline
  over the same cache/join/merge machinery (continuous batching):

      submit -> [admission queue] -> flush -> [in-flight batches]
                                                  -> collect

  ``submit`` resolves caches immediately and enqueues the misses
  (deduped against queued *and* in-flight fingerprints - a repeat
  arriving while its first copy is still on device piggybacks instead
  of re-joining).  A **flush** launches one batch per shard
  (``PatternServer.launch_rows`` with one shared query encoding,
  ``server.encode_queries``) and does NOT block: JAX dispatch is
  async, so the joins compute while later submits keep accumulating.
  Flush triggers: queue reached ``flush_batch`` (reason ``batch``),
  head-of-queue older than ``max_wait`` (reason ``deadline``, checked
  at every submit/poll against the injectable ``clock``), or a
  ``collect`` needing unresolved rows (reason ``force``).  ``collect``
  fences in admission order (``finalize_rows`` per shard), fills L2
  then L1 exactly like the synchronous path, and returns per-host
  results - bit-equal to ``route`` and the single-host server.

  **Load shedding**: with ``shed_depth`` set, a miss admitted while
  ``queue + in-flight >= shed_depth`` is not joined at all - it is
  answered from the host-side counts prescreen
  (``PatternServer.approx_rows``), a sound overapproximation flagged
  ``exact=False`` and never cached.  Off by default: exactness stays
  the default contract.

  There is one cluster-wide admission queue, not one per shard: every
  miss fans out to *all* shards (each answers its own column block),
  so per-shard queues would always flush in lockstep anyway - the
  per-shard split happens at flush time, one ``launch_rows`` per
  shard over the same batch.

Two-level cache: L1 is per-host (an arrival host answers replays of its
own traffic without any cross-host hop); L2 entries live on the
fingerprint's *owner* host (``hash(fp) % n_hosts``), so a sequence
first served on host A is a single-hop cache hit when it later arrives
on host B.  Both are keyed by the renaming-invariant
``sequence_fingerprint``, so vertex-renamed replays hit either level.

Hosts are duck-typed (see ``serving.cluster.ClusterHost``): the router
needs ``rows`` (owned global bank rows), ``server`` (a shard
``PatternServer``), ``l1``/``l2`` ordered dicts with ``l1_size``/
``l2_size`` bounds, and ``call(fn, *args)`` - the host-boundary hook
(in-process simulated hosts just call; a ``jax.distributed``-style
process group would RPC and device-put behind the same interface).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core.graphseq import TRSeq
from ..obs import trace
from ..obs.metrics import MetricsRegistry
from .bank import PatternBank, sequence_fingerprint
from .faults import (
    HostFault,
    HostTimeoutError,
    HostUnavailableError,
    PipelineBusyError,
    RetryPolicy,
)
from .layouts import get_layout
from .server import QueryResult, encode_queries, prescreen_rows, score_topk
from .trie import REQ_MASKED, TrieBank


@dataclasses.dataclass
class BankPlacement:
    """Which global bank rows each shard owns.  ``rows[s]`` is sorted,
    and the row sets partition ``range(n_patterns)`` (shards may be
    empty - fewer depth-1 subtrees than hosts)."""

    rows: List[np.ndarray]
    layout: str
    n_patterns: int

    @property
    def n_shards(self) -> int:
        return len(self.rows)


def plan_placement(
    bank: PatternBank,
    n_hosts: int,
    *,
    layout: str = "flat",
    trie: Optional[TrieBank] = None,
) -> BankPlacement:
    """Place bank rows onto ``n_hosts`` shards via the layout's
    ``place`` hook (layouts.py): by depth-1 trie subtree for the trie
    layouts (subtrees stay intact per host), by contiguous pattern
    range for flat.  Raises ``ValueError`` on an unregistered layout."""
    assert n_hosts >= 1
    rows = get_layout(layout).place(bank, n_hosts, trie)
    covered = np.concatenate(rows) if rows else np.zeros(0, np.int64)
    assert sorted(covered.tolist()) == list(range(bank.n_patterns))
    return BankPlacement(rows=rows, layout=layout,
                         n_patterns=bank.n_patterns)


def _cache_put(cache: "Dict[str, np.ndarray]", size: int, fp: str,
               row: np.ndarray) -> None:
    cache[fp] = row
    cache.move_to_end(fp)
    while len(cache) > size:
        cache.popitem(last=False)


@dataclasses.dataclass
class _PendingJoin:
    """One admitted cache-miss awaiting its shard join.  Shared by
    every ticket that references the fingerprint (in-flight dedup);
    ``row`` is filled when the batch carrying it is fenced.  ``exact``
    goes False when the batch was fenced through the prescreen rung of
    the degradation ladder (a shard's host was down with no replica)."""

    fp: str
    seq: TRSeq
    enqueued: float                       # admission clock reading
    row: Optional[np.ndarray] = None
    exact: bool = True


@dataclasses.dataclass
class _InFlightBatch:
    """One flushed batch: its admitted entries and the per-shard
    ``InFlightRows`` handles, launched but not yet fenced.  ``down``
    collects the hosts whose launch already failed the retry ladder;
    the fence answers their column blocks via the failover ladder."""

    entries: List[_PendingJoin]
    handles: list                          # [(host, InFlightRows)]
    done: bool = False
    launched: float = 0.0                  # flush clock reading
    down: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _HostHealth:
    """Per-host circuit-breaker state the router tracks when a
    ``RetryPolicy`` is installed: ``closed`` (healthy), ``open``
    (short-circuit every call until the cooldown elapses), ``half_open``
    (cooldown elapsed, exactly one probe allowed - success closes and
    counts a recovery, failure re-opens)."""

    consec: int = 0
    state: str = "closed"
    opened_at: float = 0.0


class DrainTicket:
    """Handle for one ``ClusterRouter.submit`` drain: remembers the
    request shape (per-host fingerprints, arrival hosts) and how each
    fingerprint resolved (cached row / pending join / shed).  Redeem
    with ``ClusterRouter.collect``."""

    def __init__(self, k: int, created: float = 0.0):
        self.k = k
        self.created = created        # submit clock reading (e2e base)
        self.fps: Dict[int, List[str]] = {}
        self.arrival_hosts: Dict[str, set] = {}
        self.rows: Dict[str, object] = {}   # row | _PendingJoin | None
        self.cached: Dict[str, bool] = {}
        self.shed: Dict[str, TRSeq] = {}    # fps answered approximately
        self.results: Optional[Dict[int, List[QueryResult]]] = None

    @property
    def pending(self) -> int:
        """Referenced joins not yet fenced (0 = collect won't block)."""
        return sum(
            1 for v in self.rows.values()
            if isinstance(v, _PendingJoin) and v.row is None
        )


class ClusterRouter:
    """Batches queries arriving on different hosts into shared per-shard
    device batches and merges the per-shard rows (see the module
    docstring for the protocol)."""

    def __init__(
        self,
        hosts: Sequence,           # ClusterHost duck-types, shard order
        *,
        n_patterns: int,
        support: np.ndarray,       # live scoring supports, global order
        topk: int = 10,
        metrics: Optional[MetricsRegistry] = None,
        metrics_ns: str = "cluster.router",
        max_wait: Optional[float] = None,
        flush_batch: Optional[int] = None,
        shed_depth: Optional[int] = None,
        clock: Optional[Callable[[], float]] = None,
        fault_policy: Optional[RetryPolicy] = None,
        sleep: Optional[Callable[[float], None]] = None,
    ):
        self.hosts = list(hosts)
        self.n_patterns = n_patterns
        self.support = support
        self.topk = topk
        self._row_mask: Optional[np.ndarray] = None  # None = all active
        # --- admission pipeline knobs (see module docstring) ---
        # max_wait: deadline flush - seconds the head-of-queue may wait
        # flush_batch: batch flush - queue length that triggers a flush
        # shed_depth: queue+in-flight depth past which new misses get
        #   prescreen-only approximate answers (None = never shed)
        # clock: injectable monotonic clock (tests drive a fake one)
        self.max_wait = max_wait
        self.flush_batch = flush_batch
        self.shed_depth = shed_depth
        self.clock = time.monotonic if clock is None else clock
        self._queue: List[_PendingJoin] = []     # admission order
        self._pending: Dict[str, _PendingJoin] = {}  # queued+in-flight
        self._batches: List[_InFlightBatch] = []     # launch order
        self._tickets: List[DrainTicket] = []        # uncollected
        # registry-backed: pass ``metrics=`` to keep accumulating across
        # router rebuilds (the sharded streaming bank re-plans placement
        # on every full refresh; its hit counters must survive that)
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self.stats = self.metrics.view(metrics_ns, keys=[
            "queries", "l1_hits", "l2_hits", "misses",
            "shard_batches", "mask_patches", "mask_clears",
            "inflight_hits", "shed_prescreen",
            "flush_batch", "flush_deadline", "flush_force",
        ])
        self._depth_gauge = self.metrics.gauge(
            f"{metrics_ns}.queue_depth")
        # always-on latency percentiles over the admission pipeline
        # (log-bucket histograms; observed against the injectable
        # ``self.clock`` so the pipeline tests can fake time):
        #   e2e_seconds        submit -> collected, per ticket
        #   queue_wait_seconds admit -> flush launch, per miss
        #   flush_seconds      flush launch -> batch fenced
        #   route_seconds      one synchronous route() drain
        self._h_e2e = self.metrics.bucket_histogram(
            f"{metrics_ns}.e2e_seconds")
        self._h_queue_wait = self.metrics.bucket_histogram(
            f"{metrics_ns}.queue_wait_seconds")
        self._h_flush = self.metrics.bucket_histogram(
            f"{metrics_ns}.flush_seconds")
        self._h_route = self.metrics.bucket_histogram(
            f"{metrics_ns}.route_seconds")
        # aging gauges the SLO watchdog reads: seconds the current
        # head-of-queue / oldest uncollected ticket have been waiting
        self._age_gauge = self.metrics.gauge(
            f"{metrics_ns}.queue_age")
        self._ticket_age_gauge = self.metrics.gauge(
            f"{metrics_ns}.oldest_ticket_age")
        # pre-registered so healthy snapshots carry an explicit 0
        self.metrics.counter(f"{metrics_ns}.slo_breaches")
        # optional SloWatchdog (obs.slo), driven from _note_depth -
        # every submit/poll/collect gives it a rate-limited check
        self.watchdog = None
        # --- fault semantics (serving.faults) ---
        # fault_policy: per-call timeout + retry/backoff + circuit
        #   breaker at every host call; None = the pre-fault fast path
        #   (h.call direct, zero added work, bit-identical behavior)
        # sleep: injectable backoff sleep (tests advance a fake clock)
        self.fault_policy = fault_policy
        self._sleep = sleep if sleep is not None else (
            time.sleep if clock is None else (lambda s: None))
        self._health: Dict[int, _HostHealth] = {}
        self._failover: Dict[int, Callable] = {}
        # per-host req-row mirrors (re-masked in lockstep with
        # apply_row_mask): the bottom rung of the degradation ladder
        # answers a dead shard's columns from the host-side counts
        # prescreen computed router-side, no host call at all
        self._req_base = {
            h.hid: np.array(
                h.server.bank.req[: h.server.bank.n_patterns],
                np.int32, copy=True)
            for h in self.hosts
        }
        self._req_mirror = dict(self._req_base)
        self._nlk = (self.hosts[0].server.bank.n_label_keys
                     if self.hosts else 1)
        # pre-registered (explicit 0 in healthy snapshots; the
        # breaker-open SLO rule reads these): the fault counters are a
        # fixed global namespace, not per-router, matching the
        # injector's own ``cluster.faults.injected``
        self.faults = self.metrics.view("cluster.faults", keys=[
            "injected", "retries", "breaker_open",
            "failovers", "degraded_answers", "recoveries",
        ])
        self._h_retry = self.metrics.bucket_histogram(
            "cluster.faults.retry_seconds")

    # ------------------------------------------------------------- cache
    def owner(self, fp: str) -> int:
        """The L2 owner host of a fingerprint (stable hash of the hex
        digest, so every host agrees without coordination)."""
        return int(fp[:8], 16) % len(self.hosts)

    def clear_caches(self) -> None:
        for h in self.hosts:
            h.l1.clear()
            h.l2.clear()

    def apply_row_mask(self, active: Optional[np.ndarray]) -> None:
        """Reconcile the L1/L2 caches with a new tombstone mask
        *per-row* instead of dropping them wholesale.  A masked bank row
        answers False by definition (see ``PatternServer.set_row_mask``),
        so a pure tombstone - rows only *leaving* the active set - can
        patch every cached containment row in place: newly-masked
        columns go False, untouched columns stay exact, and the entries
        (plus their LRU positions) survive.  Rows coming *back*
        (masked -> active) were cached as False with no way to recover
        the true bit, so any recovery still clears everything - the
        sound fallback.  Patches are copy-on-write: previously returned
        ``QueryResult.contained`` arrays may alias cache entries.

        The admission pipeline must be quiescent: an in-flight join was
        launched against the pre-mask requirements and its ticket holds
        references the patch cannot reach - collect every ticket before
        re-masking.  Raises ``PipelineBusyError`` (a typed error, not a
        bare assert - it must survive ``python -O``) naming the counts
        still in the pipeline."""
        if self._tickets or self._queue or self._batches:
            raise PipelineBusyError(
                queued=len(self._queue),
                inflight=sum(len(b.entries) for b in self._batches),
                tickets=len(self._tickets),
            )
        old = self._row_mask
        new = (None if active is None
               else np.asarray(active, bool).copy())
        self._row_mask = new
        old_a = (np.ones(self.n_patterns, bool) if old is None else old)
        new_a = (np.ones(self.n_patterns, bool) if new is None else new)
        # keep the degraded-path req mirrors in lockstep: masked rows
        # answer False from the prescreen too (their req is REQ_MASKED)
        for h in self.hosts:
            m = self._req_base[h.hid].copy()
            m[~new_a[h.rows]] = REQ_MASKED
            self._req_mirror[h.hid] = m
        if (new_a & ~old_a).any():  # recoveries: cached False is stale
            self.clear_caches()
            self.stats["mask_clears"] += 1
            return
        newly_masked = old_a & ~new_a
        if not newly_masked.any():
            return  # mask unchanged: every entry is still exact
        for h in self.hosts:
            for cache in (h.l1, h.l2):
                for fp, row in cache.items():
                    patched = row.copy()
                    patched[newly_masked] = False
                    cache[fp] = patched
        self.stats["mask_patches"] += 1

    # ----------------------------------------------------- fault ladder
    def _host_call(self, h, fn, *args):
        """Every cross-host access goes through here.  Without a
        ``fault_policy`` this is exactly ``h.call`` - the pre-fault
        fast path, bit-identical behavior.  With one, it is the retry
        ladder: per-call timeout on the injectable clock (a timed-out
        result is discarded), capped exponential backoff retries, and
        the per-host circuit breaker (open hosts short-circuit without
        a call; after the cooldown one half-open probe is allowed, and
        a successful probe recovers the host - caches wiped, since a
        restarted host's caches are gone).  Exhausted ladders raise
        ``HostUnavailableError``; the *caller* decides whether to fail
        over (replica / prescreen) or propagate."""
        pol = self.fault_policy
        if pol is None:
            return h.call(fn, *args)
        hh = self._health.setdefault(h.hid, _HostHealth())
        if hh.state == "open":
            if self.clock() - hh.opened_at < pol.breaker_cooldown:
                raise HostUnavailableError(
                    h.hid, f"host {h.hid} circuit breaker open")
            hh.state = "half_open"
        last: Optional[BaseException] = None
        attempts = 1 if hh.state == "half_open" else pol.retries + 1
        for attempt in range(attempts):
            t0 = self.clock()
            try:
                out = h.call(fn, *args)
                if (pol.call_timeout is not None
                        and self.clock() - t0 > pol.call_timeout):
                    raise HostTimeoutError(
                        h.hid,
                        f"host {h.hid} call exceeded "
                        f"{pol.call_timeout}s; result discarded")
            except HostFault as f:
                last = f
                trace.mark("host_fault")
                self._h_retry.observe(self.clock() - t0)
                if self._note_host_failure(hh) \
                        or attempt == attempts - 1:
                    break
                self.faults["retries"] += 1
                self._sleep(min(pol.backoff_base * 2.0 ** attempt,
                                pol.backoff_cap))
                continue
            if hh.state == "half_open":
                self._recover_host(h)
            hh.consec = 0
            hh.state = "closed"
            return out
        raise HostUnavailableError(h.hid, str(last)) from last

    def _note_host_failure(self, hh: _HostHealth) -> bool:
        """Count one failure; open the breaker (returns True) when the
        consecutive-failure threshold is hit or a half-open probe
        failed."""
        hh.consec += 1
        if (hh.state == "half_open"
                or hh.consec >= self.fault_policy.breaker_threshold):
            hh.state = "open"
            hh.opened_at = self.clock()
            self.faults["breaker_open"] += 1
            return True
        return False

    def _recover_host(self, h) -> None:
        """A half-open probe succeeded: the host rejoins routing.  Its
        caches are wiped - a really-restarted host would come back
        empty, and a stale entry served as fresh would break the
        exactness contract."""
        h.l1.clear()
        h.l2.clear()
        self.faults["recoveries"] += 1

    def set_failover_replica(self, hid: int, rows_fn: Callable) -> None:
        """Register the replica rung of the degradation ladder for one
        host: ``rows_fn(seqs) -> [len(seqs), n_patterns]`` exact
        containment rows in *global* bank order (e.g. a ReplicaGroup
        read replica's ``exact_rows`` - it holds the full bank).  While
        ``hid`` is unavailable its column block is answered from the
        replica, bit-equal and still ``exact=True``; hosts without one
        fall through to the prescreen, flagged ``exact=False``."""
        self._failover[hid] = rows_fn

    def _failover_rows(self, h, seqs: Sequence[TRSeq]):
        """Answer one down host's column block: replica if registered
        (exact), else the router-side counts prescreen over the host's
        req mirror (sound superset, inexact).  Returns
        ``(block [len(seqs), len(h.rows)], exact)``."""
        trace.mark("host_fault")
        fb = self._failover.get(h.hid)
        if fb is not None:
            rows = np.asarray(fb(seqs), bool)
            self.faults["failovers"] += 1
            return rows[:, h.rows], True
        self.faults["degraded_answers"] += len(seqs)
        block = prescreen_rows(
            list(seqs), self._req_mirror[h.hid], self._nlk)
        return block[:, : len(h.rows)], False

    # -------------------------------------------------------------- join
    def _live_hosts(self) -> List:
        return [h for h in self.hosts if len(h.rows)]

    def _shard_rows_ex(self, seqs: Sequence[TRSeq]):
        """The fault-aware core of ``joined_rows``: merged containment
        rows plus an exactness verdict.  Hosts whose launch or fence
        exhausts the retry ladder drop to the failover ladder for their
        column block; ``exact`` goes False iff any block came from the
        prescreen rung."""
        out = np.zeros((len(seqs), self.n_patterns), bool)
        exact = True
        live = self._live_hosts()
        if not len(seqs) or not live:
            return out, exact
        nlk = live[0].server.bank.n_label_keys
        cap = min(h.server.max_batch for h in live)
        with trace.span("cluster.join", n=len(seqs)):
            for c0 in range(0, len(seqs), cap):
                chunk = list(seqs[c0 : c0 + cap])
                shared = encode_queries(chunk, n_label_keys=nlk)
                launched, down = [], []
                for h in live:
                    try:
                        launched.append((h, self._host_call(
                            h, h.server.launch_rows, chunk, shared)))
                    except HostUnavailableError:
                        down.append(h)
                for h, flight in launched:
                    try:
                        shard = self._host_call(
                            h, h.server.finalize_rows, flight)
                    except HostUnavailableError:
                        down.append(h)
                        continue
                    out[c0 : c0 + len(chunk), h.rows] = \
                        shard[:, : len(h.rows)]
                for h in down:
                    block, ok = self._failover_rows(h, chunk)
                    out[c0 : c0 + len(chunk), h.rows] = \
                        block[:, : len(h.rows)]
                    exact = exact and ok
            self.stats["shard_batches"] += len(live)
        return out, exact

    def joined_rows(self, seqs: Sequence[TRSeq]) -> np.ndarray:
        """Cache-bypassing merged containment rows [len(seqs),
        n_patterns], rows scattered back into global bank order.  The
        queries are encoded ONCE (``encode_queries``) and every shard's
        join is launched before any is fenced - per-shard cost is the
        shard's own group joins, not a full re-encode, and the shards'
        device batches overlap.  Zero collectives - the shard outputs
        are disjoint column blocks.

        This entry point has a *strict* exactness contract (the
        streaming window protocol reconciles supports through it): if a
        shard's host is unavailable and no replica covers it, it raises
        ``HostUnavailableError`` rather than return prescreen bits.
        Query-serving paths (``route``/``submit``/``collect``) use the
        degrading ``_shard_rows_ex`` instead."""
        rows, exact = self._shard_rows_ex(seqs)
        if not exact:
            raise HostUnavailableError(
                -1, "exact join impossible: a shard's host is "
                    "unavailable and no replica covers it")
        return rows

    # ------------------------------------------------------------- route
    def _score(self, row: np.ndarray, k: int) -> List[tuple]:
        return score_topk(row, self.support, k)

    def route(
        self,
        requests: Mapping[int, Sequence[TRSeq]],
        k: Optional[int] = None,
    ) -> Dict[int, List[QueryResult]]:
        """Serve one drain of the cluster-wide request queue:
        ``requests`` maps arrival host id -> its pending sequences.
        Returns per-host results in request order, bit-equal to a
        single-host ``PatternServer.query`` over the unsharded bank."""
        k = self.topk if k is None else k
        t_r0 = self.clock()
        try:
            return self._route_inner(requests, k)
        finally:
            self._h_route.observe(self.clock() - t_r0)

    def _route_inner(
        self,
        requests: Mapping[int, Sequence[TRSeq]],
        k: int,
    ) -> Dict[int, List[QueryResult]]:
        with trace.root_or_span(
                "cluster.route",
                n=sum(len(s) for s in requests.values())):
            fps: Dict[int, List[str]] = {}
            rows: Dict[str, Optional[np.ndarray]] = {}
            cached: Dict[str, bool] = {}
            arrival_hosts: Dict[str, set] = {}
            miss_fps: List[str] = []
            miss_seqs: List[TRSeq] = []
            with trace.span("cluster.cache", cat="cache"):
                for hid, seqs in requests.items():
                    host = self.hosts[hid]
                    fps[hid] = hfps = [
                        sequence_fingerprint(s) for s in seqs
                    ]
                    self.stats["queries"] += len(seqs)
                    for fp, s in zip(hfps, seqs):
                        arrival_hosts.setdefault(fp, set()).add(hid)
                        if fp in rows:
                            continue
                        if fp in host.l1:
                            host.l1.move_to_end(fp)
                            rows[fp] = host.l1[fp]
                            cached[fp] = True
                            self.stats["l1_hits"] += 1
                            continue
                        own = self.hosts[self.owner(fp)]
                        if fp in own.l2:
                            own.l2.move_to_end(fp)
                            rows[fp] = own.l2[fp]
                            cached[fp] = True
                            self.stats["l2_hits"] += 1
                            continue
                        rows[fp] = None  # placeholder: first-seen order
                        cached[fp] = False
                        miss_fps.append(fp)
                        miss_seqs.append(s)
            exact = dict.fromkeys(rows, True)
            if miss_seqs:
                self.stats["misses"] += len(miss_seqs)
                # degrading join: a dead shard's block falls to the
                # failover ladder instead of failing the whole drain
                got, ok = self._shard_rows_ex(miss_seqs)
                with trace.span("cluster.cache_fill", cat="cache"):
                    for i, fp in enumerate(miss_fps):
                        rows[fp] = got[i]
                        exact[fp] = ok
                        if ok:  # inexact rows are never cached
                            own = self.hosts[self.owner(fp)]
                            _cache_put(own.l2, own.l2_size, fp, got[i])
            with trace.span("cluster.finalize"):
                # every exactly-resolved fingerprint lands in its
                # arrival hosts' L1s; degraded rows stay uncached (a
                # later lookup must not serve them as exact)
                for fp, hids in arrival_hosts.items():
                    if not exact[fp]:
                        continue
                    for hid in hids:
                        host = self.hosts[hid]
                        _cache_put(host.l1, host.l1_size, fp, rows[fp])
                return {
                    hid: [
                        QueryResult(
                            fingerprint=fp, contained=rows[fp],
                            topk=self._score(rows[fp], k),
                            cached=cached[fp],
                            exact=exact[fp],
                        )
                        for fp in fps[hid]
                    ]
                    for hid in requests
                }

    def join(self, req) -> "JoinResult":
        """The unified entry point (serving.join): exact requests run
        one synchronous drain (``route``) for the arrival host;
        ``exact=False`` requests serve the merged shard prescreen (the
        shed tier's rows on demand), flagged inexact and never
        cached."""
        from .join import JoinResult, join_span
        seqs = list(req.seqs)
        with join_span(req, "router"):
            if req.exact:
                return JoinResult(
                    self.route({req.host: seqs}, k=req.k)[req.host])
            k = self.topk if req.k is None else req.k
            self.stats["queries"] += len(seqs)
            self.stats["shed_prescreen"] += len(seqs)
            approx = self._approx_rows(seqs)
            return JoinResult([
                QueryResult(
                    fingerprint=sequence_fingerprint(s),
                    contained=approx[i], topk=self._score(approx[i], k),
                    cached=False, exact=False,
                )
                for i, s in enumerate(seqs)
            ])

    # --------------------------------------------- admission pipeline
    def depth(self) -> int:
        """Misses admitted but not yet fenced: queued + in flight."""
        return len(self._queue) + sum(
            len(b.entries) for b in self._batches if not b.done
        )

    def attach_watchdog(self, watchdog) -> None:
        """Wire an ``obs.slo.SloWatchdog``: ``_note_depth`` (already on
        every submit/poll/collect) will give it rate-limited checks."""
        self.watchdog = watchdog

    def _note_depth(self) -> None:
        self._depth_gauge.set(self.depth())
        now = self.clock()
        self._age_gauge.set(
            now - self._queue[0].enqueued if self._queue else 0.0)
        self._ticket_age_gauge.set(
            now - min(t.created for t in self._tickets)
            if self._tickets else 0.0)
        if self.watchdog is not None:
            self.watchdog.maybe_check()

    def submit(
        self,
        requests: Mapping[int, Sequence[TRSeq]],
        k: Optional[int] = None,
    ) -> DrainTicket:
        """Admit one drain without blocking: resolve the two-level
        cache exactly like ``route``, piggyback on queued/in-flight
        duplicates, shed to the approximate tier past ``shed_depth``,
        enqueue the rest, and fire any flush trigger.  Returns a ticket
        for ``collect``; the queued joins run on device while later
        drains keep submitting."""
        k = self.topk if k is None else k
        ticket = DrainTicket(k, created=self.clock())
        with trace.root_or_span(
                "cluster.submit",
                n=sum(len(s) for s in requests.values())):
            with trace.span("cluster.cache", cat="cache"):
                for hid, seqs in requests.items():
                    host = self.hosts[hid]
                    ticket.fps[hid] = hfps = [
                        sequence_fingerprint(s) for s in seqs
                    ]
                    self.stats["queries"] += len(seqs)
                    for fp, s in zip(hfps, seqs):
                        ticket.arrival_hosts.setdefault(
                            fp, set()).add(hid)
                        if fp in ticket.rows:
                            continue
                        if fp in host.l1:
                            host.l1.move_to_end(fp)
                            ticket.rows[fp] = host.l1[fp]
                            ticket.cached[fp] = True
                            self.stats["l1_hits"] += 1
                            continue
                        own = self.hosts[self.owner(fp)]
                        if fp in own.l2:
                            own.l2.move_to_end(fp)
                            ticket.rows[fp] = own.l2[fp]
                            ticket.cached[fp] = True
                            self.stats["l2_hits"] += 1
                            continue
                        pend = self._pending.get(fp)
                        if pend is not None:
                            # an earlier drain already admitted this
                            # fingerprint and it is queued or on
                            # device: share its row, no second join
                            ticket.rows[fp] = pend
                            ticket.cached[fp] = False
                            self.stats["inflight_hits"] += 1
                            continue
                        self.stats["misses"] += 1
                        if (self.shed_depth is not None
                                and self.depth() >= self.shed_depth):
                            # overload: prescreen-only answer at
                            # collect time, flagged inexact, uncached
                            ticket.shed[fp] = s
                            ticket.rows[fp] = None
                            ticket.cached[fp] = False
                            self.stats["shed_prescreen"] += 1
                            trace.mark("shed")
                            continue
                        pend = _PendingJoin(fp, s, self.clock())
                        self._queue.append(pend)
                        self._pending[fp] = pend
                        ticket.rows[fp] = pend
                        ticket.cached[fp] = False
            self._tickets.append(ticket)
            self._maybe_flush()
            self._note_depth()
        return ticket

    def poll(self) -> None:
        """Deadline pump: flush the queue if its head has waited past
        ``max_wait``.  Call between submits when arrivals are sparse -
        submit/collect fire the same check themselves."""
        self._maybe_flush()
        self._note_depth()

    def _maybe_flush(self) -> None:
        while self._queue:
            if (self.flush_batch is not None
                    and len(self._queue) >= self.flush_batch):
                self._flush("batch")
            elif (self.max_wait is not None
                    and self.clock() - self._queue[0].enqueued
                    >= self.max_wait):
                self._flush("deadline")
            else:
                break

    def _flush(self, reason: str) -> None:
        """Launch the head of the queue as one batch per shard (shared
        query encoding, ``launch_rows``) - dispatch only, no fence: the
        joins compute while the pipeline keeps admitting."""
        live = self._live_hosts()
        cap = min((h.server.max_batch for h in live),
                  default=len(self._queue))
        batch = self._queue[:cap]
        del self._queue[:cap]
        seqs = [e.seq for e in batch]
        t_launch = self.clock()
        for e in batch:
            self._h_queue_wait.observe(t_launch - e.enqueued)
        with trace.span("cluster.flush", reason=reason, n=len(seqs)):
            handles, down = [], []
            if live:
                shared = encode_queries(
                    seqs,
                    n_label_keys=live[0].server.bank.n_label_keys,
                )
                for h in live:
                    try:
                        handles.append((h, self._host_call(
                            h, h.server.launch_rows, seqs, shared)))
                    except HostUnavailableError:
                        # launch already exhausted the ladder: the
                        # fence answers this host's block via failover
                        down.append(h)
            self.stats["shard_batches"] += len(handles)
        self._batches.append(
            _InFlightBatch(entries=batch, handles=handles,
                           launched=t_launch, down=down))
        self.stats["flush_" + reason] += 1

    def _fence_batch(self, batch: _InFlightBatch) -> None:
        """Fence one in-flight batch and fill the owner L2s - the
        async analogue of ``route``'s post-join cache fill, same order:
        batch entries in admission order, L2 before any ticket's L1."""
        with trace.span("cluster.fence", n=len(batch.entries)):
            rows = np.zeros((len(batch.entries), self.n_patterns), bool)
            down = list(batch.down)
            for h, flight in batch.handles:
                try:
                    shard = self._host_call(
                        h, h.server.finalize_rows, flight)
                except HostUnavailableError:
                    down.append(h)
                    continue
                rows[:, h.rows] = shard[:, : len(h.rows)]
            exact = True
            if down:
                seqs = [e.seq for e in batch.entries]
                for h in down:
                    block, ok = self._failover_rows(h, seqs)
                    rows[:, h.rows] = block[:, : len(h.rows)]
                    exact = exact and ok
            with trace.span("cluster.cache_fill", cat="cache"):
                for i, e in enumerate(batch.entries):
                    e.row = rows[i]
                    e.exact = exact
                    if exact:  # degraded rows are never cached
                        own = self.hosts[self.owner(e.fp)]
                        _cache_put(own.l2, own.l2_size, e.fp, rows[i])
                    self._pending.pop(e.fp, None)
        self._h_flush.observe(self.clock() - batch.launched)
        batch.done = True

    def _approx_rows(self, seqs: Sequence[TRSeq]) -> np.ndarray:
        """Merged prescreen-only rows for the shed tier: each shard's
        host-side counts prescreen, global bank order, no device.  An
        unavailable host costs nothing here - the prescreen needs no
        host state, so the router computes the same bits from its req
        mirror."""
        out = np.zeros((len(seqs), self.n_patterns), bool)
        with trace.span("cluster.approx", n=len(seqs)):
            for h in self._live_hosts():
                try:
                    shard = self._host_call(h, h.server.approx_rows,
                                            seqs)
                except HostUnavailableError:
                    shard = prescreen_rows(
                        list(seqs), self._req_mirror[h.hid], self._nlk)
                out[:, h.rows] = shard[:, : len(h.rows)]
        return out

    def collect(
        self, ticket: Optional[DrainTicket] = None,
        timeout: Optional[float] = None,
    ) -> "Dict[int, List[QueryResult]] | List[Dict[int, List[QueryResult]]]":
        """Redeem one ticket (or, with ``None``, every outstanding
        ticket in submit order).  Force-flushes and fences in admission
        order until the ticket's joins are resolved, computes the shed
        tier's approximate rows, fills arrival-host L1s, and returns
        the per-host results - bit-equal to ``route`` on the same
        requests wherever ``exact`` is True.

        ``timeout`` bounds the drain on the injectable clock: once the
        deadline passes, joins still unresolved are *degraded* through
        the shed tier (prescreen answer, ``exact=False``) instead of
        blocking forever on a lost or faulting in-flight batch - every
        query still gets exactly one answer.  The timed-out joins stay
        queued/in flight and resolve exactly on a later fence; a repeat
        submit of the same fingerprint piggybacks on them."""
        if ticket is None:
            return [self.collect(t, timeout=timeout)
                    for t in list(self._tickets)]
        if ticket.results is not None:
            return ticket.results
        deadline = (None if timeout is None
                    else self.clock() + timeout)
        with trace.root_or_span("cluster.collect"):
            while ticket.pending:
                if deadline is not None and self.clock() >= deadline:
                    # deadline passed with joins unresolved: answer the
                    # stragglers from the shed tier, leave their joins
                    # in the pipeline to finish exactly later
                    for fp, v in list(ticket.rows.items()):
                        if isinstance(v, _PendingJoin) \
                                and v.row is None:
                            ticket.shed[fp] = v.seq
                            ticket.rows[fp] = None
                            self.stats["shed_prescreen"] += 1
                            trace.mark("shed")
                    break
                if self._batches:
                    self._fence_batch(self._batches.pop(0))
                    continue
                if not self._queue:
                    # not queued, not in flight, row never filled: the
                    # batch carrying it was lost.  A typed error, not
                    # an assert - this must survive ``python -O``.
                    raise RuntimeError(
                        "pending join neither queued nor in flight")
                self._flush("force")
            self._note_depth()
            with trace.span("cluster.finalize"):
                rows: Dict[str, np.ndarray] = {}
                exact: Dict[str, bool] = {}
                for fp, v in ticket.rows.items():
                    if fp in ticket.shed:
                        continue
                    if isinstance(v, _PendingJoin):
                        rows[fp] = v.row
                        exact[fp] = v.exact
                    else:
                        rows[fp] = v
                        exact[fp] = True
                if ticket.shed:
                    trace.mark("shed")
                    shed_fps = list(ticket.shed)
                    approx = self._approx_rows(
                        [ticket.shed[fp] for fp in shed_fps])
                    for i, fp in enumerate(shed_fps):
                        rows[fp] = approx[i]
                        exact[fp] = False
                # exact rows land in their arrival hosts' L1s, same as
                # route; approximate rows are never cached (a later
                # lookup must not serve them as exact)
                for fp, hids in ticket.arrival_hosts.items():
                    if not exact[fp]:
                        continue
                    for hid in hids:
                        host = self.hosts[hid]
                        _cache_put(host.l1, host.l1_size, fp, rows[fp])
                ticket.results = {
                    hid: [
                        QueryResult(
                            fingerprint=fp, contained=rows[fp],
                            topk=self._score(rows[fp], ticket.k),
                            cached=ticket.cached[fp],
                            exact=exact[fp],
                        )
                        for fp in ticket.fps[hid]
                    ]
                    for hid in ticket.fps
                }
        self._h_e2e.observe(self.clock() - ticket.created)
        self._tickets.remove(ticket)
        self._note_depth()
        return ticket.results
