"""Cross-host request batching and result merging for the serving
cluster.

The reverse-search decomposition that makes mining parallel also makes
the mined bank *shardable with zero cross-shard joins*: containment of
sequence ``b`` in pattern ``p`` touches only ``b`` and ``p``, so a bank
split across hosts answers any query as the disjoint union of per-shard
answers.  This module is the query plane over such a split:

* ``plan_placement`` - which host owns which bank rows.  Trie banks
  place by depth-1 subtree (``TrieBank.shard_rows``: a subtree is never
  torn across hosts, so every host joins intact sub-tries and keeps the
  shared-prefix savings); flat banks place by contiguous pattern range.
* ``ClusterRouter.route`` - takes the queries that arrived on *all*
  hosts in one drain, dedups them by canonical fingerprint, resolves
  the two-level cache (host-local L1, then the fingerprint owner's L2),
  and joins every remaining miss in one batch per shard - each shard
  owner runs its own ``PatternServer.exact_rows`` (pow-2 device
  batches) over the union of misses, so requests that arrived on
  different hosts share device batches.  Per-shard rows scatter back
  into global bank order and the global top-k is scored over the merged
  row, so routed answers are bit-equal to a single-host
  ``PatternServer`` over the unsharded bank.

Two-level cache: L1 is per-host (an arrival host answers replays of its
own traffic without any cross-host hop); L2 entries live on the
fingerprint's *owner* host (``hash(fp) % n_hosts``), so a sequence
first served on host A is a single-hop cache hit when it later arrives
on host B.  Both are keyed by the renaming-invariant
``sequence_fingerprint``, so vertex-renamed replays hit either level.

Hosts are duck-typed (see ``serving.cluster.ClusterHost``): the router
needs ``rows`` (owned global bank rows), ``server`` (a shard
``PatternServer``), ``l1``/``l2`` ordered dicts with ``l1_size``/
``l2_size`` bounds, and ``call(fn, *args)`` - the host-boundary hook
(in-process simulated hosts just call; a ``jax.distributed``-style
process group would RPC and device-put behind the same interface).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core.graphseq import TRSeq
from ..obs import trace
from ..obs.metrics import MetricsRegistry
from .bank import PatternBank, sequence_fingerprint
from .server import QueryResult, score_topk
from .trie import TrieBank, build_trie


@dataclasses.dataclass
class BankPlacement:
    """Which global bank rows each shard owns.  ``rows[s]`` is sorted,
    and the row sets partition ``range(n_patterns)`` (shards may be
    empty - fewer depth-1 subtrees than hosts)."""

    rows: List[np.ndarray]
    layout: str
    n_patterns: int

    @property
    def n_shards(self) -> int:
        return len(self.rows)


def plan_placement(
    bank: PatternBank,
    n_hosts: int,
    *,
    layout: str = "flat",
    trie: Optional[TrieBank] = None,
) -> BankPlacement:
    """Place bank rows onto ``n_hosts`` shards: by depth-1 trie subtree
    for the trie layout (subtrees stay intact per host), by contiguous
    pattern range for flat."""
    assert n_hosts >= 1
    if layout == "trie":
        if trie is None:
            trie = build_trie(bank)
        rows = [np.asarray(r, np.int64) for r in trie.shard_rows(n_hosts)]
    elif layout == "flat":
        rows = [
            np.asarray(r, np.int64)
            for r in np.array_split(
                np.arange(bank.n_patterns, dtype=np.int64), n_hosts
            )
        ]
    else:
        raise ValueError(f"unknown layout {layout!r}")
    covered = np.concatenate(rows) if rows else np.zeros(0, np.int64)
    assert sorted(covered.tolist()) == list(range(bank.n_patterns))
    return BankPlacement(rows=rows, layout=layout,
                         n_patterns=bank.n_patterns)


def _cache_put(cache: "Dict[str, np.ndarray]", size: int, fp: str,
               row: np.ndarray) -> None:
    cache[fp] = row
    cache.move_to_end(fp)
    while len(cache) > size:
        cache.popitem(last=False)


class ClusterRouter:
    """Batches queries arriving on different hosts into shared per-shard
    device batches and merges the per-shard rows (see the module
    docstring for the protocol)."""

    def __init__(
        self,
        hosts: Sequence,           # ClusterHost duck-types, shard order
        *,
        n_patterns: int,
        support: np.ndarray,       # live scoring supports, global order
        topk: int = 10,
        metrics: Optional[MetricsRegistry] = None,
        metrics_ns: str = "cluster.router",
    ):
        self.hosts = list(hosts)
        self.n_patterns = n_patterns
        self.support = support
        self.topk = topk
        self._row_mask: Optional[np.ndarray] = None  # None = all active
        # registry-backed: pass ``metrics=`` to keep accumulating across
        # router rebuilds (the sharded streaming bank re-plans placement
        # on every full refresh; its hit counters must survive that)
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self.stats = self.metrics.view(metrics_ns, keys=[
            "queries", "l1_hits", "l2_hits", "misses",
            "shard_batches", "mask_patches", "mask_clears",
        ])

    # ------------------------------------------------------------- cache
    def owner(self, fp: str) -> int:
        """The L2 owner host of a fingerprint (stable hash of the hex
        digest, so every host agrees without coordination)."""
        return int(fp[:8], 16) % len(self.hosts)

    def clear_caches(self) -> None:
        for h in self.hosts:
            h.l1.clear()
            h.l2.clear()

    def apply_row_mask(self, active: Optional[np.ndarray]) -> None:
        """Reconcile the L1/L2 caches with a new tombstone mask
        *per-row* instead of dropping them wholesale.  A masked bank row
        answers False by definition (see ``PatternServer.set_row_mask``),
        so a pure tombstone - rows only *leaving* the active set - can
        patch every cached containment row in place: newly-masked
        columns go False, untouched columns stay exact, and the entries
        (plus their LRU positions) survive.  Rows coming *back*
        (masked -> active) were cached as False with no way to recover
        the true bit, so any recovery still clears everything - the
        sound fallback.  Patches are copy-on-write: previously returned
        ``QueryResult.contained`` arrays may alias cache entries."""
        old = self._row_mask
        new = (None if active is None
               else np.asarray(active, bool).copy())
        self._row_mask = new
        old_a = (np.ones(self.n_patterns, bool) if old is None else old)
        new_a = (np.ones(self.n_patterns, bool) if new is None else new)
        if (new_a & ~old_a).any():  # recoveries: cached False is stale
            self.clear_caches()
            self.stats["mask_clears"] += 1
            return
        newly_masked = old_a & ~new_a
        if not newly_masked.any():
            return  # mask unchanged: every entry is still exact
        for h in self.hosts:
            for cache in (h.l1, h.l2):
                for fp, row in cache.items():
                    patched = row.copy()
                    patched[newly_masked] = False
                    cache[fp] = patched
        self.stats["mask_patches"] += 1

    # -------------------------------------------------------------- join
    def joined_rows(self, seqs: Sequence[TRSeq]) -> np.ndarray:
        """Cache-bypassing merged containment rows [len(seqs),
        n_patterns]: one ``exact_rows`` batch per non-empty shard, rows
        scattered back into global bank order.  Zero collectives - the
        shard outputs are disjoint column blocks."""
        out = np.zeros((len(seqs), self.n_patterns), bool)
        if not len(seqs):
            return out
        with trace.span("cluster.join", n=len(seqs)):
            for h in self.hosts:
                if not len(h.rows):
                    continue  # empty shard: no rows to answer
                shard = h.call(h.server.exact_rows, seqs)
                out[:, h.rows] = shard[:, : len(h.rows)]
                self.stats["shard_batches"] += 1
        return out

    # ------------------------------------------------------------- route
    def _score(self, row: np.ndarray, k: int) -> List[tuple]:
        return score_topk(row, self.support, k)

    def route(
        self,
        requests: Mapping[int, Sequence[TRSeq]],
        k: Optional[int] = None,
    ) -> Dict[int, List[QueryResult]]:
        """Serve one drain of the cluster-wide request queue:
        ``requests`` maps arrival host id -> its pending sequences.
        Returns per-host results in request order, bit-equal to a
        single-host ``PatternServer.query`` over the unsharded bank."""
        k = self.topk if k is None else k
        with trace.root_or_span(
                "cluster.route",
                n=sum(len(s) for s in requests.values())):
            fps: Dict[int, List[str]] = {}
            rows: Dict[str, Optional[np.ndarray]] = {}
            cached: Dict[str, bool] = {}
            arrival_hosts: Dict[str, set] = {}
            miss_fps: List[str] = []
            miss_seqs: List[TRSeq] = []
            with trace.span("cluster.cache", cat="cache"):
                for hid, seqs in requests.items():
                    host = self.hosts[hid]
                    fps[hid] = hfps = [
                        sequence_fingerprint(s) for s in seqs
                    ]
                    self.stats["queries"] += len(seqs)
                    for fp, s in zip(hfps, seqs):
                        arrival_hosts.setdefault(fp, set()).add(hid)
                        if fp in rows:
                            continue
                        if fp in host.l1:
                            host.l1.move_to_end(fp)
                            rows[fp] = host.l1[fp]
                            cached[fp] = True
                            self.stats["l1_hits"] += 1
                            continue
                        own = self.hosts[self.owner(fp)]
                        if fp in own.l2:
                            own.l2.move_to_end(fp)
                            rows[fp] = own.l2[fp]
                            cached[fp] = True
                            self.stats["l2_hits"] += 1
                            continue
                        rows[fp] = None  # placeholder: first-seen order
                        cached[fp] = False
                        miss_fps.append(fp)
                        miss_seqs.append(s)
            if miss_seqs:
                self.stats["misses"] += len(miss_seqs)
                got = self.joined_rows(miss_seqs)
                with trace.span("cluster.cache_fill", cat="cache"):
                    for i, fp in enumerate(miss_fps):
                        rows[fp] = got[i]
                        own = self.hosts[self.owner(fp)]
                        _cache_put(own.l2, own.l2_size, fp, got[i])
            with trace.span("cluster.finalize"):
                # every resolved fingerprint lands in its arrival
                # hosts' L1s
                for fp, hids in arrival_hosts.items():
                    for hid in hids:
                        host = self.hosts[hid]
                        _cache_put(host.l1, host.l1_size, fp, rows[fp])
                return {
                    hid: [
                        QueryResult(
                            fingerprint=fp, contained=rows[fp],
                            topk=self._score(rows[fp], k),
                            cached=cached[fp],
                        )
                        for fp in fps[hid]
                    ]
                    for hid in requests
                }
