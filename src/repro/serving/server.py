"""PatternServer: the request-facing layer over batched containment.

A query is a batch of incoming ``TRSeq``s; the answer, per sequence, is
which bank patterns it contains plus a support-weighted top-k.  The
server owns the production concerns around the batch.py entry points:

* request batching - misses are encoded into power-of-two (batch,
  token, pair-count) buckets so the jitted join recompiles a bounded
  number of times,
* the counts prescreen - only (sequence, pattern) pairs that pass the
  sound necessary condition are joined (``pair_contains``), typically a
  small fraction of the dense grid,
* an LRU cache keyed on canonical sequence fingerprints (bank.py),
* exactness - cells flagged ``overflow & ~contained`` (the only
  undecided ones, see batch.py) are re-checked against the
  ``core.containment`` host oracle, so results always equal the oracle,
* counters (queries, cache hits, device batches, prescreened pairs,
  fallback cells) for the ops dashboards.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.containment import contains
from ..core.graphseq import TRSeq
from ..mining.encoding import encode_db
from .bank import PatternBank, sequence_fingerprint
from .batch import (
    index_and_prescreen,
    max_key_bucket,
    pair_contains_indexed,
)


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class QueryResult:
    fingerprint: str
    contained: np.ndarray          # [n_patterns] bool, bank order
    topk: List[Tuple[int, int]]    # (pattern id, support score)
    cached: bool = False

    @property
    def pattern_ids(self) -> np.ndarray:
        return np.nonzero(self.contained)[0]


class PatternServer:
    def __init__(
        self,
        bank: PatternBank,
        *,
        emax: int = 4,
        emax_retry: int = 16,
        max_batch: int = 256,
        cache_size: int = 4096,
        topk: int = 10,
        use_kernel: bool = False,
        block_g: int = 64,
    ):
        self.bank = bank
        self.emax = emax
        self.emax_retry = emax_retry
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.topk = topk
        self.use_kernel = use_kernel
        self.block_g = block_g
        self._req = jnp.asarray(bank.req)
        # patterns grouped by program length: the join runs exactly L_g
        # steps per group instead of the bank-wide maximum, and the
        # group's phi width shrinks to match
        self._groups = []
        n_steps = bank.n_steps[: bank.n_patterns]
        for L_g in sorted(set(int(x) for x in n_steps)):
            rows = np.nonzero(n_steps == L_g)[0].astype(np.int32)
            steps_g = jnp.asarray(bank.steps[rows][:, :L_g])
            self._groups.append((rows, steps_g))
        self._cache: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self.stats: Dict[str, int] = {
            "queries": 0, "cache_hits": 0, "device_batches": 0,
            "pairs_possible": 0, "pairs_prescreened": 0,
            "escalated_cells": 0, "host_fallback_cells": 0,
        }

    # ------------------------------------------------------------- device
    def _run_batch(self, seqs: List[TRSeq]) -> np.ndarray:
        """Exact containment rows [len(seqs), n_patterns] for one chunk."""
        assert len(seqs) <= self.max_batch
        bank = self.bank
        tdb = encode_db(
            seqs,
            pad_to=_pow2(max(
                1, max(sum(len(it) for it in s) for s in seqs)
            )),
            pad_seqs_to=_pow2(len(seqs)),
        )
        tokens = jnp.asarray(tdb.tokens)
        tmax = _pow2(max_key_bucket(tdb.tokens, bank.n_label_keys))
        # one index build per batch, shared by every group join below
        order, start, count, possible = index_and_prescreen(
            tokens, self._req, n_label_keys=bank.n_label_keys
        )
        possible = np.asarray(possible)[: len(seqs), : bank.n_patterns]
        self.stats["device_batches"] += 1
        self.stats["pairs_possible"] += int(possible.sum())
        self.stats["pairs_prescreened"] += int(possible.size)
        contained = np.zeros((len(seqs), bank.n_patterns), bool)
        for rows, steps_g in self._groups:
            b_idx, g_idx = np.nonzero(possible[:, rows])
            if not len(b_idx):
                continue
            if steps_g.shape[1] == 1:
                # single-TR patterns: the counts prescreen IS the exact
                # containment test (one matching-key token always embeds:
                # fresh vertices bind freely under an empty psi)
                contained[b_idx, rows[g_idx]] = True
                continue
            n = len(b_idx)
            npad = _pow2(n)
            bi = np.zeros(npad, np.int32)
            pi = np.zeros(npad, np.int32)
            bi[:n], pi[:n] = b_idx, g_idx
            c, o = pair_contains_indexed(
                tokens, order, start, count, steps_g,
                jnp.asarray(bi), jnp.asarray(pi),
                nv=bank.nv, emax=self.emax, tmax=tmax,
                use_kernel=self.use_kernel, block_g=self.block_g,
                uniform_length=True,
            )
            c = np.array(c)[:n]
            o = np.array(o)[:n]
            # only overflow & ~contained cells are undecided (batch.py);
            # escalate them through a wider device frontier before
            # paying for the per-cell host oracle
            und = np.nonzero(o & ~c)[0]
            if len(und) and self.emax_retry > self.emax:
                m = len(und)
                mpad = _pow2(m)
                bi2 = np.zeros(mpad, np.int32)
                pi2 = np.zeros(mpad, np.int32)
                bi2[:m], pi2[:m] = b_idx[und], g_idx[und]
                c2, o2 = pair_contains_indexed(
                    tokens, order, start, count, steps_g,
                    jnp.asarray(bi2), jnp.asarray(pi2),
                    nv=bank.nv, emax=self.emax_retry, tmax=tmax,
                    use_kernel=self.use_kernel, block_g=self.block_g,
                    uniform_length=True,
                )
                c[und] = np.asarray(c2)[:m]
                o[und] = np.asarray(o2)[:m]
                self.stats["escalated_cells"] += m
            p_global = rows[g_idx]
            contained[b_idx, p_global] = c
            for i in np.nonzero(o & ~c)[0]:
                contained[b_idx[i], p_global[i]] = contains(
                    bank.patterns[p_global[i]], seqs[b_idx[i]]
                )
                self.stats["host_fallback_cells"] += 1
        return contained

    # ------------------------------------------------------------ scoring
    def _score(self, contained: np.ndarray, k: int) -> List[Tuple[int, int]]:
        # bank rows are ordered by (-support, canonical code), so the
        # first k contained ids are already the support-weighted top-k
        ids = np.nonzero(contained)[0][:k]
        sup = self.bank.support
        return [(int(i), int(sup[i])) for i in ids]

    # ------------------------------------------------------------- public
    def query(
        self, seqs: Sequence[TRSeq], k: Optional[int] = None
    ) -> List[QueryResult]:
        k = self.topk if k is None else k
        self.stats["queries"] += len(seqs)
        fps = [sequence_fingerprint(s) for s in seqs]
        rows: Dict[str, np.ndarray] = {}
        cached: Dict[str, bool] = {}
        miss_fps: List[str] = []
        miss_seqs: List[TRSeq] = []
        for fp, s in zip(fps, seqs):
            if fp in rows:
                continue
            if fp in self._cache:
                self._cache.move_to_end(fp)
                rows[fp] = self._cache[fp]
                cached[fp] = True
                self.stats["cache_hits"] += 1
            else:
                rows[fp] = None  # placeholder, preserves first-seen order
                cached[fp] = False
                miss_fps.append(fp)
                miss_seqs.append(s)
        for start in range(0, len(miss_seqs), self.max_batch):
            chunk = miss_seqs[start : start + self.max_batch]
            got = self._run_batch(chunk)
            for i, fp in enumerate(miss_fps[start : start + len(chunk)]):
                rows[fp] = got[i]
                self._cache[fp] = got[i]
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
        return [
            QueryResult(
                fingerprint=fp, contained=rows[fp],
                topk=self._score(rows[fp], k), cached=cached[fp],
            )
            for fp in fps
        ]

    def query_one(self, seq: TRSeq, k: Optional[int] = None) -> QueryResult:
        return self.query([seq], k)[0]
