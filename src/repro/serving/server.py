"""PatternServer: the request-facing layer over batched containment.

A query is a batch of incoming ``TRSeq``s; the answer, per sequence, is
which bank patterns it contains plus a support-weighted top-k.  The
server owns the production concerns around the batch.py entry points:

* request batching - misses are encoded into power-of-two (batch,
  token, pair-count) buckets so the jitted join recompiles a bounded
  number of times,
* the counts prescreen - only (sequence, pattern) pairs that pass the
  sound necessary condition are joined (``pair_contains``), typically a
  small fraction of the dense grid,
* an LRU cache keyed on canonical sequence fingerprints (bank.py;
  renaming-invariant, so bijection-renamed replays of a sequence hit),
* exactness - cells flagged ``overflow & ~contained`` (the only
  undecided ones, see batch.py) are re-checked against the
  ``core.containment`` host oracle, so results always equal the oracle,
* counters (queries, cache hits, device batches, prescreened pairs,
  joined steps, fallback cells) for the ops dashboards.

Three bank layouts share all of the above (``bank_layout=``; the
strategies live in the layouts.py registry and register at the bottom
of this module):

* ``"flat"`` - one (sequence, pattern) cell per surviving prescreen
  pair, grouped by program length; each cell replays its whole program.
* ``"trie"`` - the bank compiled into a prefix trie (trie.py); the join
  advances one frontier per (sequence, trie node) level-synchronously,
  seeded from the parent node's frontier, so patterns sharing a prefix
  pay for it once.  The prescreen runs per node against the residual
  ``node_req`` rows and prunes whole subtrees at their highest failing
  ancestor.
* ``"trie_fused"`` - the same trie walked by the fused megakernel
  (kernels.trie_walk): one cell per (sequence, depth-1 subtree), the
  level iteration, frontier buffers and per-node prescreen all inside
  one kernel, so a query batch costs ONE device dispatch regardless of
  trie depth.  Escalation reuses the per-level trie replay.

Answers are identical across layouts (all are exact); the trie layouts
win on banks with real prefix sharing (see trie.py), and the fused
layout additionally removes the per-level dispatch ladder.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.containment import contains
from ..core.graphseq import TRSeq
from ..mining.encoding import encode_db
from ..obs import trace
from ..obs.metrics import MetricsRegistry
from .bank import PatternBank, sequence_fingerprint
from .batch import (
    fused_trie_walk,
    index_and_node_prescreen,
    index_and_prescreen,
    max_key_bucket,
    pair_contains_indexed,
    token_index,
    token_keys_np,
    trie_level_advance_gather,
    trie_root_advance,
)
from .layouts import Layout, get_layout, register_layout
from .trie import (
    REQ_MASKED,
    TrieBank,
    build_trie,
    masked_node_req,
    pack_subtrees,
)


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


def prescreen_rows(
    seqs: Sequence[TRSeq], req_np: np.ndarray, n_label_keys: int
) -> np.ndarray:
    """The host-side counts prescreen as a standalone function: sound
    approximate rows ``[len(seqs), n_patterns]`` from token-key counts
    vs per-pattern requirement rows (``counts >= req``, all keys).
    True containment is always a cellwise subset; rows whose req is
    ``REQ_MASKED`` answer False.  ``PatternServer.approx_rows`` wraps
    this with the server's own req mirror; ``ClusterRouter`` calls it
    directly against its per-host req mirrors to answer a dead shard's
    rows ``exact=False`` without any host call - the bottom rung of the
    degradation ladder."""
    n_patterns = req_np.shape[0]
    out = np.zeros((len(seqs), n_patterns), bool)
    if not len(seqs) or not n_patterns:
        return out
    tdb = encode_db(
        list(seqs),
        pad_to=_pow2(max(
            1, max(sum(len(it) for it in s) for s in seqs)
        )),
        pad_seqs_to=_pow2(len(seqs)),
    )
    key = token_keys_np(tdb.tokens, n_label_keys)
    K = 6 * n_label_keys
    B = key.shape[0]
    rowed = key + np.arange(B)[:, None] * (K + 1)
    counts = np.bincount(
        rowed.ravel(), minlength=B * (K + 1)
    ).reshape(B, K + 1)[:, :K].astype(np.int32)
    out[:] = (
        counts[: len(seqs), None, :] >= req_np[None, :, :]
    ).all(-1)
    return out


def _bucket34(n: int) -> int:
    """Shape bucket for the fused walk's cell axis: pow-2 or
    3·2^(k-2), whichever is tighter (<= 33% padding waste vs pow-2's
    100%).  The fused dispatch is one jit call whose cost scales with
    the padded cell count, so at small serving batches the extra shape
    buckets buy back real walk time; ~1.5x more compile-cache entries
    is the price."""
    p = _pow2(n)
    q = 3 * p // 4
    return q if p >= 4 and q >= n else p


def score_topk(
    contained: np.ndarray, support: np.ndarray, k: int
) -> List[Tuple[int, int]]:
    """Support-ranked top-k of one containment row under *live*
    supports, ties broken by bank row id.  With the compile-time
    supports this equals ``PatternServer._score``'s bank-order shortcut
    (rows are ordered by (-support, canonical code)); the streaming /
    cluster layers rank with it because their supports drift from the
    compiled order.  Every layer shares this one implementation - the
    routed==single-host and replica==writer top-k bit-equality
    contracts depend on identical tie-breaking."""
    ids = np.nonzero(contained)[0]
    ranked = sorted(ids, key=lambda i: (-int(support[i]), int(i)))[:k]
    return [(int(i), int(support[i])) for i in ranked]


@dataclasses.dataclass
class QueryResult:
    fingerprint: str
    contained: np.ndarray          # [n_patterns] bool, bank order
    topk: List[Tuple[int, int]]    # (pattern id, support score)
    cached: bool = False
    # False only on the cluster's load-shed tier: ``contained`` is then
    # the prescreen overapproximation (true containment is a subset),
    # never cached, never the default (see ClusterRouter.submit)
    exact: bool = True

    @property
    def pattern_ids(self) -> np.ndarray:
        return np.nonzero(self.contained)[0]


def _fence(name: str, t0: float, out, **args) -> None:
    """Tracing-only launch/execution split for one async device call:
    under *full* tracing, fence the dispatch and record both halves.
    Under sampled tracing (``trace.fencing()`` is False) record the
    dispatch half only - a fence here would serialize the async
    pipeline the sampler exists to observe, so sampled traces carry
    launch time and the device half is attributed at the existing
    finalize fences.  When off this returns before reading any clock -
    the disabled path never blocks, so results, dispatch counts, and
    async overlap are untouched."""
    if not trace.enabled():
        return
    t1 = time.perf_counter()
    trace.add_complete(name, "dispatch", t0, t1 - t0, **args)
    if trace.fencing():
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        trace.add_complete(name + ".device", "device", t1, t2 - t1)


@dataclasses.dataclass
class SharedEncoding:
    """Query-side device encoding shared across bank shards.

    Everything here is a function of the query batch alone:
    ``slice_bank`` preserves the global ``nv``/``n_label_keys``, so the
    tokens, the inverted token index, and the per-key counts are
    identical no matter which shard consumes them.  The cluster router
    builds one per flush and passes it to every shard's
    ``launch_rows`` - without it each shard re-encodes and re-indexes
    the same sequences (the dominant per-shard dispatch cost that made
    cluster throughput go backwards with host count).  A process-group
    host boundary would ship exactly this struct alongside the request
    batch.

    ``counts_np`` is the host mirror of ``count`` (one fence at build
    time), letting shards run the counts prescreen as a host compare
    against their ``req`` rows instead of a per-shard device dispatch -
    bit-identical because both sides compare the same int32 counts."""

    seqs: List[TRSeq]
    tokens: "jnp.ndarray"          # [B, T, 6] padded query tokens
    order: "jnp.ndarray"           # inverted token index (batch.py)
    start: "jnp.ndarray"
    count: "jnp.ndarray"           # [B, K] per-key token counts
    counts_np: np.ndarray          # host mirror of ``count``
    tmax: int                      # pow-2 max same-key bucket size
    n_label_keys: int


def encode_queries(
    seqs: Sequence[TRSeq], *, n_label_keys: int
) -> SharedEncoding:
    """Encode one query batch into the shard-shareable device encoding
    (see ``SharedEncoding``).  One device_put for the tokens, one index
    build, one fence for the host counts - amortised over every shard
    instead of paid per shard."""
    seqs = list(seqs)
    assert seqs, "cannot encode an empty query batch"
    with trace.span("serving.encode", n=len(seqs), shared=True):
        tdb = encode_db(
            seqs,
            pad_to=_pow2(max(
                1, max(sum(len(it) for it in s) for s in seqs)
            )),
            pad_seqs_to=_pow2(len(seqs)),
        )
        tokens = jnp.asarray(tdb.tokens)
        tmax = _pow2(max_key_bucket(tdb.tokens, n_label_keys))
    t0 = time.perf_counter()
    order, start, count = token_index(
        tokens, n_label_keys=n_label_keys
    )
    _fence("serving.token_index", t0, (order, start, count))
    counts_np = np.asarray(count)
    return SharedEncoding(
        seqs=seqs, tokens=tokens, order=order, start=start,
        count=count, counts_np=counts_np, tmax=tmax,
        n_label_keys=n_label_keys,
    )


@dataclasses.dataclass
class InFlightRows:
    """One launched-but-unfenced containment batch
    (``PatternServer.launch_rows``): the dispatched join outputs stay
    on device until ``finalize_rows`` reads them, so a caller can keep
    launching batches (other shards, the next flush) while this one
    computes.  ``pending`` holds layout-specific deferred device reads;
    ``contained``/``ovf`` are the host accumulators they resolve into."""

    layout: str
    seqs: List[TRSeq]
    tokens: object
    order: object
    start: object
    count: object
    tmax: int
    contained: np.ndarray
    ovf: np.ndarray
    pending: list
    # launch timestamp (perf_counter): finalize_rows observes
    # launch-to-fence latency into the batch_seconds histogram
    t_launch: float = 0.0


class PatternServer:
    def __init__(
        self,
        bank: PatternBank,
        *,
        emax: int = 4,
        emax_retry: int = 16,
        max_batch: int = 256,
        cache_size: int = 4096,
        topk: int = 10,
        use_kernel: bool = False,
        block_g: int = 64,
        bank_layout: str = "flat",
        trie: Optional[TrieBank] = None,
        metrics: Optional[MetricsRegistry] = None,
        metrics_ns: str = "serving.server",
    ):
        self.bank = bank
        self.emax = emax
        self.emax_retry = emax_retry
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.topk = topk
        self.use_kernel = use_kernel
        self.block_g = block_g
        # layout strategies live in a registry (layouts.py): the string
        # resolves to a Layout record whose hooks drive launch /
        # finalize / escalate / masking below - raises ValueError on an
        # unregistered name, like the old literal check did
        self.layout = get_layout(bank_layout)
        self.bank_layout = bank_layout
        self._req = jnp.asarray(bank.req)
        # host mirror of the (possibly masked) prescreen requirements:
        # shared-encoding launches and the approx tier prescreen on host
        # against these instead of re-dispatching per shard
        self._req_np = bank.req
        # patterns grouped by program length: the join runs exactly L_g
        # steps per group instead of the bank-wide maximum, and the
        # group's phi width shrinks to match
        self._groups = []
        n_steps = bank.n_steps[: bank.n_patterns]
        for L_g in sorted(set(int(x) for x in n_steps)):
            rows = np.nonzero(n_steps == L_g)[0].astype(np.int32)
            steps_g = jnp.asarray(bank.steps[rows][:, :L_g])
            self._groups.append((rows, steps_g))
        # both layouts escalate undecided cells through a uniform-length
        # group replay (_resolve_undecided): map each bank row to its
        # (group, position)
        self._row_group = np.zeros(max(bank.n_patterns, 1), np.int32)
        self._row_pos = np.zeros(max(bank.n_patterns, 1), np.int32)
        for gi, (rows, _) in enumerate(self._groups):
            self._row_group[rows] = gi
            self._row_pos[rows] = np.arange(len(rows), dtype=np.int32)
        self.trie: Optional[TrieBank] = (
            trie if self.layout.uses_trie else None
        )
        self.layout.prepare(self)
        # tombstone mask (serving.streaming): inactive rows get their
        # prescreen requirements replaced by REQ_MASKED, so they are
        # never joined and always answer not-contained
        self._row_mask: Optional[np.ndarray] = None
        self._cache: "OrderedDict[str, np.ndarray]" = OrderedDict()
        # pairs_* count (sequence, pattern) prescreen pairs (flat
        # layout); cells_* count (sequence, trie node) prescreen cells
        # (trie layout) - deliberately distinct keys, the units differ.
        # Counters live in a registry (private unless ``metrics=`` is
        # passed), so a caller that rebuilds its server on a shared
        # registry keeps accumulating instead of silently zeroing.
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self.stats = self.metrics.view(metrics_ns, keys=[
            "queries", "cache_hits", "device_batches",
            "pairs_possible", "pairs_prescreened",
            "cells_possible", "cells_prescreened",
            "joined_steps",
            "escalated_cells", "host_fallback_cells",
        ])
        # always-on latency percentiles (constant-memory log buckets):
        # query_seconds is the public-entry wall per exact query call,
        # batch_seconds the launch-to-fence latency per device batch
        self._h_query = self.metrics.bucket_histogram(
            f"{metrics_ns}.query_seconds")
        self._h_batch = self.metrics.bucket_histogram(
            f"{metrics_ns}.batch_seconds")

    # ------------------------------------------------------ layout hooks
    # Registered as the built-in layouts' strategy hooks at the bottom
    # of this module (layouts.register_layout).

    def _prepare_flat(self) -> None:
        self.trie = None  # the flat join never touches trie tables

    def _prepare_trie(self) -> None:
        bank = self.bank
        t = self.trie = (
            self.trie if self.trie is not None else build_trie(bank)
        )
        assert t.bank is bank, "trie must be built over this bank"
        self._node_req = jnp.asarray(
            t.node_req.reshape(t.n_nodes, bank.req.shape[1])
        )
        self._node_req_np = t.node_req.reshape(
            t.n_nodes, bank.req.shape[1])
        # per-level host tables driving the level-synchronous scan.
        # Leaf nodes never seed children, so their cells take the
        # compaction-free path (the trie's analogue of the flat
        # join's uniform-length final step); only internal-node
        # cells pay for frontier compaction.
        has_child = np.zeros(max(t.n_nodes, 1), bool)
        has_child[t.node_parent[t.node_parent >= 0]] = True
        self._tlevels = []
        term_depth = t.node_depth[t.terminal_node[: bank.n_patterns]]
        for d, nodes in enumerate(t.levels):
            rows = np.nonzero(term_depth == d + 1)[0]
            term_pos = t.node_pos[t.terminal_node[rows]]
            leaf = ~has_child[nodes]
            term_leaf = leaf[term_pos]
            self._tlevels.append({
                "nodes": nodes,
                "leaf": leaf,
                "steps": t.node_step[nodes],
                "parent_pos": (
                    t.node_pos[t.node_parent[nodes]] if d
                    else np.zeros(len(nodes), np.int32)
                ),
                "term_rows_int": rows[~term_leaf],
                "term_pos_int": term_pos[~term_leaf],
                "term_rows_leaf": rows[term_leaf],
                "term_pos_leaf": term_pos[term_leaf],
            })

    def _prepare_trie_fused(self) -> None:
        # the per-level tables stay: escalation replays the failing
        # sub-trie level-synchronously at emax_retry (_escalate_trie),
        # shared between the trie and trie_fused layouts - so the
        # escalation/oracle semantics are bit-identical by construction
        self._prepare_trie()
        self._tpack = pack_subtrees(self.trie)
        # the packed subtree tables live on device once; per batch only
        # the surviving (sequence, subtree) cell list is uploaded
        self._pk_steps = jnp.asarray(self._tpack.steps)
        self._pk_parent = jnp.asarray(self._tpack.parent)
        self._pk_req = jnp.asarray(self._tpack.pack_req(self._node_req_np))

    def _mask_flat(self) -> None:
        pass  # the flat prescreen reads _req directly

    def _mask_trie(self) -> None:
        bank = self.bank
        if self._row_mask is None:
            nreq = self.trie.node_req.reshape(
                self.trie.n_nodes, bank.req.shape[1])
        else:
            nreq = masked_node_req(self.trie, self._row_mask)
        self._node_req = jnp.asarray(nreq)
        self._node_req_np = nreq

    def _mask_trie_fused(self) -> None:
        self._mask_trie()
        # the in-kernel prescreen reads the packed per-slot req rows:
        # re-gather them from the masked node table
        self._pk_req = jnp.asarray(self._tpack.pack_req(self._node_req_np))

    # ------------------------------------------------------------- masking
    def set_row_mask(self, active: Optional[np.ndarray]) -> None:
        """Install (or with ``None`` clear) a tombstone mask: rows where
        ``active`` is False get their prescreen requirement rows
        replaced by ``REQ_MASKED``, so the join never visits them - in
        the trie layout a subtree whose terminals are all masked is
        pruned at its highest all-masked ancestor - and their containment
        answers are always False.  Masking is prescreen-only: active
        rows keep bit-identical answers (the prescreen is sound, so
        removing candidates it would have kept cannot change survivors'
        join results).  Clears the row cache - cached rows predate the
        mask."""
        bank = self.bank
        self._cache.clear()
        if active is None:
            self._row_mask = None
            self._req = jnp.asarray(bank.req)
            self._req_np = bank.req
            self.layout.on_mask(self)
            return
        active = np.asarray(active, bool)
        assert active.shape == (bank.n_patterns,)
        self._row_mask = active
        req = bank.req[: bank.n_patterns].copy()
        req[~active] = REQ_MASKED
        if bank.n_rows > bank.n_patterns:  # padding rows stay masked
            pad = np.full(
                (bank.n_rows - bank.n_patterns, req.shape[1]),
                REQ_MASKED, np.int32,
            )
            req = np.concatenate([req, pad])
        self._req = jnp.asarray(req)
        self._req_np = req
        self.layout.on_mask(self)

    # ------------------------------------------------------------- device
    def exact_rows(self, seqs: Sequence[TRSeq]) -> np.ndarray:
        """Exact containment rows [len(seqs), n_patterns] computed
        directly on device (chunked by ``max_batch``), bypassing the
        fingerprint cache - the streaming layer's entry point (it
        maintains per-sequence window bitmaps, so every arrival must be
        answered fresh and row-aligned).  Counts toward ``queries`` like
        ``query`` does - routed/streamed traffic is traffic.  All chunks
        launch before any is fenced, so multi-chunk calls overlap their
        device batches."""
        self.stats["queries"] += len(seqs)
        out = np.zeros((len(seqs), self.bank.n_patterns), bool)
        with trace.root_or_span("serving.exact_rows", n=len(seqs)):
            launched = []
            for start in range(0, len(seqs), self.max_batch):
                chunk = list(seqs[start : start + self.max_batch])
                launched.append((start, self._launch(chunk)))
            for start, flight in launched:
                out[start : start + len(flight.seqs)] = \
                    self.finalize_rows(flight)
        return out

    def launch_rows(
        self, seqs: Sequence[TRSeq],
        shared: Optional[SharedEncoding] = None,
    ) -> InFlightRows:
        """Dispatch the containment joins for one chunk (``<=
        max_batch``) and return without blocking: the joins stay in
        flight on device until ``finalize_rows``.  The cluster router's
        entry point - it launches one batch per shard back-to-back and
        only fences at result finalize, so shards overlap instead of
        serializing.  Pass ``shared`` (``encode_queries``) to skip this
        shard's encode/index/prescreen dispatches entirely.  Counts the
        batch toward ``queries``."""
        self.stats["queries"] += len(seqs)
        return self._launch(list(seqs), shared)

    def _launch(
        self, seqs: List[TRSeq],
        shared: Optional[SharedEncoding] = None,
    ) -> InFlightRows:
        assert len(seqs) <= self.max_batch
        layout = self.bank_layout
        t0 = time.perf_counter()
        with trace.span("serving.batch", n=len(seqs), layout=layout):
            flight = self.layout.launch(self, seqs, shared)
        flight.t_launch = t0
        return flight

    def finalize_rows(self, flight: InFlightRows) -> np.ndarray:
        """Fence one in-flight batch: read the join outputs back,
        resolve undecided cells (escalation ladder + host oracle), and
        return the exact rows.  ``launch_rows`` + ``finalize_rows`` ==
        the old synchronous batch, bit for bit."""
        with trace.span("serving.finalize_rows", n=len(flight.seqs),
                        layout=flight.layout):
            get_layout(flight.layout).finalize(self, flight)
            self._resolve_undecided(
                flight.tokens, flight.order, flight.start,
                flight.count, flight.tmax, flight.contained,
                flight.ovf, flight.seqs,
            )
            if flight.t_launch:
                self._h_batch.observe(
                    time.perf_counter() - flight.t_launch)
            return flight.contained

    def _finalize_flat(self, flight: InFlightRows) -> None:
        for b_idx, p_global, c, o, n in flight.pending:
            flight.contained[b_idx, p_global] = np.array(c)[:n]
            flight.ovf[b_idx, p_global] = np.array(o)[:n]

    def _finalize_trie(self, flight: InFlightRows) -> None:
        for rows, sub, acc, ovf, n in flight.pending:
            acc_np = np.asarray(acc)[:n]
            ovf_np = np.asarray(ovf)[:n]
            live = sub >= 0
            idx = np.clip(sub, 0, None)
            flight.contained[:, rows] = np.where(
                live, acc_np[idx], False)
            flight.ovf[:, rows] = np.where(
                live, ovf_np[idx], False)

    def _finalize_trie_fused(self, flight: InFlightRows) -> None:
        # one deferred read per batch: acc/ovft are [n_cells, n_slots],
        # terminal t of bank row rows[t] reads slot[t] of its subtree's
        # cell (sub[b, t]; -1 = the subtree never walked for b, which
        # is exactly the per-level "never seeded" False/False)
        for rows, sub, slot, acc, ovft, n in flight.pending:
            acc_np = np.asarray(acc)[:n]
            ovf_np = np.asarray(ovft)[:n]
            live = sub >= 0
            idx = np.clip(sub, 0, None)
            flight.contained[:, rows] = np.where(
                live, acc_np[idx, slot[None, :]], False)
            flight.ovf[:, rows] = np.where(
                live, ovf_np[idx, slot[None, :]], False)

    def _run_batch(self, seqs: List[TRSeq]) -> np.ndarray:
        """Exact containment rows [len(seqs), n_patterns] for one chunk."""
        return self.finalize_rows(self._launch(seqs))

    def _encode_own(self, seqs: List[TRSeq]):
        """Per-shard encode + index for a launch without a shared
        encoding (single-host query path)."""
        bank = self.bank
        with trace.span("serving.encode", n=len(seqs)):
            tdb = encode_db(
                seqs,
                pad_to=_pow2(max(
                    1, max(sum(len(it) for it in s) for s in seqs)
                )),
                pad_seqs_to=_pow2(len(seqs)),
            )
            tokens = jnp.asarray(tdb.tokens)
            tmax = _pow2(max_key_bucket(tdb.tokens, bank.n_label_keys))
        return tokens, tmax

    def _launch_flat(
        self, seqs: List[TRSeq],
        shared: Optional[SharedEncoding] = None,
    ) -> InFlightRows:
        bank = self.bank
        if shared is None:
            tokens, tmax = self._encode_own(seqs)
            # one index build per batch, shared by every group join
            t0 = time.perf_counter()
            order, start, count, possible = index_and_prescreen(
                tokens, self._req, n_label_keys=bank.n_label_keys
            )
            _fence("serving.prescreen", t0,
                   (order, start, count, possible))
            possible = np.asarray(possible)[
                : len(seqs), : bank.n_patterns]
        else:
            assert shared.n_label_keys == bank.n_label_keys
            tokens, order, start, count, tmax = (
                shared.tokens, shared.order, shared.start,
                shared.count, shared.tmax,
            )
            # host compare against the shared counts: bit-identical to
            # the device prescreen (same int32 counts, same req rows)
            # and zero per-shard dispatches
            with trace.span("serving.prescreen_host",
                            n=len(seqs)):
                possible = (
                    shared.counts_np[: len(seqs), None, :]
                    >= self._req_np[None, : bank.n_patterns, :]
                ).all(-1)
        self.stats["device_batches"] += 1
        self.stats["pairs_possible"] += int(possible.sum())
        self.stats["pairs_prescreened"] += int(possible.size)
        contained = np.zeros((len(seqs), bank.n_patterns), bool)
        ovf_out = np.zeros_like(contained)
        pending = []
        for rows, steps_g in self._groups:
            b_idx, g_idx = np.nonzero(possible[:, rows])
            if not len(b_idx):
                continue
            if steps_g.shape[1] == 1:
                # single-TR patterns: the counts prescreen IS the exact
                # containment test (one matching-key token always embeds:
                # fresh vertices bind freely under an empty psi)
                contained[b_idx, rows[g_idx]] = True
                continue
            n = len(b_idx)
            self.stats["joined_steps"] += n * int(steps_g.shape[1])
            npad = _pow2(n)
            bi = np.zeros(npad, np.int32)
            pi = np.zeros(npad, np.int32)
            bi[:n], pi[:n] = b_idx, g_idx
            t0 = time.perf_counter()
            c, o = pair_contains_indexed(
                tokens, order, start, count, steps_g,
                jnp.asarray(bi), jnp.asarray(pi),
                nv=bank.nv, emax=self.emax, tmax=tmax,
                use_kernel=self.use_kernel, block_g=self.block_g,
                uniform_length=True,
            )
            _fence("serving.join", t0, (c, o),
                   steps=int(steps_g.shape[1]), cells=n)
            pending.append((b_idx, rows[g_idx], c, o, n))
        return InFlightRows(
            layout="flat", seqs=seqs, tokens=tokens, order=order,
            start=start, count=count, tmax=tmax, contained=contained,
            ovf=ovf_out, pending=pending,
        )

    def approx_rows(self, seqs: Sequence[TRSeq]) -> np.ndarray:
        """Prescreen-only approximate rows [len(seqs), n_patterns]: the
        sound necessary condition ``counts >= req`` evaluated entirely
        on host - zero device dispatches.  True containment is always a
        subset (``contained <= approx`` cellwise); masked rows answer
        False (their req is ``REQ_MASKED``).  The cluster's load-shed
        tier serves these, flagged ``exact=False``, when the admission
        queue is over its shed depth."""
        bank = self.bank
        with trace.span("serving.approx", n=len(seqs)):
            return prescreen_rows(
                seqs, self._req_np[: bank.n_patterns], bank.n_label_keys
            )

    def _resolve_undecided(self, tokens, order, start, count, tmax,
                           contained, ovf, seqs):
        """Resolve every ``ovf & ~contained`` cell in place - the only
        undecided ones (batch.py) - first through a wider device
        frontier (trie layout: re-seed only the failing subtrees and
        replay the level-synchronous scan at ``emax_retry``, keeping
        the shared-prefix savings on the retry path; flat layout:
        uniform-length replay per program-length group), then the
        per-cell host oracle.  Both layouts end exact: this is the
        whole exactness contract."""
        if self._row_mask is not None:
            # tombstoned rows answer False, never escalate.  The flat
            # prescreen already excludes them, but a masked *terminal*
            # on a shared trie node with active descendants is still
            # joined (the node mask prunes all-masked subtrees only)
            contained[:, ~self._row_mask] = False
            ovf[:, ~self._row_mask] = False
        bank = self.bank
        if (ovf & ~contained).any():
            # an always-keep signal for the tail sampler: escalated
            # queries are the interesting ones
            trace.mark("overflow_escalated")
            if self.emax_retry > self.emax:
                self.layout.escalate(self, tokens, order, start, count,
                                     tmax, contained, ovf)
        with trace.span("serving.oracle"):
            for b, p in zip(*np.nonzero(ovf & ~contained)):
                contained[b, p] = contains(bank.patterns[p], seqs[b])
                self.stats["host_fallback_cells"] += 1

    def _escalate_flat(self, tokens, order, start, count, tmax,
                       contained, ovf):
        """Widen undecided cells through a uniform-length replay of the
        full step program, one device batch per program-length group."""
        bank = self.bank
        und_b, und_p = np.nonzero(ovf & ~contained)
        und_g = self._row_group[und_p]
        for gi, (rows, steps_g) in enumerate(self._groups):
            sel = und_g == gi
            if not sel.any():
                continue
            ub, up = und_b[sel], und_p[sel]
            m = len(ub)
            mpad = _pow2(m)
            bi = np.zeros(mpad, np.int32)
            pi = np.zeros(mpad, np.int32)
            bi[:m], pi[:m] = ub, self._row_pos[up]
            t0 = time.perf_counter()
            c2, o2 = pair_contains_indexed(
                tokens, order, start, count, steps_g,
                jnp.asarray(bi), jnp.asarray(pi),
                nv=bank.nv, emax=self.emax_retry, tmax=tmax,
                use_kernel=self.use_kernel, block_g=self.block_g,
                uniform_length=True,
            )
            _fence("serving.escalate.join", t0, (c2, o2),
                        cells=m)
            contained[ub, up] = np.asarray(c2)[:m]
            ovf[ub, up] = np.asarray(o2)[:m]
            self.stats["escalated_cells"] += m
            self.stats["joined_steps"] += m * int(steps_g.shape[1])

    def _escalate_trie(self, tokens, order, start, count, tmax,
                       contained, ovf):
        """Trie-native escalation: re-run the level-synchronous scan at
        ``emax_retry`` over only the failing sub-trie - the union of
        the undecided rows' root-to-terminal paths - so undecided
        siblings pay for their shared prefix once on the retry path too
        (the flat replay re-joins every full program separately).  No
        prescreen here: every replayed cell already passed it on the
        first pass, and a pruned path cannot host an undecided
        terminal."""
        t, bank = self.trie, self.bank
        und_b, und_p = np.nonzero(ovf & ~contained)
        B0 = contained.shape[0]
        # cells to replay: union of the undecided rows' terminal paths
        need = np.zeros((B0, max(t.n_nodes, 1)), bool)
        for b, p in zip(und_b, und_p):
            n = int(t.terminal_node[p])
            while n >= 0:
                need[b, n] = True
                n = int(t.node_parent[n])
        und_rows = np.unique(und_p)
        term_depth = t.node_depth[t.terminal_node[und_rows]]  # 1-based
        und_mask = np.zeros_like(contained)
        und_mask[und_b, und_p] = True
        F = bank.steps.shape[2]
        prev = None
        pos_prev = None
        fetch = []
        for d, lv in enumerate(self._tlevels):
            b_idx, n_idx = np.nonzero(need[:, lv["nodes"]])
            if not len(b_idx):
                break  # paths end: nothing undecided deeper
            n_cells = len(b_idx)
            self.stats["joined_steps"] += n_cells
            npad = _pow2(n_cells)
            cells = np.zeros((npad, 2 + F), np.int32)
            cells[:n_cells, 0] = b_idx
            cells[:n_cells, 2:] = lv["steps"][n_idx]
            kw = dict(emax=self.emax_retry, tmax=tmax,
                      use_kernel=self.use_kernel, block_g=self.block_g,
                      compact=True)
            t0 = time.perf_counter()
            if d == 0:
                out = trie_root_advance(
                    tokens, order, start, count, jnp.asarray(cells),
                    ni=len(self._tlevels), nv=bank.nv, **kw,
                )
            else:
                par = pos_prev[b_idx, lv["parent_pos"][n_idx]]
                assert (par >= 0).all(), "escalation path parent missing"
                cells[:n_cells, 1] = par
                out = trie_level_advance_gather(
                    tokens, order, start, count, *prev,
                    jnp.asarray(cells), **kw,
                )
            _fence("serving.escalate.trie_level", t0, out,
                        level=d, cells=n_cells)
            phi, psi, valid, acc, ovf_state, ovf_term = out
            prev = (phi, psi, valid, ovf_state)
            cell_pos = np.full((B0, len(lv["nodes"])), -1, np.int64)
            cell_pos[b_idx, n_idx] = np.arange(n_cells)
            pos_prev = cell_pos
            rows_d = und_rows[term_depth == d + 1]
            if len(rows_d):
                sub = cell_pos[:, t.node_pos[t.terminal_node[rows_d]]]
                fetch.append((rows_d, sub, acc, ovf_term, n_cells))
        for rows, sub, acc, ovf_t, n in fetch:
            acc_np = np.asarray(acc)[:n]
            ovf_np = np.asarray(ovf_t)[:n]
            # touch only the cells that were actually undecided: their
            # neighbours in these rows are already exact
            live = (sub >= 0) & und_mask[:, rows]
            idx = np.clip(sub, 0, None)
            contained[:, rows] = np.where(
                live, acc_np[idx], contained[:, rows])
            ovf[:, rows] = np.where(live, ovf_np[idx], ovf[:, rows])
            self.stats["escalated_cells"] += int(live.sum())

    def _launch_trie(
        self, seqs: List[TRSeq],
        shared: Optional[SharedEncoding] = None,
    ) -> InFlightRows:
        """Trie-layout launch: one frontier per (sequence, trie node),
        one device dispatch per trie level; a level's frontiers are
        seeded by gathering its parents' compacted frontiers from the
        previous level's cell array.  The residual-``req`` prescreen
        compacts each level to its surviving cells (a pruned node's
        subtree never seeds).  The level loop chains device frontiers
        without any host read (terminal accept bits are deferred to
        ``finalize_rows``), so the whole walk dispatches without
        blocking.  Same exactness contract as the flat path:
        overflow-undecided terminals escalate through a wider replay,
        then the host oracle."""
        bank = self.bank
        B0 = len(seqs)
        contained = np.zeros((B0, bank.n_patterns), bool)
        ovf_out = np.zeros((B0, bank.n_patterns), bool)

        def flight(tokens=None, order=None, start=None, count=None,
                   tmax=1, fetch=()):
            return InFlightRows(
                layout="trie", seqs=seqs, tokens=tokens, order=order,
                start=start, count=count, tmax=tmax,
                contained=contained, ovf=ovf_out, pending=list(fetch),
            )

        if not self._tlevels or not bank.n_patterns:
            return flight()
        if shared is None:
            tokens, tmax = self._encode_own(seqs)
            t0 = time.perf_counter()
            order, start, count, possible = index_and_node_prescreen(
                tokens, self._node_req, n_label_keys=bank.n_label_keys
            )
            _fence("serving.prescreen", t0,
                   (order, start, count, possible))
            poss = np.asarray(possible)[:B0]
        else:
            assert shared.n_label_keys == bank.n_label_keys
            tokens, order, start, count, tmax = (
                shared.tokens, shared.order, shared.start,
                shared.count, shared.tmax,
            )
            with trace.span("serving.prescreen_host", n=len(seqs)):
                poss = (
                    shared.counts_np[:B0, None, :]
                    >= self._node_req_np[None, :, :]
                ).all(-1)
        self.stats["device_batches"] += 1
        # node cells, not pattern pairs: a pattern spans several nodes,
        # so these are NOT comparable to the flat layout's pairs_* keys
        self.stats["cells_possible"] += int(poss.sum())
        self.stats["cells_prescreened"] += int(poss.size)
        D = len(self._tlevels)
        prev = None      # device frontiers of the previous level's cells
        pos_prev = None  # [B0, m_{d-1}] internal-cell index, -1 = none
        fetch = []       # deferred device->host reads (one sync at end)

        F = bank.steps.shape[2]

        def _cells(b_idx, n_idx, lv, d, compact):
            """Advance the given (sequence, node) cells one step.  One
            packed [N, 2+F] upload carries cell_b / parent idx / step
            rows."""
            n = len(b_idx)
            npad = _pow2(n)
            cells = np.zeros((npad, 2 + F), np.int32)
            cells[:n, 0] = b_idx
            cells[:n, 2:] = lv["steps"][n_idx]
            kw = dict(emax=self.emax, tmax=tmax,
                      use_kernel=self.use_kernel, block_g=self.block_g,
                      compact=compact)
            t0 = time.perf_counter()
            if d == 0:
                out = trie_root_advance(
                    tokens, order, start, count, jnp.asarray(cells),
                    ni=D, nv=bank.nv, **kw,
                )
            else:
                par = pos_prev[b_idx, lv["parent_pos"][n_idx]]
                assert (par >= 0).all(), "parent cell pruned below child"
                cells[:n, 1] = par
                out = trie_level_advance_gather(
                    tokens, order, start, count, *prev,
                    jnp.asarray(cells), **kw,
                )
            _fence("serving.trie_advance", t0, out,
                        level=d, cells=n)
            return out

        for d, lv in enumerate(self._tlevels):
            act = poss[:, lv["nodes"]]
            b_idx, n_idx = np.nonzero(act)
            if not len(b_idx):
                break  # prescreen is monotone: no deeper cell survives
            with trace.span("serving.trie_level", level=d,
                            cells=len(b_idx)):
                is_leaf = lv["leaf"][n_idx]
                lb, ln = b_idx[is_leaf], n_idx[is_leaf]
                ib, inn = b_idx[~is_leaf], n_idx[~is_leaf]
                # ---- leaf cells: compaction-free accept test.  Depth-1
                # leaves skip the join entirely: the node prescreen IS
                # the exact containment test for single-TR patterns (a
                # matching-key token always embeds under an empty psi).
                if len(lb):  # every leaf is some pattern's terminal
                    cell_leaf = np.full(
                        (B0, len(lv["nodes"])), -1, np.int64)
                    cell_leaf[lb, ln] = np.arange(len(lb))
                    sub = cell_leaf[:, lv["term_pos_leaf"]]
                    if d == 0:
                        contained[:, lv["term_rows_leaf"]] = sub >= 0
                    else:
                        self.stats["joined_steps"] += len(lb)
                        acc, ovf = _cells(lb, ln, lv, d, compact=False)
                        fetch.append((lv["term_rows_leaf"], sub, acc,
                                      ovf, len(lb)))
                # ---- internal cells: compacted frontiers seed children
                n_int = len(ib)
                if n_int:
                    self.stats["joined_steps"] += n_int
                    phi, psi, valid, acc, ovf_state, ovf_term = _cells(
                        ib, inn, lv, d, compact=True
                    )
                    # children inherit the full path overflow; a
                    # terminal ending at this node is undecided only via
                    # ovf_term (its accept bit is exact regardless of
                    # what this step's compaction dropped)
                    prev = (phi, psi, valid, ovf_state)
                    cell_int = np.full(
                        (B0, len(lv["nodes"])), -1, np.int64)
                    cell_int[ib, inn] = np.arange(n_int)
                    pos_prev = cell_int
                    if len(lv["term_rows_int"]):
                        sub = cell_int[:, lv["term_pos_int"]]
                        fetch.append((lv["term_rows_int"], sub, acc,
                                      ovf_term, n_int))
                else:
                    break  # no internal frontier: nothing seeds deeper
        return flight(tokens=tokens, order=order, start=start,
                      count=count, tmax=tmax, fetch=fetch)

    def _launch_trie_fused(
        self, seqs: List[TRSeq],
        shared: Optional[SharedEncoding] = None,
    ) -> InFlightRows:
        """Fused-layout launch: the whole trie walk in ONE device
        dispatch per query batch, independent of trie depth
        (kernels.trie_walk).  A cell is a (sequence, depth-1 subtree)
        pair; the kernel iterates the subtree's levels over in-kernel
        frontier buffers and applies the per-node residual-``req``
        prescreen in kernel, so only the subtree *roots* are prescreened
        host-side to pick the surviving cells.  Singleton depth-1
        subtrees are answered by the root prescreen alone (their
        terminals are single-TR patterns, for which the prescreen is
        the exact containment test - same shortcut as the per-level
        path's depth-1 leaves).  Outputs, overflow semantics and the
        escalation ladder are bit-identical to the per-level trie
        layout (the differential harness in tests/test_trie_fused.py
        pins all three layouts to the host oracle)."""
        bank = self.bank
        B0 = len(seqs)
        pack = self._tpack
        contained = np.zeros((B0, bank.n_patterns), bool)
        ovf_out = np.zeros((B0, bank.n_patterns), bool)

        def flight(tokens=None, order=None, start=None, count=None,
                   tmax=1, fetch=()):
            return InFlightRows(
                layout="trie_fused", seqs=seqs, tokens=tokens,
                order=order, start=start, count=count, tmax=tmax,
                contained=contained, ovf=ovf_out, pending=list(fetch),
            )

        if not self._tlevels or not bank.n_patterns:
            return flight()
        if shared is None:
            tokens, tmax = self._encode_own(seqs)
            t0 = time.perf_counter()
            order, start, count, possible = index_and_node_prescreen(
                tokens, self._node_req, n_label_keys=bank.n_label_keys
            )
            _fence("serving.prescreen", t0,
                   (order, start, count, possible))
            poss = np.asarray(possible)[:B0]
        else:
            assert shared.n_label_keys == bank.n_label_keys
            tokens, order, start, count, tmax = (
                shared.tokens, shared.order, shared.start,
                shared.count, shared.tmax,
            )
            with trace.span("serving.prescreen_host", n=len(seqs)):
                poss = (
                    shared.counts_np[:B0, None, :]
                    >= self._node_req_np[None, :, :]
                ).all(-1)
        self.stats["device_batches"] += 1
        # fused cells are walk *entry points* (subtree shards +
        # singleton leaves), not per-node cells: the per-node prescreen
        # runs in kernel, so only entries are prescreened host-side.  A
        # shard cell is launched only if SOME exclusive terminal of the
        # shard passes its own node prescreen - every kernel output is
        # ANDed with the terminal's ``poss`` anyway, so cells with all
        # terminals prescreen-dead contribute all-False accept/ovf bits
        # and skipping them is bit-exact (and much sharper than gating
        # at the shard root, whose ``node_req`` is the subtree min)
        leaf_poss = poss[:, pack.leaf_roots]
        shard_poss = np.zeros((B0, pack.n_subtrees), bool)
        if len(pack.term_nodes):
            np.logical_or.at(shard_poss.T, pack.term_sub,
                             poss[:, pack.term_nodes].T)
        self.stats["cells_possible"] += \
            int(shard_poss.sum()) + int(leaf_poss.sum())
        self.stats["cells_prescreened"] += \
            int(shard_poss.size) + int(leaf_poss.size)
        if len(pack.leaf_rows):
            contained[:, pack.leaf_rows] = leaf_poss
        b_idx, s_idx = np.nonzero(shard_poss)
        n = len(b_idx)
        if not n:
            return flight(tokens=tokens, order=order, start=start,
                          count=count, tmax=tmax)
        # every surviving cell walks its full padded shard in kernel
        self.stats["joined_steps"] += n * pack.n_slots
        npad = _bucket34(n)
        cells = np.zeros((npad, 2), np.int32)
        cells[:n, 0] = b_idx
        cells[:n, 1] = s_idx
        t0 = time.perf_counter()
        acc, ovft = fused_trie_walk(
            tokens, order, start, count, jnp.asarray(cells),
            self._pk_steps, self._pk_parent, self._pk_req,
            ni=len(self._tlevels), nv=bank.nv, emax=self.emax,
            tmax=tmax, use_kernel=self.use_kernel,
        )
        _fence("serving.fused_walk", t0, (acc, ovft), cells=n)
        cell_of = np.full((B0, pack.n_subtrees), -1, np.int64)
        cell_of[b_idx, s_idx] = np.arange(n)
        sub = cell_of[:, pack.term_sub]
        return flight(
            tokens=tokens, order=order, start=start, count=count,
            tmax=tmax,
            fetch=[(pack.term_rows, sub, pack.term_slot, acc, ovft, n)],
        )

    # ------------------------------------------------------------ scoring
    def _score(self, contained: np.ndarray, k: int) -> List[Tuple[int, int]]:
        # bank rows are ordered by (-support, canonical code), so the
        # first k contained ids are already the support-weighted top-k
        ids = np.nonzero(contained)[0][:k]
        sup = self.bank.support
        return [(int(i), int(sup[i])) for i in ids]

    # ------------------------------------------------------------- public
    def join(self, req) -> "JoinResult":
        """The unified entry point (serving.join): exact requests run
        the cached batch pipeline, ``exact=False`` requests serve the
        prescreen-only approximate tier - sound overapproximation,
        flagged ``exact=False`` per result, never cached."""
        from .join import JoinResult, join_span
        k = self.topk if req.k is None else req.k
        seqs = list(req.seqs)
        with join_span(req, "server"):
            if req.exact:
                return JoinResult(self._query_exact(seqs, k))
            self.stats["queries"] += len(seqs)
            trace.mark("inexact")
            approx = self.approx_rows(seqs)
            return JoinResult([
                QueryResult(
                    fingerprint=sequence_fingerprint(s),
                    contained=approx[i], topk=self._score(approx[i], k),
                    cached=False, exact=False,
                )
                for i, s in enumerate(seqs)
            ])

    def query(
        self, seqs: Sequence[TRSeq], k: Optional[int] = None
    ) -> List[QueryResult]:
        from .join import JoinRequest
        return self.join(JoinRequest(seqs=tuple(seqs), k=k)).results

    def _query_exact(
        self, seqs: Sequence[TRSeq], k: int
    ) -> List[QueryResult]:
        self.stats["queries"] += len(seqs)
        t_q0 = time.perf_counter()
        try:
            return self._query_exact_inner(seqs, k)
        finally:
            self._h_query.observe(time.perf_counter() - t_q0)

    def _query_exact_inner(
        self, seqs: Sequence[TRSeq], k: int
    ) -> List[QueryResult]:
        with trace.root_or_span("serving.query", n=len(seqs)):
            rows: Dict[str, np.ndarray] = {}
            cached: Dict[str, bool] = {}
            miss_fps: List[str] = []
            miss_seqs: List[TRSeq] = []
            with trace.span("serving.cache", cat="cache"):
                fps = [sequence_fingerprint(s) for s in seqs]
                for fp, s in zip(fps, seqs):
                    if fp in rows:
                        continue
                    if fp in self._cache:
                        self._cache.move_to_end(fp)
                        rows[fp] = self._cache[fp]
                        cached[fp] = True
                        self.stats["cache_hits"] += 1
                    else:
                        # placeholder, preserves first-seen order
                        rows[fp] = None
                        cached[fp] = False
                        miss_fps.append(fp)
                        miss_seqs.append(s)
            for start in range(0, len(miss_seqs), self.max_batch):
                chunk = miss_seqs[start : start + self.max_batch]
                got = self._run_batch(chunk)
                for i, fp in enumerate(
                        miss_fps[start : start + len(chunk)]):
                    rows[fp] = got[i]
                    self._cache[fp] = got[i]
                    while len(self._cache) > self.cache_size:
                        self._cache.popitem(last=False)
            with trace.span("serving.finalize"):
                return [
                    QueryResult(
                        fingerprint=fp, contained=rows[fp],
                        topk=self._score(rows[fp], k), cached=cached[fp],
                    )
                    for fp in fps
                ]

    def query_one(self, seq: TRSeq, k: Optional[int] = None) -> QueryResult:
        return self.query([seq], k)[0]


# --------------------------------------------------- layout registration
# The built-in layouts register here, at the bottom so the hooks can
# reference PatternServer's (unbound) methods; new layouts register the
# same way instead of growing if/else chains through server / router /
# cluster / streaming (see layouts.py).

def _place_flat(bank, n_hosts, trie=None):
    """Contiguous pattern-range placement."""
    return [
        np.asarray(r, np.int64)
        for r in np.array_split(
            np.arange(bank.n_patterns, dtype=np.int64), n_hosts
        )
    ]


def _place_trie(bank, n_hosts, trie=None):
    """Depth-1-subtree placement: subtrees stay intact per host, so
    every shard keeps its prefix sharing (and the fused layout its
    one-dispatch-per-shard walk)."""
    if trie is None:
        trie = build_trie(bank)
    return [np.asarray(r, np.int64) for r in trie.shard_rows(n_hosts)]


register_layout(Layout(
    name="flat", uses_trie=False,
    prepare=PatternServer._prepare_flat,
    launch=PatternServer._launch_flat,
    finalize=PatternServer._finalize_flat,
    escalate=PatternServer._escalate_flat,
    on_mask=PatternServer._mask_flat,
    place=_place_flat,
))
register_layout(Layout(
    name="trie", uses_trie=True,
    prepare=PatternServer._prepare_trie,
    launch=PatternServer._launch_trie,
    finalize=PatternServer._finalize_trie,
    escalate=PatternServer._escalate_trie,
    on_mask=PatternServer._mask_trie,
    place=_place_trie,
))
register_layout(Layout(
    name="trie_fused", uses_trie=True,
    prepare=PatternServer._prepare_trie_fused,
    launch=PatternServer._launch_trie_fused,
    finalize=PatternServer._finalize_trie_fused,
    # escalation replays the failing sub-trie level-synchronously: the
    # fused layout builds the same per-level tables, so the retry path
    # (and hence the whole exactness ladder) is shared verbatim
    escalate=PatternServer._escalate_trie,
    on_mask=PatternServer._mask_trie_fused,
    place=_place_trie,
))
