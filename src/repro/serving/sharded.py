"""Shard-by-pattern serving over a device mesh.

Mirrors mining/distributed.py's layout: query sequences shard over the
"data" axis, the pattern bank (step programs + metadata rows) shards
over the "model" axis.  Containment cells are embarrassingly parallel -
cell (b, p) touches only sequence b and pattern p - so the step needs
*zero* collectives: each device computes its [B_loc, P_loc] block and
the output is the [B, P] matrix sharded over both axes (gather it, or
feed it sharded into downstream scoring).

Bank rows must divide the pattern axis; compile the bank with
``pad_patterns_to`` a multiple of the mesh's model-axis size (padding
rows report no containment).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map_compat
from .batch import batch_contains_ref


def make_serving_step(
    mesh: Mesh,
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    db_axis: str = "data",
    pat_axis: str = "model",
    use_kernel: bool = False,
    block_g: int = 64,
):
    """Build the jitted, shard-mapped containment step.

    Returns ``step(tokens [B,T,6], steps [P,L,F], pattern_valid [P]) ->
    (contained [B,P] bool, overflow [B,P] bool)`` with B sharded over
    ``db_axis`` and P over ``pat_axis``.
    """

    def local_step(tokens, steps, pattern_valid):
        return batch_contains_ref(
            tokens, steps, pattern_valid,
            nv=nv, n_label_keys=n_label_keys, emax=emax, tmax=tmax,
            use_kernel=use_kernel, block_g=block_g,
        )

    specs_in = (
        P(db_axis, None, None),   # tokens
        P(pat_axis, None, None),  # steps
        P(pat_axis),              # pattern_valid
    )
    specs_out = (P(db_axis, pat_axis), P(db_axis, pat_axis))
    step = shard_map_compat(local_step, mesh, specs_in, specs_out)
    return jax.jit(step)
