"""Shard-by-pattern / shard-by-subtree serving over a device mesh.

Mirrors mining/distributed.py's layout: query sequences shard over the
"data" axis, the pattern bank (step programs + metadata rows) shards
over the "model" axis.  Containment cells are embarrassingly parallel -
cell (b, p) touches only sequence b and pattern p - so the step needs
*zero* collectives: each device computes its [B_loc, P_loc] block and
the output is the [B, P] matrix sharded over both axes (gather it, or
feed it sharded into downstream scoring).

Flat banks shard by pattern row (``make_serving_step``): rows must
divide the pattern axis; compile with ``pad_patterns_to`` a multiple of
the mesh's model-axis size (padding rows report no containment).

Trie banks shard by *subtree* (``make_trie_serving_step``): splitting a
trie by pattern row would tear shared prefixes apart and re-replicate
their work, so ``TrieBank.shard`` partitions the root's depth-1
subtrees across shards (greedy node-count balancing) and every shard
joins its own intact sub-trie.  ``stack_trie_shards`` pads the shard
tries to a common (depth, level width, pattern rows) and concatenates
them along the node/pattern axes; the step's output columns follow the
concatenated shard pattern order (``patterns`` in the stack), not the
original bank order.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

import jax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map_compat
from .batch import batch_contains_ref, trie_contains_ref
from .trie import TrieBank


def make_serving_step(
    mesh: Mesh,
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    db_axis: str = "data",
    pat_axis: str = "model",
    use_kernel: bool = False,
    block_g: int = 64,
):
    """Build the jitted, shard-mapped containment step.

    Returns ``step(tokens [B,T,6], steps [P,L,F], pattern_valid [P]) ->
    (contained [B,P] bool, overflow [B,P] bool)`` with B sharded over
    ``db_axis`` and P over ``pat_axis``.
    """

    def local_step(tokens, steps, pattern_valid):
        return batch_contains_ref(
            tokens, steps, pattern_valid,
            nv=nv, n_label_keys=n_label_keys, emax=emax, tmax=tmax,
            use_kernel=use_kernel, block_g=block_g,
        )

    specs_in = (
        P(db_axis, None, None),   # tokens
        P(pat_axis, None, None),  # steps
        P(pat_axis),              # pattern_valid
    )
    specs_out = (P(db_axis, pat_axis), P(db_axis, pat_axis))
    step = shard_map_compat(local_step, mesh, specs_in, specs_out)
    return jax.jit(step)


def stack_trie_shards(shards: List[TrieBank]) -> Dict[str, object]:
    """Pad shard tries to common shapes and concatenate for the mesh.

    Returns arrays keyed ``lvl_steps`` [D, S*Mh, F], ``lvl_parent_pos``
    [D, S*Mh], ``term_level``/``term_pos``/``pattern_valid`` [S*Pl]
    (term positions stay shard-local - exactly what each device's local
    [D, Mh] block indexes), plus ``patterns`` (the concatenated pattern
    list, output-column order) and ``rows_per_shard`` = Pl."""
    S = len(shards)
    D = max(max(t.depth, 1) for t in shards)
    Mh = max(
        max((len(lv) for lv in t.levels), default=1) for t in shards
    )
    Pl = max(t.bank.n_rows for t in shards)
    steps, parent_pos = [], []
    term_level, term_pos, pvalid = [], [], []
    patterns = []
    for t in shards:
        lv = t.padded_levels(depth=D, width=Mh)
        steps.append(lv.steps)
        parent_pos.append(lv.parent_pos)
        pad = Pl - t.bank.n_rows
        term_level.append(np.pad(lv.term_level, (0, pad)))
        term_pos.append(np.pad(lv.term_pos, (0, pad)))
        pvalid.append(np.pad(t.bank.pattern_valid, (0, pad)))
        patterns.append(t.bank.patterns)
    return {
        "lvl_steps": np.concatenate(steps, axis=1),
        "lvl_parent_pos": np.concatenate(parent_pos, axis=1),
        "term_level": np.concatenate(term_level),
        "term_pos": np.concatenate(term_pos),
        "pattern_valid": np.concatenate(pvalid),
        "patterns": patterns,
        "rows_per_shard": Pl,
        "n_shards": S,
    }


def make_trie_serving_step(
    mesh: Mesh,
    *,
    nv: int,
    n_label_keys: int,
    emax: int = 8,
    tmax: int = 16,
    db_axis: str = "data",
    pat_axis: str = "model",
    use_kernel: bool = False,
    block_g: int = 64,
):
    """The trie counterpart of ``make_serving_step``: each device joins
    one intact sub-trie (see ``stack_trie_shards``) against its local
    sequence block - still zero collectives.

    Returns ``step(tokens [B,T,6], lvl_steps [D,S*Mh,F],
    lvl_parent_pos [D,S*Mh], term_level [P], term_pos [P],
    pattern_valid [P]) -> (contained [B,P] bool, overflow [B,P] bool)``
    with B sharded over ``db_axis`` and the node/pattern axes over
    ``pat_axis``."""

    def local_step(tokens, lvl_steps, lvl_parent_pos, term_level,
                   term_pos, pattern_valid):
        return trie_contains_ref(
            tokens, lvl_steps, lvl_parent_pos, term_level, term_pos,
            pattern_valid,
            nv=nv, n_label_keys=n_label_keys, emax=emax, tmax=tmax,
            use_kernel=use_kernel, block_g=block_g,
        )

    specs_in = (
        P(db_axis, None, None),    # tokens
        P(None, pat_axis, None),   # lvl_steps (nodes shard)
        P(None, pat_axis),         # lvl_parent_pos
        P(pat_axis),               # term_level
        P(pat_axis),               # term_pos
        P(pat_axis),               # pattern_valid
    )
    specs_out = (P(db_axis, pat_axis), P(db_axis, pat_axis))
    step = shard_map_compat(local_step, mesh, specs_in, specs_out)
    return jax.jit(step)
