"""StreamingBank: incremental support maintenance over a sliding window.

The batch system mines a bank once and serves it; production traffic is
a *stream* - sequences arrive continuously and old ones age out of
relevance.  ``StreamingBank`` wraps a compiled ``PatternBank`` (flat or
trie layout) and keeps per-pattern supports exact under a sliding
window of the ``window`` most recent sequences, without re-mining per
update:

* ``observe(batch)`` answers each arrival with the existing device-side
  containment join (``PatternServer.exact_rows`` - prescreen, flat or
  trie-layout join, escalation, host-oracle fallback: the served bits
  are exact) and *increments* supports by the resulting row.  The row is
  also stored in a window ring buffer of per-sequence containment
  bitmaps, so when the sequence later expires its support contribution
  is *decremented* from the stored bits - eviction never re-joins
  anything.
* Patterns whose support falls below ``minsup`` are **tombstoned**: the
  server's prescreen requirement rows are masked (``REQ_MASKED``), so
  the join stops visiting them - in the trie layout a subtree whose
  terminals are all tombstoned is pruned at its highest dead ancestor.
  A tombstoned pattern's maintained support becomes a stale lower bound
  (arrivals no longer count it); it stays in the bank as a tombstone
  until a refresh recounts or a full refresh compacts it away.
* ``refresh()`` reconciles the bank with the window *incrementally*
  (``mining.incremental.refresh_frontier``): the reverse-search walk
  from the root prunes every *clean* subtree - one no arrival touched
  since the last reconcile, per the arrival containment bitmaps
  (expiries only shrink supports, which maintenance already accounts
  for, so they dirty nothing) - and re-scans only the dirty boundary,
  discovering newly frequent patterns and recovering tombstoned ones.  New patterns are appended to the bank
  (``extend_bank``) and LCP-merged into the trie (``extend_trie``)
  without recompiling existing rows; recovered/new rows get their
  window bitmaps recounted by a device join over just those rows.
  After ``refresh()`` the active frequent map is *bit-equal* to a batch
  re-mine of the window (property-tested, both layouts).
* ``refresh(full=True)`` is the exactness escape hatch and compaction
  step: re-mine the window from scratch, recompile bank + trie, recount
  all bitmaps.  It is also the automatic fallback when an incremental
  extension cannot fit the compiled capacity (``BankCapacityError``:
  e.g. a new pattern uses a label the bank's key space never saw).

With ``tombstones=False`` nothing is ever masked, so maintained
supports stay exact for *every* bank pattern continuously (not just at
refresh points) - the differential-testing mode.

Dirtiness is tracked per ring *slot*, not per pattern: a ``fresh`` flag
marks slots written since the last reconcile, and the dirty set handed
to ``refresh_frontier`` is "patterns contained in a fresh arrival still
in the window" (the stored bitmaps of the fresh slots).  Overwriting a
slot drops its dirt, so an arrival that transits the window entirely
between two reconciles dirties nothing - under heavy churn the frontier
walk prunes subtrees an accumulated per-pattern dirty scheme would have
rescanned (see mining.incremental's module docstring).

Two production follow-ons ride on top:

* ``compact_threshold`` - automatic tombstone compaction: when the
  tombstoned-row fraction crosses the threshold, the next observe or
  refresh escalates itself to ``refresh(full=True)`` (which re-mines and
  compacts the dead rows away); ``stats["auto_compactions"]`` counts the
  triggers.
* ``delta_sink`` - the single-writer/read-replica hook (see
  serving.cluster): when set, every state change a replica must mirror
  is emitted as a delta tuple - ``("support", seq, support)`` after
  each observe, ``("mask", seq, active, support)`` when tombstones
  change, ``("extend", seq, new_patterns, active, support)`` after an
  incremental reconcile, ``("recompile", seq, mined, support)`` after
  a full refresh - so replicas apply ``extend_bank``/``extend_trie``
  instead of recompiling, and keep serving the previous masked bank
  until the delta lands.  ``seq`` is a monotone sequence id (see
  ``delta_seq``): replicas track their last applied seq, skip
  duplicates idempotently, and a restarted replica replays the
  writer's ``RecoveryLog`` (serving.faults) from that point.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.graphseq import Pattern, TRSeq
from ..mining.driver import AcceleratedMiner
from ..mining.incremental import depth1_root, refresh_frontier
from ..obs import trace
from ..obs.metrics import MetricsRegistry
from .bank import BankCapacityError, PatternBank, compile_bank, \
    extend_bank
from .layouts import get_layout
from .server import PatternServer, QueryResult, score_topk
from .trie import TrieBank, build_trie, extend_trie


@dataclasses.dataclass
class ObserveResult:
    arrived: int
    evicted: int
    tombstoned: int  # patterns newly masked by this batch
    refreshed: bool  # True when refresh_every triggered a refresh


class StreamingBank:
    def __init__(
        self,
        bank: PatternBank,
        *,
        window: int,
        minsup: int,
        bank_layout: str = "flat",
        trie: Optional[TrieBank] = None,
        max_len: Optional[int] = None,
        tombstones: bool = True,
        refresh_every: int = 0,
        compact_threshold: Optional[float] = None,
        miner_kw: Optional[dict] = None,
        **server_kw,
    ):
        assert window > 0 and minsup > 0
        assert compact_threshold is None or 0 < compact_threshold <= 1
        # an empty compile_bank({}) legitimately carries one padding row
        assert bank.n_rows == max(bank.n_patterns, 1), \
            "streaming requires an unpadded bank"
        self.window = window
        self.minsup = minsup
        self.max_len = max_len
        self.bank_layout = bank_layout
        self.tombstones = tombstones
        self.refresh_every = refresh_every
        self.compact_threshold = compact_threshold
        self.miner_kw = dict(miner_kw or {})
        self.server_kw = dict(server_kw)
        self.bank = bank
        self.trie = trie
        P = bank.n_patterns
        self.support = np.zeros(P, np.int64)
        self.active = np.ones(P, bool)
        self._bits = np.zeros((window, P), bool)
        self._seqs: List[Optional[TRSeq]] = [None] * window
        self._head = 0   # next ring slot to write (oldest when full)
        self._count = 0
        # per-slot dirtiness: True = written since the last reconcile.
        # The slot's stored bitmap IS its dirt, so eviction self-cleans
        self._fresh = np.zeros(window, bool)
        self._any_change = False
        self._batches_since_refresh = 0
        # read-replica hook: every delta a replica must mirror is
        # pushed here (see the module docstring for the tuple kinds).
        # Deltas carry monotone sequence ids - ``(kind, seq, *payload)``
        # with ``seq == 1, 2, ...`` - so a restarted replica can replay
        # the writer's RecoveryLog from its last applied seq
        # (serving.faults) and skip duplicates idempotently.  The
        # counter advances whether or not a sink is attached: a seq is
        # a property of the stream, not of who is listening
        self.delta_sink: Optional[Callable[[Tuple], None]] = None
        self._delta_seq = 0
        # the registry outlives every server/miner rebuild: a
        # refresh(full=True) recompile re-attaches to the same counters
        # instead of zeroing them (reset is registry.reset(), only)
        self.metrics = MetricsRegistry()
        self.stats = self.metrics.view("streaming.bank", keys=[
            "arrivals", "evictions", "observe_batches",
            "tombstoned", "recovered", "added",
            "refreshes", "full_refreshes", "auto_compactions",
            "frontier_scans", "frontier_scans_skipped",
            "frontier_retained",
            "dirty_subtrees", "clean_subtrees",
        ])
        # always-on latency percentiles: wall per observe() batch and
        # per refresh() reconcile (log-bucket histograms)
        self._h_observe = self.metrics.bucket_histogram(
            "streaming.bank.observe_seconds")
        self._h_refresh = self.metrics.bucket_histogram(
            "streaming.bank.refresh_seconds")
        self.server = self._make_server()

    # ------------------------------------------------------------ wiring
    def _make_server(self) -> PatternServer:
        if get_layout(self.bank_layout).uses_trie and self.trie is None:
            self.trie = build_trie(self.bank)
        return PatternServer(
            self.bank, bank_layout=self.bank_layout, trie=self.trie,
            metrics=self.metrics, **self.server_kw,
        )

    def _apply_mask(self) -> None:
        if not self.tombstones:
            return
        mask = None if self.active.all() else self.active
        self.server.set_row_mask(mask)

    @classmethod
    def from_db(
        cls,
        db: Sequence[TRSeq],
        *,
        minsup: int,
        window: Optional[int] = None,
        max_len: Optional[int] = None,
        miner_kw: Optional[dict] = None,
        **kw,
    ) -> "StreamingBank":
        """Mine ``db`` into a bank and seed the window with it (at most
        the last ``window`` sequences are retained).  The seed observe
        runs unmasked, so it leaves the bank fully reconciled: active ==
        the exact frequent set over the seeded window."""
        miner = AcceleratedMiner(db, **(miner_kw or {}))
        result = miner.mine_rs(minsup, max_len=max_len)
        bank = compile_bank(result)
        sb = cls(bank, window=window or max(len(db), 1), minsup=minsup,
                 max_len=max_len, miner_kw=miner_kw, **kw)
        sb.observe(db)
        # a single unmasked observe counts every bank pattern exactly
        # over the final window, so the tombstone cut it applied *is*
        # the exact frequent set: reconciled without a refresh
        sb._fresh[:] = False
        sb._any_change = False
        sb._batches_since_refresh = 0
        return sb

    # ----------------------------------------------------------- streams
    @property
    def n_patterns(self) -> int:
        return self.bank.n_patterns

    @property
    def window_seqs(self) -> List[TRSeq]:
        """Current window contents, oldest first."""
        if self._count < self.window:
            return [s for s in self._seqs[: self._count]]
        return (self._seqs[self._head:] + self._seqs[: self._head])

    def frequent(self) -> Dict[Pattern, int]:
        """The active frequent patterns with their window supports.
        Right after ``refresh()`` this is bit-equal to a batch re-mine
        of the window; between refreshes tombstoned-then-recovering
        patterns wait for the next refresh to reappear."""
        out = {}
        for i in np.nonzero(self.active & (self.support >= self.minsup))[0]:
            out[self.bank.patterns[i]] = int(self.support[i])
        return out

    def observe(self, batch: Sequence[TRSeq]) -> ObserveResult:
        """Slide ``batch`` into the window: device-join each arrival
        against the active bank (one containment row per sequence),
        increment supports, store the row in the ring, and decrement
        the expiring sequences' stored rows - no re-join on eviction.
        Tombstones are re-evaluated once per call, so the mask is fixed
        while the batch joins."""
        batch = list(batch)
        if not batch:
            return ObserveResult(0, 0, 0, False)
        t0 = time.perf_counter()
        try:
            return self._observe_inner(batch)
        finally:
            self._h_observe.observe(time.perf_counter() - t0)

    def _observe_inner(self, batch: List[TRSeq]) -> ObserveResult:
        with trace.root_or_span("streaming.observe", n=len(batch)):
            rows = self.server.exact_rows(batch)
            evicted = 0
            with trace.span("streaming.ring"):
                for seq, row in zip(batch, rows):
                    if self._count == self.window:
                        old = self._bits[self._head]
                        self.support -= old
                        # evictions do NOT set dirty bits: supports
                        # only decrease below an evicted-from pattern,
                        # so no new frequent descendant can appear and
                        # active descendants' supports stay
                        # maintained-exact - only arrivals can create
                        # re-scan work (incremental.py)
                        evicted += 1
                    self._seqs[self._head] = seq
                    self._bits[self._head] = row
                    self.support += row
                    # slot-granular dirt: the stored row is the dirt
                    # record, fresh marks it as arrived-since-reconcile
                    self._fresh[self._head] = True
                    self._head = (self._head + 1) % self.window
                    self._count = min(self._count + 1, self.window)
            self._any_change = True
            n_tomb = 0
            if self.tombstones:
                newly = self.active & (self.support < self.minsup)
                n_tomb = int(newly.sum())
                if n_tomb:
                    self.active &= ~newly
                    self._apply_mask()
                    self._emit("mask", self.active.copy(),
                               self.support.copy())
            self._emit("support", self.support.copy())
        self.stats["arrivals"] += len(batch)
        self.stats["evictions"] += evicted
        self.stats["observe_batches"] += 1
        self.stats["tombstoned"] += n_tomb
        self._batches_since_refresh += 1
        refreshed = False
        if self._compact_due():
            self.stats["auto_compactions"] += 1
            self.refresh(full=True)
            refreshed = True
        elif (self.refresh_every
                and self._batches_since_refresh >= self.refresh_every):
            self.refresh()
            refreshed = True
        return ObserveResult(len(batch), evicted, n_tomb, refreshed)

    @property
    def delta_seq(self) -> int:
        """Sequence id of the most recently emitted delta (0 = none):
        a replica whose ``last_seq`` equals this is fully caught up."""
        return self._delta_seq

    def _emit(self, kind: str, *payload) -> None:
        self._delta_seq += 1
        if self.delta_sink is not None:
            self.delta_sink((kind, self._delta_seq) + payload)

    def _compact_due(self) -> bool:
        """Automatic tombstone compaction trigger: the tombstoned-row
        fraction crossed ``compact_threshold`` (tombstoned rows cost
        bank capacity and prescreen width until a full refresh compacts
        them away)."""
        if self.compact_threshold is None or not self.tombstones:
            return False
        P = self.bank.n_patterns
        if not P:
            return False
        return (P - int(self.active.sum())) / P >= self.compact_threshold

    # --------------------------------------------------------- dirtiness
    def dirty_rows(self) -> np.ndarray:
        """[n_patterns] bool: patterns contained in at least one fresh
        (arrived since the last reconcile) sequence *still in the
        window* - the slot-granular dirtiness index.  Eviction
        self-cleans: a transited arrival's slot was overwritten, so its
        dirt is gone."""
        if not self._fresh.any():
            return np.zeros(self.bank.n_patterns, bool)
        return self._bits[self._fresh].any(axis=0)

    def dirty_subtree_roots(self) -> Set[Pattern]:
        """The depth-1 reverse-search roots touched since the last
        reconcile - the coarse, cheaply-communicable form of the
        dirtiness index (what the sharded-window protocol all-reduces;
        see serving.cluster)."""
        return {
            depth1_root(self.bank.patterns[i])
            for i in np.nonzero(self.dirty_rows())[0]
        }

    # ----------------------------------------------------------- refresh
    def _ring_slots(self) -> List[int]:
        """Ring slots in window (oldest-first) order."""
        if self._count < self.window:
            return list(range(self._count))
        return [(self._head + i) % self.window
                for i in range(self.window)]

    def refresh(self, full: bool = False) -> Dict[Pattern, int]:
        """Reconcile the bank with the window; returns the exact
        frequent map (== batch re-mine of the window).  Incremental by
        default (frontier re-mine + bank/trie extension + recount of
        only the recovered/new rows); ``full=True`` re-mines and
        recompiles everything (the escape hatch, also compacts
        tombstones away)."""
        self._batches_since_refresh = 0
        t0 = time.perf_counter()
        try:
            with trace.root_or_span("streaming.refresh", full=full):
                return self._refresh_inner(full)
        finally:
            self._h_refresh.observe(time.perf_counter() - t0)

    def _refresh_inner(self, full: bool) -> Dict[Pattern, int]:
        seqs = self.window_seqs
        if full:
            return self._refresh_full(seqs)
        if not self._any_change:
            return self.frequent()
        if self.tombstones:
            active_map = {
                self.bank.patterns[i]: int(self.support[i])
                for i in np.nonzero(self.active)[0]
            }
        else:
            # every support is exact when nothing is ever masked
            active_map = {
                p: int(self.support[i])
                for i, p in enumerate(self.bank.patterns)
            }
        # dirtiness only means something for rows whose supports are
        # being maintained: every row when tombstones are off, active
        # rows when on (a tombstoned row re-enters via a scan, not via
        # retention, so its dirty bit is moot)
        maintained = self.active if self.tombstones else \
            np.ones_like(self.active)
        dirty_set = {
            self.bank.patterns[i]
            for i in np.nonzero(self.dirty_rows() & maintained)[0]
        }
        with trace.span("streaming.frontier"):
            fr = refresh_frontier(
                seqs, self.minsup, active=active_map, dirty=dirty_set,
                any_change=True, max_len=self.max_len,
                metrics=self.metrics, **self.miner_kw,
            )
        self.stats["refreshes"] += 1
        self.stats["frontier_scans"] += fr.scans
        self.stats["frontier_scans_skipped"] += fr.scans_skipped
        self.stats["frontier_retained"] += fr.retained
        self.stats["dirty_subtrees"] += fr.depth1_dirty
        self.stats["clean_subtrees"] += fr.depth1_clean
        out = self._reconcile(seqs, fr.patterns, fr.gids)
        if self._compact_due():
            # the incremental reconcile left too many tombstoned rows:
            # escalate to the compacting full refresh, reusing the
            # already-exact frequent map instead of re-mining
            self.stats["auto_compactions"] += 1
            out = self._refresh_full(seqs, mined=fr.patterns)
        return out

    def _reconcile(
        self,
        seqs: List[TRSeq],
        mined: Dict[Pattern, int],
        gids: Dict[Pattern, set],
    ) -> Dict[Pattern, int]:
        with trace.span("streaming.reconcile"):
            return self._reconcile_inner(seqs, mined, gids)

    def _reconcile_inner(
        self,
        seqs: List[TRSeq],
        mined: Dict[Pattern, int],
        gids: Dict[Pattern, set],
    ) -> Dict[Pattern, int]:
        known = {p: i for i, p in enumerate(self.bank.patterns)}
        new = {p: s for p, s in mined.items() if p not in known}
        n_new = len(new)
        bank_grew = False
        if new and not self.bank.n_patterns:
            # growing out of an empty bank is a plain recompile (the
            # empty bank's padding row and 1-wide key space cannot be
            # extended in place)
            return self._refresh_full(seqs, mined=mined)
        if new:
            try:
                bank2 = extend_bank(self.bank, new)
            except BankCapacityError:
                # a new pattern does not fit the compiled key space:
                # full recompile is the only exact option
                return self._refresh_full(seqs, mined=mined)
            grow = bank2.n_patterns - self.bank.n_patterns
            self.support = np.concatenate(
                [self.support, np.zeros(grow, np.int64)])
            self.active = np.concatenate(
                [self.active, np.zeros(grow, bool)])
            # the dirtiness index is slot-granular, nothing to grow
            self._bits = np.pad(self._bits, ((0, 0), (0, grow)))
            if self.trie is not None:
                self.trie = extend_trie(self.trie, bank2)
            self.bank = bank2
            bank_grew = True
            known = {p: i for i, p in enumerate(bank2.patterns)}
            self.stats["added"] += grow
        # rows whose maintained bitmaps are stale: new rows (never
        # counted) and recovered tombstones (masked while inactive)
        mined_rows = np.zeros(self.bank.n_patterns, bool)
        for p in mined:
            mined_rows[known[p]] = True
        recount = np.nonzero(mined_rows & ~self.active)[0]
        if len(recount):
            # recovered/new rows backfill their window bitmaps from the
            # frontier miner's exact containing-gid sets - no extra
            # containment join.  gid g indexes ``seqs`` (oldest-first),
            # i.e. position g of the ring-slot order; never-written
            # slots hold all-zero bits already.
            slots = np.asarray(self._ring_slots(), np.int64)
            cols = np.zeros((len(seqs), len(recount)), bool)
            for j, r in enumerate(recount):
                gset = gids[self.bank.patterns[r]]
                cols[sorted(gset), j] = True
            self._bits[slots[:, None], recount[None, :]] = cols
            self.support[recount] = cols.sum(0)
            self.stats["recovered"] += len(recount) - n_new
        # maintained supports of still-active mined rows and recounted
        # supports of recovered/new rows must both equal the mined
        # (re-mine-exact) supports - the maintenance invariant
        for p, s in mined.items():
            assert int(self.support[known[p]]) == s, (
                "support drift on", p, int(self.support[known[p]]), s)
        self.active = mined_rows if self.tombstones else \
            np.ones(self.bank.n_patterns, bool)
        if bank_grew:
            # only an extended bank needs new server tables; otherwise
            # the mask refresh below is the whole serving-state change
            # (set_row_mask drops the row cache itself)
            self.server = self._make_server()
        self._apply_mask()
        self._fresh[:] = False
        self._any_change = False
        self._emit("extend", dict(new), self.active.copy(),
                   self.support.copy())
        return self.frequent()

    def _refresh_full(
        self, seqs: List[TRSeq], mined: Optional[Dict[Pattern, int]] = None
    ) -> Dict[Pattern, int]:
        """Re-mine + recompile + recount everything (escape hatch /
        tombstone compaction)."""
        with trace.span("streaming.full_refresh"):
            return self._refresh_full_inner(seqs, mined)

    def _refresh_full_inner(
        self, seqs: List[TRSeq], mined: Optional[Dict[Pattern, int]] = None
    ) -> Dict[Pattern, int]:
        self.stats["full_refreshes"] += 1
        if mined is None:
            if seqs:
                miner = AcceleratedMiner(
                    seqs, metrics=self.metrics, **self.miner_kw)
                mined = miner.mine_rs(
                    self.minsup, max_len=self.max_len).patterns
            else:
                mined = {}
        self.bank = compile_bank(mined)
        self.trie = None  # rebuilt by _make_server for the trie layout
        self.server = self._make_server()
        P = self.bank.n_patterns
        self.support = np.zeros(P, np.int64)
        self.active = np.ones(P, bool)
        self._fresh[:] = False
        self._bits = np.zeros((self.window, P), bool)
        if seqs and P:
            rows = self.server.exact_rows(seqs)
            for j, slot in enumerate(self._ring_slots()):
                self._bits[slot] = rows[j]
            self.support = rows.sum(0).astype(np.int64)
        # full recount over a freshly mined bank must reproduce the
        # mined supports exactly (containment join == mining counts)
        assert np.array_equal(
            self.support, self.bank.support[:P].astype(np.int64)
        ), "full-refresh recount disagrees with mined supports"
        self._any_change = False
        self._emit("recompile", dict(mined), self.support.copy())
        return self.frequent()

    # ----------------------------------------------------------- serving
    def join(self, req) -> "JoinResult":
        """The unified entry point (serving.join): the inner server
        join (which already honours the tombstone mask on both the
        exact and approximate tiers) rescored by *live* window
        supports; ``exact`` flags pass through untouched."""
        from .join import JoinRequest, JoinResult
        k = 10 if req.k is None else req.k
        inner = self.server.join(JoinRequest(
            seqs=req.seqs, k=0, exact=req.exact,
            trace_id=req.trace_id))
        return JoinResult([
            dataclasses.replace(
                r, topk=score_topk(r.contained, self.support, k))
            for r in inner.results
        ])

    def query(
        self, seqs: Sequence[TRSeq], k: int = 10
    ) -> List[QueryResult]:
        """Serve containment rows over the active bank (tombstoned rows
        answer False) with top-k scored by *live* window supports -
        compiled-time bank order goes stale as supports drift, so the
        server's order-based scoring shortcut does not apply here."""
        from .join import JoinRequest
        return self.join(JoinRequest(seqs=tuple(seqs), k=k)).results
