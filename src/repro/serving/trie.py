"""Prefix-trie pattern bank: shared-frontier serving over rFTS prefixes.

GTRACE-RS enumerates rFTSs as nodes of a reverse-search spanning tree
(Defs 8-10), so mined banks are heavily prefix-shared: sibling patterns
extend a common ancestor, and their step programs (bank.py) agree on
their leading rows.  The flat ``PatternBank`` replays those shared
prefixes once per pattern per sequence; the trie bank stores each
distinct prefix once - a node table of (step row, parent id) where the
root-to-node path is the shared prefix of every pattern below it, and a
pattern terminates at the node ending its program - so the embedding
join (batch.py) advances one frontier per (sequence, trie node) and
sibling patterns pay for their common prefix exactly once.

Construction is longest-common-prefix merging: programs are inserted
row by row into the trie, so any two patterns share nodes for exactly
their longest common program prefix.  The reverse-search ``parent()``
chain motivates the layout but cannot drive it literally: ``parent(p)``
re-canonicalizes after removing a TR (Def 7), so the parent's *program*
is a literal prefix of the child's only when the canonical relabeling
happens to survive the removal (``parent_prefix_hits`` counts these;
typically a minority).  LCP merging subsumes the parent chain - every
literal parent prefix is a trie path by construction - and also merges
prefixes the spanning tree does not relate, so it is used for every
input (``MiningResult`` or raw ``Mapping[Pattern, int]``); the chain is
only consulted for the stats.

Residual-``req`` prescreen: each node carries
``node_req[n] = min over terminals t below n of bank.req[t]``
(elementwise over token keys).  ``counts_b >= node_req[n]`` is a sound
necessary condition for *any* pattern below ``n`` to be contained in
sequence ``b`` (every such pattern needs at least ``req[t] >=
node_req[n]`` tokens per key), and it is monotone up the trie
(``node_req[parent] <= node_req[child]`` since the parent's subtree is
a superset), so a failing node fails its whole subtree and the scan
prunes it at its highest failing ancestor - no descendant cell is ever
seeded.

Flat vs trie: the trie join wins when patterns share prefixes (deep
banks mined with reverse search; the win grows with bank size since
sibling counts grow) and costs one device dispatch per trie *level*
instead of one per program-length group.  Prefer the flat layout for
tiny banks, banks of unrelated patterns (sharing ratio ~1), or
single-level banks where the flat server's prescreen-is-containment
shortcut for 1-TR patterns already answers without joining.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Tuple, Union

import numpy as np

from ..core.gtrace import MiningResult
from .bank import (
    STEP_FIELDS,
    PatternBank,
    compile_bank,
    pattern_steps,
    slice_bank,
)


@dataclasses.dataclass
class TrieLevels:
    """Level-padded dense view of a trie (the device join's layout).

    Every level is padded to a common width ``Mh``; padding nodes have
    ``step_valid=0`` rows (never match) and parent position 0.  A
    pattern row ``p`` terminates at position ``term_pos[p]`` of level
    ``term_level[p]`` (0/0 for bank padding rows - masked by
    ``pattern_valid``)."""

    steps: np.ndarray       # [D, Mh, STEP_FIELDS] int32
    parent_pos: np.ndarray  # [D, Mh] int32, position within level d-1
    term_level: np.ndarray  # [n_rows] int32
    term_pos: np.ndarray    # [n_rows] int32

    @property
    def depth(self) -> int:
        return self.steps.shape[0]

    @property
    def width(self) -> int:
        return self.steps.shape[1]


@dataclasses.dataclass
class TrieBank:
    """A ``PatternBank`` re-laid-out as a prefix trie of step rows."""

    node_step: np.ndarray      # [M, STEP_FIELDS] int32
    node_parent: np.ndarray    # [M] int32 (-1 = child of the root)
    node_depth: np.ndarray     # [M] int32 (1-based; root is implicit)
    node_req: np.ndarray       # [M, 6*n_label_keys] residual prescreen
    terminal_node: np.ndarray  # [n_rows] int32 node per bank row (-1 pad)
    bank: PatternBank          # the flat bank (same pattern row order)
    # nodes per depth, ids ascending (ids are assigned in program order,
    # so a parent's id is always smaller than its children's)
    levels: List[np.ndarray] = dataclasses.field(default_factory=list)
    node_pos: np.ndarray = None  # [M] position of each node in its level
    parent_prefix_hits: int = -1  # reverse-search stats, -1 = unknown

    @property
    def n_nodes(self) -> int:
        return self.node_step.shape[0]

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def sharing_ratio(self) -> float:
        """Flat joined-steps over trie nodes (>= 1; higher = more shared
        prefix work deduplicated)."""
        total = int(self.bank.n_steps[: self.bank.n_patterns].sum())
        return total / max(self.n_nodes, 1)

    # ------------------------------------------------------------ views
    def padded_levels(
        self, depth: int | None = None, width: int | None = None
    ) -> TrieLevels:
        """Dense [D, Mh] view for the level-synchronous device join;
        ``depth``/``width`` round up for cross-shard uniformity."""
        D = max(self.depth, 1 if depth is None else 0)
        if depth is not None:
            assert depth >= self.depth, (depth, self.depth)
            D = depth
        Mh = max((len(lv) for lv in self.levels), default=1)
        if width is not None:
            assert width >= Mh, (width, Mh)
            Mh = width
        steps = np.zeros((D, Mh, STEP_FIELDS), np.int32)
        parent_pos = np.zeros((D, Mh), np.int32)
        for d, nodes in enumerate(self.levels):
            steps[d, : len(nodes)] = self.node_step[nodes]
            if d > 0:
                parent_pos[d, : len(nodes)] = self.node_pos[
                    self.node_parent[nodes]
                ]
        n_rows = self.bank.n_rows
        term_level = np.zeros(n_rows, np.int32)
        term_pos = np.zeros(n_rows, np.int32)
        real = self.terminal_node[: self.bank.n_patterns]
        term_level[: len(real)] = self.node_depth[real] - 1
        term_pos[: len(real)] = self.node_pos[real]
        return TrieLevels(steps=steps, parent_pos=parent_pos,
                          term_level=term_level, term_pos=term_pos)

    # ------------------------------------------------------------ shard
    def shard_rows(self, n_shards: int) -> List[List[int]]:
        """The bank-row assignment behind ``shard``: rows grouped by
        depth-1 subtree, subtrees packed onto shards by greedy
        node-count balancing (a subtree's weight is the join work it
        seeds), rows sorted within each shard to keep bank
        (support-desc) order.  Shards may be empty when the root has
        fewer children than ``n_shards``.  The cluster layer
        (serving.cluster) uses this as its bank placement - a subtree is
        never split across hosts, so every host joins intact
        sub-tries."""
        bank = self.bank
        # depth-1 ancestor of each pattern row
        anc = np.asarray(self.terminal_node[: bank.n_patterns])
        anc = anc.copy()
        for i, node in enumerate(anc):
            n = int(node)
            while self.node_parent[n] >= 0:
                n = int(self.node_parent[n])
            anc[i] = n
        groups: Dict[int, List[int]] = {}
        for row, a in enumerate(anc):
            groups.setdefault(int(a), []).append(row)
        # subtree weight = its node count (the join work it seeds)
        sizes = self._subtree_sizes()
        weight = {a: int(sizes[a]) for a in groups}
        bins: List[List[int]] = [[] for _ in range(n_shards)]
        load = [0] * n_shards
        for a in sorted(groups, key=lambda a: -weight[a]):
            i = int(np.argmin(load))
            bins[i].extend(groups[a])
            load[i] += weight[a]
        return [sorted(rows) for rows in bins]

    def shard(self, n_shards: int) -> List["TrieBank"]:
        """Split by depth-1 subtree into ``n_shards`` tries whose
        pattern sets partition the bank (see ``shard_rows``).  Each
        shard keeps the global ``nv``/``n_label_keys`` so token keys and
        psi widths stay consistent across the mesh."""
        return [
            build_trie(slice_bank(self.bank, rows))
            for rows in self.shard_rows(n_shards)
        ]

    def _subtree_sizes(self) -> np.ndarray:
        sizes = np.ones(max(self.n_nodes, 1), np.int64)
        for n in range(self.n_nodes - 1, -1, -1):
            p = int(self.node_parent[n])
            if p >= 0:
                sizes[p] += sizes[n]
        return sizes

    # ---------------------------------------------------------- checks
    def program_of(self, row: int) -> List[Tuple[int, ...]]:
        """Reconstruct pattern ``row``'s step program from its
        root-to-terminal path (testing hook)."""
        path = []
        n = int(self.terminal_node[row])
        while n >= 0:
            path.append(tuple(int(x) for x in self.node_step[n]))
            n = int(self.node_parent[n])
        return path[::-1]


def _insert_programs(
    bank: PatternBank,
    rows,
    children: Dict[Tuple[int, Tuple[int, ...]], int],
    steps: List[Tuple[int, ...]],
    parents: List[int],
    depths: List[int],
    terminal: np.ndarray,
) -> None:
    """LCP-insert the given bank rows' step programs into the node
    lists (the shared core of ``build_trie`` and ``extend_trie``)."""
    for row in rows:
        cur = -1
        for k in range(int(bank.n_steps[row])):
            srow = tuple(int(x) for x in bank.steps[row, k])
            key = (cur, srow)
            nid = children.get(key)
            if nid is None:
                nid = len(steps)
                children[key] = nid
                steps.append(srow)
                parents.append(cur)
                depths.append(1 if cur < 0 else depths[cur] + 1)
            cur = nid
        terminal[row] = cur


def _finalize_trie(
    bank: PatternBank,
    steps: List[Tuple[int, ...]],
    parents: List[int],
    depths: List[int],
    terminal: np.ndarray,
) -> TrieBank:
    """Node tables -> ``TrieBank``: subtree ``node_req`` reductions (one
    reversed pass - parent ids are always smaller than their
    children's), level index, per-level positions."""
    M = len(steps)
    node_step = np.asarray(steps, np.int32).reshape(M, STEP_FIELDS)
    node_parent = np.asarray(parents, np.int32).reshape(M)
    node_depth = np.asarray(depths, np.int32).reshape(M)
    K = bank.req.shape[1]
    big = np.iinfo(np.int32).max
    node_req = np.full((M, K), big, np.int32)
    for row in range(bank.n_patterns):
        t = int(terminal[row])
        if t >= 0:
            np.minimum(node_req[t], bank.req[row], out=node_req[t])
    for n in range(M - 1, -1, -1):
        p = int(node_parent[n])
        if p >= 0:
            np.minimum(node_req[p], node_req[n], out=node_req[p])
    # patterns of length 0 never reach compile_bank; every node has a
    # terminal somewhere below, so no +inf requirement survives
    assert M == 0 or int(node_req.max(initial=0)) < big
    levels = [
        np.nonzero(node_depth == d + 1)[0].astype(np.int32)
        for d in range(int(node_depth.max(initial=0)))
    ]
    node_pos = np.zeros(max(M, 1), np.int32)
    for nodes in levels:
        node_pos[nodes] = np.arange(len(nodes), dtype=np.int32)
    return TrieBank(node_step=node_step, node_parent=node_parent,
                    node_depth=node_depth, node_req=node_req,
                    terminal_node=terminal, bank=bank, levels=levels,
                    node_pos=node_pos[:max(M, 1)])


def build_trie(bank: PatternBank) -> TrieBank:
    """LCP-merge the bank's step programs into a ``TrieBank``.

    Node ids are assigned in first-visit order walking each program
    root-to-leaf, so every parent id is smaller than its children's and
    one reversed pass computes all subtree reductions (``node_req``)."""
    children: Dict[Tuple[int, Tuple[int, ...]], int] = {}
    steps: List[Tuple[int, ...]] = []
    parents: List[int] = []
    depths: List[int] = []
    terminal = np.full(max(bank.n_rows, 1), -1, np.int32)
    _insert_programs(bank, range(bank.n_patterns), children, steps,
                     parents, depths, terminal)
    return _finalize_trie(bank, steps, parents, depths, terminal)


def extend_trie(trie: TrieBank, bank: PatternBank) -> TrieBank:
    """LCP-merge the appended rows of an extended bank (see
    ``bank.extend_bank``) into an existing trie without re-walking the
    old rows: ``bank`` must share rows ``[0, trie.bank.n_patterns)``
    with ``trie.bank`` (same patterns, same order).  New nodes are
    appended, so existing node ids - and every host table derived from
    them - stay valid, and the result is *identical* to
    ``build_trie(bank)`` (node ids are first-visit order over rows, and
    the shared rows visit first either way; differentially tested)."""
    old_n = trie.bank.n_patterns
    assert bank.patterns[:old_n] == trie.bank.patterns, \
        "extended bank must share its leading rows with the trie"
    children: Dict[Tuple[int, Tuple[int, ...]], int] = {
        (int(trie.node_parent[n]),
         tuple(int(x) for x in trie.node_step[n])): n
        for n in range(trie.n_nodes)
    }
    steps = [tuple(int(x) for x in trie.node_step[n])
             for n in range(trie.n_nodes)]
    parents = [int(p) for p in trie.node_parent[: trie.n_nodes]]
    depths = [int(d) for d in trie.node_depth[: trie.n_nodes]]
    terminal = np.full(max(bank.n_rows, 1), -1, np.int32)
    terminal[:old_n] = trie.terminal_node[:old_n]
    _insert_programs(bank, range(old_n, bank.n_patterns), children,
                     steps, parents, depths, terminal)
    return _finalize_trie(bank, steps, parents, depths, terminal)


#: prescreen row value that no token-count vector ever satisfies - a
#: masked (tombstoned) pattern or subtree is never joined
REQ_MASKED = np.iinfo(np.int32).max


def masked_node_req(trie: TrieBank, active: np.ndarray) -> np.ndarray:
    """Residual ``node_req`` rows over the *active* terminals only:
    ``min over active terminals t below n of bank.req[t]``, with
    ``REQ_MASKED`` where a subtree has no active terminal - so the
    level-synchronous scan stops joining tombstoned subtrees at their
    highest all-tombstoned ancestor (the streaming layer's tombstone
    mask; see serving.streaming).  ``active`` is a [n_patterns] bool
    mask.  With all patterns active this equals ``trie.node_req``."""
    bank = trie.bank
    M = trie.n_nodes
    node_req = np.full((max(M, 1), bank.req.shape[1]), REQ_MASKED,
                       np.int32)
    for row in range(bank.n_patterns):
        if not active[row]:
            continue
        t = int(trie.terminal_node[row])
        if t >= 0:
            np.minimum(node_req[t], bank.req[row], out=node_req[t])
    for n in range(M - 1, -1, -1):
        p = int(trie.node_parent[n])
        if p >= 0:
            np.minimum(node_req[p], node_req[n], out=node_req[p])
    return node_req[:M] if M else node_req[:0]


@dataclasses.dataclass
class SubtreePack:
    """Subtree *shards* packed into fixed slot tables - the fused
    megakernel's layout (repro.kernels.trie_walk).  One *cell* of the
    fused walk is a (sequence, shard) pair; slot ``n`` of shard ``s``
    holds one trie node with its step row, its parent's slot index
    (-1 = shard's first node, seeded from the shared root state) and -
    gathered at serve time against the possibly-masked ``node_req`` -
    its residual prescreen row.  Slots are in ascending global node-id
    order, which is topological (parents first: node ids are assigned
    in program order), so the kernel's single unrolled pass over slots
    visits every node after its parent.

    A shard is a connected piece of one depth-1 subtree.  Small
    subtrees are one shard; subtrees wider than the slot budget
    (``width_cap``) are partitioned bottom-up into parts of bounded
    *exclusive* node count, and each part carries a replicated **spine**
    - the ancestor chain from the depth-1 root down to the part root -
    so its walk re-derives the part root's frontier in-cell with no
    cross-cell traffic.  Spine slots are walked but own no terminals
    (the part where a node is exclusive answers them); the per-node
    frontier/overflow legs along the chain are the same as in the
    unsharded walk, so the replication changes work layout, not bits.
    Without the cap, one hub subtree would set every cell's slot width
    (padding is uniform), multiplying the whole batch's walk work by
    the hub's width - the measured 10x pessimization the cap removes.

    ``roots[s]`` is the shard's *part root* (its deepest spine-free
    ancestor), not the depth-1 root: ``node_req`` is a min over the
    subtree below a node, so prescreening cells at the part root is
    both sound (any cell it skips has prescreen-dead terminals, which
    the in-kernel per-node prescreen would zero anyway - bit-identical
    by monotonicity) and strictly sharper than gating at depth 1.

    Singleton depth-1 subtrees (a childless depth-1 node) are *not*
    packed: their terminals are single-TR patterns, for which the node
    prescreen IS the exact containment test (``leaf_rows`` /
    ``leaf_roots``; the per-level scan makes the same shortcut), so the
    fused path answers them from the root prescreen with no walk and
    ``ovf=False``.

    Terminals are flat triples (``term_sub``/``term_slot``/
    ``term_rows``): bank row ``term_rows[t]`` reads its accept /
    terminal-overflow bits from slot ``term_slot[t]`` of shard
    ``term_sub[t]``."""

    node_ids: np.ndarray    # [S, Nmax] int32 global node id (-1 = pad)
    steps: np.ndarray       # [S, Nmax, STEP_FIELDS] int32 (0 = pad)
    parent: np.ndarray      # [S, Nmax] int32 parent slot (-1 root/pad)
    roots: np.ndarray       # [S] int32 root node id per packed subtree
    term_sub: np.ndarray    # [nt] int64 packed-shard index
    term_slot: np.ndarray   # [nt] int64 slot within the shard
    term_rows: np.ndarray   # [nt] int64 bank row
    term_nodes: np.ndarray  # [nt] int32 global node id of the slot
    leaf_rows: np.ndarray   # [nl] int64 singleton depth-1 leaf rows
    leaf_roots: np.ndarray  # [nl] int32 their (single) node ids

    @property
    def n_subtrees(self) -> int:
        return self.node_ids.shape[0]

    @property
    def n_slots(self) -> int:
        return self.node_ids.shape[1]

    def pack_req(self, node_req: np.ndarray) -> np.ndarray:
        """Gather the (possibly tombstone-masked, see
        ``masked_node_req``) per-node prescreen rows into slot layout:
        [S, Nmax, K] with ``REQ_MASKED`` at padding slots, so pads are
        prescreen-dead inside the kernel."""
        K = node_req.shape[1] if node_req.ndim == 2 else 0
        if not self.n_subtrees:
            return np.zeros((0, self.n_slots, K), np.int32)
        live = self.node_ids >= 0
        gathered = node_req[np.clip(self.node_ids, 0, None)]
        return np.where(live[..., None], gathered,
                        REQ_MASKED).astype(np.int32)


def _shard_group(trie: TrieBank, nodes: List[int],
                 width_cap: int) -> List[Tuple[List[int], List[int]]]:
    """Partition one depth-1 subtree (``nodes``, ascending ids, first
    is the depth-1 root) into ``(spine, exclusive)`` shards whose total
    slot width (spine + exclusive) stays within ``width_cap`` wherever
    the trie's depth allows it.

    Bottom-up greedy cut: walking nodes deepest-first, each node
    accumulates the still-uncut subtree below it; when root-path depth
    plus that accumulation would overflow the cap, the widest pending
    child subtrees are cut off as shards of their own.  A shard's spine
    is the ancestor chain from the depth-1 root to its part root's
    parent (within this subtree), replicated so the walk is
    self-contained per cell."""
    root = nodes[0]
    in_group = set(nodes)
    children: Dict[int, List[int]] = {n: [] for n in nodes}
    for n in nodes[1:]:
        children[int(trie.node_parent[n])].append(n)
    # spine length a shard rooted at n pays = #ancestors within group
    spine_len = {root: 0}
    for n in nodes[1:]:
        spine_len[n] = spine_len[int(trie.node_parent[n])] + 1
    pending: Dict[int, List[int]] = {}
    shards: List[Tuple[List[int], List[int]]] = []

    def spine_of(n: int) -> List[int]:
        path: List[int] = []
        p = int(trie.node_parent[n])
        while p >= 0 and p in in_group:
            path.append(p)
            p = int(trie.node_parent[p])
        return path[::-1]  # root first (ascending ids)

    for n in reversed(nodes):  # children before parents
        acc = [n]
        for c in children[n]:
            acc.extend(pending.pop(c, ()))
        # cut the widest pending children until this node's shard-in-
        # progress fits its worst-case width (its own spine + nodes);
        # a single node deeper than the cap degrades gracefully (the
        # caller pads nmax up)
        while spine_len[n] + len(acc) > width_cap and len(acc) > 1:
            # cut whichever uncut child subtree is widest inside acc
            by_child = [(c, [m for m in acc if m == c or _under(
                trie, m, c, in_group)]) for c in children[n]]
            by_child = [(c, ms) for c, ms in by_child if ms]
            if not by_child:
                break
            cut, cut_nodes = max(by_child, key=lambda kv: len(kv[1]))
            shards.append((spine_of(cut), sorted(cut_nodes)))
            acc = [m for m in acc if m not in set(cut_nodes)]
        pending[n] = acc
    shards.append((spine_of(root), sorted(pending[root])))
    # deterministic order: by part root id (shards of one subtree stay
    # adjacent, spine-first slot order inside each)
    shards.sort(key=lambda se: se[1][0])
    return shards


def _under(trie: TrieBank, n: int, top: int, in_group: set) -> bool:
    while n >= 0 and n in in_group:
        if n == top:
            return True
        n = int(trie.node_parent[n])
    return False


def pack_subtrees(trie: TrieBank, width_cap: int = 8) -> SubtreePack:
    """Lay the trie out as fixed-width subtree-shard slot tables for
    the fused walk (see ``SubtreePack``).  ``width_cap`` bounds each
    shard's slot count (spine + exclusive nodes); ``nmax`` is the pow-2
    of the widest shard actually produced, so one hub subtree can no
    longer inflate every cell's padded width."""
    M = trie.n_nodes
    # depth-1 ancestor per node: parents have smaller ids, one pass
    anc = np.arange(max(M, 1), dtype=np.int64)
    for n in range(M):
        p = int(trie.node_parent[n])
        if p >= 0:
            anc[n] = anc[p]
    groups: Dict[int, List[int]] = {}
    for n in range(M):
        groups.setdefault(int(anc[n]), []).append(n)  # ids ascending
    term_of: Dict[int, List[int]] = {}
    for row in range(trie.bank.n_patterns):
        t = int(trie.terminal_node[row])
        if t >= 0:
            term_of.setdefault(t, []).append(row)
    leaf_roots = [r for r in sorted(groups) if len(groups[r]) == 1]
    leaf_rows = [row for r in leaf_roots for row in term_of.get(r, ())]
    shards: List[Tuple[List[int], List[int]]] = []
    for r in sorted(groups):
        if len(groups[r]) > 1:
            shards.extend(_shard_group(trie, groups[r], width_cap))
    nmax = 1
    while nmax < max((len(sp) + len(ex) for sp, ex in shards),
                     default=1):
        nmax <<= 1
    S = len(shards)
    node_ids = np.full((S, nmax), -1, np.int32)
    steps = np.zeros((S, nmax, STEP_FIELDS), np.int32)
    parent = np.full((S, nmax), -1, np.int32)
    roots: List[int] = []
    term_sub: List[int] = []
    term_slot: List[int] = []
    term_rows: List[int] = []
    term_nodes: List[int] = []
    for s, (spine, exclusive) in enumerate(shards):
        nodes = spine + exclusive  # ascending ids == topological
        roots.append(exclusive[0])
        slot_of = {n: i for i, n in enumerate(nodes)}
        node_ids[s, : len(nodes)] = nodes
        steps[s, : len(nodes)] = trie.node_step[nodes]
        for i, n in enumerate(nodes):
            p = int(trie.node_parent[n])
            parent[s, i] = slot_of.get(p, -1)
        # only exclusive slots own terminals: spine slots are walked
        # replicas whose rows another shard answers
        for i, n in ((slot_of[n], n) for n in exclusive):
            for row in term_of.get(n, ()):
                term_sub.append(s)
                term_slot.append(i)
                term_rows.append(row)
                term_nodes.append(n)
    return SubtreePack(
        node_ids=node_ids, steps=steps, parent=parent,
        roots=np.asarray(roots, np.int32),
        term_sub=np.asarray(term_sub, np.int64),
        term_slot=np.asarray(term_slot, np.int64),
        term_rows=np.asarray(term_rows, np.int64),
        term_nodes=np.asarray(term_nodes, np.int32),
        leaf_rows=np.asarray(leaf_rows, np.int64),
        leaf_roots=np.asarray(leaf_roots, np.int32),
    )


def parent_prefix_hits(bank: PatternBank) -> int:
    """How many bank patterns have a reverse-search parent whose step
    program is a *literal* prefix of theirs (the spanning-tree edges the
    trie gets for free; canonical relabeling breaks the rest, which LCP
    merging recovers whenever the leading rows still agree)."""
    from ..core.reverse_search import parent

    hits = 0
    nl = bank.n_label_keys
    for p in bank.patterns:
        q = parent(p)
        if not q:
            continue
        pp = pattern_steps(p, nl)
        qq = pattern_steps(q, nl)
        if pp[: len(qq)] == qq:
            hits += 1
    return hits


def compile_trie_bank(
    result: Union[MiningResult, Mapping], **bank_kw
) -> TrieBank:
    """``compile_bank`` then ``build_trie``; ``MiningResult`` inputs
    additionally record the reverse-search ``parent_prefix_hits`` stat
    (raw mappings have no spanning tree - pure LCP merging)."""
    bank = compile_bank(result, **bank_kw)
    trie = build_trie(bank)
    if isinstance(result, MiningResult):
        trie.parent_prefix_hits = parent_prefix_hits(bank)
    return trie
