"""Model/optimizer checkpointing: sharded-safe, atomic, async-capable.

Leaves are gathered to host numpy, written as one .npz per checkpoint
(flattened "a/b/c" keys) plus a JSON manifest, via tmp+rename so readers
never observe partial state.  ``restore`` rebuilds the pytree and
device_puts leaves with the provided shardings (resharding on restore is
how elastic restarts change topology).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        from ..models.common import path_str

        out[path_str(path).replace("/", _SEP)] = np.asarray(leaf)
    return out


def save(path: str, tree: PyTree, step: int, meta: Optional[dict] = None,
         async_: bool = False):
    flat = _flatten(tree)

    def _write():
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".ckpt-")
        os.close(fd)
        try:
            np.savez(tmp, **flat)
            os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp,
                       path)
        finally:
            for t in (tmp, tmp + ".npz"):
                if os.path.exists(t):
                    os.unlink(t)
        with open(path + ".meta.json.tmp", "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        os.replace(path + ".meta.json.tmp", path + ".meta.json")

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def restore(path: str, like: PyTree, shardings: Optional[PyTree] = None
            ) -> tuple:
    """Rebuild the pytree of ``like`` from the checkpoint; returns
    (tree, step).  ``shardings`` (same structure) re-places leaves."""
    data = np.load(path, allow_pickle=False)
    with open(path + ".meta.json") as f:
        meta = json.load(f)

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    from ..models.common import path_str

    new_leaves = []
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else None)
    for i, (p, leaf) in enumerate(leaves_p):
        key = path_str(p).replace("/", _SEP)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if flat_sh is not None:
            new_leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, [l for l in new_leaves])
    return tree, meta["step"]
