"""Hand-rolled optimizers (no optax in this environment).

* ``adamw``     - standard AdamW with decoupled weight decay.
* ``adamw8bit`` - blockwise-quantized first/second moments (Dettmers-style
  8-bit states): moments are stored as int8 with one fp32 absmax scale per
  block of 256 values.  4.1 bytes/param of optimizer state instead of 8,
  which is what lets the 400B-param MoE fit v5e HBM at 256 chips (see
  DESIGN.md §6).
* gradient clipping by global norm and cosine LR schedule with warmup.

All optimizers are pure pytree->pytree functions compatible with jit/pjit;
state tensors inherit the params' sharding (quantized blocks divide the
last axis, which our sharding rules never split unevenly).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


# --------------------------------------------------------------- schedule
def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree)


# -------------------------------------------------- 8-bit rowwise quant
# One fp32 absmax scale per last-axis row.  Codes keep the param's exact
# shape, so optimizer-state tensors shard under the *same* PartitionSpec
# rules as their parameter (scales have a size-1 trailing axis which the
# spec resolver replicates).  ~1.03 bytes/param per moment at d>=128.
def _quant_row(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    q = jnp.clip(jnp.round(x / scale * 127.0), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_row(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale / 127.0


# ---------------------------------------------------------------- adamw
class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "float32"  # float32 | bfloat16 | int8

    # --- state ---
    def init(self, params: PyTree) -> AdamWState:
        if self.state_dtype == "int8":
            def zero(x):
                q, s = _quant_row(jnp.zeros(x.shape, jnp.float32))
                return {"q": q, "s": s}
        else:
            dt = jnp.bfloat16 if self.state_dtype == "bfloat16" else jnp.float32
            def zero(x):
                return jnp.zeros(x.shape, dt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zero, params),
            v=jax.tree.map(zero, params),
        )

    def _load(self, s, like, is_v: bool = False):
        if self.state_dtype == "int8":
            val = _dequant_row(s["q"], s["s"])
            if is_v:
                # floor the second moment at its quantization resolution:
                # coords whose v underflows the int8 grid would otherwise
                # divide by eps and explode the update
                floor = (s["s"] / 127.0) ** 2 * 0.25
                val = jnp.maximum(val, floor)
            return val
        return s.astype(jnp.float32)

    def _store(self, val):
        if self.state_dtype == "int8":
            q, s = _quant_row(val)
            return {"q": q, "s": s}
        dt = jnp.bfloat16 if self.state_dtype == "bfloat16" else jnp.float32
        return val.astype(dt)

    # --- update ---
    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> Tuple[PyTree, AdamWState]:
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        new_p, new_m, new_v = [], [], []
        for p, g, m_s, v_s in zip(flat_p, flat_g, flat_m, flat_v):
            g32 = g.astype(jnp.float32)
            m = b1 * self._load(m_s, p) + (1 - b1) * g32
            v = b2 * self._load(v_s, p, is_v=True) + (1 - b2) * g32 * g32
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.state_dtype == "int8":
                # update clipping (Dettmers-style stability guard)
                upd = jnp.clip(upd, -5.0, 5.0)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
            new_m.append(self._store(m))
            new_v.append(self._store(v))
        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            AdamWState(
                step=step,
                m=jax.tree_util.tree_unflatten(treedef, new_m),
                v=jax.tree_util.tree_unflatten(treedef, new_v),
            ),
        )


def sgd_momentum(lr: float = 0.1, momentum: float = 0.9):
    """Minimal SGD+momentum (used by GNN configs, matching their papers)."""

    class _SGD:
        def init(self, params):
            return AdamWState(
                step=jnp.zeros((), jnp.int32),
                m=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                               params),
                v=None,
            )

        def update(self, grads, state, params):
            m = jax.tree.map(
                lambda mm, g: momentum * mm + g.astype(jnp.float32),
                state.m, grads,
            )
            new_p = jax.tree.map(
                lambda p, mm: (p.astype(jnp.float32) - lr * mm
                               ).astype(p.dtype), params, m,
            )
            return new_p, AdamWState(step=state.step + 1, m=m, v=None)

    return _SGD()
