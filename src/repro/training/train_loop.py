"""Generic fault-tolerant training loop used by launch/train.py and the
examples: gradient accumulation, clipping, checkpoint/restart, simple
retry-on-transient-failure (the restart path a real cluster job takes)."""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from .checkpoint import restore, save
from .optimizer import AdamW, clip_by_global_norm

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int = 0


def make_step_fn(loss_fn: Callable, opt: AdamW, grad_accum: int = 1,
                 clip: float = 1.0):
    """(state, batch) -> (loss, state).  ``batch`` leading dim must be
    divisible by grad_accum; microbatches are scanned to bound memory."""

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(i):
                mb = jax.tree.map(
                    lambda x: x.reshape(grad_accum, -1, *x.shape[1:])[i],
                    batch,
                )
                return jax.value_and_grad(loss_fn)(params, mb)

            def body(carry, i):
                loss_acc, grad_acc = carry
                l, g = micro(i)
                return (
                    loss_acc + l,
                    jax.tree.map(jnp.add, grad_acc, g),
                ), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), jnp.arange(grad_accum)
            )
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        grads = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params)
        return loss, params, opt_state

    return jax.jit(step, donate_argnums=(0, 1))


def train(
    loss_fn: Callable,
    init_params: PyTree,
    batches: Iterator[PyTree],
    n_steps: int,
    opt: Optional[AdamW] = None,
    grad_accum: int = 1,
    checkpoint_path: Optional[str] = None,
    checkpoint_every: int = 100,
    resume: bool = False,
    log_every: int = 10,
    log: Callable[[str], None] = print,
):
    opt = opt or AdamW(lr=1e-3)
    params = init_params
    opt_state = opt.init(params)
    start = 0
    if resume and checkpoint_path and os.path.exists(checkpoint_path):
        (params, opt_state), start = restore(
            checkpoint_path, (params, opt_state)
        )
        log(f"[train] resumed from step {start}")
    step_fn = make_step_fn(loss_fn, opt, grad_accum)
    losses = []
    t0 = time.time()
    pending = None
    for step in range(start, n_steps):
        batch = next(batches)
        loss, params, opt_state = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if (step + 1) % log_every == 0:
            dt = (time.time() - t0) / log_every
            log(f"[train] step {step+1}/{n_steps} "
                f"loss={sum(losses[-log_every:])/log_every:.4f} "
                f"{dt*1e3:.0f} ms/step")
            t0 = time.time()
        if checkpoint_path and (step + 1) % checkpoint_every == 0:
            if pending is not None:
                pending.join()
            pending = save(checkpoint_path, (params, opt_state), step + 1,
                           async_=True)
    if pending is not None:
        pending.join()
    if checkpoint_path:
        save(checkpoint_path, (params, opt_state), n_steps)
    return params, opt_state, losses
