import os
import sys

# Smoke tests and benches run on the single real CPU device; only
# launch/dryrun.py sets XLA_FLAGS host-device overrides (per instructions).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import random
from typing import List

from repro.core.compile import compile_sequence
from repro.core.graphseq import TRSeq
from repro.data.synthetic import random_graph_sequence


def random_db(
    seed: int,
    n_seq: int = 6,
    n_steps: int = 4,
    n_v: int = 4,
    n_vl: int = 2,
    n_el: int = 2,
) -> List[TRSeq]:
    rng = random.Random(seed)
    return [
        compile_sequence(
            random_graph_sequence(rng, n_steps=n_steps, n_v=n_v,
                                  n_vl=n_vl, n_el=n_el)
        )
        for _ in range(n_seq)
    ]
