"""Pure-pytest fallback for the optional ``hypothesis`` dependency.

The property tests only use ``@settings(max_examples=N, deadline=None)``
stacked on ``@given(st.integers(lo, hi), ...)``.  When hypothesis is not
installed we emulate exactly that subset: each wrapped test runs
``max_examples`` times with arguments drawn from a PRNG seeded
deterministically from the test's qualified name, so failures are
reproducible run-to-run (no shrinking, but the seed of a failing draw is
reported in the assertion traceback via the argument values).
"""
from __future__ import annotations

import functools
import random
import zlib


class _Integers:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def sample(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


class _Booleans:
    def sample(self, rng: random.Random) -> bool:
        return bool(rng.getrandbits(1))


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def booleans() -> _Booleans:
        return _Booleans()


st = strategies

_DEFAULT_EXAMPLES = 20


def given(*arg_strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                args = [s.sample(rng) for s in arg_strats]
                kwargs = {k: s.sample(rng) for k, s in kw_strats.items()}
                fn(*args, **kwargs)

        # pytest must see the zero-arg signature, not the wrapped one
        del wrapper.__wrapped__
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco
