"""Per-arch smoke tests: reduced config, one real train step on CPU,
assert finite loss + unchanged shapes + params actually move."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.models.common import count_params


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    arch = get_arch(arch_id)
    step, args = arch.smoke_bundle()
    out = jax.jit(step)(*args) if args else step()
    if isinstance(out, tuple):
        loss, params, opt_state = out
        assert np.isfinite(float(loss)), (arch_id, loss)
        # shapes preserved, params updated
        old_params = args[0]
        jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0,
                     old_params, params)
        moved = jax.tree.leaves(
            jax.tree.map(
                lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)).max()),
                old_params, params,
            )
        )
        assert max(moved) > 0, arch_id
        # second step still finite
        loss2, *_ = jax.jit(step)(params, opt_state, args[2])
        assert np.isfinite(float(loss2))
    else:
        assert np.isfinite(float(out))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_full_config_param_count(arch_id):
    """Full configs instantiate abstractly with plausible param counts."""
    arch = get_arch(arch_id)
    if arch.family == "lm":
        params = arch.abstract_params("train_4k")
        n = count_params(params)
        expected = {
            "glm4-9b": 9.4e9,
            "gemma-7b": 8.5e9,
            "smollm-135m": 135e6,
            "llama4-maverick-400b-a17b": 400e9,
            "olmoe-1b-7b": 6.9e9,
        }[arch_id]
        assert 0.5 * expected < n < 1.7 * expected, (arch_id, n, expected)
    elif arch.family == "recsys":
        params = arch.abstract_params("train_batch")
        n = count_params(params)
        assert 6e7 < n < 9e7, n  # ~ 2^20 items x 64
    else:
        params = arch.abstract_params("full_graph_sm")
        assert count_params(params) > 0


def test_mace_rotation_invariance():
    """Energies are invariant under global rotation+translation (E(3))."""
    from repro.data.graphs import random_molecule_batch
    from repro.models import mace as mm

    cfg = mm.MACEConfig(name="mace", n_layers=2, d_hidden=32)
    g = random_molecule_batch(np.random.default_rng(0), 4, 8, 16)
    batch = {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
             for k, v in g.items()
             if k in ("species", "pos", "edges", "graph_id", "n_graphs",
                      "targets")}
    params = mm.init_params(jax.random.PRNGKey(0), cfg)
    e0 = mm.forward(params, batch, cfg)

    # random rotation (QR of a gaussian) + translation
    key = jax.random.PRNGKey(7)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (3, 3)))
    q = q * jnp.sign(jnp.linalg.det(q))  # proper rotation
    batch2 = dict(batch)
    batch2["pos"] = batch["pos"] @ q.T + jnp.array([1.0, -2.0, 0.5])
    e1 = mm.forward(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_attention_matches_naive():
    from repro.models.attention import (
        blockwise_causal_attention,
        naive_causal_attention,
    )

    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 64, 8, 2, 16
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    ref = naive_causal_attention(q, k, v)
    for bq, bk in [(8, 16), (16, 8), (64, 64), (32, 16)]:
        out = blockwise_causal_attention(q, k, v, block_q=bq, block_kv=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_decode_matches_forward():
    from repro.models import transformer as tf

    cfg = tf.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=64, vocab=101, block_q=8, block_kv=8,
        compute_dtype=jnp.float32, loss_chunk=8,
    )
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 101)
    hidden, _ = tf.forward(params, toks, cfg)
    full = tf.logits_fn(params, hidden, cfg)
    cache = tf.init_cache(cfg, 2, 16, jnp.float32)
    step = jax.jit(lambda p, c, t: tf.decode_step(p, c, t, cfg))
    for t in range(16):
        lg, cache = step(params, cache, toks[:, t : t + 1])
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4,
                               atol=2e-4)


def test_embedding_bag_matches_loop():
    from repro.models.embedding import embedding_bag

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 50, 20).astype(np.int32))
    # each bag non-empty (segment_max identity for empty bags is -inf)
    seg_np = np.sort(
        np.concatenate([np.arange(5), rng.integers(0, 5, 15)])
    ).astype(np.int32)
    seg = jnp.asarray(seg_np)
    w = jnp.asarray(rng.random(20).astype(np.float32))
    for mode in ("sum", "mean", "max"):
        out = embedding_bag(table, idx, seg, 5, None if mode == "max" else w,
                            mode)
        ref = np.zeros((5, 8), np.float32)
        for b in range(5):
            rows = np.asarray(table)[np.asarray(idx)[np.asarray(seg) == b]]
            ww = np.asarray(w)[np.asarray(seg) == b]
            if len(rows) == 0:
                if mode == "max":
                    ref[b] = 0  # segment_max default
                continue
            if mode == "sum":
                ref[b] = (rows * ww[:, None]).sum(0)
            elif mode == "mean":
                ref[b] = (rows * ww[:, None]).sum(0) / ww.sum()
            else:
                ref[b] = rows.max(0)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5,
                                   atol=1e-5, err_msg=mode)


def test_bert4rec_chunked_topk():
    from repro.models import bert4rec as b4r

    cfg = b4r.Bert4RecConfig(name="x", n_items=1000, seq_len=16,
                             v_chunk=128, topk=17)
    params = b4r.init_params(jax.random.PRNGKey(0), cfg)
    q = jax.random.normal(jax.random.PRNGKey(1), (3, cfg.d_model))
    s, i = b4r.chunked_topk_scores(params, q, cfg)
    # brute force
    emb = np.asarray(params["item_emb"])[: cfg.n_items + 1]
    sc = np.asarray(q) @ emb.T
    sc[:, 0] = -np.inf
    order = np.argsort(-sc, axis=1)[:, : cfg.topk]
    np.testing.assert_array_equal(np.sort(np.asarray(i), 1),
                                  np.sort(order, 1))
