"""Multi-host serving cluster: every topology must be bit-equal to its
single-host counterpart on the same inputs.

The cluster is pure protocol over the already-exact shard servers -
placement (intact depth-1 subtrees / flat ranges), cross-host request
batching, two-level caching, the sharded-window all-reduce, and
writer->replica delta shipping - so the tests here are differential:
routed results vs ``PatternServer``, sharded-window frequent maps vs
``StreamingBank`` and the batch re-mine oracle, replica serving vs the
writer.  Hosts are in-process simulations; the subprocess smoke pins
one host per virtual CPU device following test_distributed.py's
conventions."""
import os
import random
import subprocess
import sys

import numpy as np
import pytest
from conftest import random_db

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI shim (see hypothesis_compat)
    from hypothesis_compat import given, settings, strategies as st

from repro.core.reverse_search import mine_gtrace_rs
from repro.mining.driver import AcceleratedMiner
from repro.serving.bank import compile_bank, sequence_fingerprint
from repro.serving.cluster import (
    ReplicaGroup,
    ServingCluster,
    ShardedStreamingBank,
)
from repro.serving.router import plan_placement
from repro.serving.server import PatternServer
from repro.serving.streaming import StreamingBank
from repro.serving.trie import build_trie

MINSUP, MAX_LEN, W = 3, 3, 8


def _bank(seed, n_seq=10, sigma=2, max_len=MAX_LEN):
    db = random_db(seed, n_seq=n_seq)
    return compile_bank(
        AcceleratedMiner(db).mine_rs(sigma, max_len=max_len))


def _spread(queries, n_hosts):
    reqs = {h: [] for h in range(n_hosts)}
    for i, s in enumerate(queries):
        reqs[i % n_hosts].append(s)
    return reqs


def _oracle(seqs):
    return dict(mine_gtrace_rs(seqs, MINSUP, max_len=MAX_LEN).patterns)


# ------------------------------------------------------- routed serving
@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_routed_cluster_equals_single_host(seed):
    """The tentpole serving contract: containment bits and top-k of
    queries routed through any host split are bit-equal to the
    single-host PatternServer, in both bank layouts."""
    rng = random.Random(seed)
    layout = rng.choice(["flat", "trie"])
    H = rng.choice([2, 3])
    bank = _bank(seed % 50)
    if not bank.n_patterns:
        return
    queries = random_db(seed % 50 + 1, n_seq=7)
    srv = PatternServer(bank, bank_layout=layout)
    want = [srv.query_one(s) for s in queries]
    cl = ServingCluster(bank, H, bank_layout=layout)
    got = cl.query_multi(_spread(queries, H))
    for i, w in enumerate(want):
        r = got[i % H][i // H]
        np.testing.assert_array_equal(r.contained, w.contained)
        assert r.topk == w.topk
        assert r.fingerprint == w.fingerprint


@pytest.mark.parametrize("layout", ["flat", "trie"])
def test_single_host_cluster_is_degenerate(layout):
    """H=1 must reproduce the PatternServer bitwise - the cluster adds
    routing, not semantics."""
    bank = _bank(23)
    queries = random_db(24, n_seq=6)
    srv = PatternServer(bank, bank_layout=layout)
    want = srv.query(queries)
    cl = ServingCluster(bank, 1, bank_layout=layout)
    got = cl.query(queries, host=0)
    for r, w in zip(got, want):
        np.testing.assert_array_equal(r.contained, w.contained)
        assert r.topk == w.topk
    assert len(cl.hosts) == 1
    assert len(cl.hosts[0].rows) == bank.n_patterns


@pytest.mark.parametrize("layout", ["flat", "trie"])
def test_empty_shard_cluster(layout):
    """More hosts than depth-1 subtrees (trie) or patterns (flat)
    leaves empty shards; they answer nothing and break nothing."""
    bank = _bank(23)
    trie = build_trie(bank)
    n_subtrees = len(trie.levels[0]) if trie.depth else 0
    H = (n_subtrees if layout == "trie" else bank.n_patterns) + 2
    cl = ServingCluster(bank, H, bank_layout=layout)
    assert any(len(h.rows) == 0 for h in cl.hosts), "need an empty shard"
    queries = random_db(24, n_seq=5)
    srv = PatternServer(bank, bank_layout=layout)
    np.testing.assert_array_equal(
        cl.exact_rows(queries), srv.exact_rows(queries))


def test_placement_partitions_bank():
    bank = _bank(29)
    trie = build_trie(bank)
    for layout, t in (("flat", None), ("trie", trie)):
        for H in (1, 2, 5):
            pl = plan_placement(bank, H, layout=layout, trie=t)
            got = sorted(
                int(i) for rows in pl.rows for i in rows)
            assert got == list(range(bank.n_patterns)), (layout, H)
    # trie placement keeps every depth-1 subtree on one host
    pl = plan_placement(bank, 3, layout="trie", trie=trie)
    anc = {}
    for row in range(bank.n_patterns):
        n = int(trie.terminal_node[row])
        while trie.node_parent[n] >= 0:
            n = int(trie.node_parent[n])
        anc[row] = n
    owner = {}
    for s, rows in enumerate(pl.rows):
        for r in rows:
            a = anc[int(r)]
            assert owner.setdefault(a, s) == s, \
                "depth-1 subtree split across shards"


def test_two_level_cache_cross_host_hits():
    """A sequence first served via host 0 is an L2 hit when it later
    arrives on host 1 (owner-keyed), and an L1 hit on replay at its own
    arrival host - all serving identical rows."""
    bank = _bank(31)
    queries = random_db(32, n_seq=6)
    cl = ServingCluster(bank, 2, bank_layout="flat")
    first = cl.query(queries, host=0)
    assert cl.router.stats["misses"] == len(
        {r.fingerprint for r in first})
    again = cl.query(queries, host=1)  # other host: L2 (owner) hits
    assert cl.router.stats["l2_hits"] > 0
    replay = cl.query(queries, host=1)  # now in host 1's own L1
    assert cl.router.stats["l1_hits"] > 0
    assert cl.router.stats["misses"] == len(
        {r.fingerprint for r in first}), "caches must absorb replays"
    for a, b, c in zip(first, again, replay):
        np.testing.assert_array_equal(a.contained, b.contained)
        np.testing.assert_array_equal(a.contained, c.contained)
        assert b.cached and c.cached


def test_cluster_row_mask_matches_single_host():
    bank = _bank(33)
    queries = random_db(34, n_seq=5)
    mask = np.arange(bank.n_patterns) % 3 != 0
    for layout in ("flat", "trie"):
        srv = PatternServer(bank, bank_layout=layout)
        srv.set_row_mask(mask)
        cl = ServingCluster(bank, 2, bank_layout=layout)
        cl.set_row_mask(mask)
        np.testing.assert_array_equal(
            cl.exact_rows(queries), srv.exact_rows(queries))
        cl.set_row_mask(None)
        srv.set_row_mask(None)
        np.testing.assert_array_equal(
            cl.exact_rows(queries), srv.exact_rows(queries))


def test_l2_entries_survive_tombstone():
    """A pure tombstone (rows only leaving the active set) patches the
    cached rows per-column instead of dropping them: untouched-row L2/L1
    entries survive, replays stay cache hits, and the patched bits are
    bit-equal to a fresh masked join.  A recovery (masked -> active)
    still clears everything - cached False bits are unrecoverable."""
    bank = _bank(35)
    queries = random_db(36, n_seq=5)
    cl = ServingCluster(bank, 2, bank_layout="flat")
    cl.query(queries, host=0)
    cl.query(queries, host=1)  # populate L1s on both hosts via L2
    n_l2 = sum(len(h.l2) for h in cl.hosts)
    n_l1 = sum(len(h.l1) for h in cl.hosts)
    assert n_l2 > 0 and n_l1 > 0
    mask = np.ones(bank.n_patterns, bool)
    mask[:: 2] = False  # tombstone half the bank
    cl.set_row_mask(mask)
    assert sum(len(h.l2) for h in cl.hosts) == n_l2, \
        "tombstone must not evict untouched L2 entries"
    assert sum(len(h.l1) for h in cl.hosts) == n_l1
    assert cl.router.stats["mask_patches"] == 1
    misses = cl.router.stats["misses"]
    got = cl.query(queries, host=0)
    assert cl.router.stats["misses"] == misses, \
        "patched entries must keep serving as cache hits"
    assert all(r.cached for r in got)
    srv = PatternServer(bank, bank_layout="flat")
    srv.set_row_mask(mask)
    np.testing.assert_array_equal(
        np.stack([r.contained for r in got]), srv.exact_rows(queries))
    # deepening the tombstone patches again; recovering a row clears
    mask2 = mask.copy()
    mask2[1] = False
    cl.set_row_mask(mask2)
    assert sum(len(h.l2) for h in cl.hosts) == n_l2
    assert cl.router.stats["mask_patches"] == 2
    cl.set_row_mask(mask)  # row 1 comes back: cached False is stale
    assert cl.router.stats["mask_clears"] == 1
    assert sum(len(h.l2) for h in cl.hosts) == 0
    got = cl.query(queries, host=0)
    np.testing.assert_array_equal(
        np.stack([r.contained for r in got]), srv.exact_rows(queries))


# ------------------------------------------------ async admission pipeline
def _flatten(results, queries, n_hosts):
    """Per-query results in original order from a _spread drain."""
    return [results[i % n_hosts][i // n_hosts]
            for i in range(len(queries))]


@pytest.mark.parametrize("layout", ["flat", "trie"])
def test_async_submit_collect_equals_route_and_single_host(layout):
    """The tentpole contract: the continuous-batching pipeline
    (submit -> flush -> collect) is bit-equal to the synchronous
    ``route`` AND to the single-host PatternServer, and every exact-tier
    answer is flagged exact."""
    bank = _bank(41)
    queries = random_db(42, n_seq=8)
    srv = PatternServer(bank, bank_layout=layout)
    want = srv.query(queries)
    sync = ServingCluster(bank, 2, bank_layout=layout)
    ref = _flatten(sync.query_multi(_spread(queries, 2)), queries, 2)
    cl = ServingCluster(bank, 2, bank_layout=layout, flush_batch=3)
    got = _flatten(cl.collect(cl.submit(_spread(queries, 2))),
                   queries, 2)
    for w, a, b in zip(want, ref, got):
        np.testing.assert_array_equal(a.contained, w.contained)
        np.testing.assert_array_equal(b.contained, w.contained)
        assert a.topk == b.topk == w.topk
        assert a.exact and b.exact
    assert cl.router.depth() == 0, "collect must drain the pipeline"


def test_inflight_dedup_shares_join():
    """A fingerprint resubmitted while its first copy is queued or on
    device piggybacks on the same join: one device batch, one shared
    row, counted as an in-flight hit instead of a second miss."""
    bank = _bank(31)
    queries = random_db(32, n_seq=4)
    ufps = len({sequence_fingerprint(s) for s in queries})
    cl = ServingCluster(bank, 2, bank_layout="flat", flush_batch=ufps)
    t1 = cl.submit(_spread(queries, 2))       # batch trigger: in flight
    assert cl.router.stats["flush_batch"] == 1
    batches = cl.router.stats["shard_batches"]
    t2 = cl.submit(_spread(queries, 2))       # same fps, still unfenced
    assert cl.router.stats["inflight_hits"] == ufps
    assert cl.router.stats["misses"] == ufps, \
        "piggybacked repeats must not count as misses"
    assert cl.router.stats["shard_batches"] == batches, \
        "piggybacked repeats must not launch a second join"
    r1 = _flatten(cl.collect(t1), queries, 2)
    r2 = _flatten(cl.collect(t2), queries, 2)
    for a, b in zip(r1, r2):
        assert a.contained is b.contained, "tickets share the row"
        assert a.topk == b.topk


@pytest.mark.parametrize("layout", ["flat", "trie"])
def test_shed_tier_is_flagged_approximate_superset(layout):
    """Load shedding: past ``shed_depth`` new misses are answered from
    the host-side counts prescreen - a sound overapproximation of the
    exact bits, flagged ``exact=False``, never cached; the default
    (no ``shed_depth``) never sheds."""
    bank = _bank(33)
    queries = random_db(34, n_seq=5)
    srv = PatternServer(bank, bank_layout=layout)
    exact = srv.exact_rows(queries)
    ufps = len({sequence_fingerprint(s) for s in queries})
    cl = ServingCluster(bank, 2, bank_layout=layout, shed_depth=0)
    got = _flatten(cl.collect(cl.submit(_spread(queries, 2))),
                   queries, 2)
    assert cl.router.stats["shed_prescreen"] == ufps
    assert cl.router.stats["misses"] == ufps, \
        "shed requests still count as misses"
    for i, r in enumerate(got):
        assert not r.exact
        assert not (exact[i] & ~r.contained).any(), \
            "prescreen must never drop a true containment"
    assert all(not h.l1 and not h.l2 for h in cl.hosts), \
        "approximate rows must never enter the caches"
    # default config: exactness is the contract, nothing sheds
    cl2 = ServingCluster(bank, 2, bank_layout=layout, flush_batch=2)
    got2 = _flatten(cl2.collect(cl2.submit(_spread(queries, 2))),
                    queries, 2)
    assert cl2.router.stats["shed_prescreen"] == 0
    for i, r in enumerate(got2):
        assert r.exact
        np.testing.assert_array_equal(r.contained, exact[i])


def test_deadline_flush_under_fake_clock():
    """Deadline-aware flushing is deterministic under an injected
    clock: nothing flushes before ``max_wait``, the head-of-queue age
    triggers exactly one deadline flush at the boundary, and the
    queue-depth gauge tracks ``depth()`` throughout."""
    bank = _bank(35)
    queries = random_db(36, n_seq=6)
    now = [0.0]
    cl = ServingCluster(bank, 2, bank_layout="flat", max_wait=1.0,
                        clock=lambda: now[0])
    gauge = lambda: cl.metrics.snapshot(
        "cluster.router")["cluster.router.queue_depth"]
    t1 = cl.submit(_spread(queries[:3], 2))
    ufps = len({sequence_fingerprint(s) for s in queries[:3]})
    assert cl.router.depth() == ufps == gauge()
    now[0] = 0.99
    cl.poll()
    assert cl.router.stats["flush_deadline"] == 0, "before the deadline"
    assert cl.router.depth() == ufps, "queue intact"
    now[0] = 1.0
    cl.poll()
    assert cl.router.stats["flush_deadline"] == 1, "head aged past max_wait"
    assert cl.router.depth() == ufps == gauge(), \
        "launched but unfenced joins still count toward depth"
    t2 = cl.submit(_spread(queries[3:], 2))   # fresh queue, young head
    results = cl.collect()                    # all tickets, submit order
    assert cl.router.stats["flush_force"] >= 1
    assert cl.router.depth() == 0 == gauge()
    srv = PatternServer(bank, bank_layout="flat")
    want = srv.exact_rows(queries)
    got = (_flatten(results[0], queries[:3], 2)
           + _flatten(results[1], queries[3:], 2))
    for i, r in enumerate(got):
        np.testing.assert_array_equal(r.contained, want[i])
        assert r.exact


def test_async_cache_parity_with_sync_route():
    """Satellite: cache behavior is path-independent.  Driving the same
    interleaved drains through ``route`` and through submit+collect
    yields identical hit/miss counters, identical L1/L2 key sets in
    identical LRU order, and identical post-mask-patch cache contents."""
    bank = _bank(37)
    pool = random_db(38, n_seq=10)
    rng = random.Random(7)
    drains = [
        _spread([pool[rng.randrange(len(pool))]
                 for _ in range(rng.randint(1, 4))], 2)
        for _ in range(6)
    ]
    sync = ServingCluster(bank, 2, bank_layout="flat")
    async_ = ServingCluster(bank, 2, bank_layout="flat", flush_batch=2)
    for d in drains:
        ra = sync.query_multi(d)
        rb = async_.collect(async_.submit(d))
        for hid in ra:
            for a, b in zip(ra[hid], rb[hid]):
                np.testing.assert_array_equal(a.contained, b.contained)
                assert a.cached == b.cached and a.topk == b.topk
    for key in ("queries", "l1_hits", "l2_hits", "misses"):
        assert sync.router.stats[key] == async_.router.stats[key], key
    for ha, hb in zip(sync.hosts, async_.hosts):
        assert list(ha.l1.keys()) == list(hb.l1.keys()), "L1 LRU order"
        assert list(ha.l2.keys()) == list(hb.l2.keys()), "L2 LRU order"
    # the copy-on-write tombstone patch sees the same cache state
    mask = np.arange(bank.n_patterns) % 2 == 0
    sync.set_row_mask(mask)
    async_.set_row_mask(mask)
    assert (sync.router.stats["mask_patches"]
            == async_.router.stats["mask_patches"] == 1)
    for ha, hb in zip(sync.hosts, async_.hosts):
        for ca, cb in ((ha.l1, hb.l1), (ha.l2, hb.l2)):
            for fp in ca:
                np.testing.assert_array_equal(ca[fp], cb[fp])


def test_exact_rows_counts_queries():
    """Satellite bugfix: the routed path enters the shard servers via
    ``exact_rows``/``launch_rows``, which used to skip the ``queries``
    bump - per-host query counters read 0 in the cluster bench."""
    bank = _bank(23)
    queries = random_db(24, n_seq=5)
    srv = PatternServer(bank)
    srv.exact_rows(queries)
    assert srv.stats["queries"] == len(queries)
    cl = ServingCluster(bank, 2)
    cl.exact_rows(queries)
    for h in cl.hosts:
        if len(h.rows):
            assert h.server.stats["queries"] == len(queries)


def test_row_mask_requires_quiescent_pipeline():
    """In-flight joins were launched against the pre-mask requirements
    and ticket-held rows escape the copy-on-write patch, so re-masking
    with uncollected tickets must refuse - with a typed error that
    names the counts and survives ``python -O`` (serving.faults)."""
    from repro.serving.faults import PipelineBusyError

    bank = _bank(39)
    queries = random_db(40, n_seq=3)
    cl = ServingCluster(bank, 2, bank_layout="flat")
    ticket = cl.submit(_spread(queries, 2))
    mask = np.ones(bank.n_patterns, bool)
    mask[0] = False
    with pytest.raises(PipelineBusyError) as exc:
        cl.set_row_mask(mask)
    assert exc.value.tickets == 1
    assert exc.value.queued + exc.value.inflight > 0
    cl.collect(ticket)
    cl.set_row_mask(mask)  # quiescent: fine


# ------------------------------------------------------- sharded window
@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_sharded_window_equals_single_host_streaming(seed):
    """The sharded-window protocol contract: after every refresh - and
    regardless of layout or host count - the frequent map is bit-equal
    to the single-host StreamingBank AND to a batch re-mine of the
    window."""
    rng = random.Random(seed)
    layout = rng.choice(["flat", "trie"])
    H = rng.choice([2, 4])
    db = random_db(seed % 40, n_seq=W)
    ref = StreamingBank.from_db(
        db, minsup=MINSUP, window=W, max_len=MAX_LEN, bank_layout=layout)
    sh = ShardedStreamingBank.from_db(
        db, minsup=MINSUP, n_hosts=H, window=W, max_len=MAX_LEN,
        bank_layout=layout)
    assert sh.window_seqs == ref.window_seqs
    for step in range(3):
        batch = random_db(1000 * seed + step, n_seq=rng.randint(1, 4))
        ref.observe(batch)
        sh.observe(batch)
        assert sh.window_seqs == ref.window_seqs
        if rng.random() < 0.5:
            full = rng.random() < 0.25
            a, b = ref.refresh(full=full), sh.refresh(full=full)
            assert a == b == _oracle(sh.window_seqs)
    a, b = ref.refresh(), sh.refresh()
    assert a == b == _oracle(sh.window_seqs)


def test_sharded_window_no_tombstones_continuously_exact():
    """With tombstones off nothing is ever masked, so the all-reduced
    partial supports equal the single-host maintained supports after
    every observe - not just at refresh points."""
    db = random_db(5, n_seq=W)
    ref = StreamingBank.from_db(
        db, minsup=MINSUP, window=W, max_len=MAX_LEN, tombstones=False)
    sh = ShardedStreamingBank.from_db(
        db, minsup=MINSUP, n_hosts=2, window=W, max_len=MAX_LEN,
        tombstones=False)
    for step in range(3):
        batch = random_db(7000 + step, n_seq=3)
        ref.observe(batch)
        sh.observe(batch)
        assert np.array_equal(sh._allreduce_support(), ref.support)
        assert sh.window_seqs == ref.window_seqs
    assert ref.refresh() == sh.refresh()


def test_sharded_window_empty_bank_grows():
    """An empty seed bank must grow through the full-recompile path
    once churn makes patterns frequent (mirrors the single-host
    test)."""
    sh = ShardedStreamingBank.from_db(
        random_db(1, n_seq=2), minsup=MINSUP, n_hosts=2, window=W,
        max_len=MAX_LEN)
    assert sh.bank.n_patterns == 0
    sh.observe(random_db(7, n_seq=6))
    got = sh.refresh()
    assert got == _oracle(sh.window_seqs) and got
    assert sh.stats["full_refreshes"] == 1


def test_sharded_window_queries_match_single_host_bits():
    """Routed streaming queries serve the same containment bits as the
    single-host streaming bank's server (tombstone cuts included once
    both sides refreshed)."""
    db = random_db(17, n_seq=W)
    ref = StreamingBank.from_db(
        db, minsup=MINSUP, window=W, max_len=MAX_LEN)
    sh = ShardedStreamingBank.from_db(
        db, minsup=MINSUP, n_hosts=2, window=W, max_len=MAX_LEN)
    batch = random_db(300, n_seq=3)
    ref.observe(batch)
    sh.observe(batch)
    ref.refresh()
    sh.refresh()
    queries = db[:3]
    a = ref.query(queries, k=5)
    b = sh.query(queries, host=1, k=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.contained, y.contained)
        assert x.topk == y.topk


# ------------------------------------------------------------- replicas
def test_replica_serves_during_writer_refresh_then_converges():
    """A replica keeps serving its pre-refresh masked bank while the
    writer refreshes (deltas queued, reads never block), and becomes
    bit-equal to the writer once the deltas ship."""
    db = random_db(21, n_seq=W)
    writer = StreamingBank.from_db(
        db, minsup=MINSUP, window=W, max_len=MAX_LEN, bank_layout="trie")
    group = ReplicaGroup(writer, 2)
    queries = random_db(22, n_seq=5)
    before = group.query(queries, replica=0, k=5)
    # the writer slides + refreshes; replica 0 has not synced yet
    writer.observe(random_db(400, n_seq=4))
    writer.refresh()
    assert group.lag(0) > 0
    during = group.query(queries, replica=0, k=5)
    for a, b in zip(before, during):
        np.testing.assert_array_equal(a.contained, b.contained)
        assert a.topk == b.topk
    group.sync(0)
    assert group.lag(0) == 0
    after = group.query(queries, replica=0, k=5)
    want = writer.query(queries, k=5)
    for a, w in zip(after, want):
        np.testing.assert_array_equal(a.contained, w.contained)
        assert a.topk == w.topk
    # replica 1 syncs independently and converges too
    group.sync(1)
    for a, w in zip(group.query(queries, replica=1, k=5), want):
        np.testing.assert_array_equal(a.contained, w.contained)


def test_replica_applies_extend_delta_without_recompile():
    """When the writer's incremental refresh appends patterns, replicas
    grow via extend_bank/extend_trie (the shipped delta), not a
    recompile - and serve the extended bank exactly."""
    found = None
    for seed in range(40):
        db = random_db(seed, n_seq=W)
        w = StreamingBank.from_db(
            db, minsup=MINSUP, window=W, max_len=MAX_LEN,
            bank_layout="trie")
        if not w.bank.n_patterns:
            continue
        g = ReplicaGroup(w, 1)
        w.observe(random_db(5000 + seed, n_seq=4))
        w.refresh()
        if w.stats["added"] > 0 and w.stats["full_refreshes"] == 0:
            found = (w, g)
            break
    assert found, "no seed produced an in-place bank extension"
    w, g = found
    g.sync()
    rep = g.replicas[0]
    assert rep.bank.n_patterns == w.bank.n_patterns
    assert rep.bank.patterns == w.bank.patterns
    queries = w.window_seqs[:4]
    for a, b in zip(w.query(queries, k=5), g.query(queries, k=5)):
        np.testing.assert_array_equal(a.contained, b.contained)
        assert a.topk == b.topk


# ---------------------------------------------------- multi-device smoke
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src"); sys.path.insert(0, "tests")
import numpy as np
import jax
from conftest import random_db
from repro.mining.driver import AcceleratedMiner
from repro.serving.bank import compile_bank
from repro.serving.cluster import ServingCluster
from repro.serving.server import PatternServer

db = random_db(3, n_seq=10)
bank = compile_bank(AcceleratedMiner(db).mine_rs(2, max_len=3))
assert bank.n_patterns > 0
queries = random_db(9, n_seq=8)
devs = jax.devices()
assert len(devs) == 8, devs
for layout in ("flat", "trie"):
    ref = PatternServer(bank, bank_layout=layout)
    want = ref.exact_rows(queries)
    cl = ServingCluster(bank, 8, bank_layout=layout, devices=devs)
    assert len({h.device for h in cl.hosts}) == 8, "one device per host"
    got = cl.exact_rows(queries)
    assert np.array_equal(got, want), layout
print("CLUSTER-OK", bank.n_patterns)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_cluster_8dev_smoke():
    """One simulated host per virtual CPU device (the jax.distributed
    stand-in): routed rows must equal the single-host server."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
    )
    assert "CLUSTER-OK" in r.stdout, r.stdout + "\n" + r.stderr
