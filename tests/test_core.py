"""Unit + property tests for the GTRACE core layer."""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: seeded-sampling fallback
    from hypothesis_compat import given, settings, strategies as st

from conftest import random_db
from repro.core.canonical import (
    canonical_form,
    canonical_map,
    is_canonical,
    relabel_pattern,
)
from repro.core.compile import compile_sequence, diff_graphs, reconstruct
from repro.core.containment import contains, iter_embeddings, support
from repro.core.graphseq import (
    LabeledGraph,
    TR,
    TRType,
    edge_tr,
    pattern_from_lists,
    pattern_length,
    pattern_vertices,
    vertex_tr,
)
from repro.core.union_graph import is_relevant, pattern_union_graph
from repro.data.synthetic import random_graph_sequence


# ---------------------------------------------------------------- compile
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000))
def test_compile_reconstruct_roundtrip(seed):
    rng = random.Random(seed)
    seq = random_graph_sequence(rng, n_steps=5, n_v=5, n_vl=3, n_el=3)
    s = compile_sequence(seq)
    rebuilt = reconstruct(s)
    assert len(rebuilt) == len(seq)
    for a, b in zip(rebuilt, seq):
        assert a == b


def test_compile_fig4_example():
    """Example 2: the Fig. 4 sequence compiles to the listed TRs."""
    A, B, C = 0, 1, 2
    g1 = LabeledGraph({1: A, 2: B, 3: A}, {(1, 3): 0, (2, 3): 0})
    g2 = g1.copy(); g2.add_vertex(4, C)
    g3 = g2.copy(); g3.add_vertex(5, C); g3.add_edge(3, 4, 0); g3.remove_edge(2, 3)
    g4 = g3.copy(); g4.remove_edge(1, 3); g4.remove_vertex(2); g4.remove_vertex(1)
    s = compile_sequence([g1, g2, g3, g4], encode_initial=False)
    assert s[0] == (vertex_tr(TRType.VI, 4, C),)
    assert set(s[1]) == {
        vertex_tr(TRType.VI, 5, C),
        edge_tr(TRType.EI, 3, 4, 0),
        edge_tr(TRType.ED, 2, 3),
    }
    assert set(s[2]) == {
        vertex_tr(TRType.VD, 1),
        vertex_tr(TRType.VD, 2),
        edge_tr(TRType.ED, 1, 3),
    }


def test_diff_is_minimal():
    g0 = LabeledGraph({1: 0, 2: 1}, {(1, 2): 0})
    g1 = LabeledGraph({1: 0, 2: 1}, {(1, 2): 0})
    assert diff_graphs(g0, g1) == []
    g1.vlabels[2] = 0
    assert len(diff_graphs(g0, g1)) == 1


# ------------------------------------------------------------- containment
def test_containment_example3():
    """Example 3 (itemset-sequence semantics; see DESIGN.md note)."""
    C = 2
    s_d = (
        (vertex_tr(TRType.VI, 4, C),),
        (vertex_tr(TRType.VI, 5, C), edge_tr(TRType.EI, 3, 4, 0),
         edge_tr(TRType.ED, 2, 3)),
        (vertex_tr(TRType.VD, 2), edge_tr(TRType.ED, 1, 3)),
    )
    s_p = pattern_from_lists([
        [vertex_tr(TRType.VI, 3, C)],
        [edge_tr(TRType.EI, 2, 3, 0), edge_tr(TRType.ED, 1, 2)],
        [vertex_tr(TRType.VD, 1)],
    ])
    assert contains(s_p, s_d)
    embs = list(iter_embeddings(s_p, s_d))
    # psi(i) = i+1 with phi = (0, 1, 2) must be among the embeddings
    assert any(
        dict(psi) == {1: 2, 2: 3, 3: 4} and phi == (0, 1, 2)
        for phi, psi in embs
    )


def test_containment_requires_injective_psi():
    s_d = ((vertex_tr(TRType.VI, 1, 0),), (vertex_tr(TRType.VI, 2, 0),))
    p = pattern_from_lists([[vertex_tr(TRType.VI, 1, 0)],
                            [vertex_tr(TRType.VI, 2, 0)]])
    assert contains(p, s_d)
    # two pattern vertices cannot both map to data vertex 1
    s_d2 = ((vertex_tr(TRType.VI, 1, 0),), (vertex_tr(TRType.VR, 1, 0),))
    assert not contains(p, s_d2)


def test_containment_phi_order():
    p = pattern_from_lists([[vertex_tr(TRType.VI, 1, 0)],
                            [vertex_tr(TRType.VD, 1)]])
    ok = ((vertex_tr(TRType.VI, 7, 0),), (vertex_tr(TRType.VD, 7),))
    rev = ((vertex_tr(TRType.VD, 7),), (vertex_tr(TRType.VI, 7, 0),))
    assert contains(p, ok)
    assert not contains(p, rev)


# --------------------------------------------------------------- canonical
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000))
def test_canonical_invariant_under_relabeling(seed):
    rng = random.Random(seed)
    db = random_db(seed, n_seq=2)
    for s in db:
        pat = pattern_from_lists([it for it in s if it])
        if not pat:
            continue
        vs = pattern_vertices(pat)
        perm = list(range(len(vs)))
        rng.shuffle(perm)
        relabeled = relabel_pattern(pat, {v: 100 + perm[i] for i, v in enumerate(vs)})
        assert canonical_form(pat) == canonical_form(relabeled)


def test_canonical_idempotent_and_compact():
    p = pattern_from_lists([[edge_tr(TRType.EI, 7, 3, 1)],
                            [vertex_tr(TRType.VR, 7, 0)]])
    c = canonical_form(p)
    assert is_canonical(c)
    assert set(pattern_vertices(c)) == {0, 1}
    m = canonical_map(p)
    assert relabel_pattern(p, m) == c


# ------------------------------------------------------------- union graph
def test_relevance():
    assert is_relevant(pattern_from_lists([[vertex_tr(TRType.VI, 1, 0)]]))
    assert not is_relevant(pattern_from_lists(
        [[vertex_tr(TRType.VI, 1, 0)], [vertex_tr(TRType.VI, 2, 0)]]))
    assert is_relevant(pattern_from_lists(
        [[vertex_tr(TRType.VI, 1, 0)], [vertex_tr(TRType.VI, 2, 0)],
         [edge_tr(TRType.EI, 1, 2, 0)]]))
    # union graph of example 4: two edge TRs sharing vertex 2
    p = pattern_from_lists([[edge_tr(TRType.EI, 1, 2, 0)],
                            [edge_tr(TRType.EI, 2, 3, 0)]])
    ug = pattern_union_graph(p)
    assert ug.vertices == {1, 2, 3} and len(ug.edges) == 2
    assert is_relevant(p)


def test_pattern_length():
    p = pattern_from_lists([[edge_tr(TRType.EI, 1, 2, 0)],
                            [edge_tr(TRType.EI, 2, 3, 0),
                             edge_tr(TRType.ED, 1, 2)]])
    assert pattern_length(p) == 3
