"""Multi-device semantics of the sharded mining step, exercised on 8
virtual CPU devices in a subprocess (device count is locked at first JAX
init, so it cannot be changed inside this process)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src"); sys.path.insert(0, "tests")
import numpy as np
import jax
import jax.numpy as jnp
from conftest import random_db
from repro.mining.encoding import encode_db, encode_embeddings, encode_pattern_trs
from repro.mining.engine import MODE_ROOT, aggregate_host, match_signatures
from repro.mining.distributed import make_mining_step

db = random_db(3, n_seq=16, n_steps=5, n_v=5)
tdb = encode_db(db, pad_to=64)  # T divisible by model axis
embs = [(g, (), ()) for g in range(len(db))]
gid_g, phi, psi = encode_embeddings(embs, 8, 8)
valid = np.ones((len(embs),), np.int32)
existing = encode_pattern_trs((), 16)

# exact host reference (single device path)
sigs = match_signatures(
    jnp.asarray(tdb.tokens), jnp.asarray(gid_g), jnp.asarray(phi),
    jnp.asarray(psi), jnp.asarray(valid), jnp.asarray(existing),
    jnp.int32(0), jnp.int32(0), jnp.int32(MODE_ROOT))
host = {s: len(gs) for s, (gs, _) in aggregate_host(np.asarray(sigs), gid_g).items()}

from repro.compat import set_mesh_compat
mesh = jax.make_mesh((4, 2), ("data", "model"))
gid_local = (gid_g % (len(db) // 4)).astype(np.int32)
for prededup in (False, True):
    step = make_mining_step(mesh, k=1024, db_axes=("data",),
                            tok_axis="model", prededup=prededup)
    with set_mesh_compat(mesh):
        uniq, counts, n_distinct = step(
            jnp.asarray(tdb.tokens), jnp.asarray(gid_local), jnp.asarray(phi),
            jnp.asarray(psi), jnp.asarray(valid), jnp.asarray(existing),
            jnp.int32(0), jnp.int32(0), jnp.int32(MODE_ROOT))
    dev = {int(s): int(c) for s, c in zip(np.asarray(uniq), np.asarray(counts)) if s >= 0}
    assert int(n_distinct) <= 1024
    assert dev == host, (prededup, len(dev), len(host))
print("DISTRIBUTED-OK", len(dev))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_sharded_mining_step_8dev():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
    )
    assert "DISTRIBUTED-OK" in r.stdout, r.stdout + "\n" + r.stderr
