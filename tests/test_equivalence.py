"""Property test: GTRACE-RS output == postfiltered GTRACE output.

This is the paper's central correctness claim (Sec. 3): traversing the
reverse-search tree enumerates exactly the set of relevant FTSs that the
original GTRACE obtains by mining all FTSs and filtering, with identical
supports.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: seeded-sampling fallback
    from hypothesis_compat import given, settings, strategies as st

from conftest import random_db
from repro.core.gtrace import mine_gtrace
from repro.core.reverse_search import mine_gtrace_rs


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 100_000),
    sigma=st.integers(2, 3),
    n_seq=st.integers(4, 8),
)
def test_rs_equals_filtered_gtrace(seed, sigma, n_seq):
    db = random_db(seed, n_seq=n_seq, n_steps=5, n_v=5, n_vl=2, n_el=2)
    gt = mine_gtrace(db, sigma, max_len=5)
    rs = mine_gtrace_rs(db, sigma, max_len=5)
    assert gt.relevant() == rs.patterns


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_rs_enumeration_is_never_larger(seed):
    """RS expands at most as many nodes as GT (usually far fewer):
    the speedup mechanism of the paper."""
    db = random_db(seed, n_seq=6, n_steps=5, n_v=5, n_vl=2, n_el=3)
    gt = mine_gtrace(db, 2, max_len=5)
    rs = mine_gtrace_rs(db, 2, max_len=5)
    assert rs.n_enumerated <= gt.n_enumerated
