"""Fault-tolerant cluster: chaos property suite (serving.faults).

The failure model's contract, tested differentially against the
single-host ``PatternServer`` oracle:

* under ANY seeded fault schedule (delays, transient errors, at most
  one concurrent host crash), every submitted query gets exactly one
  answer that is either bit-equal to the single-host server or flagged
  ``exact=False`` as a sound superset - never a silent wrong bit,
  never a lost query;
* a fault-free run with the injector installed but idle is
  bit-identical to no injector at all (the fast path really is the
  pre-fault path);
* replica failover answers stay ``exact=True`` and bit-equal;
* circuit-breaker open/half-open/close transitions are deterministic
  under a fake clock;
* a crashed replica recovers by replaying the writer's sequenced delta
  log and rejoins only after verified bit-equal catch-up.
"""
import jax
import numpy as np
import pytest
from conftest import random_db

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI shim (see hypothesis_compat)
    from hypothesis_compat import given, settings, strategies as st

from repro.mining.driver import AcceleratedMiner
from repro.serving.bank import compile_bank
from repro.serving.cluster import BankReplica, ReplicaGroup, ServingCluster
from repro.serving.faults import (
    FaultInjector,
    HostDownError,
    HostUnavailableError,
    PipelineBusyError,
    RecoveryLog,
    RetryPolicy,
)
from repro.serving.server import PatternServer, prescreen_rows
from repro.serving.streaming import StreamingBank

MINSUP, MAX_LEN, W = 3, 3, 8


@pytest.fixture(autouse=True, scope="module")
def _release_compile_cache():
    """This module mines ~a dozen distinct banks (each a fresh set of
    XLA executables) on top of whatever the suite compiled before it;
    keeping them all live for the rest of a full single-process run
    pushes the CPU backend's compiler into segfault territory in later
    modules.  Drop every cached executable once the chaos suite is
    done - later modules recompile what they need."""
    yield
    jax.clear_caches()


def _bank(seed, n_seq=10, sigma=2, max_len=MAX_LEN):
    db = random_db(seed, n_seq=n_seq)
    return compile_bank(
        AcceleratedMiner(db).mine_rs(sigma, max_len=max_len))


def _spread(queries, n_hosts):
    reqs = {h: [] for h in range(n_hosts)}
    for i, s in enumerate(queries):
        reqs[i % n_hosts].append(s)
    return reqs


def _flat(results, n_hosts, n):
    """Undo _spread: results back into query submission order."""
    return [results[i % n_hosts][i // n_hosts] for i in range(n)]


def _assert_sound(r, truth_row):
    """The one-answer contract: exact rows are bit-equal, inexact rows
    are flagged and a sound superset (no false negatives)."""
    if r.exact:
        np.testing.assert_array_equal(r.contained, truth_row)
    else:
        assert not (truth_row & ~r.contained).any(), \
            "inexact answer dropped a true containment"


# ------------------------------------------------------------- injector
def test_injector_schedule_is_deterministic():
    """No RNG at query time: two injectors with the same seed agree
    call-for-call, different seeds differ somewhere."""
    a = FaultInjector(7, error_rate=0.3, delay_rate=0.2)
    b = FaultInjector(7, error_rate=0.3, delay_rate=0.2)
    va = [a.decide(h, i) for h in range(4) for i in range(64)]
    vb = [b.decide(h, i) for h in range(4) for i in range(64)]
    assert va == vb
    assert {"error", "delay", "ok"} == set(va)
    c = FaultInjector(8, error_rate=0.3, delay_rate=0.2)
    assert va != [c.decide(h, i) for h in range(4) for i in range(64)]


def test_injector_blackout_window_on_fake_clock():
    now = [0.0]
    inj = FaultInjector(0, blackouts=[(1, 5.0, 10.0)],
                        clock=lambda: now[0])
    inj.on_call(1)            # t=0: before the window - fine
    now[0] = 7.0
    inj.on_call(0)            # other host unaffected
    with pytest.raises(HostDownError):
        inj.on_call(1)
    now[0] = 10.0             # window is half-open [t0, t1)
    inj.on_call(1)


def test_recovery_log_ring():
    log = RecoveryLog(capacity=2)
    log.append(1, ("support", 1))
    log.append(2, ("support", 2))
    assert log.since(0) == [("support", 1), ("support", 2)]
    log.append(3, ("support", 3))          # evicts seq 1
    assert log.dropped_through == 1
    assert log.since(0) is None            # gap: full resync required
    assert log.since(1) == [("support", 2), ("support", 3)]
    assert log.since(3) == []
    with pytest.raises(AssertionError):
        log.append(3, ("support", 3))      # seq must be monotone


def test_pipeline_busy_error_is_typed_and_counted():
    err = PipelineBusyError(queued=2, inflight=3, tickets=1)
    assert isinstance(err, RuntimeError)
    assert (err.queued, err.inflight, err.tickets) == (2, 3, 1)
    assert "2 queued" in str(err) and "3 in-flight" in str(err)


def test_prescreen_rows_matches_server_approx_rows():
    """The router-side degraded answer is the same computation the
    host's own shed tier runs - bit-identical, mask included."""
    bank = _bank(3)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    queries = random_db(4, n_seq=6)
    srv = PatternServer(bank, bank_layout="flat")
    mask = np.ones(bank.n_patterns, bool)
    mask[:: 2] = False
    srv.set_row_mask(mask)
    want = srv.approx_rows(queries)
    got = prescreen_rows(queries, srv._req_np[: bank.n_patterns],
                         bank.n_label_keys)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------- idle-injector identity
def test_idle_injector_is_bit_identical():
    """Acceptance: fault-free runs with the injector installed but idle
    (and the retry policy armed) are bit-identical to the pre-fault
    cluster - route AND the async pipeline."""
    bank = _bank(11)
    queries = random_db(12, n_seq=8)
    plain = ServingCluster(bank, 3, bank_layout="flat")
    inj = FaultInjector(0)     # all rates zero, no blackouts
    faulty = ServingCluster(bank, 3, bank_layout="flat",
                            injector=inj,
                            fault_policy=RetryPolicy())
    want = plain.query_multi(_spread(queries, 3))
    got = faulty.query_multi(_spread(queries, 3))
    for hid in want:
        for w, g in zip(want[hid], got[hid]):
            np.testing.assert_array_equal(w.contained, g.contained)
            assert w.topk == g.topk and g.exact
    t1 = plain.submit(_spread(queries, 3))
    t2 = faulty.submit(_spread(queries, 3))
    r1, r2 = plain.collect(t1), faulty.collect(t2)
    for hid in r1:
        for w, g in zip(r1[hid], r2[hid]):
            np.testing.assert_array_equal(w.contained, g.contained)
            assert g.exact
    snap = faulty.metrics.snapshot()
    assert snap.get("cluster.faults.injected", 0) == 0
    assert snap.get("cluster.faults.retries", 0) == 0
    assert inj.calls  # the injector really sat on the call boundary


# --------------------------------------------------------- retry ladder
def test_transient_errors_retry_to_exact():
    """Transient errors under an adequate retry budget stay invisible:
    answers bit-equal to single-host, only the retry counters move."""
    bank = _bank(21)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    queries = random_db(22, n_seq=8)
    srv = PatternServer(bank, bank_layout="flat")
    truth = srv.exact_rows(queries)
    # seed 1's schedule errors within the first few calls on both
    # hosts (deterministic - see FaultInjector.decide)
    inj = FaultInjector(1, error_rate=0.25)
    cl = ServingCluster(
        bank, 2, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(retries=8, backoff_base=0.0,
                                 breaker_threshold=10 ** 6),
    )
    got = _flat(cl.query_multi(_spread(queries, 2)), 2, len(queries))
    for i, r in enumerate(got):
        assert r.exact
        np.testing.assert_array_equal(r.contained, truth[i])
    snap = cl.metrics.snapshot()
    assert snap["cluster.faults.injected"] > 0
    assert snap["cluster.faults.retries"] > 0
    assert snap["cluster.faults.degraded_answers"] == 0


def test_call_timeout_discards_slow_result_and_retries():
    """A call that overruns ``call_timeout`` on the injectable clock is
    a fault: its result is discarded and the attempt retried - the
    final answers stay exact and bit-equal."""
    now = [0.0]
    bank = _bank(25)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    queries = random_db(26, n_seq=6)
    srv = PatternServer(bank, bank_layout="flat")
    truth = srv.exact_rows(queries)
    # delayed calls take 2s against a 1s budget -> HostTimeoutError;
    # the injector's sleep drives the fake clock forward
    inj = FaultInjector(
        3, delay_rate=0.4, delay=2.0,
        clock=lambda: now[0],
        sleep=lambda s: now.__setitem__(0, now[0] + s),
    )
    cl = ServingCluster(
        bank, 2, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(call_timeout=1.0, retries=8,
                                 backoff_base=0.0,
                                 breaker_threshold=10 ** 6),
        clock=lambda: now[0],
    )
    got = _flat(cl.query_multi(_spread(queries, 2)), 2, len(queries))
    for i, r in enumerate(got):
        assert r.exact
        np.testing.assert_array_equal(r.contained, truth[i])
    snap = cl.metrics.snapshot()
    assert snap["cluster.faults.injected"] > 0
    assert snap["cluster.faults.retries"] > 0
    assert snap["cluster.faults.retry_seconds.count"] > 0


def test_crashed_host_degrades_to_flagged_superset():
    """With one host blacked out and no replica, its column block is
    answered from the prescreen: flagged ``exact=False``, sound
    superset, breaker opens, service continues."""
    now = [0.0]
    bank = _bank(31)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    queries = random_db(32, n_seq=8)
    srv = PatternServer(bank, bank_layout="flat")
    truth = srv.exact_rows(queries)
    inj = FaultInjector(0, blackouts=[(1, 0.0, 10 ** 9)],
                        clock=lambda: now[0])
    cl = ServingCluster(
        bank, 3, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(retries=1, breaker_threshold=2),
        clock=lambda: now[0],
    )
    got = _flat(cl.query_multi(_spread(queries, 3)), 3, len(queries))
    for i, r in enumerate(got):
        assert not r.exact
        _assert_sound(r, truth[i])
    snap = cl.metrics.snapshot()
    assert snap["cluster.faults.degraded_answers"] > 0
    assert snap["cluster.faults.breaker_open"] >= 1
    assert snap["cluster.faults.failovers"] == 0
    # the strict-exactness entry point must refuse, not degrade
    with pytest.raises(HostUnavailableError):
        cl.exact_rows(queries)


def test_replica_failover_is_bit_equal():
    """Acceptance: a registered read replica promotes for the crashed
    host's shard - answers stay ``exact=True`` and bit-equal to
    single-host."""
    now = [0.0]
    bank = _bank(41)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    queries = random_db(42, n_seq=8)
    srv = PatternServer(bank, bank_layout="flat")
    truth = srv.exact_rows(queries)
    inj = FaultInjector(0, blackouts=[(0, 0.0, 10 ** 9)],
                        clock=lambda: now[0])
    cl = ServingCluster(
        bank, 2, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(retries=0, breaker_threshold=1),
        clock=lambda: now[0],
    )
    cl.attach_failover_replica(0, BankReplica(bank, bank_layout="flat"))
    got = _flat(cl.query_multi(_spread(queries, 2)), 2, len(queries))
    for i, r in enumerate(got):
        assert r.exact
        np.testing.assert_array_equal(r.contained, truth[i])
    snap = cl.metrics.snapshot()
    assert snap["cluster.faults.failovers"] > 0
    assert snap["cluster.faults.degraded_answers"] == 0
    # joined_rows keeps its exactness contract through the replica too
    np.testing.assert_array_equal(cl.exact_rows(queries), truth)


def test_breaker_transitions_deterministic_under_fake_clock():
    """closed -> open (threshold consecutive failures) -> short-circuit
    (no host calls while open) -> half-open probe after the cooldown ->
    closed (recovery: caches wiped, counter bumped), all on a fake
    clock."""
    now = [0.0]
    bank = _bank(51)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    qs = [random_db(52 + i, n_seq=4) for i in range(6)]
    inj = FaultInjector(0, blackouts=[(1, 5.0, 10.0)],
                        clock=lambda: now[0])
    cl = ServingCluster(
        bank, 2, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(retries=0, breaker_threshold=2,
                                 breaker_cooldown=3.0),
        clock=lambda: now[0],
    )
    snap = lambda: cl.metrics.snapshot()  # noqa: E731
    # t=0: healthy - exact, caches filled
    assert all(r.exact for r in cl.query(qs[0], host=1))
    assert len(cl.hosts[1].l1) > 0
    # t=6: inside the blackout - failure #1, degraded, breaker closed
    now[0] = 6.0
    assert not any(r.exact for r in cl.query(qs[1]))
    assert snap()["cluster.faults.breaker_open"] == 0
    # t=6.5: failure #2 hits the threshold - breaker opens
    now[0] = 6.5
    assert not any(r.exact for r in cl.query(qs[2]))
    assert snap()["cluster.faults.breaker_open"] == 1
    # t=7: open + cooldown not elapsed - short-circuit, NO host call
    now[0] = 7.0
    calls_before = inj.calls.get(1, 0)
    assert not any(r.exact for r in cl.query(qs[3]))
    assert inj.calls.get(1, 0) == calls_before
    assert snap()["cluster.faults.breaker_open"] == 1
    # t=15: cooldown elapsed AND blackout over - the half-open probe
    # succeeds, host rejoins with wiped caches, recovery counted
    now[0] = 15.0
    assert all(r.exact for r in cl.query(qs[4]))
    assert snap()["cluster.faults.recoveries"] == 1
    # recovery wiped host 1's caches (qs[4] arrived on host 0, so its
    # L1 stays empty afterwards; qs[0]'s entries from t=0 are gone)
    assert len(cl.hosts[1].l1) == 0
    # closed again: next drain is plain exact serving, no new faults
    injected = snap()["cluster.faults.injected"]
    assert all(r.exact for r in cl.query(qs[5]))
    assert snap()["cluster.faults.injected"] == injected


def test_breaker_reopen_on_failed_probe():
    """A failing half-open probe re-opens the breaker immediately (one
    probe, not a fresh retry budget)."""
    now = [0.0]
    bank = _bank(61)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    q = random_db(62, n_seq=4)
    inj = FaultInjector(0, blackouts=[(1, 0.0, 100.0)],
                        clock=lambda: now[0])
    cl = ServingCluster(
        bank, 2, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(retries=3, breaker_threshold=1,
                                 breaker_cooldown=2.0),
        clock=lambda: now[0],
    )
    cl.query(q)                       # opens at the first failure
    assert cl.metrics.snapshot()["cluster.faults.breaker_open"] == 1
    now[0] = 5.0                      # cooldown elapsed, still down
    calls_before = inj.calls.get(1, 0)
    cl.query(q)
    # exactly ONE probe call despite retries=3, and the breaker re-opened
    assert inj.calls.get(1, 0) == calls_before + 1
    assert cl.metrics.snapshot()["cluster.faults.breaker_open"] == 2


# ----------------------------------------------------- collect(timeout=)
def test_collect_timeout_degrades_then_resolves_exactly():
    """A deadline'd collect answers unresolved joins from the shed tier
    (flagged supersets) instead of blocking; the joins stay in the
    pipeline and a later collect resolves the same fingerprints
    exactly."""
    now = [0.0]
    bank = _bank(71)
    if not bank.n_patterns:
        pytest.skip("empty bank")
    queries = random_db(72, n_seq=6)
    srv = PatternServer(bank, bank_layout="flat")
    truth = srv.exact_rows(queries)
    cl = ServingCluster(bank, 2, bank_layout="flat",
                        clock=lambda: now[0])
    t1 = cl.collect(cl.submit(_spread(queries, 2)), timeout=0.0)
    got = _flat(t1, 2, len(queries))
    for i, r in enumerate(got):
        assert not r.exact
        _assert_sound(r, truth[i])
    # inexact answers were not cached, and the joins are still pending
    assert all(len(h.l1) == 0 for h in cl.hosts)
    assert cl.router.depth() > 0
    # resubmitting piggybacks on the still-queued joins and a plain
    # collect drains them exactly
    t2 = cl.collect(cl.submit(_spread(queries, 2)))
    got = _flat(t2, 2, len(queries))
    for i, r in enumerate(got):
        assert r.exact
        np.testing.assert_array_equal(r.contained, truth[i])
    assert cl.router.depth() == 0
    assert cl.metrics.snapshot()["cluster.router.inflight_hits"] > 0


# -------------------------------------------------------- chaos property
@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_chaos_schedule_every_query_answered_soundly(seed):
    """The chaos property: under a seeded schedule of delays, transient
    errors and one host blackout, every submitted query gets exactly
    one answer - bit-equal when ``exact``, flagged sound superset when
    not - and no ticket is ever lost."""
    import random as _random
    rng = _random.Random(seed)
    now = [0.0]
    bank = _bank(seed % 40)
    if not bank.n_patterns:
        return
    srv = PatternServer(bank, bank_layout="flat")
    H = rng.choice([2, 3, 4])
    crash_host = rng.randrange(H)
    inj = FaultInjector(
        seed,
        error_rate=rng.choice([0.0, 0.05, 0.15]),
        delay_rate=0.1,
        delay=0.01,
        blackouts=[(crash_host, 2.0, 6.0)],
        clock=lambda: now[0],
    )
    cl = ServingCluster(
        bank, H, bank_layout="flat", injector=inj,
        fault_policy=RetryPolicy(retries=2, backoff_base=0.001,
                                 breaker_threshold=3,
                                 breaker_cooldown=1.5),
        clock=lambda: now[0],
        max_wait=0.5, flush_batch=4,
    )
    answered = 0
    for round_i in range(8):
        queries = random_db(seed % 40 + 1 + round_i,
                            n_seq=rng.choice([2, 3, 4]))
        truth = srv.exact_rows(queries)
        reqs = _spread(queries, H)
        ticket = cl.submit(reqs)
        now[0] += rng.choice([0.1, 0.6, 1.2])
        cl.poll()
        res = cl.collect(ticket, timeout=1.0)
        got = _flat(res, H, len(queries))
        assert len(got) == len(queries)  # exactly one answer each
        for i, r in enumerate(got):
            _assert_sound(r, truth[i])
        answered += len(got)
    assert answered > 0
    assert not cl.router._tickets      # no ticket lost or leaked


# ----------------------------------------------------- replica recovery
def test_replica_recovery_replays_delta_log_bit_equal():
    """A crashed replica restarts by replaying the writer's sequenced
    recovery log from its last applied seq; after verified catch-up its
    supports/mask/patterns are bit-equal to the writer and the
    recovery is counted."""
    db = random_db(81, n_seq=W)
    writer = StreamingBank.from_db(
        db, minsup=MINSUP, window=W, max_len=MAX_LEN,
        bank_layout="flat")
    # the seed observe already emitted sequenced deltas (the counter
    # advances with or without a sink attached)
    seed_seq = writer.delta_seq
    assert seed_seq > 0
    grp = ReplicaGroup(writer, 2)
    assert grp.replicas[0].last_seq == seed_seq
    writer.observe(random_db(82, n_seq=3))
    grp.sync()
    assert grp.replicas[1].last_seq == writer.delta_seq > 0
    grp.crash(1)
    with pytest.raises(HostDownError):
        grp.query([db[0]], replica=1)
    with pytest.raises(HostDownError):
        grp.sync(1)
    # the writer keeps moving while replica 1 is dark; replica 0
    # stays live throughout
    writer.observe(random_db(83, n_seq=3))
    writer.refresh()
    grp.sync(0)
    assert grp.lag(1) == 0             # its mailbox is gone, not full
    seq_before = grp.replicas[1].last_seq
    replayed = grp.restart(1)
    assert replayed > 0                # caught up by replay, not resync
    rep = grp.replicas[1]
    assert rep.last_seq == writer.delta_seq > seq_before
    assert rep.bank.patterns == writer.bank.patterns
    np.testing.assert_array_equal(
        rep.support, writer.support[: writer.bank.n_patterns])
    assert grp.writer.metrics.snapshot()[
        "cluster.faults.recoveries"] == 1
    # and it serves again, identically on both replicas
    grp.sync()
    a = grp.query(db[:3], replica=0)
    b = grp.query(db[:3], replica=1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.contained, y.contained)
        assert x.topk == y.topk


def test_replica_recovery_full_resync_when_log_evicted():
    """When the ring already evicted the replica's gap, restart falls
    back to a full state transfer (never a corrupt partial replay)."""
    db = random_db(91, n_seq=W)
    writer = StreamingBank.from_db(
        db, minsup=MINSUP, window=W, max_len=MAX_LEN,
        bank_layout="flat")
    grp = ReplicaGroup(writer, 1, log_capacity=1)
    grp.crash(0)
    writer.observe(random_db(92, n_seq=2))
    writer.observe(random_db(93, n_seq=2))   # > capacity: ring evicted
    assert grp.log.since(grp.replicas[0].last_seq) is None
    replayed = grp.restart(0)
    assert replayed == 0                      # full state transfer
    rep = grp.replicas[0]
    assert rep.last_seq == writer.delta_seq
    assert rep.bank.patterns == writer.bank.patterns
    np.testing.assert_array_equal(
        rep.support, writer.support[: writer.bank.n_patterns])
    grp.query(db[:2], replica=0)              # serving again
